"""Result-cache unit battery (``repro/query/cache.py``).

The bitwise guarantee lives in ``test_cache_properties.py``; this file
pins the mechanism underneath it: exact-fingerprint keying, LRU bounds,
journal-driven wholesale flush (vs provable no-op bumps), the
version-guarded ``put``, the belt-and-braces tombstone drop on ``get``,
and the engine-level integration (repeat queries hit and stay bitwise
equal to an uncached engine across mutations).
"""
import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.cache import ResultCache
from repro.query.engine import QueryConfig, QueryEngine
from repro.query.index import build_index
from repro.types import PAD_ID

K, BEAM, HOPS = 10, 16, 3


@pytest.fixture(scope="module")
def dataset():
    return make_dataset("synth", scale=0.1, seed=3)


@pytest.fixture()
def index(dataset):
    return build_index(dataset, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.1, seed=77)
    return [qds.profile(u) for u in range(24)]


@pytest.fixture(scope="module")
def insert_profiles():
    ids = make_dataset("synth", scale=0.1, seed=5)
    return [ids.profile(u) for u in range(8)]


def _engine(index, cache=0, **kw):
    kw.setdefault("refresh_every", 10 ** 9)
    return QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          cache=cache, **kw))


# -- unit: keying, LRU, version guard --------------------------------------

def test_key_is_exact_fingerprint_plus_knobs(index):
    cache = ResultCache(index, capacity=4)
    w = index.words[0]
    base = cache.key(w, 7, K, HOPS)
    assert base == cache.key(w.copy(), 7, K, HOPS)   # value equality
    assert base != cache.key(index.words[1], 7, K, HOPS)
    assert base != cache.key(w, 8, K, HOPS)
    assert base != cache.key(w, 7, K + 1, HOPS)
    assert base != cache.key(w, 7, K, HOPS + 1)


def test_get_returns_copies_and_counts(index):
    cache = ResultCache(index, capacity=4)
    key = ("k", 1, K, HOPS)
    assert cache.get(key) is None and cache.misses == 1
    ids = np.arange(K, dtype=np.int32)
    sims = np.linspace(1.0, 0.5, K, dtype=np.float32)
    cache.put(key, ids, sims)
    got_ids, got_sims = cache.get(key)
    assert cache.hits == 1
    np.testing.assert_array_equal(got_ids, ids)
    np.testing.assert_array_equal(got_sims, sims)
    got_ids[0] = -42  # caller mutations must not reach the cache
    again, _ = cache.get(key)
    assert again[0] == 0


def test_lru_eviction_respects_recency(index):
    cache = ResultCache(index, capacity=2)
    ids = np.arange(K, dtype=np.int32)
    sims = np.ones(K, np.float32)
    for name in ("a", "b"):
        cache.put((name,), ids, sims)
    assert cache.get(("a",)) is not None  # refresh a → b becomes LRU
    cache.put(("c",), ids, sims)
    assert len(cache) == 2
    assert cache.get(("b",)) is None      # evicted
    assert cache.get(("a",)) is not None
    assert cache.get(("c",)) is not None


def test_put_refuses_results_straddling_a_mutation(index, insert_profiles):
    eng = _engine(index)
    cache = ResultCache(index, capacity=4)
    key = ("stale",)
    eng.insert(insert_profiles[0])  # version bump AFTER key was taken
    assert index.version != cache.version
    cache.put(key, np.arange(K, dtype=np.int32), np.ones(K, np.float32))
    assert len(cache) == 0  # refused: computed against an older state
    cache.sync()
    cache.put(key, np.arange(K, dtype=np.int32), np.ones(K, np.float32))
    assert len(cache) == 1  # same call accepted once reconciled


def test_capacity_must_be_positive(index):
    with pytest.raises(ValueError):
        ResultCache(index, capacity=0)


# -- unit: invalidation ----------------------------------------------------

def test_real_mutation_flushes_wholesale(index, insert_profiles):
    eng = _engine(index)
    cache = ResultCache(index, capacity=8)
    cache.put(("x",), np.arange(K, dtype=np.int32), np.ones(K, np.float32))
    eng.insert(insert_profiles[0])
    cache.sync()
    # A new row can reroute ANY descent — everything goes, not just
    # entries naming touched ids.
    assert len(cache) == 0 and cache.flushes == 1
    assert cache.version == index.version
    cache.sync()
    assert cache.flushes == 1  # idempotent at the same version


def test_noop_version_bump_keeps_entries(index):
    cache = ResultCache(index, capacity=8)
    cache.put(("x",), np.arange(K, dtype=np.int32), np.ones(K, np.float32))
    index.version += 1  # bump with EMPTY journals (nothing recorded)
    changed = index.rows_changed_since(cache.version)
    if changed is None or changed:
        pytest.skip("journals cannot prove this bump was a no-op")
    cache.sync()
    assert len(cache) == 1 and cache.flushes == 0
    assert cache.version == index.version
    assert cache.get(("x",)) is not None


def test_tombstoned_id_is_never_served(index):
    """Belt and braces: even if an entry naming a dead id survived (it
    cannot, per the flush rule — poke the tombstone WITHOUT a version
    bump to simulate exactly that impossible state), get() drops it."""
    cache = ResultCache(index, capacity=4)
    victim = int(index.alive_ids()[0])
    ids = np.full(K, PAD_ID, np.int32)
    ids[0] = victim
    cache.put(("dead",), ids, np.ones(K, np.float32))
    index.tombstone[victim] = True
    try:
        assert cache.get(("dead",)) is None
        assert cache.stale_drops == 1 and cache.misses == 1
        assert len(cache) == 0  # dropped, not retained
    finally:
        index.tombstone[victim] = False


def test_stats_shape(index):
    cache = ResultCache(index, capacity=4)
    cache.get(("miss",))
    cache.put(("x",), np.arange(K, dtype=np.int32), np.ones(K, np.float32))
    cache.get(("x",))
    s = cache.stats()
    assert s == {"capacity": 4, "entries": 1, "hits": 1, "misses": 1,
                 "hit_rate": 0.5, "flushes": 0, "stale_drops": 0,
                 "degraded_skips": 0}


# -- engine integration ----------------------------------------------------

@pytest.mark.parametrize("continuous", [False, True])
def test_repeat_queries_hit_and_stay_bitwise(index, query_profiles,
                                             continuous):
    ref = _engine(index, cache=0, continuous=continuous, slots=8)
    eng = _engine(index, cache=64, continuous=continuous, slots=8)
    probe = query_profiles[:8]
    r_ids, r_sims = ref.query_batch(probe)
    c_ids, c_sims = eng.query_batch(probe)   # cold: fills
    h_ids, h_sims = eng.query_batch(probe)   # warm: pure hits
    st = eng.plan.cache.stats()
    assert st["hits"] == len(probe)
    assert st["misses"] == len(probe)
    for got in ((c_ids, c_sims), (h_ids, h_sims)):
        np.testing.assert_array_equal(got[0], r_ids)
        np.testing.assert_array_equal(got[1], r_sims)


def test_mutation_invalidates_then_tracks_fresh_truth(index, query_profiles,
                                                      insert_profiles):
    ref = _engine(index, cache=0)
    eng = _engine(index, cache=64)
    probe = query_profiles[:6]
    eng.query_batch(probe)
    assert len(eng.plan.cache) == len(probe)
    for p in insert_profiles[:3]:
        ref.insert(p)  # one engine mutates the SHARED index...
    c_ids, c_sims = eng.query_batch(probe)  # ...the other must notice
    assert eng.plan.cache.flushes == 1
    r_ids, r_sims = ref.query_batch(probe)
    np.testing.assert_array_equal(c_ids, r_ids)
    np.testing.assert_array_equal(c_sims, r_sims)


def test_removed_user_disappears_from_cached_results(index, query_profiles):
    eng = _engine(index, cache=64)
    probe = query_profiles[:6]
    ids, _ = eng.query_batch(probe)
    victim = int(ids[0][0])  # definitely part of a cached result
    eng.remove_user(victim)
    ids2, _ = eng.query_batch(probe)
    assert eng.plan.cache.flushes == 1
    assert not (ids2 == victim).any()
