"""Hypothesis battery for the blue/green re-balance swap: across ANY
interleaving of insert / delete / cohort-flush / query with swaps mixed
in, (a) the final device state is bitwise-equal to a from-scratch
rebuild of the current plan, (b) a merge-based swap (symmetric merge of
the old shard subgraphs) equals a re-scatter swap bitwise — tensors AND
every query answered along the way, (c) a cache-on engine stays
bitwise-equal to cache-off (no pre-swap entry is ever served), and
(d) mid-flight swaps under continuous serving are invisible on an
unmutated index (the same-plan swap is a results no-op even for
in-flight slot beams). tests/test_rebalance.py carries the
deterministic battery."""
import copy

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.rebalance import RebalanceConfig, Rebalancer

from test_plan import _assert_matches_rebuild  # same-dir test module


@pytest.fixture(scope="module")
def small_index():
    from repro.query.index import build_index

    ds = make_dataset("synth", scale=0.05, seed=5)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=32))


@pytest.fixture(scope="module")
def profiles():
    qds = make_dataset("synth", scale=0.05, seed=7)
    return [qds.profile(u) for u in range(24)]


OPS = ["insert", "delete", "flush", "query", "swap"]


def _drive(eng, reb, ops, profiles, out=None):
    """Apply one op sequence; deletes draw from a fixed-seed stream so
    two engines fed the same ``ops`` see identical mutations."""
    rng = np.random.default_rng(11)
    n_ins = 0
    for op in ops:
        if op == "insert":
            eng.insert(profiles[8 + (n_ins % 16)])
            n_ins += 1
        elif op == "delete":
            alive = eng.index.alive_ids()
            if len(alive) > 8:
                eng.remove_user(int(alive[rng.integers(len(alive))]))
        elif op == "flush":
            eng.flush_cohort()
        elif op == "query":
            ids, sims = eng.query_batch(profiles[:4])
            if out is not None:
                out.append((np.asarray(ids), np.asarray(sims)))
        else:
            reb.swap()


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(st.sampled_from(OPS), min_size=2, max_size=10),
       n_shards=st.integers(min_value=2, max_value=3))
def test_any_interleaving_with_swaps_matches_rebuild(small_index, profiles,
                                                     ops, n_shards):
    """After any op sequence containing swaps, the delta-maintained
    device state equals a from-scratch materialization of the extended
    current base plan — the swap resets the frozen base, it never
    corrupts the sync discipline."""
    ix = copy.deepcopy(small_index)
    eng = QueryEngine(ix, QueryConfig(k=8, beam=12, hops=2,
                                      shards=n_shards,
                                      refresh_every=10**9,
                                      rebalance_every=10**9))
    eng.query_batch(profiles[:4])  # freeze the initial base plan
    _drive(eng, eng.rebalance, ops, profiles)
    _assert_matches_rebuild(eng)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(st.sampled_from(OPS), min_size=2, max_size=10),
       n_shards=st.integers(min_value=2, max_value=3))
def test_merge_swap_equals_rescatter_swap(small_index, profiles, ops,
                                          n_shards):
    """The symmetric-merge rebuild (rows united from the old shard
    subgraphs + audit patch) and the plain index re-scatter produce
    bitwise-identical shard tensors and answers, whatever churn preceded
    the swap."""
    results = {}
    states = {}
    for merge in (True, False):
        ix = copy.deepcopy(small_index)
        eng = QueryEngine(ix, QueryConfig(k=8, beam=12, hops=2,
                                          shards=n_shards,
                                          refresh_every=10**9))
        reb = Rebalancer(eng.plan, RebalanceConfig(every=10**9,
                                                   merge=merge))
        out = []
        eng.query_batch(profiles[:4])
        _drive(eng, reb, ops, profiles, out=out)
        sd = eng.sharded_state()  # syncs trailing mutations
        results[merge] = out
        states[merge] = (np.asarray(sd._g2l).copy(),
                         tuple(np.asarray(a).copy() for a in sd._dev))
    assert len(results[True]) == len(results[False])
    for i, (a, b) in enumerate(zip(results[True], results[False])):
        np.testing.assert_array_equal(a[0], b[0], err_msg=f"ids query {i}")
        np.testing.assert_array_equal(a[1], b[1], err_msg=f"sims query {i}")
    np.testing.assert_array_equal(states[True][0], states[False][0])
    names = ("l_graph", "l_rev", "l_words", "l_card", "l2g", "l_tomb")
    for a, b, name in zip(states[True][1], states[False][1], names):
        np.testing.assert_array_equal(a, b, err_msg=name)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(st.sampled_from(OPS), min_size=2, max_size=10))
def test_cache_transparent_across_swaps(small_index, profiles, ops):
    """Cache-on == cache-off bitwise across any interleaving of churn
    and swaps; repeated queries force the cache to actually serve, and
    a swap must flush it (pre-swap placement results are stale even
    though no journal records the event)."""
    outs = {}
    for cap in (0, 64):
        ix = copy.deepcopy(small_index)
        eng = QueryEngine(ix, QueryConfig(k=8, beam=12, hops=2, shards=2,
                                          refresh_every=10**9, cache=cap,
                                          rebalance_every=10**9))
        out = []
        eng.query_batch(profiles[:4])
        _drive(eng, eng.rebalance, ops, profiles, out=out)
        # Repeat the same wave twice: with a cache the second pass is
        # served from entries written by the first — which must reflect
        # the CURRENT placement, not any pre-swap one.
        for _ in range(2):
            ids, sims = eng.query_batch(profiles[:4])
            out.append((np.asarray(ids), np.asarray(sims)))
        outs[cap] = out
        if cap and not any(op in ("insert", "delete", "flush", "swap")
                           for op in ops[-1:]):
            pass  # hit-rate assertions live in the deterministic battery
    assert len(outs[0]) == len(outs[64])
    for i, (a, b) in enumerate(zip(outs[0], outs[64])):
        np.testing.assert_array_equal(a[0], b[0], err_msg=f"ids query {i}")
        np.testing.assert_array_equal(a[1], b[1], err_msg=f"sims query {i}")


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(swap_ticks=st.sets(st.integers(min_value=1, max_value=8),
                          min_size=1, max_size=3))
def test_mid_flight_swaps_are_invisible_on_fixed_index(small_index,
                                                       profiles,
                                                       swap_ticks):
    """Continuous serving with swaps fired BETWEEN ticks while slots are
    in flight: on an unmutated index the re-derived plan is identical,
    so the blue/green swap (tensor rebuild + in-flight beam remap) must
    be bitwise invisible — every request completes with exactly the
    results of an engine that never swapped, and the cache flushes once
    per swap (no half-swapped generation is ever observed)."""
    ix = copy.deepcopy(small_index)
    eng = QueryEngine(ix, QueryConfig(k=8, beam=12, hops=2, shards=2,
                                      continuous=True, slots=5, cache=16,
                                      rebalance_every=10**9))
    ref = QueryEngine(small_index, QueryConfig(k=8, beam=12, hops=2,
                                               shards=2, continuous=True,
                                               slots=5))
    fired = []

    def do_swap(engine, tick):
        if tick in swap_ticks:
            engine.rebalance.swap()
            fired.append(engine.sharded_state().generation)

    for rid, p in enumerate(profiles):
        eng.submit(QueryRequest(rid=rid, profile=p))
        ref.submit(QueryRequest(rid=rid, profile=p))
    stats = eng.run(on_tick=do_swap)
    ref.run()
    assert stats["requests"] == len(profiles)
    assert fired == list(range(1, len(fired) + 1))  # one generation per swap
    assert eng.plan.cache.flushes == len(fired)
    got = {r.rid: r for r in eng.done}
    want = {r.rid: r for r in ref.done}
    assert set(got) == set(want)
    for rid in want:
        np.testing.assert_array_equal(got[rid].ids, want[rid].ids,
                                      err_msg=f"ids rid={rid}")
        np.testing.assert_array_equal(got[rid].sims, want[rid].sims,
                                      err_msg=f"sims rid={rid}")
