"""Per-kernel interpret-mode validation: shape sweeps vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing
from repro.kernels.frh_minhash import ops as mh_ops
from repro.kernels.frh_minhash import ref as mh_ref
from repro.kernels.goldfinger_knn import ops as gk_ops
from repro.kernels.goldfinger_knn import ref as gk_ref
from repro.types import PAD_ID


def _random_gf(rng, n, n_bits, density=0.1):
    words = rng.integers(0, 2**32, size=(n, n_bits // 32), dtype=np.uint64)
    # Sparsify: AND a few random masks so popcounts vary.
    for _ in range(3):
        words &= rng.integers(0, 2**32, size=words.shape, dtype=np.uint64)
    words = words.astype(np.uint32)
    card = np.unpackbits(words.view(np.uint8), axis=1).sum(1).astype(np.int32)
    return jnp.asarray(words), jnp.asarray(card)


@pytest.mark.parametrize("nq,nd", [(32, 32), (64, 128), (128, 512),
                                   (200, 300), (1, 64)])
@pytest.mark.parametrize("n_bits", [512, 1024])
@pytest.mark.parametrize("k", [5, 30])
def test_knn_kernel_matches_ref(nq, nd, n_bits, k):
    rng = np.random.default_rng(nq * 1000 + nd + k)
    qw, qc = _random_gf(rng, nq, n_bits)
    dw, dc = _random_gf(rng, nd, n_bits)
    qi = jnp.arange(nq, dtype=jnp.int32)
    di = jnp.arange(nd, dtype=jnp.int32)
    ri, rs = gk_ref.knn_ref(qw, qc, qi, dw, dc, di, k)
    ki, ks = gk_ops.knn(qw, qc, qi, dw, dc, di, k)
    np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), atol=0)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))


@pytest.mark.parametrize("block_q,block_d", [(32, 64), (128, 128), (64, 512)])
def test_knn_kernel_block_shape_invariance(block_q, block_d):
    rng = np.random.default_rng(9)
    w, c = _random_gf(rng, 256, 1024)
    ids = jnp.arange(256, dtype=jnp.int32)
    ri, rs = gk_ref.knn_ref(w, c, ids, w, c, ids, 10)
    ki, ks = gk_ops.knn(w, c, ids, w, c, ids, 10,
                        block_q=block_q, block_d=block_d)
    np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), atol=0)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))


def test_knn_kernel_pad_rows_and_self_exclusion():
    rng = np.random.default_rng(4)
    w, c = _random_gf(rng, 64, 512)
    ids = np.arange(64, dtype=np.int32)
    ids[10:20] = PAD_ID
    ids_j = jnp.asarray(ids)
    ki, ks = gk_ops.knn(w, c, ids_j, w, c, ids_j, 8)
    ki = np.asarray(ki)
    # PAD query rows produce PAD ids everywhere they would self-match;
    # no row may list itself or a PAD id as a neighbor.
    live = ids != PAD_ID
    assert (ki[live] != ids[live, None]).all()
    assert (ki[live] != PAD_ID).sum() > 0
    ri, rs = gk_ref.knn_ref(w, c, ids_j, w, c, ids_j, 8)
    np.testing.assert_array_equal(ki[live], np.asarray(ri)[live])


@pytest.mark.parametrize("m,cap", [(1, 32), (3, 64), (2, 256)])
def test_cluster_knn_matches_group_ref(m, cap):
    rng = np.random.default_rng(m * 17 + cap)
    w, c = _random_gf(rng, m * cap, 512)
    mem = np.full((m, cap), PAD_ID, np.int32)
    for j in range(m):
        sz = int(rng.integers(2, cap + 1))
        mem[j, :sz] = rng.choice(m * cap, sz, replace=False)
    gm = np.where(mem == PAD_ID, 0, mem)
    wc = jnp.asarray(np.asarray(w)[gm])
    cc = jnp.asarray(np.where(mem == PAD_ID, 0, np.asarray(c)[gm]))
    memj = jnp.asarray(mem)
    ri, rs = gk_ref.cluster_knn_ref(wc, cc, memj, 6)
    ki, ks = gk_ops.cluster_knn(wc, cc, memj, 6)
    valid = (mem != PAD_ID)[..., None]
    np.testing.assert_allclose(np.where(valid, np.asarray(ks), 0),
                               np.where(valid, np.asarray(rs), 0), atol=0)
    np.testing.assert_array_equal(np.where(valid, np.asarray(ki), -9),
                                  np.where(valid, np.asarray(ri), -9))


def test_local_knn_pallas_path_matches_jnp_path(small_ds, small_gf):
    from repro.core.clustering import build_plan
    from repro.core.local_knn import local_knn
    from repro.core.params import C2Params

    p_jnp = C2Params(k=6, b=128, t=2, max_cluster=100, use_pallas=False)
    p_pal = C2Params(k=6, b=128, t=2, max_cluster=100, use_pallas=True)
    plan = build_plan(small_ds, p_jnp)
    i1, s1 = local_knn(plan, small_gf, p_jnp)
    i2, s2 = local_knn(plan, small_gf, p_pal)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(np.where(i1 == PAD_ID, 0, s1),
                               np.where(i2 == PAD_ID, 0, s2), atol=0)


# ---------------------------------------------------------------- minhash


@pytest.mark.parametrize("n,P", [(8, 16), (100, 40), (256, 64), (300, 7)])
@pytest.mark.parametrize("t", [1, 8])
@pytest.mark.parametrize("b", [256, 4096])
def test_minhash_kernel_matches_ref(n, P, t, b):
    rng = np.random.default_rng(n + P + t + b)
    padded = rng.integers(0, 10**6, size=(n, P)).astype(np.int32)
    # Random padding tails.
    for i in range(n):
        cut = int(rng.integers(1, P + 1))
        padded[i, cut:] = PAD_ID
    seeds = np.arange(t, dtype=np.int32) * 7 + 1
    r = mh_ref.minhash_ref(jnp.asarray(padded), jnp.asarray(seeds), b)
    k = mh_ops.minhash(jnp.asarray(padded), seeds, b)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_minhash_kernel_matches_host_csr(small_ds):
    seeds = np.arange(4, dtype=np.int32)
    host = hashing.user_min_hash_np(
        hashing.item_hashes(small_ds.items, seeds, 1024), small_ds.offsets)
    dev = mh_ops.dataset_minhash(small_ds, seeds, 1024)
    np.testing.assert_array_equal(dev, host)


# -- interpret-mode configuration (kernels/config.py) -----------------------


def test_interpret_flag_shared_by_all_kernel_packages():
    """One switch, three packages: every kernel wrapper resolves its
    ``interpret=`` through ``kernels.config.interpret_mode()`` — none
    carries a private INTERPRET constant that could drift."""
    import inspect

    from repro.kernels import config
    from repro.kernels.descent_score import ops as ds_ops

    for mod in (ds_ops, gk_ops, mh_ops):
        assert not hasattr(mod, "INTERPRET"), mod.__name__
        assert getattr(mod, "config") is config, mod.__name__
        assert "config.interpret_mode()" in inspect.getsource(mod), \
            mod.__name__
    # All three agree by construction: the shared resolver is the only
    # source of the flag.
    assert config.interpret_mode() in (True, False)


def test_interpret_env_parsing(monkeypatch):
    from repro.kernels import config

    monkeypatch.setattr(config, "_override", None)
    for raw, expect in [(None, True), ("1", True), ("yes", True),
                        ("weird", True), ("0", False), ("false", False),
                        ("No", False), (" OFF ", False)]:
        if raw is None:
            monkeypatch.delenv(config.ENV_VAR, raising=False)
        else:
            monkeypatch.setenv(config.ENV_VAR, raw)
        assert config.interpret_mode() is expect, raw


def test_interpret_override_beats_env(monkeypatch):
    from repro.kernels import config

    monkeypatch.setenv(config.ENV_VAR, "0")
    config.set_interpret(True)
    try:
        assert config.interpret_mode() is True
        config.set_interpret(None)  # back to env-driven
        assert config.interpret_mode() is False
    finally:
        config.set_interpret(None)
