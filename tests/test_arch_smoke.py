"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.config import scaled_down
from repro.models.layers import ShardCtx
from repro.models.model import forward, init_params
from repro.serve.steps import decode_step, prefill_step
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.steps import train_step

CTX = ShardCtx()
B, S = 2, 32


def _batch(cfg, key):
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.frontend:
        emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        return {"embeddings": emb.astype(cfg.dtype), "labels": labels}
    return {"tokens": labels, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train(arch):
    cfg = scaled_down(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))

    logits, _, aux = jax.jit(
        lambda p, b: forward(p, cfg, CTX,
                             tokens=b.get("tokens"),
                             input_embeds=b.get("embeddings")))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    oc = OptConfig(lr=1e-3)
    opt = init_opt_state(params, oc)
    step = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, CTX, oc))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), f"{arch}: non-finite loss"
    assert int(m["step"]) == 1
    # Params actually moved.
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b_: (a.astype(jnp.float32)
                                    - b_.astype(jnp.float32)),
                     params, p2), 0.0)
    assert delta > 0, f"{arch}: no parameter update"


@pytest.mark.parametrize("arch", ["llama3_2-1b", "olmoe-1b-7b",
                                  "recurrentgemma-2b", "xlstm-125m",
                                  "musicgen-medium"])
def test_smoke_prefill_decode(arch):
    """Decode shapes lower serve_step — check the cache path end-to-end on
    a representative member of each family (dense/moe/hybrid/ssm/audio)."""
    cfg = scaled_down(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    if cfg.frontend:
        x = jax.random.normal(
            jax.random.key(2), (B, S, cfg.d_model)).astype(cfg.dtype)
        logits, cache = jax.jit(lambda p, t: prefill_step(
            p, t, cfg, CTX, s_alloc=S + 4, is_embeds=True))(params, x)
    else:
        logits, cache = jax.jit(lambda p, t: prefill_step(
            p, t, cfg, CTX, s_alloc=S + 4))(params, toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    lg, cache2 = jax.jit(lambda p, c, t: decode_step(
        p, c, t, S, cfg, CTX))(params, cache, toks[:, :1])
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any()), f"{arch}: NaN decode logits"
    # Cache structure preserved.
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_decode_matches_forward_dense():
    """Tight consistency check on the dense family (no MoE capacity drops)."""
    cfg = scaled_down(get_config("gemma-2b"))
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    _, cache = jax.jit(lambda p, t: prefill_step(
        p, t, cfg, CTX, s_alloc=S + 2))(params, toks)
    lg, _ = jax.jit(lambda p, c, t: decode_step(
        p, c, t, S, cfg, CTX))(params, cache, toks[:, :1])
    full = jnp.concatenate([toks, toks[:, :1]], axis=1)
    lf, _, _ = jax.jit(lambda p, t: forward(p, cfg, CTX, tokens=t))(
        params, full)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(lf[:, -1]),
                               atol=0.15)  # bf16 chunked-vs-cached paths


def test_param_counts_match_published():
    expected = {
        "olmoe-1b-7b": (6.9e9, 1.3e9),
        "kimi-k2-1t-a32b": (1.04e12, 31e9),
        "granite-34b": (34e9, 34e9),
        "granite-20b": (20.3e9, 20.3e9),
        "gemma-2b": (2.5e9, 2.5e9),
        "llama3_2-1b": (1.24e9, 1.24e9),
        "recurrentgemma-2b": (2.7e9, 2.7e9),
        "phi-3-vision-4_2b": (3.8e9, 3.8e9),
        "musicgen-medium": (1.4e9, 1.4e9),
        "xlstm-125m": (0.15e9, 0.15e9),
    }
    for arch, (tot, act) in expected.items():
        cfg = get_config(arch)
        assert abs(cfg.param_count() - tot) / tot < 0.08, (
            arch, cfg.param_count(), tot)
        assert abs(cfg.active_param_count() - act) / act < 0.08, (
            arch, cfg.active_param_count(), act)


def test_long_500k_applicability():
    from repro.configs.shapes import applicable

    runs = [a for a in ARCH_IDS if applicable(get_config(a), "long_500k")]
    assert set(runs) == {"recurrentgemma-2b", "xlstm-125m"}
