"""FastRandomHash unit + property tests, incl. Theorem 1 (paper §III)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import hashing
from repro.types import dataset_from_profiles


def _hash_profile(profile, seed, b):
    ds = dataset_from_profiles("x", [sorted(profile)], 10**6)
    h = hashing.item_hashes(ds.items, np.array([seed], np.int32), b)
    return hashing.user_min_hash_np(h, ds.offsets)[0, 0], h[0]


def test_range_and_determinism():
    items = np.arange(1000, dtype=np.int32)
    h1 = hashing.item_hashes(items, np.arange(4, dtype=np.int32), 256)
    h2 = hashing.item_hashes(items, np.arange(4, dtype=np.int32), 256)
    assert (h1 == h2).all()
    assert h1.min() >= 0 and h1.max() < 256
    # Different seeds give different streams.
    assert (h1[0] != h1[1]).any()


def test_min_hash_is_min_of_item_hashes():
    rng = np.random.default_rng(1)
    profiles = [rng.choice(5000, size=rng.integers(1, 50), replace=False)
                for _ in range(30)]
    ds = dataset_from_profiles("x", [sorted(p) for p in profiles], 5000)
    seeds = np.arange(3, dtype=np.int32)
    item_h = hashing.item_hashes(ds.items, seeds, 512)
    H = hashing.user_min_hash_np(item_h, ds.offsets)
    for i in range(3):
        for u in range(ds.n_users):
            hs = item_h[i, ds.offsets[u]:ds.offsets[u + 1]]
            assert H[i, u] == hs.min()


def test_distinct_hashes_ascending_and_complete():
    rng = np.random.default_rng(2)
    profiles = [rng.choice(2000, size=rng.integers(1, 40), replace=False)
                for _ in range(25)]
    ds = dataset_from_profiles("x", [sorted(p) for p in profiles], 2000)
    seeds = np.arange(2, dtype=np.int32)
    item_h = hashing.item_hashes(ds.items, seeds, 64)
    cands = hashing.user_distinct_hashes_np(item_h, ds.offsets, depth=5)
    for i in range(2):
        for u in range(ds.n_users):
            expected = np.unique(item_h[i, ds.offsets[u]:ds.offsets[u + 1]])[:5]
            got = cands[i, u][cands[i, u] != hashing.NO_HASH]
            assert (got == expected).all()
            assert (np.diff(got) > 0).all()  # strictly ascending


def test_hash_above():
    items = np.array([3, 7, 42, 99], dtype=np.int32)
    ds = dataset_from_profiles("x", [items], 1000)
    h = hashing.item_hashes(ds.items, np.array([0], np.int32), 128)
    hs = np.sort(np.unique(h[0]))
    eta = int(hs[0])
    out = hashing.user_hash_above_np(h[0], ds.offsets, eta, np.array([0]))
    if len(hs) > 1:
        assert out[0] == hs[1]
    else:
        assert out[0] == hashing.NO_HASH


@settings(deadline=None, max_examples=20)
@given(
    shared=st.sets(st.integers(0, 9999), min_size=5, max_size=40),
    only1=st.sets(st.integers(10000, 19999), min_size=0, max_size=30),
    only2=st.sets(st.integers(20000, 29999), min_size=0, max_size=30),
)
def test_theorem1_collision_probability(shared, only1, only2):
    """P[H(u1)=H(u2)] ∈ [J − κ/ℓ, (J + κ/ℓ)/(1 − κ/ℓ)] for every h (Eq. 9).

    We check the *per-hash-function* identity (6): the empirical rate over
    many seeds must respect the bound built from each seed's own κ.
    """
    p1 = sorted(shared | only1)
    p2 = sorted(shared | only2)
    union = sorted(shared | only1 | only2)
    ell = len(union)
    j12 = len(shared) / ell
    b = 4096
    n_seeds = 300
    ds = dataset_from_profiles("x", [p1, p2, union], 30000)
    seeds = np.arange(n_seeds, dtype=np.int32)
    item_h = hashing.item_hashes(ds.items, seeds, b)
    H = hashing.user_min_hash_np(item_h, ds.offsets)
    hits = (H[:, 0] == H[:, 1]).mean()
    # κ per seed: collisions of h on P1 ∪ P2.
    o_u = slice(ds.offsets[2], ds.offsets[3])
    kappas = np.array([ell - len(np.unique(item_h[s, o_u]))
                       for s in range(n_seeds)])
    kl = kappas.mean() / ell
    lo = j12 - kl
    hi = (j12 + kl) / max(1 - kl, 1e-9)
    # 3σ slack for the empirical estimate over n_seeds draws.
    sigma = 3 * np.sqrt(max(hits * (1 - hits), 0.25 / n_seeds) / n_seeds)
    assert lo - sigma <= hits <= hi + sigma
