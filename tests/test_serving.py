"""Serving engine tests: wave batching, EOS, latency accounting, and
decode-vs-prefill consistency under left-padding."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.config import scaled_down
from repro.models.model import init_params
from repro.serve.engine import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = scaled_down(get_config("gemma-2b"))
    params = init_params(jax.random.key(0), cfg)
    return Engine(params, cfg, ServeConfig(max_batch=3, max_prompt=16,
                                           max_new=8))


def test_engine_drains_queue_in_waves(engine):
    rng = np.random.default_rng(1)
    for rid in range(7):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, 200, 8).astype(np.int32),
            max_new=4))
    stats = engine.run()
    assert stats["requests"] == 7
    assert stats["waves"] == 3  # 3 + 3 + 1
    assert all(r.output is not None and len(r.output) == 4
               for r in engine.done)
    assert stats["tokens_per_s"] > 0
    assert stats["p95_latency_s"] >= stats["mean_latency_s"] > 0
    engine.done.clear()


def test_engine_eos_truncation(engine):
    # eos = the token the model actually produces first → length 1 output.
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 200, 8).astype(np.int32)
    engine.submit(Request(rid=100, prompt=prompt, max_new=8))
    engine.run()
    first_tok = int(engine.done[-1].output[0])
    engine.submit(Request(rid=101, prompt=prompt, max_new=8,
                          eos_id=first_tok))
    engine.run()
    assert len(engine.done[-1].output) == 1
    engine.done.clear()


def test_engine_rejects_overlong_prompt(engine):
    with pytest.raises(AssertionError):
        engine.submit(Request(
            rid=0, prompt=np.zeros(99, np.int32), max_new=2))


def test_greedy_generate_matches_engine_single():
    """Engine output for a lone request == direct greedy_generate."""
    from repro.serve.steps import greedy_generate
    from repro.models.layers import ShardCtx

    cfg = scaled_down(get_config("llama3_2-1b"))
    params = init_params(jax.random.key(0), cfg)
    prompt = np.arange(1, 13, dtype=np.int32) % cfg.vocab_size
    sc = ServeConfig(max_batch=1, max_prompt=12, max_new=6)
    eng = Engine(params, cfg, sc)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    eng.run()
    direct = greedy_generate(params, prompt[None, :], cfg, ShardCtx(),
                             max_new=6, s_alloc=12 + 6)
    np.testing.assert_array_equal(eng.done[0].output,
                                  np.asarray(direct)[0])
