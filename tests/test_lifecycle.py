"""Lifecycle battery (deterministic): deletes, updates, TTL expiry,
repair, and the tombstone mask's two central theorems —

* kernel parity: the fused Pallas hop under a tombstone mask is
  bitwise-identical to the jnp reference, and its ``n_scored`` counter
  shows dead lanes retiring BEFORE the estimator;
* masking ≡ excision: serving a churned index under its tombstone mask
  equals (bitwise, ids AND sims) serving a copy whose dead references
  were physically PAD'd in place (``lifecycle.scrub_dead_references``).

``tests/test_lifecycle_properties.py`` carries the hypothesis
interleaving battery on top of these.
"""
import copy

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.kernels.descent_score import ops as ds_ops
from repro.kernels.descent_score import ref as ds_ref
from repro.lifecycle import scrub_dead_references
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.query.search import descent_init, exact_knn
from repro.sched import Cadence
from repro.types import PAD_ID

DEAD = (2, 7, 19, 33)


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.05, seed=5)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=32))


@pytest.fixture(scope="module")
def profiles():
    qds = make_dataset("synth", scale=0.05, seed=7)
    return [qds.profile(u) for u in range(24)]


def _engine(ix, **kw):
    kw.setdefault("refresh_every", 10**9)
    kw.setdefault("hops", 2)
    return QueryEngine(ix, QueryConfig(k=8, beam=12, slots=8, **kw))


# -- scheduler cadence -----------------------------------------------------

def test_cadence_fires_every_n():
    c = Cadence(3)
    fired = [c.tick() for _ in range(9)]
    assert fired == [False, False, True] * 3
    assert c.n_fired == 3


def test_cadence_disabled():
    c = Cadence(0)
    assert not any(c.tick() for _ in range(5))
    assert c.n_fired == 0


# -- index-level mutation primitives ---------------------------------------

def test_remove_tombstones_and_clears_row(index):
    ix = copy.deepcopy(index)
    v0 = ix.version
    ix.remove_user(3)
    assert ix.tombstone[3] and ix.n_live == ix.n - 1
    assert (ix.graph_ids[3] == PAD_ID).all()
    assert (ix.rev_ids[3] == PAD_ID).all()
    assert ix.card[3] == 0 and (ix.words[3] == 0).all()
    assert ix.version > v0
    assert ix.tombstones_since(v0) == {3}
    with pytest.raises(ValueError):
        ix.remove_user(3)  # double delete


def test_free_list_reuse_keeps_n(index, profiles):
    ix = copy.deepcopy(index)
    eng = _engine(ix)
    n0 = ix.n
    eng.remove_user(5)
    u = eng.insert(profiles[0])
    assert u == 5 and ix.n == n0 and not ix.tombstone[5]
    # The resurrection rides the tombstone journal both ways.
    assert 5 in ix.tombstones_since(0)


def test_update_rescores_and_relinks(index, profiles):
    ix = copy.deepcopy(index)
    eng = _engine(ix)
    ids, sims = eng.update_user(6, profiles[2])
    # Row sims are bit-consistent with the host pair scorer.
    for j, v in enumerate(ix.graph_ids[6]):
        if v != PAD_ID:
            assert ix.graph_sims[6, j] == ix._pair_sim(6, int(v))
    # Serving the same profile now finds the updated user first.
    got, gsims = eng.query_batch([profiles[2]])
    assert got[0, 0] == 6 and gsims[0, 0] == pytest.approx(1.0)
    # Mutuality: every forward neighbor knows u in reverse.
    for v in ix.graph_ids[6]:
        if v != PAD_ID:
            assert 6 in ix.rev_ids[int(v)]


# -- tombstone mask in the scorers -----------------------------------------

def test_kernel_tomb_parity_and_suppression(index):
    ix = copy.deepcopy(index)
    rng = np.random.default_rng(0)
    qsel = rng.integers(0, ix.n, 16)
    qw, qc = jnp.asarray(ix.words[qsel]), jnp.asarray(ix.card[qsel])
    seeds = jnp.asarray(rng.integers(0, ix.n, (16, 12)).astype(np.int32))
    for u in DEAD:
        ix.remove_user(u)
    tomb = jnp.asarray(ix.tombstone)
    g, r, w, c = map(jnp.asarray, (ix.graph_ids, ix.rev_ids,
                                   ix.words, ix.card))
    bi, bs = descent_init(w, c, qw, qc, seeds, beam=12, tomb=tomb)
    assert not np.isin(np.asarray(bi), DEAD).any()
    ri, rs = ds_ref.descent_hop_ref(g, r, w, c, qw, qc, bi, bs, tomb=tomb)
    ki, ks, nsc, _, _ = ds_ops.descent_hop(
        g, r, w, c, qw, qc, bi, bs, tomb=tomb, with_counts=True)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(ks))
    assert not np.isin(np.asarray(ki), DEAD).any()
    # Dead candidate lanes retire BEFORE the estimator: the masked run
    # scores no more lanes than the unmasked one on the same beams.
    _, _, nsc0, _, _ = ds_ops.descent_hop(g, r, w, c, qw, qc, bi, bs,
                                          with_counts=True)
    assert int(np.asarray(nsc).sum()) < int(np.asarray(nsc0).sum())
    # An all-live mask is bitwise a no-op (None synthesizes it).
    zi, zs = ds_ops.descent_hop(g, r, w, c, qw, qc, bi, bs,
                                tomb=jnp.zeros(ix.n, bool))
    ni, ns = ds_ops.descent_hop(g, r, w, c, qw, qc, bi, bs)
    np.testing.assert_array_equal(np.asarray(zi), np.asarray(ni))
    np.testing.assert_array_equal(np.asarray(zs), np.asarray(ns))


def test_exact_knn_excludes_dead(index):
    ix = copy.deepcopy(index)
    for u in DEAD:
        ix.remove_user(u)
    qsel = [1, 4, 9]
    ids, _ = exact_knn(ix.words, ix.card, ix.words[qsel], ix.card[qsel],
                       k=8, tomb=ix.tombstone)
    assert not np.isin(np.asarray(ids), DEAD).any()


# -- masking == excision ---------------------------------------------------

@pytest.mark.parametrize("kernel", [False, True])
def test_masking_equals_excision(index, profiles, kernel):
    ix = copy.deepcopy(index)
    eng = _engine(ix, kernel=kernel)
    for u in DEAD:
        eng.remove_user(u)
    ids_m, sims_m = eng.query_batch(profiles[:6])
    scrubbed = copy.deepcopy(ix)
    scrub_dead_references(scrubbed)
    eng2 = _engine(scrubbed, kernel=kernel)
    ids_s, sims_s = eng2.query_batch(profiles[:6])
    np.testing.assert_array_equal(ids_m, ids_s)
    np.testing.assert_array_equal(sims_m, sims_s)
    assert not np.isin(ids_m, DEAD).any()


# -- serving across the plan matrix ----------------------------------------

@pytest.mark.parametrize("shards,continuous,kernel", [
    (1, False, False), (1, True, True), (3, False, True), (3, True, False),
])
def test_no_dead_id_served(index, profiles, shards, continuous, kernel):
    ix = copy.deepcopy(index)
    eng = _engine(ix, shards=shards, continuous=continuous, kernel=kernel)
    eng.query_batch(profiles[:4])  # freeze base plan / warm programs
    for u in DEAD:
        eng.remove_user(u)
    eng.update_user(5, profiles[10])
    reused = eng.insert(profiles[11])  # resurrects the lowest freed row
    assert reused == min(DEAD)
    still_dead = [u for u in DEAD if u != reused]
    for i, p in enumerate(profiles[:8]):
        eng.submit(QueryRequest(rid=i, profile=np.asarray(p, np.int32)))
    eng.run()
    for r in eng.done:
        assert not np.isin(r.ids, still_dead).any()
        live = r.ids[r.ids != PAD_ID]
        assert not ix.tombstone[live].any()


def test_mid_flight_delete_masks_next_hop(index, profiles):
    """A delete landing between continuous ticks reaches in-flight beams
    as the updated mask on their next hop — no dead id survives to the
    released result."""
    ix = copy.deepcopy(index)
    eng = _engine(ix, continuous=True, hops=4)
    for i, p in enumerate(profiles[:6]):
        eng.submit(QueryRequest(rid=i, profile=np.asarray(p, np.int32)))
    eng.plan.step(eng.queue, eng.done)  # tick 1: admit + first hop
    st = eng.plan._slots
    in_beam = np.unique(np.asarray(st.beam_ids))
    in_beam = in_beam[(in_beam != PAD_ID) & ~ix.tombstone[
        np.clip(in_beam, 0, ix.n - 1)]]
    victim = int(in_beam[len(in_beam) // 2])  # currently mid-beam
    eng.remove_user(victim)
    eng.run()
    assert len(eng.done) == 6
    for r in eng.done:
        assert victim not in r.ids


# -- TTL expiry ------------------------------------------------------------

def test_ttl_expiry_spares_touched_rows(index, profiles):
    ix = copy.deepcopy(index)
    eng = _engine(ix, ttl=3)
    keep = (0, 1)
    for step in range(6):
        for u in keep:
            eng.touch(u)
        eng.submit(QueryRequest(rid=step,
                                profile=np.asarray(profiles[step], np.int32)))
        eng.step()
    assert eng.lifecycle.n_expired > 0
    for u in keep:
        assert not ix.tombstone[u]
    # Expiry is batched: at most expire_batch rows per maintain call.
    assert eng.lifecycle.n_expired <= 6 * eng.lifecycle.cfg.expire_batch


def test_inserted_rows_start_fresh_ttl(index, profiles):
    ix = copy.deepcopy(index)
    eng = _engine(ix, ttl=10)
    eng.lifecycle.clock = 7
    u = eng.insert(profiles[0])
    assert ix.last_touch[u] == 7


# -- repair ----------------------------------------------------------------

def test_repair_fills_delete_holes(index, profiles):
    ix = copy.deepcopy(index)
    eng = _engine(ix, repair_every=1)
    for u in DEAD:
        eng.remove_user(u)
    holey = [int(v) for v in ix.alive_ids()
             if (ix.graph_ids[v] == PAD_ID).any()]
    assert holey, "deletes should have punched holes"
    n = eng.lifecycle.repair()
    assert n == len(holey)
    for v in holey:
        row = ix.graph_ids[v]
        assert not (row == PAD_ID).any(), f"row {v} still has holes"
        assert not np.isin(row, DEAD).any()
        # Rebuilt rows stay sorted by similarity (stable invariant).
        sims = ix.graph_sims[v]
        assert (np.diff(sims) <= 0).all()
    assert not eng.lifecycle._touched  # cohort drained


def test_repair_leaves_full_rows_alone(index):
    ix = copy.deepcopy(index)
    eng = _engine(ix, repair_every=1)
    eng.lifecycle._touched = {int(u) for u in ix.alive_ids()[:20]
                              if not (ix.graph_ids[u] == PAD_ID).any()}
    before = ix.graph_ids.copy()
    assert eng.lifecycle.repair() == 0
    np.testing.assert_array_equal(ix.graph_ids, before)


# -- single-placement delta sync under churn -------------------------------

@pytest.mark.parametrize("kernel", [False, True])
def test_single_delta_sync_matches_rebuild(index, profiles, kernel):
    from repro.query.plan import DescentPlan

    ix = copy.deepcopy(index)
    eng = _engine(ix, kernel=kernel, repair_every=2)
    eng.query_batch(profiles[:4])  # materialize device copies
    for u in DEAD:
        eng.remove_user(u)
    eng.update_user(5, profiles[10])
    eng.insert(profiles[11])
    eng.lifecycle.repair()
    delta = eng.plan._sync_single()       # journal-scatter repaired
    fresh = DescentPlan(ix, eng.plan.spec)._sync_single()
    for a, b, name in zip(delta, fresh,
                          ("graph", "rev", "words", "card", "tomb")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
