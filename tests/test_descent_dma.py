"""HBM-resident DMA placement of the fused descent hop.

The contract: ``descent_hop(dma=True)`` — tables in ANY/HBM memory,
per-chunk candidate-row DMA into rotating VMEM buffers, suppressed
lanes skipped at the DMA level — is *bitwise* (ids AND sims) equal to
the jnp oracle and to the VMEM placement, for arbitrary well-formed
inputs: sketch widths straddling the popcount→MXU boundary, score
chunks that do not divide the lane count, all-suppressed chunks,
tombstone-heavy tables, single- and double-buffered pipelines. On top
of parity, the byte accounting must be exact (``dma_bytes`` ==
``n_scored·W·4``; ``bytes_saved`` the complement over the full
candidate count) and the shape-keyed autotuner must keep the serving
plans compile-once across admissions and reshards.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.kernels.descent_score import ops as ds_ops
from repro.kernels.descent_score import ref as ds_ref
from repro.kernels.descent_score import tune
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.sched import trace
from repro.types import NEG_INF, PAD_ID


def _random_words(rng, n, W):
    w = (rng.integers(0, 2**32, size=(n, W), dtype=np.uint64)
         & rng.integers(0, 2**32, size=(n, W), dtype=np.uint64)
         ).astype(np.uint32)
    card = np.unpackbits(w.view(np.uint8), axis=1).sum(1).astype(np.int32)
    return w, card


def _hop_inputs(rng, n, kg, kr, W, q, B, *, tomb_frac=0.0):
    g = rng.integers(-1, n, size=(n, kg)).astype(np.int32)
    r = rng.integers(-1, n, size=(n, kr)).astype(np.int32)
    w, c = _random_words(rng, n, W)
    qw, qc = _random_words(rng, q, W)
    bi = np.full((q, B), PAD_ID, np.int32)
    for i in range(q):
        m = int(rng.integers(0, min(n, B) + 1))
        bi[i, :m] = rng.choice(n, size=m, replace=False)
    bs = np.where(bi == PAD_ID, NEG_INF,
                  -np.sort(-rng.random((q, B)))).astype(np.float32)
    tomb = None
    if tomb_frac > 0:
        tomb = jnp.asarray(rng.random(n) < tomb_frac)
    args = tuple(jnp.asarray(x) for x in (g, r, w, c, qw, qc, bi, bs))
    return args, tomb


def _assert_dma_parity(args, tomb=None, **dma_kw):
    """ids AND sims bitwise vs the jnp oracle and the VMEM kernel, plus
    exact byte accounting against the scored-lane counter."""
    B = args[6].shape[1]
    W = args[2].shape[1]
    C = B * (args[0].shape[1] + args[1].shape[1])
    ri, rs = ds_ref.descent_hop_ref(*args, tomb=tomb)
    ki, ks, nsc, kb, ksv = ds_ops.descent_hop(*args, tomb=tomb,
                                              with_counts=True)
    di, dsm, dnsc, dmab, saved = ds_ops.descent_hop(
        *args, tomb=tomb, dma=True, with_counts=True, **dma_kw)
    np.testing.assert_array_equal(np.asarray(di), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(dsm), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(di), np.asarray(ki))
    np.testing.assert_array_equal(np.asarray(dsm), np.asarray(ks))
    # Both placements suppress the same lanes; only the DMA placement
    # turns the suppression into byte traffic it never moves.
    np.testing.assert_array_equal(np.asarray(dnsc), np.asarray(nsc))
    assert (np.asarray(kb) == 0).all() and (np.asarray(ksv) == 0).all()
    np.testing.assert_array_equal(np.asarray(dmab),
                                  np.asarray(dnsc) * W * 4)
    np.testing.assert_array_equal(np.asarray(saved),
                                  (C - np.asarray(dnsc)) * W * 4)
    return np.asarray(dnsc), np.asarray(dmab), np.asarray(saved)


def test_dma_hop_parity_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(deadline=None, max_examples=25)
    @given(st.data())
    def battery(data):
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
        n = data.draw(st.integers(2, 60))
        kg = data.draw(st.integers(1, 6))
        kr = data.draw(st.integers(1, 6))
        # Straddle MXU_MIN_WORDS (=64): VPU popcount below, int8
        # bit-plane MXU matmul at/above — identical bits required.
        W = data.draw(st.sampled_from([1, 2, 64, 65]))
        q = data.draw(st.integers(1, 8))
        B = data.draw(st.integers(1, 6))
        tomb_frac = data.draw(st.sampled_from([0.0, 0.5, 0.9]))
        # Chunks that do NOT divide the lane count (and over-long ones),
        # single and double buffering.
        chunk = data.draw(st.sampled_from([None, 3, 7, 16, 1024]))
        n_buffers = data.draw(st.sampled_from([1, 2]))
        args, tomb = _hop_inputs(rng, n, kg, kr, W, q, B,
                                 tomb_frac=tomb_frac)
        kw = {"n_buffers": n_buffers}
        if chunk is not None:
            kw["score_chunk"] = chunk
        _assert_dma_parity(args, tomb=tomb, **kw)

    battery()


@pytest.mark.parametrize("W", [1, 2, 64, 65])
@pytest.mark.parametrize("chunk,n_buffers", [(3, 2), (7, 1), (None, 2)])
def test_dma_parity_sweep(W, chunk, n_buffers):
    """Deterministic slice of the battery above (runs even without
    hypothesis): MXU-boundary widths × non-dividing chunks × buffer
    depths, with tombstones in the mix."""
    rng = np.random.default_rng(W * 100 + (chunk or 0) * 10 + n_buffers)
    args, tomb = _hop_inputs(rng, 45, 4, 5, W, 6, 5, tomb_frac=0.4)
    kw = {"n_buffers": n_buffers}
    if chunk is not None:
        kw["score_chunk"] = chunk
    _assert_dma_parity(args, tomb=tomb, **kw)


def test_dma_all_suppressed_chunks():
    """Beams that already contain every reachable neighbor: every
    candidate lane is suppressed, so the hop fetches and scores NOTHING
    — zero DMA bytes, full bytes_saved — and still matches the oracle."""
    rng = np.random.default_rng(3)
    n, B, W = 6, 6, 4
    # Ring adjacency within {0..5}; every beam holds all six rows.
    g = np.stack([(np.arange(n) + 1) % n, (np.arange(n) + 2) % n],
                 axis=1).astype(np.int32)
    r = np.stack([(np.arange(n) - 1) % n], axis=1).astype(np.int32)
    w, c = _random_words(rng, n, W)
    qw, qc = _random_words(rng, 5, W)
    bi = np.tile(np.arange(n, dtype=np.int32), (5, 1))
    bs = -np.sort(-rng.random((5, B))).astype(np.float32)
    args = tuple(jnp.asarray(x) for x in (g, r, w, c, qw, qc, bi, bs))
    C = B * (g.shape[1] + r.shape[1])
    nsc, dmab, saved = _assert_dma_parity(args, score_chunk=5)
    assert (nsc == 0).all()
    assert (dmab == 0).all()
    assert (saved == C * W * 4).all()


def test_dma_tombstone_heavy():
    """Mostly-dead tables: tombstoned lanes are skipped at the DMA
    level, so the byte traffic shrinks vs the same hop on a live table
    (and parity with the masked oracle still holds bitwise)."""
    rng = np.random.default_rng(17)
    args, _ = _hop_inputs(rng, 50, 5, 4, 4, 9, 6)
    tomb = jnp.asarray(rng.random(50) < 0.8)
    _, live_bytes, _ = _assert_dma_parity(args)
    _, dead_bytes, dead_saved = _assert_dma_parity(args, tomb=tomb)
    assert dead_bytes.sum() < live_bytes.sum()
    assert dead_saved.sum() > 0


# -- serving-plan matrix ----------------------------------------------------


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.05, seed=3)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.05, seed=77)
    return [qds.profile(u) for u in range(10)]


def _serve(index, profiles, **kw):
    eng = QueryEngine(index, QueryConfig(k=8, beam=12, hops=2, **kw))
    for rid, p in enumerate(profiles):
        eng.submit(QueryRequest(rid=rid, profile=p))
    eng.run()
    by_rid = {r.rid: (r.ids, r.sims) for r in eng.done}
    ids = np.stack([by_rid[i][0] for i in range(len(profiles))])
    sims = np.stack([by_rid[i][1] for i in range(len(profiles))])
    return eng, ids, sims


@pytest.mark.parametrize("placement", [{}, {"shards": 2}],
                         ids=["single", "sharded"])
@pytest.mark.parametrize("batching", [{}, {"continuous": True, "slots": 8}],
                         ids=["wave", "continuous"])
def test_plan_matrix_dma_bitwise(index, query_profiles, placement,
                                 batching):
    """scorer="pallas_dma" is results-transparent across the full plan
    matrix: bitwise (ids, sims) vs the jnp scorer for every placement ×
    batching, with live byte accounting in the serving stats."""
    _, ri, rs = _serve(index, query_profiles, **placement, **batching)
    eng, di, dsm = _serve(index, query_profiles, kernel=True, dma=True,
                          **placement, **batching)
    np.testing.assert_array_equal(di, ri)
    np.testing.assert_array_equal(dsm, rs)
    d = eng.plan.descent_stats
    assert d["scored_lanes"] > 0
    assert d["bytes_saved"] > 0
    W = index.words.shape[1]
    # The DMA guard predicate IS the scoring mask: bytes moved must
    # agree with lanes scored exactly.
    assert d["dma_bytes"] == d["scored_lanes"] * W * 4


# -- autotuner / compile-once ----------------------------------------------


def test_tune_memoizes_per_shape():
    tune.clear()
    p1 = tune.hop_params(1000, 16, 32, 20)
    assert tune.stats["misses"] == 1
    p2 = tune.hop_params(1000, 16, 32, 20)
    assert p2 == p1
    assert tune.stats["hits"] == 1
    # A different shape resolves independently...
    tune.hop_params(1000, 64, 32, 20)
    assert tune.stats["misses"] == 2
    # ...and the wave width only clamps block_q, never forks the key.
    p3 = tune.hop_params(1000, 16, 32, 20, q=2)
    assert p3.block_q <= 2
    assert tune.stats["misses"] == 2


def test_tune_heuristic_respects_scratch_budget():
    for n, W, beam, kdeg in [(100, 1, 4, 8), (10_000, 32, 32, 20),
                             (100_000, 256, 64, 32)]:
        p = tune.hop_params(n, W, beam, kdeg)
        assert p.block_q >= 1 and p.score_chunk >= 16
        assert p.n_buffers in (1, 2)
        buf = p.n_buffers * p.block_q * p.score_chunk * (W + 1) * 4
        assert buf <= 2 * tune._SCRATCH_BUDGET


def test_tune_disk_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.ENV_CACHE, str(tmp_path / "tune.json"))
    tune.clear()
    try:
        key = tune.shape_key(512, 16, 24, 18)
        tune.record(key, tune.HopParams(4, 32, 2))
        tune.clear()  # drop the memo; force the disk path
        p = tune.hop_params(*key)
        assert p == tune.HopParams(4, 32, 2)
        assert tune.stats["disk_hits"] == 1
    finally:
        tune.clear()


def test_dma_compile_once_across_admissions(index, query_profiles):
    """The tuner memo keeps the DMA scorer compile-once under streaming
    admission: however requests arrive, the fused slot programs trace
    once per shape and the tuner resolves each index shape once."""
    tune.clear()
    # beam/slots unique to this test: an outer program cached by an
    # earlier test would skip the trace (and the tuner) entirely.
    qc = QueryConfig(k=8, beam=14, hops=2, continuous=True, slots=9,
                     kernel=True, dma=True)
    engine = QueryEngine(index, qc)
    assert engine.plan.key == (1, "continuous", "pallas_dma")

    base = trace.compile_count(engine.plan.key)
    for rid, p in enumerate(query_profiles[:4]):
        engine.submit(QueryRequest(rid=rid, profile=p))
    engine.run()
    after = trace.compile_count(engine.plan.key)
    assert after - base >= 1
    misses = tune.stats["misses"]
    assert misses >= 1
    # Later admissions — bursty and one-by-one — reuse both caches.
    for rid, p in enumerate(query_profiles[4:8]):
        engine.submit(QueryRequest(rid=rid, profile=p))
    engine.run()
    for p in query_profiles[8:]:
        engine.submit(QueryRequest(rid=99, profile=p))
        engine.run()
    assert trace.compile_count(engine.plan.key) == after
    # No new resolutions: either the jit cache short-circuits before the
    # tuner is consulted (descent_hop runs only at trace time) or the
    # memo answers — never a fresh miss.
    assert tune.stats["misses"] == misses


def test_dma_compile_once_across_reshards(index, query_profiles):
    """Insert-driven delta reshards keep the sharded DMA wave program
    and the tuner resolution stable (padded capacities hold the shapes,
    the memo holds the params — no re-trace, no re-miss)."""
    tune.clear()
    qc = QueryConfig(k=8, beam=13, hops=2, shards=2, kernel=True,
                     dma=True)
    engine = QueryEngine(index, qc)
    _, ids_a, sims_a = _serve_through(engine, query_profiles)
    misses = tune.stats["misses"]
    ins = make_dataset("synth", scale=0.05, seed=123)
    for u in range(3):
        # Each insert delta-reshards AND runs its own 1-row search wave
        # (a new, narrower shape — one extra legitimate trace).
        engine.insert(ins.profile(u))
    after = trace.compile_count(engine.plan.key)
    _, ids_b, _ = _serve_through(engine, query_profiles)
    # The re-served wave re-uses its pre-reshard program, and the tuner
    # never re-missed: q clamps block_q without forking the cache key,
    # and padded capacities held the index shape across the reshard.
    assert trace.compile_count(engine.plan.key) == after
    assert tune.stats["misses"] == misses
    assert ids_b.shape == ids_a.shape


def _serve_through(engine, profiles):
    for rid, p in enumerate(profiles):
        engine.submit(QueryRequest(rid=rid, profile=p))
    engine.run()
    by_rid = {r.rid: (r.ids, r.sims) for r in engine.done}
    engine.done.clear()
    ids = np.stack([by_rid[i][0] for i in range(len(profiles))])
    sims = np.stack([by_rid[i][1] for i in range(len(profiles))])
    return engine, ids, sims
