"""Continuous-batching test battery (query + LM serving).

Locks down the PR-3 scheduler: wave/continuous equivalence on both
engines, compile-count regressions (one step program per static config,
never retraced on admission), interleaved insert+query under streaming
load, and LM slot recycling on skewed-length batches.
"""
import copy

import jax
import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.sched import trace
from repro.types import PAD_ID

K, BEAM, HOPS = 10, 16, 3


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.1, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.1, seed=77)
    return [qds.profile(u) for u in range(48)]


def _submit_all(engine, profiles):
    for rid, p in enumerate(profiles):
        engine.submit(QueryRequest(rid=rid, profile=p))


def _by_rid(engine):
    return {r.rid: (r.ids, r.sims) for r in engine.done}


# -- continuous vs wave equivalence (query side) ---------------------------

def test_query_continuous_matches_wave_exactly(index, query_profiles):
    """Identical query sets produce identical (ids, sims) per request —
    streaming admission must not change a single result."""
    wave = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          max_wave=64))
    _submit_all(wave, query_profiles)
    ws = wave.run()

    # slots < n_queries forces several admission generations mid-flight.
    cont = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          continuous=True, slots=7))
    _submit_all(cont, query_profiles)
    cs = cont.run()

    assert ws["requests"] == cs["requests"] == len(query_profiles)
    assert cs["mode"] == "continuous"
    # Recycling happened: more ticks than a single full-wave pass, fewer
    # than one per request (slots advance in parallel).
    assert cs["waves"] > HOPS
    w, c = _by_rid(wave), _by_rid(cont)
    assert set(w) == set(c)
    for rid in w:
        np.testing.assert_array_equal(w[rid][0], c[rid][0],
                                      err_msg=f"ids rid={rid}")
        np.testing.assert_array_equal(w[rid][1], c[rid][1],
                                      err_msg=f"sims rid={rid}")


def test_query_continuous_streaming_submission(index, query_profiles):
    """Requests submitted *while* the scheduler runs (between ticks) are
    admitted into freed slots and produce wave-identical results."""
    wave = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          max_wave=64))
    _submit_all(wave, query_profiles)
    wave.run()

    cont = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          continuous=True, slots=5))
    pending = list(enumerate(query_profiles))

    def drip(engine, tick):
        # Two new arrivals per tick — admission interleaves with descent.
        for rid, p in pending[:2]:
            engine.submit(QueryRequest(rid=rid, profile=p))
        del pending[:2]

    cont.submit(QueryRequest(rid=pending[0][0], profile=pending[0][1]))
    del pending[0]
    cont.run(on_tick=drip)
    assert not pending
    w, c = _by_rid(wave), _by_rid(cont)
    assert set(w) == set(c)
    for rid in w:
        np.testing.assert_array_equal(w[rid][0], c[rid][0])
        np.testing.assert_array_equal(w[rid][1], c[rid][1])


def test_query_continuous_per_request_hop_budgets(index, query_profiles):
    """Mixed hop budgets: continuous serves each request at ITS budget —
    request results match a uniform wave run at that same budget exactly
    (wave mode would convoy the whole wave to the deepest member)."""
    deep = 2 * HOPS
    ref = {}
    for hops in (0, HOPS, deep):  # 0 = seed-only lookup, no hop
        eng = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=hops,
                                             max_wave=64))
        _submit_all(eng, query_profiles)
        eng.run()
        ref[hops] = _by_rid(eng)

    cont = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          continuous=True, slots=6))
    budgets = [deep if rid % 3 == 0 else (0 if rid % 5 == 0 else HOPS)
               for rid in range(len(query_profiles))]
    for rid, p in enumerate(query_profiles):
        cont.submit(QueryRequest(rid=rid, profile=p, hops=budgets[rid]))
    cont.run()
    assert len(cont.done) == len(query_profiles)
    for r in cont.done:
        want_ids, want_sims = ref[budgets[r.rid]][r.rid]
        np.testing.assert_array_equal(r.ids, want_ids,
                                      err_msg=f"rid={r.rid}")
        np.testing.assert_array_equal(r.sims, want_sims,
                                      err_msg=f"rid={r.rid}")


def test_query_continuous_kernel_matches_jnp_wave(index, query_profiles):
    """The fused Pallas hop behind QueryConfig(kernel=True) is bitwise
    transparent: a continuous kernel run equals the plain jnp wave run
    per request — same ids, same sims — across streaming admissions."""
    wave = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          max_wave=64))
    _submit_all(wave, query_profiles)
    wave.run()

    cont = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          continuous=True, slots=9,
                                          kernel=True))
    _submit_all(cont, query_profiles)
    cs = cont.run()
    assert cs["requests"] == len(query_profiles)
    w, c = _by_rid(wave), _by_rid(cont)
    assert set(w) == set(c)
    for rid in w:
        np.testing.assert_array_equal(w[rid][0], c[rid][0],
                                      err_msg=f"ids rid={rid}")
        np.testing.assert_array_equal(w[rid][1], c[rid][1],
                                      err_msg=f"sims rid={rid}")


# -- compile-count regression ----------------------------------------------

def test_query_slot_step_compiles_once_across_admissions(index,
                                                         query_profiles):
    """One step program per (plan, shape); admission interleavings never
    retrace it — asserted through ``trace.compile_count`` on the plan's
    key, which sums every program tagged with the plan's
    (placement, batching, scorer) identity."""
    qc = QueryConfig(k=K, beam=BEAM, hops=HOPS, continuous=True, slots=6)
    engine = QueryEngine(index, qc)
    assert engine.plan.key == (1, "continuous", "jnp")

    base = trace.compile_count(engine.plan.key)
    # First run may compile the slot programs — at most one admit shape
    # plus one hop shape (another test in this process may already have
    # warmed some shapes of this plan key).
    _submit_all(engine, query_profiles[:9])
    engine.run()
    after = trace.compile_count(engine.plan.key)
    assert after >= 1  # the counters are really wired
    assert after - base <= 2
    # Different queue shapes / admission orders / one-at-a-time streams.
    _submit_all(engine, query_profiles[9:20])
    engine.run()
    for p in query_profiles[20:27]:
        engine.submit(QueryRequest(rid=99, profile=p))
        engine.run()
    # No retrace on any admission pattern — neither the per-tick hop
    # program nor the bucketed admission program.
    assert trace.compile_count(engine.plan.key) == after


def test_query_slot_hop_kernel_compiles_once(index, query_profiles):
    """scorer="pallas" keeps the compile-once property: the fused slot
    programs trace once per shape under their own plan key — admission
    interleavings never retrace the pallas program."""
    qc = QueryConfig(k=K, beam=BEAM, hops=HOPS, continuous=True,
                     slots=11, kernel=True)
    engine = QueryEngine(index, qc)
    assert engine.plan.key == (1, "continuous", "pallas")

    base = trace.compile_count(engine.plan.key)
    _submit_all(engine, query_profiles[:8])
    engine.run()
    after = trace.compile_count(engine.plan.key)
    assert after >= 1
    assert after - base <= 2  # one admit shape + one fused hop shape
    _submit_all(engine, query_profiles[8:17])
    engine.run()
    for p in query_profiles[17:22]:
        engine.submit(QueryRequest(rid=98, profile=p))
        engine.run()
    assert trace.compile_count(engine.plan.key) == after


def test_lm_decode_compiles_once_across_admissions():
    from repro.configs import get_config
    from repro.models.config import scaled_down
    from repro.models.model import init_params
    from repro.serve.engine import Engine, Request, ServeConfig

    cfg = scaled_down(get_config("gemma-2b"))
    params = init_params(jax.random.key(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_prompt=8,
                                          max_new=6, continuous=True,
                                          slots=2))
    rng = np.random.default_rng(0)

    def serve(n, max_new):
        for rid in range(n):
            eng.submit(Request(
                rid=rid, prompt=rng.integers(0, 50, 5).astype(np.int32),
                max_new=max_new))
        eng.run()

    base = trace.count(("lm_cont_decode", 2))
    serve(3, 4)
    assert trace.count(("lm_cont_decode", 2)) == base + 1
    serve(5, 3)   # different queue length + budgets: same program
    serve(1, 6)
    assert trace.count(("lm_cont_decode", 2)) == base + 1


# -- scheduler-level behavior through the engine ---------------------------

def test_continuous_slot_recycling_and_fifo(index, query_profiles):
    """Slots free mid-stream and are reused; completion covers every
    request exactly once."""
    cont = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          continuous=True, slots=3))
    _submit_all(cont, query_profiles[:11])
    stats = cont.run()
    assert stats["requests"] == 11
    sched = cont.plan.scheduler
    sched.check_invariants()
    assert sched.n_submitted == sched.n_admitted == sched.n_completed == 11
    assert not sched.has_work()
    rids = sorted(r.rid for r in cont.done)
    assert rids == list(range(11))  # exactly once each


def test_continuous_composes_with_sharded(index, query_profiles):
    """PR 3's one unsupported combination is now a first-class plan:
    sharded × continuous returns bitwise what the sharded wave returns
    (the full matrix battery lives in tests/test_plan.py)."""
    wave = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          max_wave=64, shards=2))
    _submit_all(wave, query_profiles[:16])
    wave.run()
    cont = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          continuous=True, slots=5,
                                          shards=2))
    assert cont.plan.key == (2, "continuous", "jnp")
    _submit_all(cont, query_profiles[:16])
    cs = cont.run()
    assert cs["requests"] == 16
    w, c = _by_rid(wave), _by_rid(cont)
    for rid in w:
        np.testing.assert_array_equal(w[rid][0], c[rid][0])
        np.testing.assert_array_equal(w[rid][1], c[rid][1])


# -- interleaved insert + query under continuous load ----------------------

def test_interleaved_insert_under_continuous_load(index, query_profiles):
    """Cohort refresh mid-stream keeps reverse-adjacency consistency and
    recall within tolerance of the drain-then-insert baseline."""
    ins_ds = make_dataset("synth", scale=0.1, seed=99)
    n_ins = 12

    # Baseline: drain all queries first (wave), then insert.
    ix_base = copy.deepcopy(index)
    base = QueryEngine(ix_base, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                            max_wave=64, refresh_every=6))
    _submit_all(base, query_profiles)
    base.run()
    base_recall = base.recall_vs_brute_force()
    for m in range(n_ins):
        base.insert(ins_ds.profile(m))

    # Continuous: inserts (and the cohort refreshes they trigger) land
    # between ticks while queries are in flight.
    ix_cont = copy.deepcopy(index)
    cont = QueryEngine(ix_cont, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                            continuous=True, slots=5,
                                            refresh_every=6))
    inserted = []

    def check_adjacency(u):
        # Reverse-adjacency consistency right after the insert (later
        # inserts may displace entries of BOUNDED reverse lists, so the
        # mirror property is an at-insert-time invariant): u→v must be
        # mirrored in rev(v), and every w∈rev(u) must really edge to u.
        fwd = ix_cont.graph_ids[u]
        for v in fwd[fwd != PAD_ID]:
            assert u in ix_cont.rev_ids[int(v)], (u, int(v))
        rev = ix_cont.rev_ids[u]
        for w in rev[rev != PAD_ID]:
            assert u in ix_cont.graph_ids[int(w)], (u, int(w))

    def insert_some(engine, tick):
        if tick % 2 == 0 and len(inserted) < n_ins:
            u = engine.insert(ins_ds.profile(len(inserted)))
            inserted.append(u)
            check_adjacency(u)

    _submit_all(cont, query_profiles)
    stats = cont.run(on_tick=insert_some)
    while len(inserted) < n_ins:
        u = cont.insert(ins_ds.profile(len(inserted)))
        inserted.append(u)
        check_adjacency(u)
    assert stats["requests"] == len(query_profiles)
    assert cont.n_refreshes >= 1  # the cohort refresh fired mid-stream

    # Index state matches the baseline structurally...
    assert ix_cont.n == ix_base.n
    assert len(ix_cont.cluster_offsets) == ix_cont.n_clusters + 1
    assert ix_cont.cluster_offsets[-1] == len(ix_cont.cluster_members)
    # ...and serving quality stays within tolerance of drain-then-insert
    # (results before/after a mid-stream mutation may differ; quality
    # must not).
    cont_recall = cont.recall_vs_brute_force()
    assert cont_recall >= base_recall - 0.02, (cont_recall, base_recall)


# -- Poisson open-loop bench (bench-adjacent → slow marker) ----------------

@pytest.mark.slow
def test_poisson_open_loop_bench_smoke(index, query_profiles):
    """The query_bench open-loop driver completes a mixed-budget Poisson
    run in both modes with recall parity (latency itself is asserted by
    the committed BENCH_query.json, not CI timing)."""
    import importlib.util
    from pathlib import Path

    bench = Path(__file__).resolve().parent.parent / "benchmarks"
    spec = importlib.util.spec_from_file_location(
        "query_bench", bench / "query_bench.py")
    qb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(qb)

    rec = qb.run_continuous(index, query_profiles, k=K, beam=BEAM,
                            hops=HOPS, slots=5, load=0.7, seed=0)
    ol = rec["open_loop"]
    assert ol["wave"]["p95_latency_ms"] > 0
    assert ol["continuous"]["p95_latency_ms"] > 0
    # Both modes completed the full run at the same offered load.
    assert ol["wave"]["rate_qps"] == ol["continuous"]["rate_qps"]
    assert abs(rec["open_loop_recall"]["delta"]) <= 0.005
    # Closed-loop continuous rows match wave recall exactly (identical
    # descent → identical results).
    warm = rec["closed_loop"]["warm"]
    assert warm[f"recall_at_{K}"] > 0.8


# -- LM side: equivalence + EOS slot recycling -----------------------------

@pytest.fixture(scope="module")
def lm():
    from repro.configs import get_config
    from repro.models.config import scaled_down
    from repro.models.model import init_params

    cfg = scaled_down(get_config("gemma-2b"))
    params = init_params(jax.random.key(0), cfg)
    return params, cfg


def _lm_engines(lm, **kw):
    from repro.serve.engine import Engine, ServeConfig

    params, cfg = lm
    return Engine(params, cfg, ServeConfig(**kw))


def test_lm_continuous_matches_wave_token_streams(lm):
    """Identical token streams per request, wave vs continuous, including
    left-padded prompts of different lengths and per-request budgets."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, 200, int(n)).astype(np.int32), int(mn))
            for n, mn in zip(rng.integers(3, 12, 7), [9, 2, 5, 1, 7, 3, 2])]

    wave = _lm_engines(lm, max_batch=3, max_prompt=12, max_new=10)
    for rid, (p, mn) in enumerate(reqs):
        wave.submit(Request(rid=rid, prompt=p, max_new=mn))
    ws = wave.run()

    cont = _lm_engines(lm, max_batch=3, max_prompt=12, max_new=10,
                       continuous=True, slots=3)
    for rid, (p, mn) in enumerate(reqs):
        cont.submit(Request(rid=rid, prompt=p, max_new=mn))
    cs = cont.run()

    assert ws["requests"] == cs["requests"] == len(reqs)
    w = {r.rid: r.output for r in wave.done}
    c = {r.rid: r.output for r in cont.done}
    for rid in w:
        np.testing.assert_array_equal(w[rid], c[rid], err_msg=f"rid={rid}")


def test_lm_eos_recycles_slots_into_new_decodes(lm):
    """On a skewed-length batch, EOS'd slots admit queued requests
    mid-flight: continuous finishes the same work in fewer decode steps
    (higher requests-per-step throughput) with identical outputs."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 200, 6).astype(np.int32) for _ in range(6)]
    # Learn each prompt's first greedy token, then use it as the EOS for
    # the "short" requests — they terminate via EOS, not via max_new.
    probe = _lm_engines(lm, max_batch=2, max_prompt=8, max_new=14)
    for rid, p in enumerate(prompts):
        probe.submit(Request(rid=rid, prompt=p, max_new=1))
    probe.run()
    first_tok = {r.rid: int(r.output[0]) for r in probe.done}

    def build(rid, p):
        # Requests 0 and 3 run long; the rest stop at their first token
        # via EOS — the skew that makes wave batching pad to wave end.
        if rid in (0, 3):
            return Request(rid=rid, prompt=p, max_new=12)
        return Request(rid=rid, prompt=p, max_new=12,
                       eos_id=first_tok[rid])

    wave = _lm_engines(lm, max_batch=2, max_prompt=8, max_new=14)
    for rid, p in enumerate(prompts):
        wave.submit(build(rid, p))
    ws = wave.run()

    cont = _lm_engines(lm, max_batch=2, max_prompt=8, max_new=14,
                       continuous=True, slots=2)
    for rid, p in enumerate(prompts):
        cont.submit(build(rid, p))
    cs = cont.run()

    w = {r.rid: r.output for r in wave.done}
    c = {r.rid: r.output for r in cont.done}
    for rid in w:
        np.testing.assert_array_equal(w[rid], c[rid], err_msg=f"rid={rid}")
    for rid in range(6):
        if rid not in (0, 3):
            assert len(c[rid]) == 1  # EOS fired on the first token
    # Slot recycling is the throughput win: strictly fewer decode steps
    # for the same completed work.
    assert cs["decode_steps"] < ws["decode_steps"], (cs, ws)
    tput_c = cs["requests"] / max(cs["decode_steps"], 1)
    tput_w = ws["requests"] / max(ws["decode_steps"], 1)
    assert tput_c > tput_w
