"""Deterministic battery for the background re-balancer
(query/rebalance.py) and the sharding/bench fixes that ride with it:
the ``owner ∈ residents`` plan invariant, journal-scoped ``extend_plan``
membership scans, tiered residency (``resident_configs``), the
blue/green swap (trigger cadence, cache flush, beam remap math), and
the query_bench median-row selection fix. The hypothesis interleaving
battery lives in tests/test_rebalance_properties.py.
"""
import copy
import dataclasses
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.query.rebalance import measured_imbalance
from repro.query.sharded import ShardedDescent, extend_plan, plan_shards
from repro.types import PAD_ID

from test_plan import _assert_matches_rebuild  # same-dir test module

K, BEAM, HOPS = 10, 16, 3


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.1, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.1, seed=77)
    return [qds.profile(u) for u in range(32)]


@pytest.fixture(scope="module")
def insert_profiles():
    ids = make_dataset("synth", scale=0.1, seed=99)
    return [ids.profile(u) for u in range(48)]


def _serve(engine, profiles):
    for rid, p in enumerate(profiles):
        engine.submit(QueryRequest(rid=rid, profile=p))
    engine.run()
    return {r.rid: (np.asarray(r.ids), np.asarray(r.sims))
            for r in engine.done[-len(profiles):]}


def _assert_same(a, b, msg=""):
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid][0], b[rid][0],
                                      err_msg=f"{msg} ids rid={rid}")
        np.testing.assert_array_equal(a[rid][1], b[rid][1],
                                      err_msg=f"{msg} sims rid={rid}")


# -- owner ∈ residents invariant -------------------------------------------

def test_validate_rejects_owner_outside_residents(index):
    plan = plan_shards(index, 3)  # derivation validates internally
    victim = int(np.flatnonzero(plan.owner == 0)[0])
    res = [r.copy() for r in plan.residents]
    res[0] = res[0][res[0] != victim]
    bad = dataclasses.replace(plan, residents=res)
    with pytest.raises(AssertionError, match="owns users"):
        bad.validate()


def test_unowned_users_are_owned_by_a_hosting_shard(index):
    """The leftover stride hands residency AND ownership to the same
    shard — under tiered residency (where most users ride the stride)
    every owner must still host its user's rows."""
    for m in (0, 2, 4):
        plan = plan_shards(index, 3, resident_configs=m)
        for s in range(3):
            owned = np.flatnonzero(plan.owner == s)
            assert np.isin(owned, plan.residents[s]).all(), (m, s)
        covered = np.zeros(index.n, dtype=bool)
        for r in plan.residents:
            covered[r] = True
        assert covered.all(), f"resident_configs={m} lost coverage"


# -- journal-scoped extend_plan --------------------------------------------

def test_extend_plan_scopes_membership_scans(index, insert_profiles,
                                             monkeypatch):
    ix = copy.deepcopy(index)
    eng = QueryEngine(ix, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                      max_wave=8, shards=3,
                                      refresh_every=10**9))
    base = eng.sharded_state().base_plan
    for p in insert_profiles[:6]:
        eng.insert(p)
    calls = []
    orig = ix.cluster_users
    monkeypatch.setattr(
        ix, "cluster_users", lambda ci: (calls.append(ci), orig(ci))[1])
    scoped = extend_plan(base, ix)
    scoped_calls = len(calls)
    calls.clear()
    full = extend_plan(dataclasses.replace(base, version=-1), ix)
    full_calls = len(calls)
    # Same plan either way (the scoped scan is an optimization, never a
    # different answer), but the journal-scoped path only scans clusters
    # born or membership-touched since the base was derived.
    np.testing.assert_array_equal(scoped.cluster_shard, full.cluster_shard)
    np.testing.assert_array_equal(scoped.owner, full.owner)
    for s, (a, b) in enumerate(zip(scoped.residents, full.residents)):
        np.testing.assert_array_equal(a, b, err_msg=f"residents shard={s}")
    assert scoped_calls < full_calls, (scoped_calls, full_calls)
    assert full_calls >= index.n_clusters  # the O(S·C) scan it replaces


# -- tiered residency ------------------------------------------------------

def test_tiered_residency_shrinks_memory(index):
    full = ShardedDescent(index, 3, use_mesh=False)
    tier = ShardedDescent(index, 3, use_mesh=False, resident_configs=2)
    assert tier.plan.resident_configs == 2
    assert sum(len(r) for r in tier.plan.residents) < \
        sum(len(r) for r in full.plan.residents)
    assert sum(tier.resident_bytes()) < sum(full.resident_bytes())
    # m >= t (or 0) means full residency — identical plans.
    off = ShardedDescent(index, 3, use_mesh=False,
                         resident_configs=index.t)
    assert off.plan.resident_configs == 0
    for a, b in zip(off.plan.residents, full.plan.residents):
        np.testing.assert_array_equal(a, b)


def test_tiered_residency_spec_requires_sharding(index):
    with pytest.raises(ValueError, match="resident_configs"):
        QueryEngine(index, QueryConfig(resident_configs=2))


def test_tiered_residency_recall_and_delta_sync(index, query_profiles,
                                                insert_profiles):
    full_eng = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                              max_wave=32, shards=3))
    _serve(full_eng, query_profiles)
    full_recall = full_eng.recall_vs_brute_force()

    ix = copy.deepcopy(index)
    eng = QueryEngine(ix, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                      max_wave=32, shards=3,
                                      resident_configs=4,
                                      refresh_every=10**9))
    _serve(eng, query_profiles)
    assert eng.recall_vs_brute_force() >= full_recall - 0.1
    # Journal-driven delta sync under restricted residency still equals
    # the from-scratch extend_plan rebuild, bitwise.
    for p in insert_profiles[:8]:
        eng.insert(p)
    _serve(eng, query_profiles)
    _assert_matches_rebuild(eng)


# -- rebalancer trigger / cadence / swap -----------------------------------

def test_rebalance_config_requires_sharding(index):
    with pytest.raises(ValueError, match="rebalance"):
        QueryEngine(index, QueryConfig(rebalance_every=4))


def test_rebalancer_cadence_and_threshold(index, query_profiles):
    ix = copy.deepcopy(index)
    eng = QueryEngine(ix, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                      max_wave=2, shards=2,
                                      rebalance_every=2,
                                      rebalance_threshold=10.0))
    _serve(eng, query_profiles[:8])  # 4 waves -> the cadence fires twice
    reb = eng.rebalance
    assert reb.active
    assert reb.n_checks >= 1
    assert reb.n_swaps == 0  # threshold unreachable: measure, never swap
    assert reb.last_imbalance is not None
    assert measured_imbalance(ix, eng.sharded_state().plan) == \
        pytest.approx(reb.last_imbalance)
    gen0 = eng.sharded_state().generation
    post = reb.check(force=True)  # the swap machinery works regardless
    assert post is not None and post >= 1.0 - 1e-9
    assert reb.n_swaps == 1
    assert eng.sharded_state().generation == gen0 + 1
    assert "swaps" in reb.stats() and reb.stats()["swaps"] == 1


def test_swap_is_invisible_at_fixed_index_state(index, query_profiles):
    """On an unmutated index a swap re-derives the SAME partition, so
    serving must be bitwise unchanged — and a cache-on engine must stay
    bitwise-equal to cache-off across the swap (flushed, not stale)."""
    on = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                        max_wave=32, shards=2, cache=64,
                                        rebalance_every=10**9))
    off = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                         max_wave=32, shards=2,
                                         rebalance_every=10**9))
    a0 = _serve(on, query_profiles)
    b0 = _serve(off, query_profiles)
    _assert_same(a0, b0, "pre-swap")
    _serve(on, query_profiles)
    assert on.plan.cache.hits > 0  # the cache actually served
    f0 = on.plan.cache.flushes
    on.rebalance.swap()
    off.rebalance.swap()
    assert on.plan.cache.flushes == f0 + 1  # journal-invisible event
    assert len(on.plan.cache) == 0
    a1 = _serve(on, query_profiles)
    b1 = _serve(off, query_profiles)
    _assert_same(a1, b1, "post-swap cache-on vs cache-off")
    _assert_same(a1, a0, "same-plan swap must not move results")


def test_adopt_plan_records_total_remap(index, insert_profiles):
    """The old→new local-id map a swap leaves for in-flight beams is
    exactly new_g2l ∘ old_l2g: still-resident rows get their new local
    id, evicted rows drop to PAD (the continuous plan masks their sims).
    """
    ix = copy.deepcopy(index)
    eng = QueryEngine(ix, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                      max_wave=8, shards=3,
                                      refresh_every=10**9,
                                      rebalance_every=10**9))
    eng.query_batch([insert_profiles[0]])  # builds the sharded state
    sd = eng.sharded_state()
    for p in insert_profiles[:10]:
        eng.insert(p)
    sd.sync()
    sd.take_beam_remap()  # drop any pending map from the insert burst
    old_l2g = np.asarray(sd._dev[4]).copy()
    eng.rebalance.swap()
    mp = sd.take_beam_remap()
    assert mp is not None and mp.shape == old_l2g.shape
    for s in range(sd.n_shards):
        safe = np.where(old_l2g[s] == PAD_ID, 0, old_l2g[s])
        want = np.where(old_l2g[s] == PAD_ID, PAD_ID, sd._g2l[s][safe])
        np.testing.assert_array_equal(mp[s], want, err_msg=f"shard={s}")
    assert sd.take_beam_remap() is None  # consumed


# -- query_bench median-row fix --------------------------------------------

def test_median_row_reports_one_coherent_rep():
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    try:
        from query_bench import median_row
    finally:
        sys.path.pop(0)
    rows = [
        {"rate_qps": 10.0, "achieved_qps": 9.0, "p50_latency_ms": 5.0,
         "p95_latency_ms": 50.0, "max_latency_ms": 60.0},
        {"rate_qps": 10.0, "achieved_qps": 7.0, "p50_latency_ms": 1.0,
         "p95_latency_ms": 20.0, "max_latency_ms": 30.0},
        {"rate_qps": 10.0, "achieved_qps": 8.0, "p50_latency_ms": 9.0,
         "p95_latency_ms": 40.0, "max_latency_ms": 45.0},
    ]
    out = median_row(rows)
    # The rep with the median p95 (40.0) is reported WHOLE. The old
    # per-key median would have stitched p50=5.0 (rep 0) onto p95=40.0
    # (rep 2) — a row no rep measured.
    assert out == {"rate_qps": 10.0, "achieved_qps": 8.0,
                   "p50_latency_ms": 9.0, "p95_latency_ms": 40.0,
                   "max_latency_ms": 45.0,
                   "p95_latency_ms_reps": [50.0, 20.0, 40.0]}
