"""GoldFinger sketch unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.sketch.goldfinger import (
    fingerprint_dataset,
    jaccard_pairwise,
    jaccard_pairwise_mxu,
    popcount_rows,
)
from repro.types import dataset_from_profiles


def test_fingerprint_shapes(small_ds, small_gf):
    assert small_gf.words.shape == (small_ds.n_users, 512 // 32)
    assert small_gf.card.shape == (small_ds.n_users,)
    assert (small_gf.card <= np.minimum(small_ds.profile_sizes, 512)).all()
    assert (small_gf.card >= 1).all()


def test_mxu_path_matches_popcount(small_gf):
    w = jnp.asarray(small_gf.words[:96])
    c = jnp.asarray(small_gf.card[:96])
    s_pop = jaccard_pairwise(w, c, w, c)
    s_mxu = jaccard_pairwise_mxu(w, c, w, c)
    np.testing.assert_allclose(np.asarray(s_pop), np.asarray(s_mxu), atol=0)


def test_identical_profiles_sim_one(small_gf):
    w = jnp.asarray(small_gf.words[:8])
    c = jnp.asarray(small_gf.card[:8])
    s = np.asarray(jaccard_pairwise(w, c, w, c))
    np.testing.assert_allclose(np.diag(s), 1.0)


def test_disjoint_profiles_sim_zero():
    ds = dataset_from_profiles("d", [[0, 1, 2], [100, 101, 102]], 200)
    gf = fingerprint_dataset(ds, n_bits=1024)
    s = np.asarray(jaccard_pairwise(
        jnp.asarray(gf.words), jnp.asarray(gf.card),
        jnp.asarray(gf.words), jnp.asarray(gf.card)))
    # Disjoint → near 0 (exactly 0 unless the 6 items collide in 1024 bits).
    assert s[0, 1] < 0.35


@settings(deadline=None, max_examples=25)
@given(
    p1=st.sets(st.integers(0, 499), min_size=1, max_size=60),
    p2=st.sets(st.integers(0, 499), min_size=1, max_size=60),
)
def test_goldfinger_estimates_jaccard(p1, p2):
    """GoldFinger (2048 bits, few collisions) ≈ exact Jaccard."""
    ds = dataset_from_profiles("h", [sorted(p1), sorted(p2)], 500)
    gf = fingerprint_dataset(ds, n_bits=2048)
    s = float(np.asarray(jaccard_pairwise(
        jnp.asarray(gf.words), jnp.asarray(gf.card),
        jnp.asarray(gf.words), jnp.asarray(gf.card)))[0, 1])
    exact = len(p1 & p2) / len(p1 | p2)
    assert abs(s - exact) <= 0.12


def test_popcount_rows():
    w = np.array([[0, 0xFFFFFFFF, 0x0F0F0F0F]], dtype=np.uint32)
    assert popcount_rows(w)[0] == 32 + 16
