"""Query-serving subsystem tests: routing, descent recall, online
insertion, and index persistence."""
import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import KNNIndex, build_index
from repro.query.router import profiles_to_csr, route
from repro.types import PAD_ID


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.15, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def engine(index):
    return QueryEngine(index, QueryConfig(k=10, beam=32, hops=3,
                                          max_wave=64))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.15, seed=77)
    return [qds.profile(u) for u in range(64)]


def test_router_returns_seeds_for_clustered_queries(index, query_profiles):
    items, offsets = profiles_to_csr(query_profiles)
    seeds = route(index, items, offsets, seeds_per_config=16)
    assert seeds.shape == (len(query_profiles), index.t * 16)
    # Every query gets at least one seed (fallback guarantees it) and all
    # seeds are valid user ids.
    assert ((seeds != PAD_ID).sum(axis=1) > 0).all()
    valid = seeds[seeds != PAD_ID]
    assert (0 <= valid).all() and (valid < index.n).all()


def test_engine_recall_vs_brute_force(engine, query_profiles):
    for rid, p in enumerate(query_profiles):
        engine.submit(QueryRequest(rid=rid, profile=p))
    stats = engine.run()
    assert stats["requests"] == len(query_profiles)
    assert stats["qps"] > 0
    assert stats["p95_latency_s"] >= stats["p50_latency_s"] > 0
    assert engine.recall_vs_brute_force() >= 0.8
    engine.done.clear()


def test_results_are_sorted_and_self_free(engine, query_profiles):
    ids, sims = engine.query_batch(query_profiles[:8])
    assert ids.shape == (8, 10)
    valid = ids != PAD_ID
    # PAD slots score -inf and sort last; compare on a finite stand-in so
    # the diff stays NaN-free.
    assert (np.diff(np.where(valid, sims, -1.0), axis=1) <= 1e-6).all()
    assert (np.where(valid, sims, 0.0) >= 0).all()


def test_inserted_user_is_findable(engine, query_profiles):
    n_before = engine.index.n
    profile = query_profiles[0]
    u = engine.insert(profile)
    assert u == n_before and engine.index.n == n_before + 1
    # The inserted user's fingerprint is identical to the query's, so it
    # must come back as the top neighbor of the same profile.
    ids, sims = engine.query_batch([profile])
    assert ids[0, 0] == u
    assert sims[0, 0] == pytest.approx(1.0)
    # And it must be linked into the graph (forward edges exist).
    assert (engine.index.graph_ids[u] != PAD_ID).any()


def test_insert_patches_reverse_edges(engine, query_profiles):
    ix = engine.index
    u = engine.insert(query_profiles[1])
    nbrs = ix.graph_ids[u]
    nbrs = nbrs[nbrs != PAD_ID]
    # u joined the reverse lists of its forward neighbors.
    assert any(u in ix.rev_ids[int(v)] for v in nbrs)


def test_index_save_load_roundtrip(index, tmp_path):
    path = tmp_path / "index.npz"
    index.save(path)
    loaded = KNNIndex.load(path)
    for name in ("graph_ids", "graph_sims", "words", "card", "rev_ids",
                 "hash_seeds", "cluster_paths", "cluster_config",
                 "cluster_members", "cluster_offsets"):
        np.testing.assert_array_equal(getattr(index, name),
                                      getattr(loaded, name), err_msg=name)
    for name in ("b", "n_bits", "fp_seed", "split_depth", "version"):
        assert getattr(index, name) == getattr(loaded, name), name
    # The loaded artifact serves identically.
    e1 = QueryEngine(index)
    e2 = QueryEngine(loaded)
    qds = make_dataset("synth", scale=0.15, seed=5)
    profiles = [qds.profile(u) for u in range(8)]
    ids1, sims1 = e1.query_batch(profiles)
    ids2, sims2 = e2.query_batch(profiles)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(sims1, sims2)


def test_serve_cli_smoke(capsys):
    from repro.launch.knn_serve import main

    stats, recall = main(["--dataset", "synth", "--scale", "0.05",
                          "--queries", "32", "--insert", "2"])
    out = capsys.readouterr().out
    assert "QPS" in out and "recall" in out
    assert stats["requests"] == 32
    assert recall >= 0.6  # tiny index; the full-size bar is tested above