"""Query-serving subsystem tests: routing, descent recall, online
insertion, and index persistence."""
import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import KNNIndex, build_index
from repro.query.router import profiles_to_csr, route
from repro.types import PAD_ID


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.15, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def engine(index):
    return QueryEngine(index, QueryConfig(k=10, beam=32, hops=3,
                                          max_wave=64))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.15, seed=77)
    return [qds.profile(u) for u in range(64)]


def test_router_returns_seeds_for_clustered_queries(index, query_profiles):
    items, offsets = profiles_to_csr(query_profiles)
    seeds = route(index, items, offsets, seeds_per_config=16)
    assert seeds.shape == (len(query_profiles), index.t * 16)
    # Every query gets at least one seed (fallback guarantees it) and all
    # seeds are valid user ids.
    assert ((seeds != PAD_ID).sum(axis=1) > 0).all()
    valid = seeds[seeds != PAD_ID]
    assert (0 <= valid).all() and (valid < index.n).all()


def test_engine_recall_vs_brute_force(engine, query_profiles):
    for rid, p in enumerate(query_profiles):
        engine.submit(QueryRequest(rid=rid, profile=p))
    stats = engine.run()
    assert stats["requests"] == len(query_profiles)
    assert stats["qps"] > 0
    assert stats["p95_latency_s"] >= stats["p50_latency_s"] > 0
    assert engine.recall_vs_brute_force() >= 0.8
    engine.done.clear()


def test_results_are_sorted_and_self_free(engine, query_profiles):
    ids, sims = engine.query_batch(query_profiles[:8])
    assert ids.shape == (8, 10)
    valid = ids != PAD_ID
    # PAD slots score -inf and sort last; compare on a finite stand-in so
    # the diff stays NaN-free.
    assert (np.diff(np.where(valid, sims, -1.0), axis=1) <= 1e-6).all()
    assert (np.where(valid, sims, 0.0) >= 0).all()


def test_inserted_user_is_findable(engine, query_profiles):
    n_before = engine.index.n
    profile = query_profiles[0]
    u = engine.insert(profile)
    assert u == n_before and engine.index.n == n_before + 1
    # The inserted user's fingerprint is identical to the query's, so it
    # must come back as the top neighbor of the same profile.
    ids, sims = engine.query_batch([profile])
    assert ids[0, 0] == u
    assert sims[0, 0] == pytest.approx(1.0)
    # And it must be linked into the graph (forward edges exist).
    assert (engine.index.graph_ids[u] != PAD_ID).any()


def test_insert_patches_reverse_edges(engine, query_profiles):
    ix = engine.index
    u = engine.insert(query_profiles[1])
    nbrs = ix.graph_ids[u]
    nbrs = nbrs[nbrs != PAD_ID]
    # u joined the reverse lists of its forward neighbors.
    assert any(u in ix.rev_ids[int(v)] for v in nbrs)


def test_append_is_amortized_no_per_insert_realloc(index):
    """Regression for the O(n)-copy-per-insert bug: row buffers may only
    reallocate on geometric-doubling boundaries, never per insert."""
    import copy

    ix = copy.deepcopy(index)
    engine = QueryEngine(ix, QueryConfig(k=10, refresh_every=10**9))
    qds = make_dataset("synth", scale=0.15, seed=11)
    n_ins = 40
    n0, cap0 = ix.n, ix.capacity
    caps, buf_ids = [], []
    for m in range(n_ins):
        engine.insert(qds.profile(m))
        caps.append(ix.capacity)
        buf_ids.append(id(ix._bufs["graph_ids"]))
    caps = np.array([cap0] + caps)
    # Capacity only changes when the previous one was exhausted, and then
    # exactly doubles (so reallocations are O(log inserts), not O(inserts)).
    for prev, cur, n_now in zip(caps, caps[1:], range(n0 + 1, n0 + n_ins + 1)):
        if cur != prev:
            assert prev < n_now <= cur and cur == max(2 * prev, 64)
    n_reallocs = len(set(buf_ids))
    assert n_reallocs <= int(np.log2(n_ins)) + 1, n_reallocs
    # Buffers are stable between doublings: inserts write in place.
    assert buf_ids[-1] == buf_ids[-2]


def test_insert_reverse_adjacency_consistent(index):
    """After insert, every forward edge u→v is mirrored in rev(v), and
    every reverse entry w∈rev(u) is a real forward edge w→u."""
    import copy

    ix = copy.deepcopy(index)
    engine = QueryEngine(ix, QueryConfig(k=10))
    qds = make_dataset("synth", scale=0.15, seed=13)
    for m in range(4):
        u = engine.insert(qds.profile(m))
        fwd = ix.graph_ids[u]
        for v in fwd[fwd != PAD_ID]:
            assert u in ix.rev_ids[int(v)], (u, int(v))
        rev = ix.rev_ids[u]
        for w in rev[rev != PAD_ID]:
            assert u in ix.graph_ids[int(w)], (u, int(w))


def test_inserted_user_reachable_from_router_clusters(index):
    """The inserted node must be reachable from its registered router
    clusters by following forward/reverse edges (≤ hops steps) — i.e.
    routing a similar query can actually descend to it."""
    import copy

    from repro.query.router import placements

    ix = copy.deepcopy(index)
    engine = QueryEngine(ix, QueryConfig(k=10, hops=3))
    qds = make_dataset("synth", scale=0.15, seed=17)
    profile = qds.profile(0)
    u = engine.insert(profile)
    items, offsets = profiles_to_csr([profile])
    placed = placements(ix, items, offsets)
    registered = [m[0] for m in placed[0] if m]
    assert registered, "profile must place in at least one cluster"
    for ci in registered:
        assert u in ix.cluster_users(ci)  # registered in deepest clusters
    # Descent seeds from the union of the matched clusters (route()), so
    # reachability is over that union, following forward+reverse edges.
    frontier = set()
    for ci in registered:
        frontier |= set(int(x) for x in ix.cluster_users(ci) if x != u)
    seen = set(frontier)
    reached = u in frontier
    for _ in range(engine.qc.hops):
        if reached:
            break
        nxt = set()
        for x in frontier:
            for nb in np.concatenate([ix.graph_ids[x], ix.rev_ids[x]]):
                if nb != PAD_ID and int(nb) not in seen:
                    nxt.add(int(nb))
        seen |= nxt
        frontier = nxt
        reached = u in frontier
    assert reached


def test_incremental_device_sync_matches_full_upload(index, query_profiles):
    """Inserts repair the engine's device copies via the row journal
    (scatter of touched rows); results must be identical to a fresh
    engine that uploads the mutated index from scratch."""
    import copy

    ix = copy.deepcopy(index)
    warm = QueryEngine(ix, QueryConfig(k=10, refresh_every=10**9))
    warm.query_batch(query_profiles[:4])  # populate the device cache
    v0 = ix.version
    qds = make_dataset("synth", scale=0.15, seed=23)
    for m in range(5):
        u = warm.insert(qds.profile(m))
        touched = ix.rows_changed_since(v0)
        assert touched is not None and u in touched
    # Journal semantics: per-step diffs are supersets of the final row.
    assert ix.rows_changed_since(ix.version) == set()
    assert ix.rows_changed_since(ix.version - 1) is not None
    ids1, sims1 = warm.query_batch(query_profiles[:8])
    fresh = QueryEngine(ix, QueryConfig(k=10))
    ids2, sims2 = fresh.query_batch(query_profiles[:8])
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_allclose(sims1, sims2, atol=1e-6)


def test_cohort_refresh_registers_new_clusters(index):
    """Once the insert cohort exceeds the threshold, the engine re-runs
    C² clustering on it: new split paths become routable clusters and
    the routing tables stay structurally consistent."""
    import copy

    ix = copy.deepcopy(index)
    engine = QueryEngine(ix, QueryConfig(k=10, refresh_every=12))
    # A *different* synth seed drifts the insert stream away from the
    # build distribution, so fresh split paths appear.
    qds = make_dataset("synth", scale=0.15, seed=99)
    c_before = ix.n_clusters
    v_before = ix.version
    for m in range(12):
        engine.insert(qds.profile(m))
    assert engine.n_refreshes == 1
    assert engine._cohort == []  # drained
    assert ix.version > v_before
    assert ix.n_clusters >= c_before
    # CSR stays consistent after the refresh appended clusters.
    assert len(ix.cluster_offsets) == ix.n_clusters + 1
    assert ix.cluster_offsets[-1] == len(ix.cluster_members)
    assert len(ix.cluster_paths) == ix.n_clusters
    assert (np.diff(ix.cluster_offsets) >= 0).all()
    mem = ix.cluster_members
    assert ((mem >= 0) & (mem < ix.n)).all()
    # The refreshed LUT routes: every new cluster is findable by path.
    lut = ix.path_lut()
    assert len(lut) == ix.n_clusters
    # Serving still works end to end on the refreshed tables.
    ids, _ = engine.query_batch([qds.profile(0)])
    assert (ids[0] != PAD_ID).any()


def test_index_save_load_roundtrip(index, tmp_path):
    path = tmp_path / "index.npz"
    index.save(path)
    loaded = KNNIndex.load(path)
    for name in ("graph_ids", "graph_sims", "words", "card", "rev_ids",
                 "hash_seeds", "cluster_paths", "cluster_config",
                 "cluster_members", "cluster_offsets"):
        np.testing.assert_array_equal(getattr(index, name),
                                      getattr(loaded, name), err_msg=name)
    for name in ("b", "n_bits", "fp_seed", "split_depth", "version"):
        assert getattr(index, name) == getattr(loaded, name), name
    # The loaded artifact serves identically.
    e1 = QueryEngine(index)
    e2 = QueryEngine(loaded)
    qds = make_dataset("synth", scale=0.15, seed=5)
    profiles = [qds.profile(u) for u in range(8)]
    ids1, sims1 = e1.query_batch(profiles)
    ids2, sims2 = e2.query_batch(profiles)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(sims1, sims2)


def test_serve_cli_smoke(capsys):
    from repro.launch.knn_serve import main

    stats, recall = main(["--dataset", "synth", "--scale", "0.05",
                          "--queries", "32", "--insert", "2"])
    out = capsys.readouterr().out
    assert "QPS" in out and "recall" in out
    assert stats["requests"] == 32
    assert recall >= 0.6  # tiny index; the full-size bar is tested above