"""Plan-matrix battery (query/plan.py): the placement × batching ×
scorer cross-product, delta resharding, and compile-once per plan.

Result semantics locked down here: *placement* is the one axis that may
change results (disjoint owner-seeded basins, dropped cross-shard
edges — recall parity is asserted, not equality); *batching* and
*scorer* are results-TRANSPARENT — for any fixed placement, continuous
== wave and pallas == jnp, bitwise on (ids, sims), for every shard
count in 2..4. Delta resharding must be invisible: a journal-driven
delta-maintained ShardedDescent is bitwise-equal to a from-scratch
rematerialization under the same frozen-base plan extension, for any
interleaving of insert / flush_cohort / query (hypothesis-driven), and
a sharded engine never materializes a full-index device copy.
"""
import copy

import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.query.plan import PlanSpec
from repro.query.sharded import ShardedDescent, extend_plan
from repro.sched import trace

K, BEAM, HOPS = 10, 16, 3


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.1, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.1, seed=77)
    return [qds.profile(u) for u in range(48)]


@pytest.fixture(scope="module")
def insert_profiles():
    ids = make_dataset("synth", scale=0.1, seed=99)
    return [ids.profile(u) for u in range(40)]


def _serve(engine, profiles):
    for rid, p in enumerate(profiles):
        engine.submit(QueryRequest(rid=rid, profile=p))
    engine.run()
    return {r.rid: (r.ids, r.sims) for r in engine.done}


def _assert_same_results(a, b, msg=""):
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid][0], b[rid][0],
                                      err_msg=f"{msg} ids rid={rid}")
        np.testing.assert_array_equal(a[rid][1], b[rid][1],
                                      err_msg=f"{msg} sims rid={rid}")


# -- spec validation (no silently dropped flags) ---------------------------

def test_spec_validation_fails_loudly():
    with pytest.raises(ValueError, match="placement"):
        PlanSpec(placement=0)
    with pytest.raises(ValueError, match="batching"):
        PlanSpec(batching="waves")
    with pytest.raises(ValueError, match="scorer"):
        PlanSpec(scorer="numpy")
    with pytest.raises(ValueError, match="slots"):
        PlanSpec(batching="continuous", slots=0)
    with pytest.raises(ValueError, match="max_wave"):
        PlanSpec(batching="wave", max_wave=0)


def test_config_maps_onto_plan(index):
    qc = QueryConfig(shards=3, continuous=True, kernel=True, slots=9)
    spec = qc.spec()
    assert spec.key == (3, "continuous", "pallas")
    assert "sharded(3)" in spec.describe()
    assert "continuous" in spec.describe()
    with pytest.raises(ValueError):
        QueryEngine(index, QueryConfig(shards=0))
    with pytest.raises(ValueError):
        QueryEngine(index, QueryConfig(continuous=True, slots=0))


# -- the matrix: batching and scorer are results-transparent ---------------

@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_sharded_continuous_bitwise_equals_wave(index, query_profiles,
                                                n_shards):
    """For every shard count, the sharded continuous plan returns
    bitwise-identical (ids, sims) to the wave plan on the same
    placement, with and without the fused kernel — and recall parity
    with the single-device wave (placement's recall cost is bounded the
    same under every batching × scorer)."""
    single = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                            max_wave=64))
    _serve(single, query_profiles)
    single_recall = single.recall_vs_brute_force()

    wave = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          max_wave=64, shards=n_shards))
    w = _serve(wave, query_profiles)
    for kernel in (False, True):
        cont = QueryEngine(index, QueryConfig(
            k=K, beam=BEAM, hops=HOPS, continuous=True, slots=7,
            shards=n_shards, kernel=kernel))
        c = _serve(cont, query_profiles)
        _assert_same_results(w, c, f"shards={n_shards} kernel={kernel}")
        recall = cont.recall_vs_brute_force()
        assert recall >= single_recall - 0.01, (n_shards, kernel, recall)


def test_sharded_continuous_per_request_budgets(index, query_profiles):
    """Per-slot hop budgets under the sharded placement: each request
    matches a uniform sharded wave at its own budget, including the
    zero-hop (seed-only) budget."""
    deep = 2 * HOPS
    ref = {}
    for hops in (0, HOPS, deep):
        eng = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=hops,
                                             max_wave=64, shards=2))
        ref[hops] = _serve(eng, query_profiles)
    cont = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          continuous=True, slots=6,
                                          shards=2))
    budgets = [deep if rid % 3 == 0 else (0 if rid % 5 == 0 else HOPS)
               for rid in range(len(query_profiles))]
    for rid, p in enumerate(query_profiles):
        cont.submit(QueryRequest(rid=rid, profile=p, hops=budgets[rid]))
    cont.run()
    assert len(cont.done) == len(query_profiles)
    for r in cont.done:
        want_ids, want_sims = ref[budgets[r.rid]][r.rid]
        np.testing.assert_array_equal(r.ids, want_ids, err_msg=f"{r.rid}")
        np.testing.assert_array_equal(r.sims, want_sims,
                                      err_msg=f"{r.rid}")


# -- compile-once per plan across admissions AND reshards ------------------

def test_compile_once_across_admissions_and_reshards(index, query_profiles,
                                                     insert_profiles):
    """trace.compile_count(plan.key) goes flat once every program shape
    of the plan is warm — further admission interleavings AND delta
    reshards (insert bursts) reuse the compiled programs."""
    ix = copy.deepcopy(index)
    engine = QueryEngine(ix, QueryConfig(
        k=K, beam=BEAM, hops=HOPS, continuous=True, slots=6, shards=2,
        refresh_every=10**9))
    key = engine.plan.key
    assert key == (2, "continuous", "jnp")
    # Warm every shape this plan uses: slot programs, the insert-search
    # wave program, and a post-reshard tick.
    _serve(engine, query_profiles[:9])
    engine.insert(insert_profiles[0])
    _serve(engine, query_profiles[9:14])
    warm = trace.compile_count(key)
    assert warm >= 1
    # Insert burst (delta reshards) interleaved with streamed serving.
    for m, p in enumerate(insert_profiles[1:7]):
        engine.insert(p)
        engine.submit(QueryRequest(rid=100 + m,
                                   profile=query_profiles[m % 9]))
        engine.run()
    _serve(engine, query_profiles[14:25])
    assert trace.compile_count(key) == warm
    assert engine.sharded_state().version == ix.version


def test_wave_plan_compile_once_across_reshards(index, query_profiles,
                                                insert_profiles):
    """The sharded wave program is also plan-tagged and survives delta
    reshards without a retrace."""
    ix = copy.deepcopy(index)
    engine = QueryEngine(ix, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                         max_wave=64, shards=3,
                                         refresh_every=10**9))
    _serve(engine, query_profiles[:32])
    engine.insert(insert_profiles[0])
    _serve(engine, query_profiles[:32])
    warm = trace.compile_count(engine.plan.key)
    for p in insert_profiles[1:5]:
        engine.insert(p)
    _serve(engine, query_profiles[:32])
    assert trace.compile_count(engine.plan.key) == warm


# -- delta resharding ------------------------------------------------------

def _assert_matches_rebuild(engine):
    """Delta-maintained shard state == from-scratch rematerialization
    under the same frozen-base plan extension, bitwise."""
    sd = engine.sharded_state()  # syncs
    ix = engine.index
    fresh = ShardedDescent(ix, sd.n_shards,
                           plan=extend_plan(sd.base_plan, ix),
                           use_mesh=False,
                           oversample=sd.oversample)
    assert sd.version == fresh.version == ix.version
    np.testing.assert_array_equal(sd.plan.cluster_shard,
                                  fresh.plan.cluster_shard)
    np.testing.assert_array_equal(sd.plan.owner, fresh.plan.owner)
    for s in range(sd.n_shards):
        np.testing.assert_array_equal(sd.plan.residents[s],
                                      fresh.plan.residents[s],
                                      err_msg=f"residents shard={s}")
    np.testing.assert_array_equal(sd._g2l, fresh._g2l)
    names = ("l_graph", "l_rev", "l_words", "l_card", "l2g", "l_tomb")
    assert len(sd._dev) == len(fresh._dev) == len(names)
    for a, b, name in zip(sd._dev, fresh._dev, names):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_delta_reshard_equals_rebuild_after_insert_burst(index,
                                                         insert_profiles,
                                                         query_profiles):
    """An insert burst (spanning a cohort refresh) goes through the
    delta path and leaves shard tensors bitwise-equal to a full
    rematerialization — without the engine ever holding a full-index
    device copy."""
    ix = copy.deepcopy(index)
    engine = QueryEngine(ix, QueryConfig(k=K, shards=3, refresh_every=6))
    engine.query_batch(query_profiles[:8])  # freeze the base plan
    sd = engine.sharded_state()
    kinds = []
    for p in insert_profiles[:14]:  # crosses refreshes at 6 and 12
        engine.insert(p)
        kinds.append(sd.sync())
    assert "delta" in kinds  # journal-driven path actually exercised
    assert engine.n_refreshes == 2
    _assert_matches_rebuild(engine)
    # Tentpole memory claim: sharded plans never materialize the padded
    # full-index device arrays the single placement serves from.
    assert engine.plan._single is None
    # And the engine still answers: inserted users are findable.
    ids, sims = engine.query_batch([insert_profiles[0]])
    assert sims[0, 0] == pytest.approx(1.0)


def test_interleaved_insert_under_sharded_continuous_load(
        index, query_profiles, insert_profiles):
    """Mid-stream inserts + cohort refreshes while sharded slots are in
    flight: the local-id remap keeps every request completing with
    sensible quality, and the final shard state matches a rebuild."""
    ix = copy.deepcopy(index)
    cont = QueryEngine(ix, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                       continuous=True, slots=5, shards=2,
                                       refresh_every=4))
    base = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                          max_wave=64, shards=2))
    _serve(base, query_profiles)
    base_recall = base.recall_vs_brute_force()

    inserted = []

    def insert_some(engine, tick):
        if tick % 2 == 0 and len(inserted) < 10:
            inserted.append(engine.insert(insert_profiles[len(inserted)]))

    for rid, p in enumerate(query_profiles):
        cont.submit(QueryRequest(rid=rid, profile=p))
    stats = cont.run(on_tick=insert_some)
    assert stats["requests"] == len(query_profiles)
    assert cont.n_refreshes >= 1  # refresh fired while slots were live
    assert cont.recall_vs_brute_force() >= base_recall - 0.02
    _assert_matches_rebuild(cont)


# -- mesh parity for the composed plan -------------------------------------

@pytest.mark.slow
def test_mesh_sharded_continuous_and_delta_sync():
    """The mesh branches of the composed plan — NamedSharding-pinned
    slot state, shard_slot programs under GSPMD, delta sync's re-pin
    block, and the in-flight beam remap — return exactly what the
    single-device vmap path returns, across an insert burst that spans
    a cohort refresh (subprocess so the emulated device count doesn't
    leak into this session)."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}
    code = r"""
import copy, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.query.sharded import ShardedDescent

assert jax.device_count() == 2
ds = make_dataset("synth", scale=0.1, seed=3)
index = build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))
qds = make_dataset("synth", scale=0.1, seed=77)
ins = make_dataset("synth", scale=0.1, seed=99)
profiles = [qds.profile(u) for u in range(24)]

def drive(use_mesh):
    ix = copy.deepcopy(index)
    eng = QueryEngine(ix, QueryConfig(k=10, beam=16, hops=3,
                                      continuous=True, slots=5, shards=2,
                                      refresh_every=4))
    # Pre-build the placement state with the requested execution mode
    # (auto-detection would pick the mesh for both on 2 devices).
    eng.plan._sharded = ShardedDescent(ix, 2, use_mesh=use_mesh,
                                       oversample=eng.qc.shard_oversample)
    inserted = []
    def mutate(engine, tick):
        if tick % 2 == 0 and len(inserted) < 9:
            inserted.append(engine.insert(ins.profile(len(inserted))))
    for rid, p in enumerate(profiles):
        eng.submit(QueryRequest(rid=rid, profile=p))
    eng.run(on_tick=mutate)
    assert eng.n_refreshes >= 1
    assert (eng.sharded_state().mesh is not None) == use_mesh
    return {r.rid: (r.ids, r.sims) for r in eng.done}

mesh_res = drive(use_mesh=True)
vmap_res = drive(use_mesh=False)
for rid in mesh_res:
    np.testing.assert_array_equal(mesh_res[rid][0], vmap_res[rid][0])
    np.testing.assert_allclose(mesh_res[rid][1], vmap_res[rid][1],
                               atol=1e-6)
print("MESH_PLAN_PARITY_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "MESH_PLAN_PARITY_OK" in r.stdout, r.stdout + r.stderr


# The hypothesis-driven arbitrary-interleaving == rebuild property lives
# in tests/test_plan_properties.py (importorskip-guarded, like the other
# *_properties files), reusing _assert_matches_rebuild above.
