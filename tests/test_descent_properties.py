"""Hypothesis property tests for the fused descent-scoring kernel:
bitwise hop parity with the jnp oracle on arbitrary well-formed inputs
(random adjacency/PAD patterns, beam widths, sketch widths spanning the
popcount→MXU boundary, degenerate rows)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.descent_score import ops as ds_ops
from repro.kernels.descent_score import ref as ds_ref
from repro.types import NEG_INF, PAD_ID


@settings(deadline=None, max_examples=40)
@given(st.data())
def test_hop_parity_on_arbitrary_inputs(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    n = data.draw(st.integers(2, 80))
    kg = data.draw(st.integers(1, 8))
    kr = data.draw(st.integers(1, 8))
    W = data.draw(st.sampled_from([1, 2, 4, 64, 65]))
    q = data.draw(st.integers(1, 20))
    B = data.draw(st.integers(1, 10))

    # Adjacency with random PAD tails (including fully-PAD rows).
    g = rng.integers(-1, n, size=(n, kg)).astype(np.int32)
    r = rng.integers(-1, n, size=(n, kr)).astype(np.int32)
    dead_rows = rng.random(n) < 0.15
    g[dead_rows] = PAD_ID
    w = (rng.integers(0, 2**32, size=(n, W), dtype=np.uint64)
         & rng.integers(0, 2**32, size=(n, W), dtype=np.uint64)
         ).astype(np.uint32)
    c = np.unpackbits(w.view(np.uint8), axis=1).sum(1).astype(np.int32)
    qw = rng.integers(0, 2**32, size=(q, W),
                      dtype=np.uint64).astype(np.uint32)
    qc = np.unpackbits(qw.view(np.uint8), axis=1).sum(1).astype(np.int32)
    zero_q = rng.random(q) < 0.2          # empty-profile queries
    qw[zero_q] = 0
    qc[zero_q] = 0

    # Beams: per-row distinct ids (the merge_topk invariant), PAD tails,
    # sim-descending, NEG_INF under PAD. Sims need not equal the
    # estimator's value — the hop must still agree bitwise.
    bi = np.full((q, B), PAD_ID, np.int32)
    for i in range(q):
        m = int(rng.integers(0, min(n, B) + 1))
        bi[i, :m] = rng.choice(n, size=m, replace=False)
    bs = np.where(bi == PAD_ID, NEG_INF,
                  -np.sort(-rng.random((q, B)))).astype(np.float32)

    args = tuple(jnp.asarray(x) for x in (g, r, w, c, qw, qc, bi, bs))
    ri, rs = ds_ref.descent_hop_ref(*args)
    ki, ks, nsc, _, _ = ds_ops.descent_hop(*args, with_counts=True)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))
    # The count never exceeds the unfused path's fixed scoring work, and
    # dead beam rows score nothing.
    nsc = np.asarray(nsc)
    assert (nsc <= B * (kg + kr)).all()
    assert (nsc[(bi == PAD_ID).all(axis=1)] == 0).all()
