import numpy as np
import pytest

from repro.data.synthetic import make_dataset
from repro.sketch.goldfinger import fingerprint_dataset


@pytest.fixture(scope="session")
def small_ds():
    return make_dataset("ml1M", scale=0.08, seed=7)


@pytest.fixture(scope="session")
def small_gf(small_ds):
    return fingerprint_dataset(small_ds, n_bits=512)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
