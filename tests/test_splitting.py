"""Recursive splitting invariants (paper §II-D)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import hashing
from repro.core.clustering import build_plan
from repro.core.params import C2Params
from repro.core.splitting import split_config
from repro.data.synthetic import make_dataset
from repro.types import dataset_from_profiles


def _cands_for(ds, seed, b, depth):
    item_h = hashing.item_hashes(ds.items, np.array([seed], np.int32), b)
    return hashing.user_distinct_hashes_np(item_h, ds.offsets, depth)[0]


def test_partition_preserves_all_users(small_ds):
    cands = _cands_for(small_ds, 0, 64, 6)
    res = split_config(cands, max_cluster=40)
    all_users = np.concatenate(res.members)
    assert len(all_users) == len(np.unique(all_users))
    valid = cands[:, 0] != hashing.NO_HASH
    assert set(all_users.tolist()) == set(np.flatnonzero(valid).tolist())


def test_split_reduces_max_cluster(small_ds):
    cands = _cands_for(small_ds, 0, 16, 6)  # tiny b → huge skew
    unsplit = split_config(cands, max_cluster=10**9)
    split = split_config(cands, max_cluster=50)
    assert split.sizes.max() <= max(50, unsplit.sizes.max() // 2) \
        or split.sizes.max() < unsplit.sizes.max()
    assert len(split.members) > len(unsplit.members)


def test_paths_are_strictly_increasing(small_ds):
    cands = _cands_for(small_ds, 1, 32, 6)
    res = split_config(cands, max_cluster=30)
    for path in res.paths:
        assert all(a < b for a, b in zip(path, path[1:]))


def test_members_match_path_semantics(small_ds):
    """Every member of a cluster with path (η₁..η_d) has exactly that
    prefix of distinct hash values."""
    cands = _cands_for(small_ds, 2, 32, 6)
    res = split_config(cands, max_cluster=30)
    for mem, path in zip(res.members, res.paths):
        d = len(path)
        for u in mem[:10]:
            seq = cands[u][cands[u] != hashing.NO_HASH]
            # The user followed this path: its first d distinct hashes start
            # with the path, OR it stayed early (exhausted / singleton).
            assert seq[0] == path[0]
            upto = min(d, len(seq))
            assert tuple(seq[:upto]) == path[:upto]


@settings(deadline=None, max_examples=10)
@given(n_users=st.integers(20, 120), b=st.sampled_from([8, 32, 128]),
       cap=st.integers(4, 60), seed=st.integers(0, 10))
def test_split_partition_property(n_users, b, cap, seed):
    rng = np.random.default_rng(seed)
    profiles = [rng.choice(500, size=rng.integers(1, 30), replace=False)
                for _ in range(n_users)]
    ds = dataset_from_profiles("x", [sorted(p) for p in profiles], 500)
    cands = _cands_for(ds, seed, b, 6)
    res = split_config(cands, max_cluster=cap)
    allu = np.concatenate(res.members) if res.members else np.array([])
    assert len(allu) == len(np.unique(allu)) == ds.n_users


def test_plan_covers_every_config(small_ds):
    p = C2Params(k=5, b=128, t=4, max_cluster=100)
    plan = build_plan(small_ds, p)
    assert plan.t == 4
    assert set(np.unique(plan.config_of)) <= set(range(4))
    # Each user appears at most once per configuration.
    for cfg in range(4):
        users = np.concatenate(
            [m for m, c in zip(plan.members, plan.config_of) if c == cfg])
        assert len(users) == len(np.unique(users))


def test_ml20M_stats_plan_scales():
    ds = make_dataset("ml10M", scale=0.02, seed=0)
    plan = build_plan(ds, C2Params(b=256, t=2, max_cluster=200))
    assert plan.brute_force_sims() < ds.n_users * (ds.n_users - 1) // 2
