"""Property-based tests for the slot scheduler (hypothesis).

Random admit/finish interleavings against ``repro.sched.SlotScheduler``:
no slot double-assignment, FIFO admission order, exactly-once
completion, and active-mask/free-list consistency at every step.

Skipped (not failed) when hypothesis isn't installed — same guard as
tests/test_properties.py.
"""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.sched import SlotScheduler  # noqa: E402

# An interleaving script: each entry is ("submit",) or ("release", j) —
# release the j-th currently-active slot (mod n_active).
_ops = st.lists(
    st.one_of(
        st.tuples(st.just("submit")),
        st.tuples(st.just("release"), st.integers(0, 63)),
    ),
    min_size=1, max_size=120,
)


@settings(max_examples=200, deadline=None)
@given(n_slots=st.integers(1, 9), ops=_ops, admit_every=st.integers(1, 4))
def test_scheduler_invariants_under_random_interleavings(n_slots, ops,
                                                         admit_every):
    sched = SlotScheduler(n_slots)
    next_id = 0
    admitted_order: list[int] = []
    completed: list[int] = []
    slot_of: dict[int, int] = {}

    for step, op in enumerate(ops):
        if op[0] == "submit":
            sched.submit(next_id)
            next_id += 1
        else:
            active = sched.active_slots
            if active:
                slot = active[op[1] % len(active)]
                item = sched.release(slot)
                completed.append(item)
                assert slot_of.pop(item) == slot
        if step % admit_every == 0:
            for slot, item in sched.admit():
                # No double assignment: the slot was free.
                assert all(s != slot for s in slot_of.values())
                slot_of[item] = slot
                admitted_order.append(item)
        sched.check_invariants()

    # Drain: admit + release everything still pending/active.
    while sched.has_work():
        for slot, item in sched.admit():
            assert all(s != slot for s in slot_of.values())
            slot_of[item] = slot
            admitted_order.append(item)
        for slot in list(sched.active_slots):
            item = sched.release(slot)
            completed.append(item)
            assert slot_of.pop(item) == slot
        sched.check_invariants()

    # FIFO admission: requests entered slots in submission order.
    assert admitted_order == sorted(admitted_order)
    # Every submitted request completed exactly once.
    assert sorted(completed) == list(range(next_id))
    assert sched.n_submitted == sched.n_completed == next_id


@settings(max_examples=100, deadline=None)
@given(n_slots=st.integers(1, 8), n_reqs=st.integers(0, 40))
def test_scheduler_active_mask_matches_occupancy(n_slots, n_reqs):
    sched = SlotScheduler(n_slots)
    for i in range(n_reqs):
        sched.submit(i)
    seen = 0
    while sched.has_work():
        admitted = sched.admit()
        mask = sched.active_mask()
        assert mask.sum() == sched.n_active == min(n_slots,
                                                   n_reqs - seen)
        for slot, _ in admitted:
            assert mask[slot]
        # Lowest-index-first reuse: the active slots are a prefix when
        # everything was admitted in one go.
        assert np.array_equal(np.flatnonzero(mask),
                              np.arange(mask.sum()))
        for slot in list(sched.active_slots):
            sched.release(slot)
            seen += 1
        sched.check_invariants()
    assert seen == n_reqs


def test_scheduler_rejects_bad_capacity():
    with pytest.raises(ValueError):
        SlotScheduler(0)


def test_release_of_free_slot_asserts():
    sched = SlotScheduler(2)
    with pytest.raises(AssertionError):
        sched.release(0)
