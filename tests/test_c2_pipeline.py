"""End-to-end C² behaviour tests (replaces the placeholder)."""
import numpy as np

from repro.core.params import C2Params, params_for
from repro.core.pipeline import cluster_and_conquer
from repro.eval.metrics import exact_avg_sim, quality, recall, recommend
from repro.knn.brute_force import brute_force_knn, n_similarities
from repro.knn.greedy import hyrec, nndescent
from repro.knn.lsh import lsh_knn
from repro.types import PAD_ID


def test_c2_quality_vs_exact(small_ds, small_gf):
    p = C2Params(k=10, b=256, t=4, max_cluster=120, n_bits=512)
    exact = brute_force_knn(small_gf, k=10)
    g, st = cluster_and_conquer(small_ds, p, gf=small_gf)
    q = quality(small_ds, g, exact)
    assert q > 0.8, q  # paper: ≥ 0.84 across datasets
    assert st.n_sims < n_similarities(small_ds.n_users)


def test_c2_graph_invariants(small_ds, small_gf):
    p = C2Params(k=8, b=256, t=3, max_cluster=120, n_bits=512)
    g, _ = cluster_and_conquer(small_ds, p, gf=small_gf)
    n = small_ds.n_users
    assert g.ids.shape == (n, 8)
    rows = np.arange(n)[:, None]
    assert not (g.ids == rows).any(), "self edges"
    # Sims sorted descending; PAD edges have -inf.
    valid = g.ids != PAD_ID
    s = np.where(valid, g.sims, -1e30)
    assert (np.diff(s, axis=1) <= 1e-6).all()
    # No duplicate neighbors per row.
    for u in range(0, n, 37):
        ids = g.ids[u][g.ids[u] != PAD_ID]
        assert len(ids) == len(set(ids.tolist()))


def test_more_hash_functions_improve_quality(small_ds, small_gf):
    """Paper Fig. 6: t trades time for quality."""
    exact = brute_force_knn(small_gf, k=10)
    qs = []
    for t in (1, 8):
        p = C2Params(k=10, b=256, t=t, max_cluster=120, n_bits=512, seed=3)
        g, _ = cluster_and_conquer(small_ds, p, gf=small_gf)
        qs.append(quality(small_ds, g, exact))
    assert qs[1] >= qs[0] - 0.01, qs


def test_hybrid_switch_uses_hyrec_for_large_clusters(small_ds, small_gf):
    # Force a giant max_cluster with a tiny ρk² so Step 2 routes via Hyrec.
    p = C2Params(k=5, b=4, t=1, max_cluster=10**6, rho=1, n_bits=512)
    assert p.bf_threshold == 25
    g, st = cluster_and_conquer(small_ds, p, gf=small_gf)
    assert st.max_cluster > p.bf_threshold
    assert (g.ids != PAD_ID).any()


def test_recommendation_recall_close_to_exact(small_ds, small_gf):
    """Paper Table III: small recall loss vs brute force."""
    from repro.data.synthetic import train_test_split

    train, test_rows = train_test_split(small_ds, 0.2, seed=1)
    from repro.sketch.goldfinger import fingerprint_dataset
    gf = fingerprint_dataset(train, n_bits=512)
    exact = brute_force_knn(gf, k=10)
    g, _ = cluster_and_conquer(train, C2Params(k=10, b=256, t=6,
                                               max_cluster=150, n_bits=512),
                               gf=gf)
    r_exact = recall(recommend(train, exact, 30), test_rows)
    r_c2 = recall(recommend(train, g, 30), test_rows)
    assert r_c2 >= r_exact - 0.08, (r_c2, r_exact)


def test_baselines_agree_on_quality(small_ds, small_gf):
    exact = brute_force_knn(small_gf, k=10)
    gh, _ = hyrec(small_gf, k=10, max_iters=10)
    gn, _ = nndescent(small_gf, k=10, max_iters=10)
    gl, _ = lsh_knn(small_ds, small_gf, k=10, t=6)
    for name, g in [("hyrec", gh), ("nnd", gn), ("lsh", gl)]:
        q = quality(small_ds, g, exact)
        assert q > 0.75, (name, q)


def test_avg_sim_monotone_in_k(small_ds, small_gf):
    """k=5 neighbors are the best 5 of k=10 → higher avg_sim."""
    g10 = brute_force_knn(small_gf, k=10)
    from repro.types import KNNGraph
    g5 = KNNGraph(ids=g10.ids[:, :5], sims=g10.sims[:, :5])
    assert exact_avg_sim(small_ds, g5) >= exact_avg_sim(small_ds, g10) - 1e-9


def test_paper_params_lookup():
    assert params_for("DBLP").t == 15
    assert params_for("ml20M").max_cluster == 4000
    assert params_for("ml10M@0.1").t == 8
    assert params_for("unknown").b == 4096
