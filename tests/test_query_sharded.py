"""Sharded query serving: shard-plan invariants, shard-count equivalence
vs single-device descent, mesh/vmap parity, and the serving CLI."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.eval.metrics import knn_recall
from repro.query.engine import QueryConfig, QueryEngine
from repro.query.index import build_index
from repro.query.router import fingerprint_profiles, profiles_to_csr
from repro.query.search import exact_knn
from repro.query.sharded import ShardedDescent, plan_shards
from repro.types import PAD_ID

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.15, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.15, seed=77)
    return [qds.profile(u) for u in range(96)]


@pytest.fixture(scope="module")
def exact(index, query_profiles):
    items, offsets = profiles_to_csr(query_profiles)
    qgf = fingerprint_profiles(items, offsets, index.n_bits, index.fp_seed)
    ids, _ = exact_knn(index.words, index.card, np.asarray(qgf.words),
                       np.asarray(qgf.card), 10)
    return ids


@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_shard_plan_invariants(index, n_shards):
    plan = plan_shards(index, n_shards)
    # Every cluster is assigned to exactly one shard.
    assert plan.cluster_shard.shape == (index.n_clusters,)
    assert ((plan.cluster_shard >= 0)
            & (plan.cluster_shard < n_shards)).all()
    # Every indexed user is resident on ≥ 1 shard, and owned by exactly
    # one shard where it is also resident (seeds must be explorable).
    covered = np.zeros(index.n, dtype=bool)
    for s, res in enumerate(plan.residents):
        covered[res] = True
        assert len(np.unique(res)) == len(res)
    assert covered.all()
    assert ((plan.owner >= 0) & (plan.owner < n_shards)).all()
    for s in range(n_shards):
        owned = np.flatnonzero(plan.owner == s)
        assert np.isin(owned, plan.residents[s]).all()
    assert plan.imbalance < 3.0


def test_owned_seeds_partition(index):
    sd = ShardedDescent(index, 3)
    seeds = np.array([[0, 5, PAD_ID, 17], [index.n - 1, 2, 3, PAD_ID]],
                     dtype=np.int32)
    l_seeds = sd.shard_seeds(seeds)
    assert l_seeds.shape == (3,) + seeds.shape
    live = l_seeds != PAD_ID
    # Each non-PAD global seed appears on exactly one shard.
    np.testing.assert_array_equal(live.sum(axis=0),
                                  (seeds != PAD_ID).astype(int))
    # And maps back to the same global id through that shard's l2g.
    l2g = np.asarray(sd._dev[4])
    for s in range(3):
        sel = live[s]
        np.testing.assert_array_equal(l2g[s][l_seeds[s][sel]], seeds[sel])


@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_sharded_equivalence(index, query_profiles, exact, n_shards):
    """The shard-count equivalence check: sharded descent must match
    single-device recall@10 (±0.01) on the same dataset and seed."""
    single = QueryEngine(index, QueryConfig(k=10))
    ids1, _ = single.query_batch(query_profiles)
    r1 = knn_recall(ids1, exact)
    sharded = QueryEngine(index, QueryConfig(k=10, shards=n_shards))
    ids_s, sims_s = sharded.query_batch(query_profiles)
    r_s = knn_recall(ids_s, exact)
    assert r_s >= r1 - 0.01, (n_shards, r_s, r1)
    # Result hygiene: valid global ids, sim-descending, no duplicates.
    valid = ids_s != PAD_ID
    assert ((ids_s >= 0) | ~valid).all() and (ids_s < index.n).all()
    assert (np.diff(np.where(valid, sims_s, -1.0), axis=1) <= 1e-6).all()
    for row in ids_s:
        live = row[row != PAD_ID]
        assert len(live) == len(set(live.tolist()))


def test_sharded_serves_inserted_users(index, query_profiles):
    """Insertion under sharded serving: the lazily-resharded state picks
    up the new user and routes queries to it."""
    import copy

    ix = copy.deepcopy(index)  # keep the module-scoped fixture pristine
    engine = QueryEngine(ix, QueryConfig(k=10, shards=2))
    profile = query_profiles[0]
    u = engine.insert(profile)
    ids, sims = engine.query_batch([profile])
    assert ids[0, 0] == u
    assert sims[0, 0] == pytest.approx(1.0)
    # The delta-resharded plan covers the appended row (on its home
    # shard and/or the shards of the clusters that registered it).
    sd = engine.sharded_state()
    assert sd.version == ix.version
    assert any(u in res for res in sd.plan.residents)


@pytest.mark.slow
def test_mesh_matches_vmap():
    """shard_map over 4 emulated devices returns exactly what the
    single-device vmap fallback returns (subprocess so the device count
    doesn't leak into this session)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine
from repro.query.index import build_index
from repro.query.sharded import ShardedDescent, plan_shards
from repro.core.local_knn import capacity_of
from repro.query.router import profiles_to_csr, fingerprint_profiles, route
from repro.types import PAD_ID

assert jax.device_count() == 4
ds = make_dataset("synth", scale=0.1, seed=3)
index = build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))
qds = make_dataset("synth", scale=0.1, seed=77)
profiles = [qds.profile(u) for u in range(32)]
items, offsets = profiles_to_csr(profiles)
qgf = fingerprint_profiles(items, offsets, index.n_bits, index.fp_seed)
seeds = route(index, items, offsets, 16)
qn = len(profiles); qcap = capacity_of(qn, minimum=8)
qw = np.zeros((qcap, np.asarray(qgf.words).shape[1]), np.uint32); qw[:qn] = qgf.words
qc = np.zeros(qcap, np.int32); qc[:qn] = qgf.card
qs = np.full((qcap, seeds.shape[1]), PAD_ID, np.int32); qs[:qn] = seeds
plan = plan_shards(index, 4)
mesh_sd = ShardedDescent(index, 4, plan=plan, use_mesh=True)
vmap_sd = ShardedDescent(index, 4, plan=plan, use_mesh=False)
assert mesh_sd.mesh is not None and vmap_sd.mesh is None
i1, s1 = mesh_sd.descend(qw, qc, qs, k=10, beam=32, hops=3)
i2, s2 = vmap_sd.descend(qw, qc, qs, k=10, beam=32, hops=3)
np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-6)
print("MESH_PARITY_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=420)
    assert "MESH_PARITY_OK" in r.stdout, r.stdout + r.stderr


def test_serve_cli_sharded_smoke(capsys):
    from repro.launch.knn_serve import main

    stats, recall = main(["--dataset", "synth", "--scale", "0.05",
                          "--queries", "16", "--shards", "2"])
    out = capsys.readouterr().out
    assert "sharded: 2 shards" in out
    assert stats["requests"] == 16 and stats["shards"] == 2
    assert recall >= 0.6  # tiny index; full-size bars live in test_query
