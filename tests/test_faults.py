"""Deterministic battery for the fault-tolerance stack (repro/faults/):
the fault plan grammar + injector schedule semantics, the per-shard
health machine (backoff sequence, caps, transient recovery), degraded
serving (seed masking, result stamping, cache exclusion), the failover
rebuild + blue/green swap, the write-ahead log + crash store (bitwise
recovery), the save/load journal-persistence fix, and the injectable
engine clock. The hypothesis batteries live in
tests/test_faults_properties.py.
"""
import copy

import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.faults import (CrashStore, EngineCrash, FaultInjector, FaultPlan,
                          FleetHealth, HealthConfig, WriteAheadLog, replay)
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import _ROWS, build_index
from repro.query.router import fingerprint_profiles, profiles_to_csr, route
from repro.query.sharded import ShardedDescent
from repro.sched import ManualClock
from repro.types import PAD_ID


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.1, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.1, seed=77)
    return [qds.profile(u) for u in range(32)]


@pytest.fixture(scope="module")
def insert_profiles():
    ids = make_dataset("synth", scale=0.1, seed=99)
    return [ids.profile(u) for u in range(32)]


def _serve(engine, profiles):
    for rid, p in enumerate(profiles):
        engine.submit(QueryRequest(rid=rid, profile=p))
    engine.run()
    return {r.rid: (np.asarray(r.ids), np.asarray(r.sims))
            for r in engine.done[-len(profiles):]}


def _assert_same(a, b, msg=""):
    assert set(a) == set(b)
    for rid in a:
        np.testing.assert_array_equal(a[rid][0], b[rid][0],
                                      err_msg=f"{msg} ids rid={rid}")
        np.testing.assert_array_equal(a[rid][1], b[rid][1],
                                      err_msg=f"{msg} sims rid={rid}")


# -- fault plan grammar ----------------------------------------------------

def test_fault_plan_parse_roundtrip():
    spec = "kill:1@4;fail:0@2+3;slow:2@5+2:1.5;crash@9"
    plan = FaultPlan.parse(spec)
    kinds = sorted(e.kind for e in plan.events)
    assert kinds == ["crash", "fail", "kill", "slow"]
    # describe() re-parses to the same schedule (canonical order).
    assert FaultPlan.parse(plan.describe()) == plan
    slow = next(e for e in plan.events if e.kind == "slow")
    assert slow.latency_s == pytest.approx(1.5e-3)
    assert slow.duration == 2


@pytest.mark.parametrize("bad", [
    "kill:1", "fail:0@2", "slow:1@2+3", "crash@x", "boom:0@1", "kill:@3"])
def test_fault_plan_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_fault_plan_random_is_seeded():
    a = FaultPlan.random(4, 20, seed=11)
    b = FaultPlan.random(4, 20, seed=11)
    c = FaultPlan.random(4, 20, seed=12)
    assert a == b
    assert a != c


# -- injector schedule semantics -------------------------------------------

def test_injector_schedule_windows():
    inj = FaultInjector(FaultPlan.parse("kill:0@2;fail:1@1+2"))
    down = []
    for _ in range(5):
        inj.begin_step()
        down.append((inj.shard_down(0), inj.shard_down(1)))
    # kill: permanent from step 2; fail: steps 1-2 only.
    assert down == [(False, False), (False, True), (True, True),
                    (True, False), (True, False)]
    inj.clear_shard(0)  # failover cleared the fired kill
    assert not inj.shard_down(0)


def test_injector_crash_and_arm():
    inj = FaultInjector(FaultPlan.parse("crash@1"), armed=False)
    for _ in range(5):
        inj.begin_step()  # disarmed: nothing fires, step stays frozen
    assert inj.step == -1
    inj.arm()
    inj.begin_step()  # step 0
    with pytest.raises(EngineCrash):
        inj.begin_step()  # step 1
    assert inj.n_crashes == 1


def test_injector_slow_advances_manual_clock():
    clock = ManualClock()
    inj = FaultInjector(FaultPlan.parse("slow:0@1+2:250"), clock=clock)
    t = [clock()]
    for _ in range(4):
        inj.begin_step()
        t.append(clock())
    # 250ms injected at steps 1 and 2, nothing elsewhere — and no
    # real time.sleep anywhere in this test.
    deltas = np.diff(t)
    np.testing.assert_allclose(deltas, [0.0, 0.25, 0.25, 0.0])
    assert inj.n_slow_steps == 2
    assert inj.injected_latency_s == pytest.approx(0.5)


# -- health machine --------------------------------------------------------

def test_health_backoff_sequence_to_death():
    cfg = HealthConfig(max_retries=3, backoff_cap=8, recover_after=4)
    h = FleetHealth(1, cfg)
    h.observe([False])          # step 0: healthy
    assert h.state[0] == "healthy"
    h.observe([True])           # step 1: first failure -> suspect
    assert h.state[0] == "suspect"
    # Re-probes land at steps 2 (backoff 1), 4 (backoff 2), 8
    # (backoff 4); each failure doubles the backoff; the third failed
    # re-probe is the max_retries-th -> dead.
    transitions = {}
    for step in range(2, 9):
        h.observe([True])
        transitions[step] = (h.state[0], int(h.retries[0]))
    assert transitions[2] == ("suspect", 1)
    assert transitions[3] == ("suspect", 1)   # waiting out backoff 2
    assert transitions[4] == ("suspect", 2)
    assert transitions[7] == ("suspect", 2)   # waiting out backoff 4
    assert transitions[8] == ("dead", 3)
    assert h.dead_since[0] == 8
    assert h.n_deaths == 1
    assert h.backoff_steps > 0
    # Dead shards wait out the grace period before recovery.
    assert h.ready_for_recovery() == []
    for _ in range(cfg.recover_after):
        h.observe([True])
    assert h.ready_for_recovery() == [0]


def test_health_backoff_is_capped():
    cfg = HealthConfig(max_retries=50, backoff_cap=4, recover_after=4)
    h = FleetHealth(1, cfg)
    h.observe([True])
    for _ in range(40):
        h.observe([True])
    assert int(h.backoff[0]) == 4  # never exceeds the cap
    assert h.state[0] == "suspect"


def test_health_transient_failure_recovers_without_failover():
    h = FleetHealth(2, HealthConfig(max_retries=3))
    h.observe([False, True])    # shard 1 suspect
    assert h.serving_mask().tolist() == [False, True]
    h.observe([False, False])   # re-probe succeeds -> healthy again
    assert h.state[1] == "healthy"
    assert h.serving_mask().tolist() == [False, False]
    assert h.n_deaths == 0


# -- degraded serving ------------------------------------------------------

def test_masked_seed_descent_parity(index, query_profiles):
    """Killing a shard == never seeding it: descend with the dead mask
    matches descend on a healthy fleet whose seeds were pre-filtered to
    drop the dead shard's owned basins."""
    items, offsets = profiles_to_csr(query_profiles)
    qgf = fingerprint_profiles(items, offsets, index.n_bits, index.fp_seed)
    seeds = route(index, items, offsets, 16)
    qw = np.asarray(qgf.words)
    qc = np.asarray(qgf.card)

    sd_dead = ShardedDescent(index, 2)
    sd_dead.set_dead([False, True])
    i1, s1 = sd_dead.descend(qw, qc, seeds, k=10, beam=32, hops=3)

    sd_ok = ShardedDescent(index, 2)
    owner = sd_ok.plan.owner
    safe = np.where(seeds == PAD_ID, 0, seeds)
    filtered = np.where((seeds != PAD_ID) & (owner[safe] == 1),
                        PAD_ID, seeds).astype(np.int32)
    i2, s2 = sd_ok.descend(qw, qc, filtered, k=10, beam=32, hops=3)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_degraded_serving_keeps_answering(index, query_profiles):
    """1 of 2 shards dead: every request is still served (stamped
    degraded), no dead-only id appears, and recall stays bounded."""
    ix = copy.deepcopy(index)
    inj = FaultInjector(FaultPlan.parse("kill:1@0"),
                        health=HealthConfig(max_retries=1, backoff_cap=1,
                                            recover_after=10**6))
    eng = QueryEngine(ix, QueryConfig(k=10, shards=2, max_wave=16),
                      clock=ManualClock(), faults=inj)
    res = _serve(eng, query_profiles)
    assert len(res) == len(query_profiles)
    recent = eng.done[-len(query_profiles):]
    assert all(r.status == "done" for r in recent)
    assert sum(r.degraded for r in recent) > 0
    assert eng.degraded
    stats = eng.failover.stats()
    assert stats["shards_down"] == 1
    deg = [r for r in recent if r.degraded]
    assert eng.recall_vs_brute_force(deg) >= 0.2  # bounded, not zero
    # Deterministic: an identical run serves identical degraded answers.
    eng2 = QueryEngine(copy.deepcopy(index),
                       QueryConfig(k=10, shards=2, max_wave=16),
                       clock=ManualClock(),
                       faults=FaultInjector(
                           FaultPlan.parse("kill:1@0"),
                           health=HealthConfig(max_retries=1, backoff_cap=1,
                                               recover_after=10**6)))
    _assert_same(res, _serve(eng2, query_profiles), "degraded determinism")


def test_degraded_results_never_cached(index, query_profiles):
    ix = copy.deepcopy(index)
    inj = FaultInjector(FaultPlan.parse("kill:1@0"),
                        health=HealthConfig(recover_after=10**6))
    eng = QueryEngine(ix, QueryConfig(k=10, shards=2, max_wave=16, cache=32),
                      clock=ManualClock(), faults=inj)
    _serve(eng, query_profiles[:8])
    _serve(eng, query_profiles[:8])  # exact repeats: would hit if cached
    cache = eng.plan.cache
    assert len(cache) == 0
    assert cache.degraded_skips > 0
    assert cache.hits == 0


def test_maintenance_defers_while_degraded(index, query_profiles):
    """Lifecycle TTL/repair and the re-balancer both stand down while a
    shard is masked out — degraded descents must not be baked into the
    graph."""
    ix = copy.deepcopy(index)
    inj = FaultInjector(FaultPlan.parse("kill:1@0"),
                        health=HealthConfig(recover_after=10**6))
    eng = QueryEngine(ix, QueryConfig(k=10, shards=2, max_wave=16, ttl=1,
                                      rebalance_every=1),
                      clock=ManualClock(), faults=inj)
    _serve(eng, query_profiles[:8])
    assert eng.degraded
    out = eng.lifecycle.maintain()
    assert out.get("deferred") and out["expired"] == 0
    assert eng.lifecycle.n_expired == 0  # TTL=1 would expire rows if live
    assert eng.rebalance.n_deferred > 0
    assert eng.rebalance.n_swaps == 0


# -- failover rebuild + swap -----------------------------------------------

def test_failover_swaps_once_and_restores_answers(index, query_profiles):
    ix = copy.deepcopy(index)
    inj = FaultInjector(FaultPlan.parse("kill:1@1"), armed=False,
                        health=HealthConfig(max_retries=2, backoff_cap=2,
                                            recover_after=3))
    eng = QueryEngine(ix, QueryConfig(k=10, shards=2, max_wave=16, cache=32),
                      clock=ManualClock(), faults=inj)
    pre = _serve(eng, query_profiles)
    flushes0 = eng.plan.cache.flushes
    inj.arm()
    _serve(eng, query_profiles)           # the kill lands mid-window
    for _ in range(24):                   # idle steps: dead -> recovered
        eng.step()
    assert eng.failover.n_failovers == 1
    assert eng.failover.health.state == ["healthy", "healthy"]
    assert not eng.degraded
    sd = eng.sharded_state()
    assert sd.generation == 1             # exactly one blue/green swap
    assert not sd.dead.any()
    assert eng.plan.cache.flushes > flushes0   # swap flushed the cache
    assert eng.failover.recovery_steps         # dwell was recorded
    assert eng.failover.last_merge_stats["excluded"] == [1]
    # Post-recovery answers are bitwise what the healthy fleet served.
    _assert_same(pre, _serve(eng, query_profiles), "post-failover")


# -- WAL + crash store -----------------------------------------------------

def test_wal_replay_is_bitwise(index, insert_profiles, tmp_path):
    ix_live = copy.deepcopy(index)
    ix_rec = copy.deepcopy(index)
    wal = WriteAheadLog(tmp_path / "wal.jsonl", append=False)
    ix_live.attach_wal(wal)
    eng = QueryEngine(ix_live, QueryConfig(k=10, refresh_every=8))
    for p in insert_profiles[:10]:  # crosses a cohort refresh at 8
        eng.insert(p)
    eng.remove_user(3)
    eng.update_user(7, insert_profiles[10])
    eng.touch(11)
    ix_live.detach_wal()
    replay(ix_rec, WriteAheadLog.read(tmp_path / "wal.jsonl"))
    assert ix_rec.version == ix_live.version
    for name in _ROWS:
        np.testing.assert_array_equal(getattr(ix_rec, name),
                                      getattr(ix_live, name), err_msg=name)
    ix_live.consolidate(), ix_rec.consolidate()
    for name in ("cluster_members", "cluster_offsets", "cluster_paths",
                 "cluster_config"):
        np.testing.assert_array_equal(getattr(ix_rec, name),
                                      getattr(ix_live, name), err_msg=name)


def test_crash_store_recovers_engine_bitwise(index, insert_profiles,
                                             query_profiles, tmp_path):
    """Crash mid-stream, recover from snapshot + WAL: index tensors AND
    served answers match a never-crashed mirror driven identically."""
    qc = QueryConfig(k=10, shards=2, max_wave=16)
    store = CrashStore(tmp_path / "store", every=3)
    eng = QueryEngine(copy.deepcopy(index), qc, clock=ManualClock(),
                      faults=FaultInjector(FaultPlan.parse("crash@5")),
                      store=store)
    mirror = QueryEngine(copy.deepcopy(index), qc, clock=ManualClock())
    crashed = False
    for t in range(10):
        for e in (eng, mirror):
            e.insert(insert_profiles[t])
            if t % 3 == 2:
                e.remove_user(10 * t)
        try:
            eng.step()
        except EngineCrash:
            crashed = True
            break
        mirror.step()
    assert crashed
    mirror.step()  # the mirror runs the step the crash pre-empted
    rec = QueryEngine.recover(tmp_path / "store", qc, clock=ManualClock())
    assert rec.index.version == mirror.index.version
    for name in _ROWS:
        np.testing.assert_array_equal(getattr(rec.index, name),
                                      getattr(mirror.index, name),
                                      err_msg=name)
    _assert_same(_serve(rec, query_profiles),
                 _serve(mirror, query_profiles), "post-recovery answers")


def test_crash_store_compaction_bounds_wal(index, insert_profiles,
                                           tmp_path):
    store = CrashStore(tmp_path / "store", every=2)
    eng = QueryEngine(copy.deepcopy(index), QueryConfig(k=10, max_wave=16),
                      clock=ManualClock(), store=store)
    for t in range(9):
        eng.insert(insert_profiles[t])
        eng.step()
    # Snapshots fired on cadence; the LIVE wal only holds the suffix
    # since the last one (about one insert's records), not the whole
    # mutation history.
    assert store.n_snapshots >= 4
    wals = sorted((tmp_path / "store").glob("wal_*.jsonl"))
    assert len(wals) == store.n_snapshots
    total = sum(len(WriteAheadLog.read(w)) for w in wals)
    assert 0 < store.wal.n_records <= total / 2


# -- satellite: save/load persists journal state ---------------------------

def test_save_load_persists_journals(index, insert_profiles, tmp_path):
    """A saved+loaded index continues the mutate/delta-sync trajectory
    bitwise-equal to the unsaved one — the journals (row / member /
    tombstone logs) now survive persistence, so the loaded side delta-
    syncs instead of silently full-rebuilding (or worse, missing
    rows)."""
    ix_a = copy.deepcopy(index)
    eng_a = QueryEngine(ix_a, QueryConfig(k=10))
    for p in insert_profiles[:4]:
        eng_a.insert(p)
    eng_a.remove_user(5)

    ix_a.save(tmp_path / "ix.npz")
    from repro.query.index import KNNIndex
    ix_b = KNNIndex.load(tmp_path / "ix.npz")
    assert ix_b.version == ix_a.version
    assert ix_b.rows_changed_since(0) == ix_a.rows_changed_since(0)
    assert ix_b.tombstones_since(0) == ix_a.tombstones_since(0)
    assert ix_b.members_added_since(0) == ix_a.members_added_since(0)

    # Same sharded plan, same further mutations: the two delta syncs
    # must land on bitwise-identical device tensors.
    sd_a = ShardedDescent(ix_a, 2)
    sd_b = ShardedDescent(ix_b, 2)
    eng_b = QueryEngine(ix_b, QueryConfig(k=10))
    for p in insert_profiles[4:8]:
        eng_a.insert(p)
        eng_b.insert(p)
    eng_a.remove_user(9), eng_b.remove_user(9)
    assert sd_a.sync() == "delta"
    assert sd_b.sync() == "delta"
    for a, b in zip(sd_a._dev, sd_b._dev):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- satellite: injectable clock -------------------------------------------

def test_manual_clock_contract():
    clock = ManualClock(start=5.0)
    assert clock() == 5.0
    clock.advance(0.25)
    assert clock() == 5.25
    clock.sleep(0.75)  # sleep == advance: no real time passes
    assert clock() == 6.0
    with pytest.raises(ValueError):
        clock.advance(-1.0)


def test_engine_latencies_deterministic_under_manual_clock(index,
                                                           query_profiles):
    def run():
        # start > 0: QueryRequest.latency treats t_submit == 0.0 as
        # "never submitted", so the epoch must not be exactly zero.
        eng = QueryEngine(copy.deepcopy(index),
                          QueryConfig(k=10, continuous=True, slots=8),
                          clock=ManualClock(start=1.0))
        for rid, p in enumerate(query_profiles[:16]):
            eng.submit(QueryRequest(rid=rid, profile=p))
            eng.clock.advance(0.001)
        eng.run()
        return [r.latency for r in eng.done[-16:]]

    a, b = run(), run()
    assert a == b  # bitwise-equal latencies: zero wall-clock in the loop
    assert all(lat is not None and lat >= 0 for lat in a)
