"""Fused descent-scoring kernel vs the jnp oracle (interpret mode).

The contract under test is *bitwise* equality of (ids, sims) with
``kernels/descent_score/ref.descent_hop_ref`` — the historical
``descent_step`` body — across PAD patterns, beam widths, degenerate
rows, and both estimator layouts (VPU popcount and the wide-sketch MXU
bit-plane variant), plus the end-to-end serving paths behind
``QueryConfig(kernel=True)`` and the compile-shape regressions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.kernels.descent_score import ops as ds_ops
from repro.kernels.descent_score import ref as ds_ref
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.query.search import exact_knn
from repro.sched import trace
from repro.types import NEG_INF, PAD_ID


def _random_words(rng, n, W):
    w = rng.integers(0, 2**32, size=(n, W), dtype=np.uint64)
    w = (w & rng.integers(0, 2**32, size=(n, W), dtype=np.uint64))
    w = w.astype(np.uint32)
    card = np.unpackbits(w.view(np.uint8), axis=1).sum(1).astype(np.int32)
    return w, card


def _random_hop_inputs(rng, n, kg, kr, W, q, B, *, pad_frac=0.2):
    """Well-formed hop inputs: adjacency with PAD tails, beams with
    distinct ids (the merge_topk invariant every real beam satisfies),
    sim-descending with NEG_INF under PAD."""
    g = rng.integers(-1, n, size=(n, kg)).astype(np.int32)
    r = rng.integers(-1, n, size=(n, kr)).astype(np.int32)
    w, c = _random_words(rng, n, W)
    qw, qc = _random_words(rng, q, W)
    bi = np.full((q, B), PAD_ID, np.int32)
    for i in range(q):
        m = int(rng.integers(0, min(n, B) + 1))
        if rng.random() < pad_frac:
            m = 0  # fully-dead row (e.g. an unadmitted slot)
        bi[i, :m] = rng.choice(n, size=m, replace=False)
    bs = np.where(bi == PAD_ID, NEG_INF,
                  -np.sort(-rng.random((q, B)))).astype(np.float32)
    return tuple(jnp.asarray(x)
                 for x in (g, r, w, c, qw, qc, bi, bs))


def _assert_hop_parity(args):
    ri, rs = ds_ref.descent_hop_ref(*args)
    ki, ks = ds_ops.descent_hop(*args)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


@pytest.mark.parametrize("n,q,B", [(60, 5, 4), (200, 33, 16),
                                   (128, 64, 8), (50, 1, 1)])
@pytest.mark.parametrize("kg,kr", [(6, 9), (10, 16), (3, 1)])
def test_hop_matches_ref_shapes(n, q, B, kg, kr):
    rng = np.random.default_rng(n * 1000 + q + B + kg + kr)
    _assert_hop_parity(_random_hop_inputs(rng, n, kg, kr, 4, q, B))


@pytest.mark.parametrize("W", [1, 32, 64, 80])
def test_hop_matches_ref_sketch_widths(W):
    """Crosses the MXU_MIN_WORDS boundary: W≥64 scores through the int8
    bit-plane matmul, below it the VPU popcount — identical bits."""
    rng = np.random.default_rng(W)
    _assert_hop_parity(_random_hop_inputs(rng, 90, 5, 7, W, 17, 6))


def test_hop_degenerate_rows():
    """All-PAD beams, empty-adjacency rows, zero-cardinality sketches."""
    rng = np.random.default_rng(11)
    g, r, w, c, qw, qc, bi, bs = _random_hop_inputs(
        rng, 40, 4, 5, 4, 12, 5)
    g = g.at[:10].set(PAD_ID)            # rows with no forward edges
    r = r.at[5:15].set(PAD_ID)
    w = w.at[3].set(0)                   # empty-profile fingerprint
    c = c.at[3].set(0)
    bi = bi.at[0].set(PAD_ID)            # dead query rows
    bs = bs.at[0].set(NEG_INF)
    qw = qw.at[1].set(0)
    qc = qc.at[1].set(0)
    _assert_hop_parity((g, r, w, c, qw, qc, bi, bs))


def test_hop_counts_bounded_and_reduced():
    """n_scored counts exactly the lanes surviving PAD / dead-beam-row /
    already-in-beam suppression — and on a graph with mutual edges the
    reduction vs the unfused beam·(kg+kr) is real."""
    rng = np.random.default_rng(2)
    n, kg, kr, B = 64, 8, 8, 12
    # Ring-ish mutual adjacency: heavy friend-of-a-friend duplication.
    g = np.stack([(np.arange(n) + j + 1) % n for j in range(kg)],
                 axis=1).astype(np.int32)
    r = np.stack([(np.arange(n) - j - 1) % n for j in range(kr)],
                 axis=1).astype(np.int32)
    w, c = _random_words(rng, n, 4)
    qw, qc = _random_words(rng, 9, 4)
    bi = np.stack([np.arange(i, i + B) % n for i in range(9)]).astype(np.int32)
    bs = -np.sort(-rng.random((9, B))).astype(np.float32)
    args = tuple(jnp.asarray(x) for x in (g, r, w, c, qw, qc, bi, bs))
    ki, ks, nsc, _, _ = ds_ops.descent_hop(*args, with_counts=True)
    nsc = np.asarray(nsc)
    total = B * (kg + kr)
    # Host-side truth: lanes not PAD and not already in the beam.
    cand = np.concatenate([g[bi].reshape(9, -1), r[bi].reshape(9, -1)], 1)
    live = (cand != PAD_ID) & ~(cand[:, :, None] == bi[:, None, :]).any(-1)
    np.testing.assert_array_equal(nsc, live.sum(1))
    assert (nsc <= total).all()
    # Contiguous beams on a ring re-meet constantly: the dedup must bite.
    assert nsc.mean() < 0.75 * total
    _assert_hop_parity(args)


def test_hop_wide_block_padding():
    """q not a multiple of block_q exercises the row-padding path."""
    rng = np.random.default_rng(3)
    args = _random_hop_inputs(rng, 70, 4, 6, 4, 7, 5)
    ki, ks = ds_ops.descent_hop(*args, block_q=4)
    ri, rs = ds_ref.descent_hop_ref(*args)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


# -- end-to-end serving parity (QueryConfig(kernel=True)) ------------------

@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.08, seed=13)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=40))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.08, seed=14)
    return [qds.profile(u) for u in range(24)]


def _serve(index, profiles, **kw):
    eng = QueryEngine(index, QueryConfig(k=8, beam=12, hops=3,
                                         max_wave=32, **kw))
    for rid, p in enumerate(profiles):
        eng.submit(QueryRequest(rid=rid, profile=p))
    eng.run()
    return {r.rid: (r.ids, r.sims) for r in eng.done}


def test_wave_serving_kernel_matches_jnp(index, query_profiles):
    ref = _serve(index, query_profiles)
    got = _serve(index, query_profiles, kernel=True)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid][0], got[rid][0],
                                      err_msg=f"ids rid={rid}")
        np.testing.assert_array_equal(ref[rid][1], got[rid][1],
                                      err_msg=f"sims rid={rid}")


def test_sharded_serving_kernel_matches_jnp(index, query_profiles):
    """vmapped-over-shards composition of the pallas hop (the CPU/CI
    sharded execution) is bitwise-identical to the jnp sharded path."""
    ref = _serve(index, query_profiles, shards=2)
    got = _serve(index, query_profiles, shards=2, kernel=True)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid][0], got[rid][0])
        np.testing.assert_array_equal(ref[rid][1], got[rid][1])


# -- compile-shape regressions ---------------------------------------------

def test_exact_knn_partial_block_compiles_one_shape():
    """exact_knn pads the final partial query block up to ``block``: one
    _exact_block shape per (index rows, block, k), regardless of how
    many queries each call brings."""
    rng = np.random.default_rng(5)
    n = 123  # unique row count → trace keys not shared with other tests
    w, c = _random_words(rng, n, 4)
    k = 7

    def shapes():
        return {key for key in trace.counts("exact_block")
                if key[1] == n and key[3] == k}

    base = shapes()
    for q in (8, 40, 300, 256, 1):   # partials, exact multiple, tiny
        qw, qc = _random_words(rng, q, 4)
        ids, sims = exact_knn(w, c, qw, qc, k)
        assert ids.shape == (q, k)
        assert (ids[:, 0] != PAD_ID).all()
    new = shapes() - base
    assert len(new) == 1, new            # exactly one block shape ever
    assert next(iter(new))[2] == 256     # ...the full block


def test_exact_knn_results_unaffected_by_padding():
    rng = np.random.default_rng(6)
    w, c = _random_words(rng, 123, 4)
    qw, qc = _random_words(rng, 40, 4)
    ids_a, sims_a = exact_knn(w, c, qw, qc, 5, block=16)
    ids_b, sims_b = exact_knn(w, c, qw, qc, 5, block=256)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sims_a, sims_b)
