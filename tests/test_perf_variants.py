"""§Perf optimization variants must be semantically equivalent to their
baselines — these tests pin that down."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.models import layers as L
from repro.models.config import ModelConfig, scaled_down
from repro.models.layers import ShardCtx
from repro.models.model import init_params
from repro.train.steps import loss_fn

CTX = ShardCtx()


def test_distinct_hashes_reduceat_matches_lexsort_oracle(small_ds):
    seeds = np.arange(6, dtype=np.int32)
    ih = hashing.item_hashes(small_ds.items, seeds, 256)
    fast = hashing.user_distinct_hashes_np(ih, small_ds.offsets, 5)
    ref = hashing.user_distinct_hashes_np_ref(ih, small_ds.offsets, 5)
    np.testing.assert_array_equal(fast, ref)


def test_chunkwise_mlstm_matches_sequential():
    cfg0 = ModelConfig(name="x", family="ssm", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=128,
                       head_dim=16, block_pattern=(("mlstm",),))
    cfg1 = dataclasses.replace(cfg0, mlstm_chunk=16)
    p = L.init_mlstm(jax.random.key(0), cfg0)
    x = jax.random.normal(jax.random.key(1), (2, 64, 64)).astype(jnp.bfloat16)
    y0, _ = jax.jit(lambda p, x: L.apply_mlstm(p, x, cfg0, CTX))(p, x)
    y1, _ = jax.jit(lambda p, x: L.apply_mlstm(p, x, cfg1, CTX))(p, x)
    rel = (float(jnp.max(jnp.abs(y0.astype(jnp.float32)
                                 - y1.astype(jnp.float32))))
           / float(jnp.max(jnp.abs(y0.astype(jnp.float32)))))
    assert rel < 0.02, rel
    _, c0 = jax.jit(lambda p, x: L.apply_mlstm(
        p, x, cfg0, CTX, want_cache=True))(p, x)
    _, c1 = jax.jit(lambda p, x: L.apply_mlstm(
        p, x, cfg1, CTX, want_cache=True))(p, x)
    np.testing.assert_allclose(np.asarray(c0["C"]), np.asarray(c1["C"]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(c0["n"]), np.asarray(c1["n"]),
                               atol=1e-4)


def test_chunked_loss_matches_unchunked():
    from repro.configs import get_config

    cfg = scaled_down(get_config("llama3_2-1b"))
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, CTX, True, 0))(
        params, batch)
    l1, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, CTX, True, 8))(
        params, batch)
    np.testing.assert_allclose(float(l0), float(l1), atol=1e-4)


def test_save_tp_remat_policy_matches_full():
    """remat='save_tp' must not change gradients (only what's recomputed)."""
    from repro.configs import get_config
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.steps import train_step

    cfg = scaled_down(get_config("gemma-2b"))
    params = init_params(jax.random.key(0), cfg)
    oc = OptConfig()
    opt = init_opt_state(params, oc)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    _, _, m0 = jax.jit(lambda p, o, b: train_step(
        p, o, b, cfg, CTX, oc, remat=True))(params, opt, batch)
    _, _, m1 = jax.jit(lambda p, o, b: train_step(
        p, o, b, cfg, CTX, oc, remat="save_tp"))(params, opt, batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               atol=1e-5)


def test_incidence_fingerprint_is_exact_jaccard(small_ds):
    from repro.sketch.exact import edge_jaccard
    from repro.sketch.goldfinger import incidence_fingerprint, \
        jaccard_pairwise

    gf = incidence_fingerprint(small_ds)
    w = jnp.asarray(gf.words[:24])
    c = jnp.asarray(gf.card[:24])
    sims = np.asarray(jaccard_pairwise(w, c, w, c))
    src = np.repeat(np.arange(24, dtype=np.int32), 24)
    dst = np.tile(np.arange(24, dtype=np.int32), 24)
    ref = edge_jaccard(small_ds, src, dst).reshape(24, 24)
    np.testing.assert_allclose(sims, ref, atol=1e-6)
