"""Hypothesis battery for the fault-tolerance stack: (a) a crash at ANY
step of ANY mutation schedule, under any plan shape (1|2 shards ×
wave|continuous), recovers via snapshot + WAL replay to an index that
is bitwise-equal — tensors, consolidated cluster tables, and served
answers — to a never-crashed engine driven identically; (b) any
kill/recover interleaving under serving never returns a user removed
before the request was submitted, keeps serving through the degraded
window, and converges back to healthy (the post-recovery fleet answers
bitwise what a fresh engine on the same index answers).
tests/test_faults.py carries the deterministic battery."""
import copy
import tempfile

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.faults import (CrashStore, EngineCrash, FaultInjector, FaultPlan,
                          HealthConfig)
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import _ROWS
from repro.sched import ManualClock


@pytest.fixture(scope="module")
def small_index():
    from repro.query.index import build_index

    ds = make_dataset("synth", scale=0.05, seed=5)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=32))


@pytest.fixture(scope="module")
def profiles():
    qds = make_dataset("synth", scale=0.05, seed=7)
    return [qds.profile(u) for u in range(40)]


def _schedule(ops_seed: int, n_steps: int):
    """A deterministic per-step mutation schedule: same seed ⇒ same ops
    applied to every engine under comparison."""
    rng = np.random.default_rng(ops_seed)
    sched = []
    for _ in range(n_steps):
        ops = []
        if rng.random() < 0.7:
            ops.append(("insert", int(rng.integers(8, 40))))
        if rng.random() < 0.3:
            ops.append(("remove", int(rng.integers(0, 100))))
        if rng.random() < 0.2:
            ops.append(("touch", int(rng.integers(100, 180))))
        sched.append(ops)
    return sched


def _apply(eng, ops, profiles, removed):
    for op, a in ops:
        if op == "insert":
            eng.insert(profiles[a])
        elif op == "remove":
            if a not in removed and not eng.index.tombstone[a]:
                eng.remove_user(a)
            removed.add(a)
        elif op == "touch":
            if not eng.index.tombstone[a]:
                eng.touch(a)


def _wave(eng, profiles, n=8):
    base = len(eng.done)
    for rid, p in enumerate(profiles[:n]):
        eng.submit(QueryRequest(rid=rid, profile=p))
    eng.run()
    return [(np.asarray(r.ids), np.asarray(r.sims))
            for r in eng.done[base:]]


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(crash_step=st.integers(min_value=1, max_value=9),
       shards=st.integers(min_value=1, max_value=2),
       continuous=st.booleans(),
       ops_seed=st.integers(min_value=0, max_value=10**6))
def test_any_crash_point_recovers_bitwise(small_index, profiles, crash_step,
                                          shards, continuous, ops_seed):
    """Crash at any step of any schedule under any plan shape: snapshot
    + WAL replay lands bitwise where the never-crashed mirror is."""
    qc = QueryConfig(k=8, beam=12, hops=2, shards=shards,
                     continuous=continuous, slots=8, max_wave=8,
                     refresh_every=6)
    sched = _schedule(ops_seed, 12)
    tmp = tempfile.mkdtemp()
    eng = QueryEngine(copy.deepcopy(small_index), qc, clock=ManualClock(),
                      faults=FaultInjector(
                          FaultPlan((FaultPlan.parse(
                              f"crash@{crash_step}").events))),
                      store=CrashStore(tmp, every=3))
    mirror = QueryEngine(copy.deepcopy(small_index), qc, clock=ManualClock())
    rA, rB = set(), set()
    crashed = False
    for ops in sched:
        _apply(eng, ops, profiles, rA)
        try:
            eng.step()
        except EngineCrash:
            crashed = True
            break
        _apply(mirror, ops, profiles, rB)
        mirror.step()
    assert crashed  # crash_step <= len(sched) guarantees it fired
    # The crash pre-empted the step AFTER eng applied its ops: the
    # mirror applies the same ops and runs the step the crash ate.
    _apply(mirror, sched[eng.faults.step], profiles, rB)
    mirror.step()

    rec = QueryEngine.recover(tmp, qc, clock=ManualClock())
    assert rec.index.version == mirror.index.version
    for name in _ROWS:
        np.testing.assert_array_equal(getattr(rec.index, name),
                                      getattr(mirror.index, name),
                                      err_msg=name)
    rec.index.consolidate(), mirror.index.consolidate()
    for name in ("cluster_members", "cluster_offsets", "cluster_paths",
                 "cluster_config"):
        np.testing.assert_array_equal(getattr(rec.index, name),
                                      getattr(mirror.index, name),
                                      err_msg=name)
    # Served answers, not just tensors: a fresh wave answers bitwise
    # the same on both (the mirror's leftover in-flight slots are
    # independent of fresh submissions).
    for (ia, sa), (ib, sb) in zip(_wave(rec, profiles),
                                  _wave(mirror, profiles)):
        np.testing.assert_array_equal(ia, ib)
        np.testing.assert_array_equal(sa, sb)


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(kill_step=st.integers(min_value=0, max_value=6),
       kill_shard=st.integers(min_value=0, max_value=1),
       ops_seed=st.integers(min_value=0, max_value=10**6),
       continuous=st.booleans())
def test_any_kill_recover_interleaving_serves_and_converges(
        small_index, profiles, kill_step, kill_shard, ops_seed, continuous):
    """Kill either shard at any step with removes interleaved: every
    request completes, no result names a user removed before it was
    submitted, the fleet converges back to healthy, and post-recovery
    answers equal a fresh engine's on the same index."""
    qc = QueryConfig(k=8, beam=12, hops=2, shards=2,
                     continuous=continuous, slots=8, max_wave=8)
    inj = FaultInjector(
        FaultPlan.parse(f"kill:{kill_shard}@{kill_step}"),
        health=HealthConfig(max_retries=1, backoff_cap=1, recover_after=2))
    eng = QueryEngine(copy.deepcopy(small_index), qc, clock=ManualClock(),
                      faults=inj)
    rng = np.random.default_rng(ops_seed)
    removed: set[int] = set()
    for t in range(10):
        removed_at_submit = set(removed)
        base = len(eng.done)
        for rid, p in enumerate(profiles[t:t + 4]):
            eng.submit(QueryRequest(rid=1000 * t + rid, profile=p))
        if rng.random() < 0.4:
            a = int(rng.integers(0, 100))
            if not eng.index.tombstone[a]:
                eng.remove_user(a)
                removed.add(a)
        eng.run()  # drain: every submitted request completes
        for r in eng.done[base:]:
            assert r.status == "done"
            served = set(int(i) for i in r.ids if i >= 0)
            # Nothing removed BEFORE submission is ever served (later
            # removes may race a result legally).
            assert not (served & removed_at_submit), (t, r.rid)
    # Idle steps let the health machine walk dead -> recovered.
    for _ in range(20):
        eng.step()
    assert not eng.degraded
    assert eng.failover.n_failovers >= 1
    assert eng.failover.health.state == ["healthy", "healthy"]
    # Converged: the recovered fleet answers exactly like a fresh
    # engine built on the SAME mutated index.
    fresh = QueryEngine(eng.index, qc, clock=ManualClock())
    for (ia, sa), (ib, sb) in zip(_wave(eng, profiles),
                                  _wave(fresh, profiles)):
        np.testing.assert_array_equal(ia, ib)
        np.testing.assert_array_equal(sa, sb)
