"""SLO-aware serving battery.

Locks down the PR-7 admission layer and its satellite fixes:

* ``shed_and_select`` ordering (priority class, then earliest deadline,
  then submission order) and its two shed populations (expired,
  bounded-queue overflow);
* SlotScheduler slo policy: admission order, explicit shedding with
  exactly-once accounting (``n_submitted == n_admitted + pending +
  n_shed``), ``drain_shed``;
* engine-level rejected markers (shed requests complete WITHOUT results
  and are excluded from latency/recall), in wave and continuous modes;
* ``QueryRequest.latency`` None-until-served semantics (the old
  ``0.0 - t_submit`` negative-latency bug);
* heterogeneous-k ``recall_vs_brute_force`` (the old ``np.stack`` crash
  on ragged id rows);
* the zero-hop-burst regression: a continuous tick's completions cost
  ONE slot-result snapshot (``sched.trace.launch_count``), however many
  admission chunks fed it;
* adaptive hop budgets: fewer ticks than fixed-budget serving at
  near-parity recall, all requests still served exactly once;
* the open-loop driver's stall guard: shedding counts as progress, a
  stuck engine raises.
"""
import time
from collections import deque
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index
from repro.sched import SlotScheduler, shed_and_select, trace

K, BEAM, HOPS = 10, 16, 3


@pytest.fixture(scope="module")
def index():
    ds = make_dataset("synth", scale=0.1, seed=3)
    return build_index(ds, C2Params(k=10, b=64, t=8, max_cluster=48))


@pytest.fixture(scope="module")
def query_profiles():
    qds = make_dataset("synth", scale=0.1, seed=77)
    return [qds.profile(u) for u in range(48)]


def _req(rid=0, pri=0, deadline=None):
    return QueryRequest(rid=rid, profile=np.array([1, 2, 3], np.int32),
                        priority=pri, deadline=deadline)


# -- shed_and_select -------------------------------------------------------

def test_select_orders_by_class_then_deadline_then_submission():
    pending = deque([_req(0, pri=1), _req(1, pri=0, deadline=5.0),
                     _req(2, pri=0, deadline=2.0), _req(3, pri=1),
                     _req(4, pri=0)])
    selected, shed = shed_and_select(pending, 3, now=0.0)
    assert not shed
    # Class 0 first; inside the class earliest deadline wins and
    # no-deadline (inf) goes last.
    assert [r.rid for r in selected] == [2, 1, 4]
    # Remainder keeps submission order for deterministic FIFO tiebreaks.
    assert [r.rid for r in pending] == [0, 3]


def test_select_sheds_expired_and_bounded_overflow():
    pending = deque([_req(0, pri=0, deadline=0.5), _req(1, pri=0,
                                                        deadline=10.0),
                     _req(2, pri=1), _req(3, pri=1), _req(4, pri=1)])
    selected, shed = shed_and_select(pending, 1, now=1.0, max_pending=1)
    assert [r.rid for r in selected] == [1]
    # rid 0 expired; rids 3, 4 are worst-ranked overflow past the bound
    # (same class + deadline, so later submissions shed first).
    assert sorted(r.rid for r in shed) == [0, 3, 4]
    assert [r.rid for r in pending] == [2]


def test_select_unbounded_never_sheds_unexpired():
    pending = deque([_req(i, pri=i % 3) for i in range(20)])
    selected, shed = shed_and_select(pending, 4, now=0.0, max_pending=0)
    assert len(selected) == 4 and not shed and len(pending) == 16


# -- SlotScheduler slo policy ----------------------------------------------

def test_scheduler_slo_admission_shedding_and_accounting():
    sched = SlotScheduler(2, policy="slo", max_pending=2,
                          clock=lambda: 0.0)
    for i in range(6):
        sched.submit(_req(i, pri=1 if i < 4 else 0))
    admitted = sched.admit()
    # The two class-0 stragglers jump the four earlier class-1 submits.
    assert [r.rid for _, r in admitted] == [4, 5]
    assert [s for s, _ in admitted] == [0, 1]
    # Queue bounded at 2: the two worst-ranked class-1 requests shed.
    assert sched.n_shed == 2 and len(sched.pending) == 2
    shed = sched.drain_shed()
    assert sorted(r.rid for r in shed) == [2, 3]
    assert sched.drain_shed() == []  # drained exactly once
    sched.check_invariants()
    # Release + drain the rest; exactly-once end to end.
    sched.release(0)
    sched.release(1)
    assert [r.rid for _, r in sched.admit()] == [0, 1]
    sched.release_many([0, 1])
    sched.check_invariants()
    assert sched.n_submitted == 6
    assert sched.n_admitted == sched.n_completed == 4
    assert sched.n_shed == 2


def test_scheduler_slo_sheds_expired_by_injected_clock():
    now = [0.0]
    sched = SlotScheduler(1, policy="slo", clock=lambda: now[0])
    sched.submit(_req(0, deadline=1.0))
    sched.submit(_req(1))
    now[0] = 2.0  # rid 0 expires while pending
    admitted = sched.admit()
    assert [r.rid for _, r in admitted] == [1]
    assert [r.rid for r in sched.drain_shed()] == [0]
    sched.check_invariants()


def test_scheduler_rejects_bad_policy_and_bounds():
    with pytest.raises(ValueError):
        SlotScheduler(4, policy="nope")
    with pytest.raises(ValueError):
        SlotScheduler(4, max_pending=-1)


# -- engine-level rejected markers -----------------------------------------

@pytest.mark.parametrize("continuous", [False, True])
def test_expired_requests_complete_with_rejected_marker(
        index, query_profiles, continuous):
    eng = QueryEngine(index, QueryConfig(
        k=K, beam=BEAM, hops=HOPS, admission="slo",
        continuous=continuous, slots=4))
    past = time.perf_counter() - 1.0
    eng.submit(QueryRequest(rid=0, profile=query_profiles[0]))
    eng.submit(QueryRequest(rid=1, profile=query_profiles[1],
                            priority=1, deadline=past))
    eng.submit(QueryRequest(rid=2, profile=query_profiles[2]))
    stats = eng.run()
    assert stats["requests"] == 3
    assert stats["served"] == 2 and stats["shed"] == 1
    rej = [r for r in eng.done if r.rejected]
    assert len(rej) == 1 and rej[0].rid == 1
    # Shed requests complete WITHOUT results and never count as served.
    assert rej[0].ids is None and rej[0].sims is None
    assert rej[0].status == "rejected" and rej[0].t_done > 0.0
    served = [r for r in eng.done if r.status == "done"]
    assert {r.rid for r in served} == {0, 2}
    assert all(r.ids is not None for r in served)
    # Recall skips the rejected request instead of crashing on ids=None.
    assert eng.recall_vs_brute_force() > 0.5


def test_wave_slo_serves_high_priority_class_first(index, query_profiles):
    eng = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                         admission="slo", max_wave=4))
    for rid in range(8):
        eng.submit(QueryRequest(rid=rid, profile=query_profiles[rid],
                                priority=0 if rid >= 4 else 1))
    eng.run()
    # First wave = the class-0 requests, despite later submission.
    assert {r.rid for r in eng.done[:4]} == {4, 5, 6, 7}
    assert {r.rid for r in eng.done[4:]} == {0, 1, 2, 3}


def test_fifo_engine_never_sheds(index, query_profiles):
    eng = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS))
    past = time.perf_counter() - 1.0
    for rid in range(4):
        eng.submit(QueryRequest(rid=rid, profile=query_profiles[rid],
                                deadline=past))  # fifo ignores deadlines
    stats = eng.run()
    assert stats["served"] == 4 and stats["shed"] == 0
    assert not any(r.rejected for r in eng.done)


# -- latency semantics (satellite bugfix) ----------------------------------

def test_latency_is_none_until_served():
    r = _req(0)
    assert r.latency is None          # neither timestamp set
    r.t_submit = 5.0
    assert r.latency is None          # submitted, not completed — the
    #                                   old code returned -5.0 here
    r.t_done = 6.5
    assert r.latency == pytest.approx(1.5)


def test_stats_latency_excludes_unserved(index, query_profiles):
    eng = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                         admission="slo"))
    past = time.perf_counter() - 1.0
    eng.submit(QueryRequest(rid=0, profile=query_profiles[0]))
    eng.submit(QueryRequest(rid=1, profile=query_profiles[1],
                            priority=1, deadline=past))
    stats = eng.run()
    # One served request: every latency stat is its (positive) latency;
    # the old negative-poisoning bug made these go below zero.
    assert stats["p50_latency_s"] > 0.0
    assert stats["p95_latency_s"] > 0.0
    assert stats["mean_latency_s"] > 0.0


# -- heterogeneous-k recall (satellite bugfix) -----------------------------

def test_recall_vs_brute_force_handles_mixed_k(index, query_profiles):
    eng5 = QueryEngine(index, QueryConfig(k=5, beam=BEAM, hops=HOPS))
    eng10 = QueryEngine(index, QueryConfig(k=10, beam=BEAM, hops=HOPS))
    for rid in range(6):
        eng5.submit(QueryRequest(rid=rid, profile=query_profiles[rid]))
        eng10.submit(QueryRequest(rid=rid,
                                  profile=query_profiles[6 + rid]))
    eng5.run()
    eng10.run()
    mixed = eng5.done + eng10.done  # ragged id rows: k=5 and k=10
    rec = eng10.recall_vs_brute_force(mixed)  # old code: np.stack raised
    assert 0.0 < rec <= 1.0
    # Mixed recall is the size-weighted mean of the per-k groups.
    r5 = eng10.recall_vs_brute_force(eng5.done)
    r10 = eng10.recall_vs_brute_force(eng10.done)
    expect = (r5 * len(eng5.done) + r10 * len(eng10.done)) / len(mixed)
    assert rec == pytest.approx(expect)


# -- zero-hop burst: one snapshot per tick (satellite perf fix) ------------

def test_zero_hop_burst_costs_one_snapshot_per_tick(index, query_profiles):
    eng = QueryEngine(index, QueryConfig(k=K, beam=BEAM, hops=HOPS,
                                         continuous=True, slots=8))
    eng.submit(QueryRequest(rid=-1, profile=query_profiles[0]))
    eng.run()
    eng.done.clear()
    key = ("slot_results", eng.plan.key)
    # A zero-hop burst larger than the slot count, plus normal requests:
    # the old admit loop snapshotted once per admission chunk.
    n_zero = 12
    for rid in range(n_zero):
        eng.submit(QueryRequest(rid=rid, profile=query_profiles[rid],
                                hops=0))
    for rid in range(n_zero, n_zero + 4):
        eng.submit(QueryRequest(rid=rid, profile=query_profiles[rid]))
    while eng.busy():
        before = trace.launch_count(key)
        n = eng.step()
        assert trace.launch_count(key) - before == (1 if n else 0), \
            "a tick's completions must cost exactly one slot-result " \
            "snapshot"
    assert len(eng.done) == n_zero + 4
    # Zero-hop results are wave hops=0 results, bitwise.
    w_ids, w_sims = eng.query_batch(query_profiles[:n_zero], hops=0)
    by_rid = {r.rid: r for r in eng.done}
    for rid in range(n_zero):
        np.testing.assert_array_equal(by_rid[rid].ids, w_ids[rid])
        np.testing.assert_array_equal(by_rid[rid].sims, w_sims[rid])


# -- adaptive hop budgets --------------------------------------------------

def test_adaptive_budgets_save_ticks_at_near_parity_recall(
        index, query_profiles):
    def serve(patience):
        eng = QueryEngine(index, QueryConfig(
            k=K, beam=BEAM, hops=2 * HOPS, continuous=True, slots=8,
            adaptive=patience))
        for rid, p in enumerate(query_profiles[:16]):
            eng.submit(QueryRequest(rid=-1 - rid, profile=p))
        eng.run()
        eng.done.clear()
        t0 = eng.n_ticks
        for rid, p in enumerate(query_profiles):
            eng.submit(QueryRequest(rid=rid, profile=p))
        eng.run()
        assert len(eng.done) == len(query_profiles)  # all served once
        return (eng.n_ticks - t0,
                eng.recall_vs_brute_force(eng.done))

    fixed_ticks, fixed_recall = serve(0)
    adapt_ticks, adapt_recall = serve(1)
    assert adapt_ticks <= fixed_ticks
    assert adapt_recall >= fixed_recall - 0.02


def test_adaptive_requires_continuous_batching():
    with pytest.raises(ValueError):
        QueryConfig(k=K, adaptive=2).spec()


def test_max_pending_requires_slo():
    with pytest.raises(ValueError):
        QueryConfig(k=K, max_pending=8).spec()


# -- open-loop stall guard (satellite bugfix) ------------------------------

def _load_query_bench():
    import importlib.util
    from pathlib import Path

    bench = Path(__file__).resolve().parent.parent / "benchmarks"
    spec = importlib.util.spec_from_file_location(
        "query_bench", bench / "query_bench.py")
    qb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(qb)
    return qb


def test_open_loop_raises_on_stuck_engine_not_on_shedding():
    qb = _load_query_bench()

    class StuckEngine:
        """Accepts work, never completes any — the bug the guard is for."""

        def __init__(self):
            self.queue = deque()
            self.done = []
            self.plan = SimpleNamespace(scheduler=None)

        def busy(self):
            return bool(self.queue)

        def step(self):
            return 0

    profiles = [np.array([1, 2, 3], np.int32)] * 3
    with pytest.raises(RuntimeError, match="stopped completing work"):
        qb.open_loop(StuckEngine(), profiles, rate_qps=1000.0,
                     stall_s=0.2)

    class SheddingEngine(StuckEngine):
        """Completes everything as rejected — overload response, NOT a
        stall; the old assertion could not tell these apart."""

        def step(self):
            n = 0
            while self.queue:
                r = self.queue.popleft()
                r.status = "rejected"
                r.t_done = time.perf_counter()
                self.done.append(r)
                n += 1
            return n

    row = qb.open_loop(SheddingEngine(), profiles, rate_qps=1000.0,
                       stall_s=0.2)
    assert row["shed"] == 3 and row["served"] == 0
    assert row["p95_latency_ms"] is None  # no served latencies to rank
