"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.knn.topk import dedup_mask, merge_topk
from repro.types import NEG_INF, PAD_ID


@settings(deadline=None, max_examples=30)
@given(st.lists(st.lists(st.integers(-1, 20), min_size=4, max_size=12),
                min_size=1, max_size=6))
def test_dedup_mask_keeps_exactly_one_of_each(rows):
    c = max(len(r) for r in rows)
    ids = np.full((len(rows), c), PAD_ID, np.int32)
    for i, r in enumerate(rows):
        ids[i, : len(r)] = r
    mask = np.asarray(dedup_mask(jnp.asarray(ids)))
    for i, row in enumerate(ids):
        for v in np.unique(row):
            assert mask[i][row == v].sum() == 1


@settings(deadline=None, max_examples=30)
@given(st.integers(1, 40), st.integers(1, 12), st.integers(0, 2**31 - 2))
def test_merge_topk_invariants(c, k, seed):
    rng = np.random.default_rng(seed)
    n = 5
    ids = rng.integers(0, 30, size=(n, c)).astype(np.int32)
    ids[rng.random((n, c)) < 0.2] = PAD_ID
    sims = rng.random((n, c)).astype(np.float32)
    self_ids = jnp.arange(n, dtype=jnp.int32)
    out_ids, out_sims = merge_topk(jnp.asarray(ids), jnp.asarray(sims), k,
                                   self_ids)
    out_ids, out_sims = np.asarray(out_ids), np.asarray(out_sims)
    rows = np.arange(n)[:, None]
    assert not (out_ids == rows).any(), "self edge survived"
    finite = np.where(out_ids != PAD_ID, out_sims, -1e30)
    assert (np.diff(finite, axis=1) <= 1e-6).all(), "not sorted"
    for i in range(n):
        live = out_ids[i][out_ids[i] != PAD_ID]
        assert len(live) == len(set(live.tolist())), "duplicate neighbor"
        # Every returned (id, sim) must exist in the candidates.
        for v, s in zip(out_ids[i], out_sims[i]):
            if v == PAD_ID:
                continue
            j = np.flatnonzero(ids[i] == v)
            assert np.isclose(sims[i][j], s).any()


@settings(deadline=None, max_examples=20)
@given(st.integers(2, 64), st.integers(0, 10_000))
def test_rope_preserves_norm_and_relative_angle(hd2, pos):
    from repro.models.layers import rope

    hd = hd2 * 2
    x = jax.random.normal(jax.random.key(hd2), (1, 1, 1, hd))
    p = jnp.full((1, 1), pos, jnp.int32)
    y = rope(x.astype(jnp.float32), p, 10_000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)


def test_rope_relative_position_property():
    """⟨rope(q,i), rope(k,j)⟩ depends only on i−j."""
    from repro.models.layers import rope

    q = jax.random.normal(jax.random.key(0), (1, 1, 1, 32), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (1, 1, 1, 32), jnp.float32)

    def dot(i, j):
        qi = rope(q, jnp.full((1, 1), i, jnp.int32), 10_000.0)
        kj = rope(k, jnp.full((1, 1), j, jnp.int32), 10_000.0)
        return float(jnp.sum(qi * kj))

    np.testing.assert_allclose(dot(5, 3), dot(105, 103), rtol=1e-4)
    np.testing.assert_allclose(dot(17, 0), dot(1017, 1000), rtol=1e-4)


def test_hlo_analysis_on_synthetic_module():
    """The cost model on a hand-written HLO: dot flops, while trip
    multiplication, collective bytes."""
    from repro.launch.hlo_analysis import analyze

    hlo = """
HloModule test

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %d = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %a)
  %w = (s32[], f32[8,16]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,16] get-tuple-element(%w), index=1
}
"""
    a = analyze(hlo)
    # dot: 2·8·16·16 = 4096 flops × 10 trips.
    assert a["flops_per_device"] == 4096 * 10
    # all-reduce: 8·16·4 bytes × 10 trips.
    assert a["collective_bytes_per_device"] == 512 * 10


@pytest.mark.slow
def test_elastic_restore_across_device_counts(tmp_path):
    """Save under an 8-device mesh layout, restore under 1 device
    (restore_sharded re-places leaves under the new mesh)."""
    import subprocess
    import sys
    import os

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint as ckpt
mesh = jax.make_mesh((8,), ("data",))
x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                   NamedSharding(mesh, P("data", None)))
ckpt.save(r"{tmp_path}", {{"w": x}}, step=3)
print("SAVED")
"""
    r = subprocess.run([sys.executable, "-c", code],
                       env={**os.environ,
                            "PYTHONPATH": os.path.join(repo, "src")},
                       capture_output=True, text=True, timeout=180)
    assert "SAVED" in r.stdout, r.stdout + r.stderr
    # Restore in THIS process (1 device).
    from repro import checkpoint as ckpt
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh1 = jax.make_mesh((1,), ("data",))
    like = {"w": np.zeros((8, 8), np.float32)}
    sh = {"w": NamedSharding(mesh1, P("data", None))}
    (tree, step) = ckpt.restore_sharded(tmp_path, like, sh)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(tree["w"]), np.arange(64, dtype=np.float32).reshape(8, 8))


def test_data_pipeline_deterministic_and_c2_ordered():
    from repro.configs import get_config
    from repro.data.tokens import DataConfig, TokenPipeline
    from repro.models.config import scaled_down

    cfg = scaled_down(get_config("llama3_2-1b"))
    dc = DataConfig(seq_len=32, global_batch=4, seed=5, n_docs=256)
    p1, p2 = TokenPipeline(cfg, dc), TokenPipeline(cfg, dc)
    for step in (0, 3, 17):
        np.testing.assert_array_equal(p1.batch(step)["tokens"],
                                      p2.batch(step)["tokens"])
    # c2 ordering is a permutation of docs and is itself deterministic.
    dc2 = DataConfig(seq_len=32, global_batch=4, seed=5, n_docs=256,
                     ordering="c2")
    q1, q2 = TokenPipeline(cfg, dc2), TokenPipeline(cfg, dc2)
    assert sorted(q1._order.tolist()) == list(range(256))
    np.testing.assert_array_equal(q1._order, q2._order)
    np.testing.assert_array_equal(q1.batch(7)["tokens"],
                                  q2.batch(7)["tokens"])
