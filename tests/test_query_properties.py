"""Hypothesis property tests for the query layer: FRH longest-prefix
routing and KNNIndex persistence."""
import tempfile
from pathlib import Path

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hashing import NO_HASH
from repro.query.index import KNNIndex
from repro.query.router import _matches_for
from repro.types import NEG_INF, PAD_ID

# Ascending distinct hash sequences, like user_distinct_hashes_np emits.
_hash_seq = st.lists(st.integers(0, 50), min_size=1, max_size=6,
                     unique=True).map(sorted)


@settings(deadline=None, max_examples=60)
@given(query=_hash_seq, table=st.lists(_hash_seq, max_size=8),
       pad=st.integers(0, 3))
def test_router_longest_prefix_match(query, table, pad):
    """_matches_for returns exactly the table paths that are prefixes of
    the query's distinct-hash sequence, deepest first."""
    cfg = 0
    # LUT over the table paths plus a few of the query's own prefixes (so
    # matches exist often), mimicking KNNIndex.path_lut().
    paths = {tuple(p) for p in table}
    paths |= {tuple(query[:d]) for d in range(1, len(query) + 1)
              if d % 2 == 1}
    lut = {(cfg, p): ci for ci, p in enumerate(sorted(paths))}
    row = np.array(query + [NO_HASH] * pad, dtype=np.int64)
    got = _matches_for(lut, cfg, row)
    expect = [lut[(cfg, tuple(query[:d]))]
              for d in range(len(query), 0, -1)
              if (cfg, tuple(query[:d])) in lut]
    assert got == expect
    # A different configuration never matches.
    assert _matches_for(lut, cfg + 1, row) == []


@settings(deadline=None, max_examples=25)
@given(st.data())
def test_index_save_load_roundtrip_identity(data):
    """save → load is the identity on every array and meta field, for
    arbitrary (well-formed) index shapes."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    n = data.draw(st.integers(2, 24))
    k = data.draw(st.integers(1, 5))
    W = data.draw(st.integers(1, 4))
    t = data.draw(st.integers(1, 3))
    depth = data.draw(st.integers(1, 3))
    c = data.draw(st.integers(0, 6))

    graph_ids = rng.integers(-1, n, size=(n, k)).astype(np.int32)
    graph_sims = np.where(graph_ids == PAD_ID, NEG_INF,
                          rng.random((n, k))).astype(np.float32)
    sizes = rng.integers(0, n, size=c)
    offsets = np.zeros(c + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    members = rng.integers(0, n, size=int(offsets[-1])).astype(np.int32)
    paths = rng.integers(0, 100, size=(c, depth)).astype(np.int32)
    ix = KNNIndex(
        graph_ids=graph_ids,
        graph_sims=graph_sims,
        words=rng.integers(0, 2**32, size=(n, W), dtype=np.uint32),
        card=rng.integers(0, 32 * W, size=n).astype(np.int32),
        rev_ids=rng.integers(-1, n, size=(n, k)).astype(np.int32),
        hash_seeds=rng.integers(0, 2**31 - 1, size=t).astype(np.int32),
        cluster_paths=paths,
        cluster_config=rng.integers(0, t, size=c).astype(np.int32),
        cluster_members=members,
        cluster_offsets=offsets,
        b=int(data.draw(st.integers(1, 512))),
        n_bits=32 * W,
        fp_seed=int(data.draw(st.integers(0, 1000))),
        split_depth=depth,
        version=int(data.draw(st.integers(0, 7))),
    )
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "ix.npz"
        ix.save(path)
        loaded = KNNIndex.load(path)
    for name in ("graph_ids", "graph_sims", "words", "card", "rev_ids",
                 "hash_seeds", "cluster_paths", "cluster_config",
                 "cluster_members", "cluster_offsets"):
        np.testing.assert_array_equal(getattr(ix, name),
                                      getattr(loaded, name), err_msg=name)
    for name in ("b", "n_bits", "fp_seed", "split_depth", "version"):
        assert getattr(ix, name) == getattr(loaded, name), name
    assert loaded.n == ix.n and loaded.capacity >= loaded.n


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.integers(1, 20))
def test_roundtrip_after_inserts(seed, n_ins):
    """Growth state (spare capacity, online cluster members) never leaks
    into the artifact: save trims to n rows and consolidates the CSR."""
    rng = np.random.default_rng(seed)
    n, k, W = 8, 3, 2
    ids = rng.integers(0, n, size=(n, k)).astype(np.int32)
    ix = KNNIndex(
        graph_ids=ids,
        graph_sims=rng.random((n, k)).astype(np.float32),
        words=rng.integers(0, 2**32, size=(n, W), dtype=np.uint32),
        card=rng.integers(1, 64, size=n).astype(np.int32),
        rev_ids=rng.integers(-1, n, size=(n, k)).astype(np.int32),
        hash_seeds=np.array([1], np.int32),
        cluster_paths=np.array([[7]], np.int32),
        cluster_config=np.array([0], np.int32),
        cluster_members=np.arange(n, dtype=np.int32),
        cluster_offsets=np.array([0, n], np.int64),
        b=64, n_bits=32 * W, fp_seed=0, split_depth=1,
    )
    for _ in range(n_ins):
        u = ix.append_user(rng.integers(0, 2**32, size=W, dtype=np.uint32),
                           int(rng.integers(1, 64)),
                           np.array([0, 1], np.int32),
                           np.array([0.5, 0.25], np.float32))
        ix.add_cluster_member(0, u)
    assert ix.capacity >= ix.n == n + n_ins
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "ix.npz"
        ix.save(path)
        loaded = KNNIndex.load(path)
    assert loaded.n == ix.n
    assert loaded.graph_ids.shape[0] == ix.n  # no spare rows in the npz
    np.testing.assert_array_equal(loaded.graph_ids, ix.graph_ids)
    np.testing.assert_array_equal(loaded.cluster_users(0),
                                  ix.cluster_users(0))
