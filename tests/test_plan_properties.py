"""Hypothesis property test for delta resharding: ANY interleaving of
insert / flush_cohort / query under a sharded plan leaves shard tensors
bitwise-equal to a from-scratch rebuild of the extended ShardPlan
(tests/test_plan.py carries the deterministic battery and the shared
rebuild comparator)."""
import copy

import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine

from test_plan import _assert_matches_rebuild  # same-dir test module


@pytest.fixture(scope="module")
def small_index():
    from repro.query.index import build_index

    ds = make_dataset("synth", scale=0.05, seed=5)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=32))


@pytest.fixture(scope="module")
def profiles():
    qds = make_dataset("synth", scale=0.05, seed=7)
    return [qds.profile(u) for u in range(24)]


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(st.sampled_from(["insert", "flush", "query"]),
                    min_size=1, max_size=12),
       n_shards=st.integers(min_value=2, max_value=3))
def test_any_interleaving_matches_rebuild(small_index, profiles, ops,
                                          n_shards):
    ix = copy.deepcopy(small_index)
    engine = QueryEngine(ix, QueryConfig(k=8, beam=12, hops=2,
                                         shards=n_shards,
                                         refresh_every=10**9))
    engine.query_batch(profiles[:4])  # freeze the base plan
    n_ins = 0
    for op in ops:
        if op == "insert":
            engine.insert(profiles[8 + (n_ins % 16)])
            n_ins += 1
        elif op == "flush":
            engine.flush_cohort()
        else:
            engine.query_batch(profiles[:4])
    _assert_matches_rebuild(engine)
