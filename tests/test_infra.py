"""Infrastructure tests: checkpoint/restart, deterministic data skip,
distributed C² (8 emulated devices), LPT scheduling, grad compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.distributed import build_dist_plan, lpt_assign
from repro.train.optimizer import (OptConfig, apply_updates, init_opt_state,
                                   quantize_int8)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), np.int32)}}
    ckpt.save(tmp_path, tree, step=7)
    assert ckpt.latest_step(tmp_path) == 7
    got, step = ckpt.restore(tmp_path, tree)
    assert step == 7
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["b"]["c"], tree["b"]["c"])


def test_checkpoint_atomic_overwrite(tmp_path):
    tree = {"a": np.zeros(4)}
    ckpt.save(tmp_path, tree, step=1)
    ckpt.save(tmp_path, {"a": np.ones(4)}, step=2)
    got, step = ckpt.restore(tmp_path, tree)
    assert step == 2 and got["a"].sum() == 4


@pytest.mark.slow
def test_train_restart_resumes_identically(tmp_path):
    """Crash at step 6, restart, and land on the same final loss as an
    uninterrupted run — checkpoint + deterministic data skip together."""
    from repro.launch import train as T

    base = ["--arch", "xlstm-125m", "--smoke", "--steps", "10",
            "--batch", "2", "--seq", "32", "--ckpt-every", "3"]
    loss_straight = T.main(base + ["--ckpt-dir", str(tmp_path / "a")])
    with pytest.raises(SystemExit):
        T.main(base + ["--ckpt-dir", str(tmp_path / "b"),
                       "--fail-at-step", "6"])
    loss_resumed = T.main(base + ["--ckpt-dir", str(tmp_path / "b")])
    assert abs(loss_straight - loss_resumed) < 1e-4, (
        loss_straight, loss_resumed)


def test_knn_build_resumes_after_failure(tmp_path):
    """Per-hash-config checkpointing: a crash after 2/4 configs resumes
    and produces the same graph as an uninterrupted build."""
    from repro.core.params import C2Params
    from repro.data.synthetic import make_dataset
    from repro.launch.knn_build import build

    ds = make_dataset("ml1M", scale=0.05, seed=3)
    p = C2Params(k=5, b=128, t=4, max_cluster=80, n_bits=512)
    g_full, _ = build(ds, p, ckpt_dir=None, verbose=False)
    import dataclasses
    build(ds, dataclasses.replace(p, t=2), ckpt_dir=str(tmp_path),
          verbose=False)  # "crash" after 2 configs
    g_resumed, _ = build(ds, p, ckpt_dir=str(tmp_path), verbose=False)
    np.testing.assert_array_equal(g_full.ids, g_resumed.ids)


def test_lpt_balances():
    rng = np.random.default_rng(0)
    costs = rng.pareto(1.5, size=200) + 0.1
    assign = lpt_assign(costs, 8)
    loads = np.zeros(8)
    np.add.at(loads, assign, costs)
    assert loads.max() / loads.mean() < 1.5


def test_dist_plan_covers_all_clusters(small_ds):
    from repro.core.clustering import build_plan
    from repro.core.params import C2Params

    plan = build_plan(small_ds, C2Params(k=5, b=128, t=3, max_cluster=100))
    dp = build_dist_plan(plan, n_dev=4)
    seen = sorted(int(c) for cof in dp.cluster_of
                  for c in cof.reshape(-1) if c >= 0)
    assert seen == list(range(plan.n_clusters))
    assert dp.imbalance < 2.5


@pytest.mark.slow
def test_distributed_c2_matches_single_device():
    """Run distributed C² on 8 emulated host devices (subprocess so the
    device count doesn't leak into this test session) and compare with
    the single-device pipeline."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.data.synthetic import make_dataset
from repro.sketch.goldfinger import fingerprint_dataset
from repro.core.params import C2Params
from repro.core.pipeline import cluster_and_conquer
from repro.core.distributed import distributed_c2

ds = make_dataset("ml1M", scale=0.08, seed=7)
gf = fingerprint_dataset(ds, n_bits=512)
p = C2Params(k=6, b=128, t=3, max_cluster=100, n_bits=512)
g1, _ = cluster_and_conquer(ds, p, gf=gf)
mesh = jax.make_mesh((8,), ("data",))
g2, stats = distributed_c2(ds, p, mesh, gf=gf)
assert stats["n_devices"] == 8
np.testing.assert_array_equal(g1.ids, g2.ids)
mism = np.abs(np.where(g1.ids>=0, g1.sims, 0) - np.where(g2.ids>=0, g2.sims, 0)).max()
assert mism < 1e-6, mism
print("DISTRIBUTED_OK imbalance=%.3f" % stats["lpt_imbalance"])
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=420)
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + r.stderr


def test_int8_grad_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 1e-3)
    err = jnp.zeros((64,), jnp.bfloat16)
    deq1, err1 = quantize_int8(g, err)
    # Error feedback: residual carries exactly what quantization lost.
    np.testing.assert_allclose(np.asarray(deq1 + err1.astype(jnp.float32)),
                               np.asarray(g), atol=1e-5)
    # Over steps, the running average of dequantized grads converges.
    acc = jnp.zeros_like(g)
    err = jnp.zeros((64,), jnp.bfloat16)
    for _ in range(32):
        deq, err = quantize_int8(g, err)
        acc += deq
    np.testing.assert_allclose(np.asarray(acc / 32), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.05)


def test_adamw_state_dtype_bf16():
    params = {"w": jnp.ones((8, 8), jnp.float32)}
    oc = OptConfig(state_dtype="bfloat16")
    st = init_opt_state(params, oc)
    assert st["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((8, 8), 0.1, jnp.float32)}
    p2, st2 = apply_updates(params, g, st, oc)
    assert st2["m"]["w"].dtype == jnp.bfloat16
    assert float(jnp.abs(p2["w"] - params["w"]).sum()) > 0
