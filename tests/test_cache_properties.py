"""Hypothesis battery for the result cache: under ANY interleaving of
mutations, repeated queries, and scheduler serving, a cache-on engine is
results-INVISIBLE —

* bitwise parity: cache-on returns the same (ids AND sims) as a
  cache-off engine driven through the identical interleaving, on the
  final probe wave AND on every request served through the scheduler
  loop (descent is deterministic in (index state, fingerprint, k, hops),
  and the journal-driven wholesale flush means a hit is only ever served
  when a fresh descent would reproduce it exactly);
* no served id is tombstoned at serve time — cache hits included (the
  flush-on-mutation rule plus get()'s belt-and-braces tombstone drop);
* both engines walk the identical index trajectory (version, graph,
  tombstones), i.e. the cache never perturbs a mutation.

The op mix leans on REPEATED hot profiles so hits actually occur —
parity of a cache that never hits proves nothing; the battery asserts
the interleavings collectively produced hits.

Adaptive hop budgets are deliberately ABSENT here: adaptive early-frees
are approximate (served at prefix-stability, never cached) while a hit
replays the exact full-budget result, so cache-on + adaptive is not
bitwise vs cache-off + adaptive by design (README: SLO-aware serving).
"""
import copy

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest

OPS = ("insert", "remove", "update", "hot_query", "cold_query", "serve")

HITS_SEEN = {"n": 0}  # across examples: the battery must exercise hits


@pytest.fixture(scope="module")
def small_index():
    from repro.query.index import build_index

    ds = make_dataset("synth", scale=0.05, seed=5)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=32))


@pytest.fixture(scope="module")
def profiles():
    qds = make_dataset("synth", scale=0.05, seed=7)
    return [qds.profile(u) for u in range(24)]


def _drive(engine, ops, profiles, seed):
    """Apply an op sequence; targets come from a seeded rng over the
    engine's own live set so cache-on and cache-off walk identical
    index trajectories. hot_query repeats the same 4 profiles (cache
    fodder); cold_query rotates so fills/evictions churn too."""
    rng = np.random.default_rng(seed)
    n_ins = 0
    n_cold = 0
    waves = []
    for op in ops:
        ix = engine.index
        if op == "insert":
            engine.insert(profiles[8 + (n_ins % 16)])
            n_ins += 1
        elif op == "remove":
            alive = ix.alive_ids()
            if len(alive) > ix.k + 2:
                engine.remove_user(int(rng.choice(alive)))
        elif op == "update":
            alive = ix.alive_ids()
            engine.update_user(int(rng.choice(alive)),
                               profiles[int(rng.integers(0, 8))])
        elif op == "hot_query":
            waves.append(engine.query_batch(profiles[:4]))
        elif op == "cold_query":
            lo = 4 + (n_cold % 4) * 4
            waves.append(engine.query_batch(profiles[lo:lo + 4]))
            n_cold += 1
        else:  # serve the hot set through the scheduler loop
            for i in range(3):
                engine.submit(QueryRequest(
                    rid=i, profile=np.asarray(profiles[i], np.int32)))
            engine.run()
    waves.append(engine.query_batch(profiles[:4]))  # final probe
    return waves


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(st.sampled_from(OPS), min_size=1, max_size=10),
       continuous=st.booleans(),
       capacity=st.sampled_from([2, 64]),  # tiny forces eviction churn
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_cache_is_results_invisible_under_any_interleaving(
        small_index, profiles, ops, continuous, capacity, seed):
    def build(cache):
        eng = QueryEngine(copy.deepcopy(small_index),
                          QueryConfig(k=8, beam=12, hops=2, slots=8,
                                      continuous=continuous, cache=cache,
                                      refresh_every=10**9))
        eng.query_batch(profiles[:4])  # freeze the base plan (and, with
        #                                the cache on, pre-fill hot keys)
        return eng

    eng = build(capacity)
    ref = build(0)
    waves = _drive(eng, ops, profiles, seed)
    ref_waves = _drive(ref, ops, profiles, seed)

    # Bitwise parity on every wave (probe included) and every request
    # served through the scheduler loop.
    assert len(waves) == len(ref_waves)
    for (ids, sims), (r_ids, r_sims) in zip(waves, ref_waves):
        np.testing.assert_array_equal(ids, r_ids)
        np.testing.assert_array_equal(sims, r_sims)
    assert len(eng.done) == len(ref.done)
    for a, b in zip(eng.done, ref.done):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.sims, b.sims)

    # No tombstoned id is ever served — cache hits included.
    tomb = eng.index.tombstone
    for ids, _ in waves:
        live = ids[ids != -1]
        assert not tomb[live].any()
    for r in eng.done:
        served = r.ids[r.ids != -1]
        assert not tomb[served].any()

    # The cache never perturbs the index trajectory.
    assert eng.index.version == ref.index.version
    np.testing.assert_array_equal(eng.index.graph_ids, ref.index.graph_ids)
    np.testing.assert_array_equal(eng.index.tombstone, ref.index.tombstone)

    HITS_SEEN["n"] += eng.plan.cache.stats()["hits"]


def test_battery_actually_exercised_cache_hits():
    """Parity over interleavings that never hit proves nothing — the
    hypothesis battery above must have served real hits. (Ordered after
    it in the file; pytest runs file order.)"""
    assert HITS_SEEN["n"] > 0
