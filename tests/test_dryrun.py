"""Dry-run integration: one real cell lowers + compiles on the production
mesh with 512 emulated devices (subprocess so the device count and the
XLA_FLAGS never leak into the test session). Uses a throwaway tag so the
recorded baseline artifacts are untouched."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


@pytest.mark.slow
def test_dryrun_cell_compiles_on_512_devices(tmp_path):
    code = r"""
import repro.launch.dryrun as dr
from pathlib import Path
import sys
dr.ART = Path(sys.argv[1])
rec = dr.run_cell("xlstm-125m", "decode_32k", multi_pod=True,
                  force=True, tag="_citest")
assert rec["status"] == "ok", rec
assert rec["n_devices"] == 512
a = rec["analysis"]
assert a["flops_per_device"] > 0
assert a["collective_bytes_per_device"] >= 0
assert rec["memory"]["temp_size_in_bytes"] > 0
print("DRYRUN_OK", rec["collectives"]["total_bytes_per_device"])
"""
    r = subprocess.run([sys.executable, "-c", code, str(tmp_path)],
                       env=ENV, capture_output=True, text=True, timeout=420)
    assert "DRYRUN_OK" in r.stdout, r.stdout + r.stderr
    rec = json.loads(
        (tmp_path / "xlstm-125m_decode_32k_multipod_citest.json")
        .read_text())
    assert rec["status"] == "ok"


@pytest.mark.slow
def test_dryrun_records_long500k_skips(tmp_path):
    code = r"""
import repro.launch.dryrun as dr
from pathlib import Path
import sys
dr.ART = Path(sys.argv[1])
rec = dr.run_cell("gemma-2b", "long_500k", multi_pod=False,
                  force=True, tag="_citest")
assert rec["status"] == "skipped" and "sub-quadratic" in rec["reason"]
print("SKIP_OK")
"""
    r = subprocess.run([sys.executable, "-c", code, str(tmp_path)],
                       env=ENV, capture_output=True, text=True, timeout=180)
    assert "SKIP_OK" in r.stdout, r.stdout + r.stderr


def test_all_baseline_artifacts_green():
    """The committed dry-run record: 40 cells × 2 meshes, zero failures."""
    art = os.path.join(REPO, "artifacts", "dryrun")
    if not os.path.isdir(art):
        import pytest

        pytest.skip("dry-run artifacts not generated yet")
    from repro.configs import ARCH_IDS
    from repro.configs.shapes import SHAPES

    n_ok = n_skip = 0
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("pod", "multipod"):
                f = os.path.join(art, f"{arch}_{shape}_{mesh}.json")
                assert os.path.exists(f), f"missing cell {f}"
                rec = json.load(open(f))
                assert rec["status"] in ("ok", "skipped"), (
                    arch, shape, mesh, rec.get("error"))
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
    assert n_ok == 64 and n_skip == 16, (n_ok, n_skip)
