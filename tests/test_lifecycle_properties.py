"""Hypothesis battery for the lifecycle subsystem: ANY interleaving of
insert / remove / update / repair / query stays coherent across the
whole plan matrix —

* results parity: an engine on any (batching × scorer) combination
  returns BITWISE the same (ids AND sims) as the wave × jnp reference
  driven through the identical interleaving (batching and scorer are
  results-transparent, and every mutation routes through both engines'
  own plans identically);
* no served id is tombstoned at serve time;
* device state equals a from-scratch rebuild of the surviving rows:
  the sharded placement's delta-maintained shard tensors (including the
  per-shard tombstone column) match a fresh rematerialization
  (tests/test_plan.py comparator), and the single placement's
  journal-scattered padded copies match a fresh full upload.
"""
import copy

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # [test] extra; skip, don't break collection
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.params import C2Params
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.plan import DescentPlan

from test_plan import _assert_matches_rebuild  # same-dir test module

OPS = ("insert", "remove", "update", "repair", "query", "serve")


@pytest.fixture(scope="module")
def small_index():
    from repro.query.index import build_index

    ds = make_dataset("synth", scale=0.05, seed=5)
    return build_index(ds, C2Params(k=8, b=64, t=4, max_cluster=32))


@pytest.fixture(scope="module")
def profiles():
    qds = make_dataset("synth", scale=0.05, seed=7)
    return [qds.profile(u) for u in range(24)]


def _assert_single_matches_rebuild(engine):
    """Journal-scattered single-placement device copies == a fresh full
    upload of the same host index, bitwise (tomb column included)."""
    delta = engine.plan._sync_single()
    fresh = DescentPlan(engine.index, engine.plan.spec)._sync_single()
    for a, b, name in zip(delta, fresh,
                          ("graph", "rev", "words", "card", "tomb")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def _drive(engine, ops, profiles, seed):
    """Apply an op sequence; mutation targets are drawn from a seeded
    rng over the engine's own live set, so two engines with identical
    result semantics walk identical index trajectories."""
    rng = np.random.default_rng(seed)
    n_ins = 0
    for op in ops:
        ix = engine.index
        if op == "insert":
            engine.insert(profiles[8 + (n_ins % 16)])
            n_ins += 1
        elif op == "remove":
            alive = ix.alive_ids()
            if len(alive) > ix.k + 2:
                engine.remove_user(int(rng.choice(alive)))
        elif op == "update":
            alive = ix.alive_ids()
            engine.update_user(int(rng.choice(alive)),
                               profiles[int(rng.integers(0, 8))])
        elif op == "repair":
            engine.lifecycle.repair()
        elif op == "query":
            engine.query_batch(profiles[:4])
        else:  # serve through the scheduler loop (maintain fires)
            for i in range(3):
                engine.submit(QueryRequest(
                    rid=i, profile=np.asarray(profiles[i], np.int32)))
            engine.run()
    return engine.query_batch(profiles[:4])  # the final probe wave


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(ops=st.lists(st.sampled_from(OPS), min_size=1, max_size=10),
       shards=st.integers(min_value=1, max_value=3),
       continuous=st.booleans(),
       kernel=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_any_interleaving_matches_reference_and_rebuild(
        small_index, profiles, ops, shards, continuous, kernel, seed):
    def build(cont, kern):
        eng = QueryEngine(copy.deepcopy(small_index),
                          QueryConfig(k=8, beam=12, hops=2, shards=shards,
                                      slots=8, continuous=cont, kernel=kern,
                                      refresh_every=10**9))
        eng.query_batch(profiles[:4])  # freeze the base plan
        return eng

    eng = build(continuous, kernel)
    ids, sims = _drive(eng, ops, profiles, seed)

    # No tombstoned id is ever served — probe wave and scheduler runs.
    tomb = eng.index.tombstone
    live = ids[ids != -1]
    assert not tomb[live].any()
    for r in eng.done:
        served = r.ids[r.ids != -1]
        assert not tomb[served].any()

    # Device state == from-scratch rebuild over the surviving rows.
    if shards > 1:
        _assert_matches_rebuild(eng)
    else:
        _assert_single_matches_rebuild(eng)

    # Batching × scorer are results-transparent under churn: the wave ×
    # jnp reference walks the identical trajectory, bitwise.
    if continuous or kernel:
        ref = build(False, False)
        ref_ids, ref_sims = _drive(ref, ops, profiles, seed)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_array_equal(sims, ref_sims)
        assert eng.index.version == ref.index.version
        np.testing.assert_array_equal(eng.index.graph_ids,
                                      ref.index.graph_ids)
        np.testing.assert_array_equal(eng.index.tombstone,
                                      ref.index.tombstone)
