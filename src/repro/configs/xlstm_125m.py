"""xLSTM-125M [arXiv:2405.04517; unverified]: 12 blocks d=768 4 heads,
no separate FFN (d_ff=0; xLSTM blocks carry their own up/down projection).
mLSTM:sLSTM ratio 5:1 (period-6 pattern), per the paper's mostly-mLSTM
small configs. subquadratic → runs long_500k with O(1) state."""
from repro.models.config import ModelConfig

_M = ("mlstm",)
_S = ("slstm",)


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        head_dim=192, d_ff=0, vocab_size=50304,
        block_pattern=(_M, _M, _M, _M, _M, _S),
        subquadratic=True,
    )
