"""Granite-34B-code [arXiv:2405.04324; hf]: 88L d=6144 48H MQA (kv=1)
d_ff=24576 (4·d, plain GELU — the 4× ratio implies the non-gated
GPTBigCode-style MLP; with it the config lands on 34B), vocab 49152."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        head_dim=128, d_ff=24576, vocab_size=49152,
        block_pattern=(("attn", "mlp"),),
        mlp_type="gelu",
    )
