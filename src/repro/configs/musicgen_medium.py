"""MusicGen-medium backbone [arXiv:2306.05284; hf]: 48L d=1536 24H MHA
d_ff=6144 (plain GELU MLP), vocab 2048 (EnCodec codes). The EnCodec
frontend is a stub: input_specs() provides precomputed frame embeddings
(assignment spec); decode emits EnCodec tokens via the embedding table."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        head_dim=64, d_ff=6144, vocab_size=2048,
        block_pattern=(("attn", "mlp"),),
        mlp_type="gelu", frontend="audio",
    )
