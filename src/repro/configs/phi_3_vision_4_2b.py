"""Phi-3-vision-128k [hf:microsoft/Phi-3-vision-128k-instruct; hf]:
phi3-mini backbone 32L d=3072 32H MHA d_ff=8192 SwiGLU vocab 32064.
CLIP frontend is a stub: input_specs() provides precomputed patch
embeddings mixed into the sequence (assignment spec)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        head_dim=96, d_ff=8192, vocab_size=32064,
        block_pattern=(("attn", "mlp"),),
        mlp_type="swiglu", frontend="vision",
    )
