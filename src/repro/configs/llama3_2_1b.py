"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B; unverified]: 16L d=2048 32H
GQA kv=8, SwiGLU d_ff=8192, vocab 128256, tied embeddings, rope 500k."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
        head_dim=64, d_ff=8192, vocab_size=128256,
        block_pattern=(("attn", "mlp"),),
        mlp_type="swiglu", tie_embeddings=True, rope_theta=500_000.0,
    )
