"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published config;
``repro.configs.shapes`` defines the per-arch input-shape set.
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "olmoe-1b-7b",
    "kimi-k2-1t-a32b",
    "musicgen-medium",
    "granite-34b",
    "llama3_2-1b",
    "gemma-2b",
    "granite-20b",
    "recurrentgemma-2b",
    "phi-3-vision-4_2b",
    "xlstm-125m",
)

# CLI ids (with dots) → module names.
ALIASES = {
    "llama3.2-1b": "llama3_2-1b",
    "phi-3-vision-4.2b": "phi-3-vision-4_2b",
}


def get_config(arch_id: str):
    arch_id = ALIASES.get(arch_id, arch_id)
    assert arch_id in ARCH_IDS, f"unknown arch {arch_id!r}; have {ARCH_IDS}"
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.get_config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
