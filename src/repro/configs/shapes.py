"""Assigned input shapes for the LM-family pool (seq_len × global_batch).

``train_4k`` lowers train_step; ``prefill_32k`` lowers prefill_step;
``decode_32k``/``long_500k`` lower decode_step (one new token against a
seq_len cache). ``long_500k`` requires sub-quadratic attention: it runs
only for SSM/hybrid archs (cfg.subquadratic) and is recorded as skipped
for pure full-attention archs (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True
