"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d=2048 16H (MHA) per-expert
d_ff=1024, 64 experts top-8, vocab 50304. ~7B total / ~1.3B active."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=1024, vocab_size=50304,
        block_pattern=(("attn", "moe"),),
        n_experts=64, experts_per_token=8,
        mlp_type="swiglu",
    )
