"""Kimi K2 (paper-table proxy) [arXiv:2501.kimi2; unverified]: 61L d=7168
64H GQA kv=8, per-expert d_ff=2048, 384 experts top-8, vocab 163840.
~1.03T total / ~31B active. Spec followed as assigned (no MLA/shared
expert — the pool entry lists plain GQA)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        head_dim=112, d_ff=2048, vocab_size=163840,
        block_pattern=(("attn", "moe"),),
        n_experts=384, experts_per_token=8,
        mlp_type="swiglu",
    )
