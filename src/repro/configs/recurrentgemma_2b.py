"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf]: 26L d=2560 10H MQA
head_dim=256, GeGLU d_ff=7680, vocab 256000, RG-LRU + local attention
(window 2048) at a 2:1 ratio. 26 = 2×13, so the (r,r,a) cycle is encoded
as a 13-layer pattern (9r+4a) — identical block counts (18 recurrent /
8 attention), positions shifted by one in the second half. subquadratic →
runs long_500k (local-attn ring cache + O(1) recurrent state)."""
from repro.models.config import ModelConfig

_R = ("rglru", "mlp")
_A = ("local_attn", "mlp")


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        block_pattern=(_R, _R, _A, _R, _R, _A, _R, _R, _A, _R, _R, _A, _R),
        mlp_type="geglu", window=2048, rglru_width=2560,
        tie_embeddings=True, scale_embed=True, subquadratic=True,
    )
