"""Granite-20B-code [arXiv:2405.04324; hf]: 52L, otherwise as granite-34b."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        head_dim=128, d_ff=24576, vocab_size=49152,
        block_pattern=(("attn", "mlp"),),
        mlp_type="gelu",
    )
