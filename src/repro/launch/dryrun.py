import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell on the production mesh and record memory/cost/collective
analysis. This is the proof that the distribution config is coherent:
sharding mismatches, compile-time OOMs, or unsupported collectives all
fail here.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Results are cached as JSON under artifacts/dryrun/ (one file per cell);
launch/roofline.py and EXPERIMENTS.md read from there.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import sharding as sh
from repro.models.sharding import make_ctx
from repro.serve.steps import decode_step, prefill_step
from repro.train.optimizer import OptConfig
from repro.train.steps import train_step

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective op in the
    partitioned HLO (shapes in post-SPMD HLO are per-device)."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "fusion" in s.split("=")[0]:
            continue
        for c in _COLLECTIVES:
            # Match the op name at the instruction position, e.g.
            # "%ag = bf16[16,1024]{1,0} all-gather(...)".
            if f" {c}(" in s or f" {c}-start(" in s:
                lhs = s.split(f" {c}")[0]
                nbytes = 0
                for m in _SHAPE_RE.finditer(lhs):
                    dt, dims = m.group(1), m.group(2)
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dt]
                out[c] += nbytes
                counts[c] += 1
                break
    out_total = int(sum(out.values()))
    return {"per_op_bytes": out, "per_op_counts": counts,
            "total_bytes_per_device": out_total}


def _mem_dict(mem) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "host_temp_size_in_bytes")
    d = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            d[k] = int(v)
    return d


def build_cell(arch: str, shape_name: str, mesh, *, oc=None,
               n_microbatches: int = 1, loss_chunk: int = 0,
               donate: bool = False, grad_scatter: bool = False,
               remat="full", cfg_overrides: dict | None = None):
    """Returns (step_fn, args, in_shardings, out_shardings, donate)."""
    import dataclasses

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    oc = oc or OptConfig()
    ctx = make_ctx(mesh)
    specs = input_specs(cfg, shape, oc)
    ba = sh.batch_axes_of(mesh)

    pspec = sh.param_pspecs(cfg, specs["params"], mesh)
    psh = sh.to_shardings(pspec, mesh)
    rep = NamedSharding(mesh, P())

    def bshard(tree):
        return jax.tree.map(
            lambda x: NamedSharding(mesh, P(ba, *([None] * (x.ndim - 1))))
            if x.ndim >= 1 and x.shape[0] % _nbatch(mesh) == 0 else rep,
            tree)

    if shape.kind == "train":
        opt_sh = jax.tree.map(
            lambda path_leaf: None, specs["opt_state"])  # placeholder
        opt_pspec = {
            "step": P(),
            "m": pspec, "v": pspec,
        }
        if "err" in specs["opt_state"]:
            opt_pspec["err"] = pspec
        opt_sh = sh.to_shardings(opt_pspec, mesh)

        gsh = psh if grad_scatter else None  # opt-in: FSDP grad scatter

        def step(params, opt_state, batch):
            return train_step(params, opt_state, batch, cfg, ctx, oc,
                              n_microbatches=n_microbatches, remat=remat,
                              loss_chunk=loss_chunk, grad_shardings=gsh)

        args = (specs["params"], specs["opt_state"], specs["batch"])
        in_sh = (psh, opt_sh, bshard(specs["batch"]))
        metrics_sh = {"loss": rep, "ce": rep, "step": rep}
        out_sh = (psh, opt_sh, metrics_sh)
        dn = (0, 1) if donate else ()  # opt-in: donate params+opt state
        return step, args, in_sh, out_sh, dn

    if shape.kind == "prefill":
        is_emb = cfg.frontend is not None

        def step(params, batch):
            x = batch["embeddings"] if is_emb else batch["tokens"]
            return prefill_step(params, x, cfg, ctx,
                                s_alloc=shape.seq_len, is_embeds=is_emb)

        args = (specs["params"], specs["batch"])
        cache_abs = jax.eval_shape(step, *args)[1]
        cache_sh = sh.to_shardings(
            sh.cache_pspecs(cfg, cache_abs, mesh), mesh)
        logits_sh = NamedSharding(mesh, P(ba, None, "model"))
        return step, args, (psh, bshard(specs["batch"])), \
            (logits_sh, cache_sh), ()

    # decode
    cache_sh = sh.to_shardings(
        sh.cache_pspecs(cfg, specs["cache"], mesh), mesh)

    def step(params, cache, batch):
        return decode_step(params, cache, batch["tokens"],
                           batch["cur_index"], cfg, ctx)

    args = (specs["params"], specs["cache"], specs["batch"])
    B = shape.global_batch
    bax = ba if B % _nbatch(mesh) == 0 else None  # long_500k: batch=1
    bsh = {"tokens": NamedSharding(mesh, P(bax, None)), "cur_index": rep}
    logits_sh = NamedSharding(mesh, P(bax, None, "model"))
    dn = (1,) if donate else ()  # alias the decode cache in place
    return step, args, (psh, cache_sh, bsh), (logits_sh, cache_sh), dn


def _nbatch(mesh):
    import numpy as np

    return int(np.prod([mesh.shape[a] for a in sh.batch_axes_of(mesh)]))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, n_microbatches: int = 1,
             loss_chunk: int = 0, donate: bool = False,
             grad_scatter: bool = False, cfg_overrides: dict | None = None,
             remat="full", tag: str = "") -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    ART.mkdir(parents=True, exist_ok=True)
    out_path = ART / f"{arch}_{shape_name}_{mesh_name}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "status": "skipped"}
    if not applicable(cfg, shape_name):
        rec["reason"] = ("long_500k needs sub-quadratic attention; "
                         f"{arch} is pure full-attention (DESIGN.md §6)")
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        step, args, in_sh, out_sh, donate_nums = build_cell(
            arch, shape_name, mesh, n_microbatches=n_microbatches,
            loss_chunk=loss_chunk, donate=donate,
            grad_scatter=grad_scatter, cfg_overrides=cfg_overrides,
            remat=remat)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh,
                              donate_argnums=donate_nums).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax: list of dicts
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        from repro.launch.hlo_analysis import analyze

        analysis = analyze(hlo)
        rec.update(
            analysis=analysis,
            status="ok",
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            n_devices=int(mesh.size),
            memory=_mem_dict(mem),
            cost={k: float(v) for k, v in (cost or {}).items()
                  if isinstance(v, (int, float))},
            collectives=collective_bytes(hlo),
            hlo_bytes=len(hlo),
        )
        print(f"[dryrun] OK  {arch} × {shape_name} × {mesh_name}"
              f"{tag}  lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops={rec['cost'].get('flops', 0):.3e} "
              f"coll={rec['collectives']['total_bytes_per_device']:.3e}B")
        print(f"         memory: {rec['memory']}")
    except Exception as e:  # record failures — they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] FAIL {arch} × {shape_name} × {mesh_name}: {e}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--grad-scatter", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. mlstm_chunk=128")
    ap.add_argument("--remat", default="full", choices=["full", "save_tp"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        "dry-run expects 512 host devices; do not import jax before this "
        "module sets XLA_FLAGS")

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if args.mesh == "both"
              else [args.mesh == "multipod"])
    n_ok = n_fail = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                rec = run_cell(arch, shp, mp, force=args.force,
                               n_microbatches=args.microbatches,
                               loss_chunk=args.loss_chunk,
                               donate=args.donate,
                               grad_scatter=args.grad_scatter,
                               cfg_overrides={
                                   k: (int(v) if v.lstrip("-").isdigit()
                                       else v) for k, v in
                                   (o.split("=") for o in args.override)
                               } or None,
                               remat=args.remat,
                               tag=args.tag)
                if rec["status"] == "error":
                    n_fail += 1
                elif rec["status"] == "ok":
                    n_ok += 1
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
