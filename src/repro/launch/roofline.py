"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch × shape × mesh) cell:

    compute term    = HLO_matmul_FLOPs_per_device / 197 TFLOP/s
    memory term     = HLO_bytes_per_device        / 819 GB/s
    collective term = collective_bytes_per_device / 50 GB/s/link

plus MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens
(prefill/decode), the MODEL/HLO ratio (remat & masked-attention waste
show up here), the dominant term, and a what-would-move-it note.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = Path(__file__).resolve().parents[3] / "artifacts"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: 1 token/sequence


def _advice(bottleneck: str, kind: str, arch: str) -> str:
    cfg = get_config(arch)
    if bottleneck == "collective":
        if cfg.n_experts:
            return ("shrink TP all-reduce traffic: sequence-sharded "
                    "norms/residual (SP) + keep expert psum in bf16")
        return ("sequence parallelism on the model axis to turn per-layer "
                "all-reduces into reduce-scatter/all-gather halves")
    if bottleneck == "memory":
        if kind == "decode":
            return ("KV-cache traffic dominates: quantize cache to int8, "
                    "grow per-chip batch, or shard heads wider")
        return ("activation traffic dominates: fuse the f32 loss/softmax "
                "pipeline, keep residuals bf16, reduce remat width")
    return "compute-bound: raise per-chip utilization (larger tiles/batch)"


def load_cells(mesh_name: str = "pod", tag: str = ""):
    rows = []
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            f = ART / "dryrun" / f"{arch}_{shape_name}_{mesh_name}{tag}.json"
            if not f.exists():
                continue
            rec = json.loads(f.read_text())
            row = {"arch": arch, "shape": shape_name,
                   "status": rec["status"]}
            if rec["status"] == "skipped":
                row["note"] = rec.get("reason", "")
                rows.append(row)
                continue
            if rec["status"] != "ok":
                row["note"] = rec.get("error", "")[:160]
                rows.append(row)
                continue
            a = rec["analysis"]
            n_dev = rec["n_devices"]
            t_c = a["flops_per_device"] / PEAK_FLOPS_BF16
            t_m = a["bytes_per_device"] / HBM_BW
            t_x = a["collective_bytes_per_device"] / ICI_BW
            terms = {"compute": t_c, "memory": t_m, "collective": t_x}
            bott = max(terms, key=terms.get)
            mf = model_flops(arch, shape_name)
            kind = SHAPES[shape_name].kind
            row.update(
                n_devices=n_dev,
                compute_s=t_c, memory_s=t_m, collective_s=t_x,
                bottleneck=bott,
                model_flops_global=mf,
                hlo_flops_per_device=a["flops_per_device"],
                model_over_hlo=mf / n_dev / max(a["flops_per_device"], 1.0),
                mfu_bound=(mf / n_dev / PEAK_FLOPS_BF16)
                / max(terms[bott], 1e-12),
                temp_bytes=rec["memory"].get("temp_size_in_bytes"),
                advice=_advice(bott, kind, arch),
            )
            rows.append(row)
    return rows


def render(rows, title="Roofline (single-pod 16×16, v5e terms)"):
    out = [f"### {title}", "",
           "| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO | MFU-bound | temp GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                       f"{r.get('note','')} | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {r['model_over_hlo']:.2f} | "
            f"{r['mfu_bound']:.3f} | "
            f"{(r['temp_bytes'] or 0) / 1e9:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = load_cells(args.mesh, args.tag)
    (ART / f"roofline_{args.mesh}{args.tag}.json").write_text(
        json.dumps(rows, indent=2))
    print(render(rows))


if __name__ == "__main__":
    main()
