"""Serving launcher: bring up the batched engine on a model and drive it
with synthetic requests (or wire a real frontend at the Engine API).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 16 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.config import scaled_down
from repro.models.model import init_params
from repro.serve.engine import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = scaled_down(cfg)
    params = init_params(jax.random.key(args.seed), cfg)
    engine = Engine(params, cfg, ServeConfig(
        max_batch=args.max_batch, max_prompt=args.max_prompt,
        max_new=args.max_new))

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.max_prompt))
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=int(rng.integers(2, args.max_new + 1))))
    stats = engine.run()
    print(f"[serve] {stats['requests']} requests in {stats['waves']} waves"
          f" | {stats['tokens_per_s']:.1f} tok/s"
          f" | latency mean {stats['mean_latency_s']:.2f}s"
          f" p95 {stats['p95_latency_s']:.2f}s")
    return stats


if __name__ == "__main__":
    main()
