"""Distributed KNN-graph construction driver (the paper's system as a
service on the trainer's mesh), with per-hash-configuration checkpointing
— the map-reduce fault-tolerance the paper sketches in §VIII: each
configuration's partial KNN graph is an independent map task; a restart
skips completed configurations.

    PYTHONPATH=src python -m repro.launch.knn_build --dataset ml1M \
        --scale 0.2 --k 10 --ckpt-dir /tmp/knn_ck
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.clustering import build_plan
from repro.core.local_knn import local_knn
from repro.core.merge import merge_partial
from repro.core.params import C2Params, params_for
from repro.data.synthetic import make_dataset
from repro.sketch.goldfinger import fingerprint_dataset
from repro.types import NEG_INF, PAD_ID


def build(ds, params: C2Params, ckpt_dir: str | None = None,
          mesh=None, verbose: bool = True, gf=None):
    if gf is None:
        gf = fingerprint_dataset(ds, n_bits=params.n_bits, seed=params.seed)
    plan = build_plan(ds, params)
    t, n, k = params.t, ds.n_users, params.k
    ids = np.full((t, n, k), PAD_ID, dtype=np.int32)
    sims = np.full((t, n, k), NEG_INF, dtype=np.float32)

    done = set()
    cdir = Path(ckpt_dir) if ckpt_dir else None
    if cdir and cdir.exists():
        for f in cdir.glob("config_*.npz"):
            i = int(f.stem.split("_")[1])
            z = np.load(f)
            ids[i], sims[i] = z["ids"], z["sims"]
            done.add(i)
        if done and verbose:
            print(f"[knn] resuming: configs {sorted(done)} already done")

    from repro.core.clustering import ClusterPlan

    for i in range(t):
        if i in done:
            continue
        t0 = time.time()
        # Restrict the plan to configuration i (independent map task).
        sub_members = [m for m, c in zip(plan.members, plan.config_of)
                       if c == i]
        sub = ClusterPlan(
            members=sub_members,
            config_of=np.zeros(len(sub_members), dtype=np.int32),
            n_users=n, t=1)
        if mesh is not None:
            from repro.core.distributed import distributed_local_knn
            i1, s1, _ = distributed_local_knn(sub, gf, params, mesh)
        else:
            i1, s1 = local_knn(sub, gf, params)
        ids[i], sims[i] = i1[0], s1[0]
        if cdir:
            cdir.mkdir(parents=True, exist_ok=True)
            tmp = cdir / f".tmp_config_{i:03d}.npz"
            np.savez(tmp, ids=ids[i], sims=sims[i])
            tmp.rename(cdir / f"config_{i:03d}.npz")
        if verbose:
            print(f"[knn] config {i}: {time.time() - t0:.2f}s")
    graph = merge_partial(ids, sims, k)
    return graph, plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ml1M")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-after-config", type=int, default=None)
    ap.add_argument("--index-out", default=None,
                    help="save a servable KNNIndex (.npz) for knn_serve")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    ds = make_dataset(args.dataset, scale=args.scale, seed=args.seed)
    params = params_for(args.dataset, k=args.k)
    if args.fail_after_config is not None:
        # Simulate a failure: run only the first m configs then exit.
        import dataclasses

        build(ds, dataclasses.replace(params, t=args.fail_after_config),
              ckpt_dir=args.ckpt_dir)
        print("[knn] simulated failure after "
              f"{args.fail_after_config} configs")
        raise SystemExit(42)
    t0 = time.time()
    gf = fingerprint_dataset(ds, n_bits=params.n_bits, seed=params.seed)
    graph, plan = build(ds, params, ckpt_dir=args.ckpt_dir, gf=gf)
    print(f"[knn] built KNN graph for {ds.n_users} users in "
          f"{time.time() - t0:.2f}s "
          f"({plan.n_clusters} clusters, {plan.brute_force_sims()} sims)")
    print(f"[knn] avg_sim = {graph.avg_sim():.4f}")
    if args.index_out:
        from repro.query.index import build_index

        index = build_index(ds, params, graph=graph, plan=plan, gf=gf)
        index.save(args.index_out)
        print(f"[knn] servable index saved to {args.index_out} "
              f"(serve with: python -m repro.launch.knn_serve "
              f"--index {args.index_out})")


if __name__ == "__main__":
    main()
