"""Training launcher: config system + checkpoint/restart + deterministic
data skip + failure simulation.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 50 --ckpt-dir /tmp/ck --ckpt-every 10

On this CPU container use --smoke (reduced config). On a pod, drop
--smoke and pass --mesh pod; the same script runs under the production
mesh with the sharding rules of models/sharding.py.

Fault tolerance: checkpoints are atomic (repro.checkpoint); on restart
the loader resumes at the saved step + 1 (batches are a pure function of
step). --fail-at-step N simulates a node failure mid-run for tests.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data.tokens import DataConfig, TokenPipeline
from repro.models.config import scaled_down
from repro.models.layers import ShardCtx
from repro.models.model import init_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.steps import train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--data-order", default="iid", choices=["iid", "c2"])
    ap.add_argument("--grad-compress", default=None, choices=[None, "int8"])
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="simulate a node failure (tests restart)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = scaled_down(cfg)
    ctx = ShardCtx()  # single host; pod runs pass a mesh via sharding.make_ctx
    oc = OptConfig(lr=args.lr, grad_compress=args.grad_compress)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    seed=args.seed, ordering=args.data_order,
                    n_docs=max(1024, 4 * args.batch))
    pipe = TokenPipeline(cfg, dc)

    params = init_params(jax.random.key(args.seed), cfg)
    opt_state = init_opt_state(params, oc)
    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step = ckpt.restore(
            args.ckpt_dir, (params, opt_state))
        start_step += 1
        print(f"[train] restored checkpoint, resuming at step {start_step}")

    step_fn = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg, ctx, oc,
                                   n_microbatches=args.microbatches))
    t0 = time.time()
    for step in range(start_step, args.steps):
        if args.fail_at_step is not None and step == args.fail_at_step:
            print(f"[train] simulating node failure at step {step}")
            raise SystemExit(42)
        batch = {k: jax.numpy.asarray(v)
                 for k, v in pipe.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f}"
                  f" ({(time.time() - t0):.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, (params, opt_state), step)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, (params, opt_state), args.steps - 1)
    print(f"[train] done: {args.steps - start_step} steps, "
          f"final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
