"""Production meshes (TPU v5e numbers: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI).

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

# Hardware constants used by the roofline analysis (launch/roofline.py).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes kept for spec reuse)."""
    return jax.make_mesh((1, 1), ("data", "model"))
