"""Online KNN query serving CLI: build (or load) an index, serve a wave
of unseen query profiles, report QPS / latency / recall vs brute force.

    PYTHONPATH=src python -m repro.launch.knn_serve --dataset synth \
        --scale 0.2 --queries 256

Pass ``--index path.npz`` to serve a previously built artifact
(``launch/knn_build --index-out``), ``--insert M`` to also exercise
online insertion before the query wave, and any combination of the
three plan axes (``repro/query/plan.py`` — the flags compose freely
and invalid values fail loudly instead of silently dropping a flag):

* ``--shards S`` — placement: LPT cluster shards (shard_map when a
  device per shard exists, vmapped on one device otherwise — see
  repro/query/sharded.py; inserts delta-reshard instead of rebuilding);
* ``--continuous`` — batching: stream requests through the slot-based
  scheduler (``repro/sched/``) instead of closed waves — same results,
  but admission happens mid-descent; composes with ``--shards`` (per-
  shard slot arrays, cross-shard merge at slot release);
* ``--kernel`` — scorer: the fused Pallas descent-scoring hop
  (``repro/kernels/descent_score``; identical results, candidates
  deduped before the estimator runs). Add ``--dma`` for the
  HBM-resident placement: tables stay in HBM and only surviving
  candidate lanes' fingerprint rows are DMA'd into VMEM per scoring
  chunk (double-buffered; identical results again) — the per-query
  byte traffic and the traffic the suppressed-lane skip avoided are
  reported on a ``[serve] descent:`` line.

Lifecycle flags (``repro/lifecycle/``): ``--churn M`` deletes M users
and profile-updates M more online before the query wave (both picked
id-strided over the live rows, so reruns are deterministic), ``--ttl``
expires rows untouched for that many scheduler ticks, and
``--repair-every`` re-links delete-damaged rows on that tick cadence.

SLO flags (``repro/sched/scheduler.py`` + ``repro/query/cache.py``):
``--admission slo`` ranks pending requests by (priority class,
deadline) and sheds expired/overflow work explicitly (``--max-pending``
bounds the queue; shed requests complete with a ``rejected`` marker),
``--priority-split F`` marks the first F fraction of the wave
high-priority (class 0, the rest class 1), ``--deadline-ms D`` stamps
every request with a D-millisecond deadline, ``--adaptive P`` frees a
continuous slot once its top-k prefix has held P hops, and
``--cache N`` serves exact-fingerprint repeats from an N-entry result
cache invalidated by index-mutation journals.

Re-balance flags (``repro/query/rebalance.py``, shards > 1 only):
``--rebalance-every N`` measures shard imbalance every N scheduler
steps and blue/green-swaps to a freshly derived plan when it exceeds
``--rebalance-threshold`` (merge-based subgraph rebuild, in-flight
beams remapped, result cache flushed); ``--resident-configs M``
restricts shard residency to clusters of the first M hash
configurations (tiered residency: ~t/M per-shard memory for a small
recall cost; routing still sees every cluster).

Fault-tolerance flags (``repro/faults/``): ``--fault-plan SPEC``
schedules deterministic faults at the plan-step boundary
(``kill:S@T``, ``fail:S@T+D``, ``slow:S@T+D:MS``, ``crash@T`` —
separated by ``;``); killed shards are masked out and served around
(degraded recall reported), then rebuilt from survivors and swapped
back in. ``--store DIR --snapshot-every N`` persists periodic index
snapshots plus a write-ahead journal of every mutation;
``--recover DIR`` skips the build entirely and restores the engine —
bitwise — from the last snapshot + WAL replay.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.params import params_for
from repro.data.synthetic import make_dataset
from repro.faults.plan import EngineCrash
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import KNNIndex, build_index


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=32)
    ap.add_argument("--hops", type=int, default=3)
    ap.add_argument("--max-wave", type=int, default=256)
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching (streaming "
                         "admission) instead of closed waves")
    ap.add_argument("--slots", type=int, default=32,
                    help="in-flight slot capacity in continuous mode")
    ap.add_argument("--shards", type=int, default=1,
                    help="serve across this many LPT cluster shards")
    ap.add_argument("--kernel", action="store_true",
                    help="fused Pallas descent-scoring hop "
                         "(kernels/descent_score; identical results)")
    ap.add_argument("--dma", action="store_true",
                    help="with --kernel: HBM-resident tables + per-"
                         "chunk candidate-row DMA (suppressed lanes "
                         "skipped at the DMA level; identical results, "
                         "reports bytes moved/saved)")
    ap.add_argument("--insert", type=int, default=0,
                    help="insert this many users online before querying")
    ap.add_argument("--churn", type=int, default=0,
                    help="delete this many users AND profile-update as "
                         "many more online before querying")
    ap.add_argument("--ttl", type=int, default=0,
                    help="expire rows untouched for this many scheduler "
                         "ticks (0 = never)")
    ap.add_argument("--repair-every", type=int, default=0,
                    help="re-link churn-damaged rows every this many "
                         "scheduler ticks (0 = off)")
    ap.add_argument("--admission", default="fifo", choices=["fifo", "slo"],
                    help="admission policy: fifo (arrival order) or slo "
                         "(priority class + earliest deadline, explicit "
                         "shedding)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="slo: bound on the pending queue; overflow is "
                         "shed with a rejected marker (0 = unbounded)")
    ap.add_argument("--priority-split", type=float, default=0.0,
                    help="fraction of the wave submitted as high "
                         "priority (class 0); the rest is best-effort "
                         "class 1 (0 = every request class 0)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline in ms from submission; "
                         "expired pending requests are shed under "
                         "--admission slo (0 = no deadline)")
    ap.add_argument("--adaptive", type=int, default=0,
                    help="continuous: free a slot once its top-k prefix "
                         "held this many hops (0 = run to budget)")
    ap.add_argument("--cache", type=int, default=0,
                    help="fingerprint result-cache capacity, journal-"
                         "invalidated on index mutation (0 = off)")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="measure shard imbalance every this many "
                         "scheduler steps; blue/green-swap the plan "
                         "past the threshold (0 = off; needs --shards)")
    ap.add_argument("--rebalance-threshold", type=float, default=1.25,
                    help="measured imbalance (max/mean resident cluster "
                         "mass) that triggers a re-balance swap")
    ap.add_argument("--resident-configs", type=int, default=0,
                    help="tiered residency: only clusters of the first "
                         "M hash configurations contribute shard "
                         "residents (0 = all t; needs --shards)")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault schedule: kill:S@T, "
                         "fail:S@T+D, slow:S@T+D:MS, crash@T "
                         "(';'-separated; steps count scheduler steps)")
    ap.add_argument("--store", default=None,
                    help="crash-store directory: snapshots + write-"
                         "ahead journal of every index mutation")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot cadence in scheduler steps (journal "
                         "compaction; 0 = snapshot only at startup)")
    ap.add_argument("--recover", default=None,
                    help="recover the engine from this crash-store "
                         "directory (skips the build; last snapshot + "
                         "WAL replay, bitwise)")
    ap.add_argument("--index", default=None, help="load a saved index")
    ap.add_argument("--save-index", default=None, help="save the built index")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    faults = None
    if args.fault_plan:
        from repro.faults import FaultInjector, FaultPlan
        faults = FaultInjector(FaultPlan.parse(args.fault_plan))
        print(f"[serve] fault plan: {faults.plan.describe()}")
    store = None
    if args.store:
        from repro.faults import CrashStore
        store = CrashStore(args.store, every=args.snapshot_every)

    qc = QueryConfig(
        k=args.k, beam=args.beam, hops=args.hops, max_wave=args.max_wave,
        shards=args.shards, continuous=args.continuous, slots=args.slots,
        kernel=args.kernel, dma=args.dma,
        ttl=args.ttl, repair_every=args.repair_every,
        admission=args.admission, max_pending=args.max_pending,
        adaptive=args.adaptive, cache=args.cache,
        resident_configs=args.resident_configs,
        rebalance_every=args.rebalance_every,
        rebalance_threshold=args.rebalance_threshold)

    if args.recover:
        engine = QueryEngine.recover(args.recover, qc, faults=faults,
                                     store=store)
        index = engine.index
        print(f"[serve] recovered from {args.recover}: {index.n} users, "
              f"{index.n_clusters} clusters, version {index.version}")
        return _serve(args, engine, index)

    if args.index:
        index = KNNIndex.load(args.index)
        print(f"[serve] loaded index: {index.n} users, k={index.k}, "
              f"t={index.t}, {index.n_clusters} clusters")
    else:
        ds = make_dataset(args.dataset, scale=args.scale, seed=args.seed)
        params = params_for(args.dataset, k=args.k,
                            b=max(64, ds.n_users // 16),
                            max_cluster=max(48, int(0.06 * ds.n_users)))
        t0 = time.perf_counter()
        index = build_index(ds, params)
        print(f"[serve] built index: {ds.n_users} users, k={params.k} "
              f"({time.perf_counter() - t0:.2f}s, "
              f"{index.n_clusters} clusters)")
    if args.save_index:
        index.save(args.save_index)
        print(f"[serve] index saved to {args.save_index}")

    engine = QueryEngine(index, qc, faults=faults, store=store)
    return _serve(args, engine, index)


def _serve(args, engine, index):
    print(f"[serve] plan: {engine.plan.describe()}")

    # Unseen profiles from the same distribution (different seed).
    qds = make_dataset(args.dataset, scale=args.scale, seed=args.seed + 1)
    n_q = min(args.queries, qds.n_users)
    profiles = [qds.profile(u) for u in range(n_q)]

    for m in range(args.insert):
        engine.insert(qds.profile(qds.n_users - 1 - m))
    if args.insert:
        print(f"[serve] inserted {args.insert} users online "
              f"(index now {index.n} users)")

    if args.churn:
        # Id-strided picks over the live rows: deterministic across
        # reruns, and the delete/update sets never overlap.
        alive = index.alive_ids()
        take = np.linspace(0, len(alive) - 1,
                           num=min(2 * args.churn, len(alive)),
                           dtype=np.int64)
        victims = alive[take]
        for u in victims[0::2]:
            engine.remove_user(int(u))
        for m, u in enumerate(victims[1::2]):
            engine.update_user(int(u), qds.profile(m % qds.n_users))
        if args.repair_every:
            engine.lifecycle.repair()  # serve the wave on a healed graph
        print(f"[serve] churned: {len(victims[0::2])} deletes, "
              f"{len(victims[1::2])} updates "
              f"(index now {index.n_live} live rows) | "
              f"lifecycle {engine.lifecycle.stats()}")

    sd = engine.sharded_state()  # after inserts: the waves reuse this state
    if sd is not None:
        mb = [round(b / 1e6, 2) for b in sd.resident_bytes()]
        print(f"[serve] sharded: {sd.n_shards} shards, resident rows "
              f"{[len(r) for r in sd.plan.residents]} "
              f"({mb} MB"
              + (f", configs {sd.plan.resident_configs}/{index.t}"
                 if sd.plan.resident_configs else "")
              + f"), imbalance {sd.plan.imbalance:.2f}, "
              f"{'mesh' if sd.mesh is not None else 'vmap'} execution")

    if not profiles:
        print("[serve] no queries requested")
        return {"requests": 0}, 0.0

    # Warm-up wave compiles the descent program; the timed run reuses it.
    engine.submit(QueryRequest(rid=-1, profile=profiles[0]))
    engine.run()
    engine.done.clear()

    n_high = (int(round(args.priority_split * len(profiles)))
              if args.priority_split > 0 else len(profiles))
    for rid, p in enumerate(profiles):
        deadline = (time.perf_counter() + args.deadline_ms / 1e3
                    if args.deadline_ms > 0 else None)
        engine.submit(QueryRequest(
            rid=rid, profile=p,
            priority=0 if rid < n_high else 1, deadline=deadline))
    try:
        stats = engine.run()
    except EngineCrash as e:
        # The injected crash lands between scheduler steps: every
        # mutation is journaled, in-flight requests are lost (clients
        # retry). Report what was durable and exit like a real death.
        print(f"[serve] CRASHED: {e}")
        if engine.store is not None:
            print(f"[serve] recover with: --recover {args.store}  "
                  f"(store: {engine.store.stats()})")
        return {"requests": 0, "crashed": True}, 0.0
    recall = engine.recall_vs_brute_force()
    unit = "ticks" if args.continuous else "waves"
    print(f"[serve] {stats['requests']} queries in {stats['waves']} {unit} "
          f"({stats['mode']}) | "
          f"QPS {stats['qps']:.0f} | "
          f"p50 {stats['p50_latency_s'] * 1e3:.1f}ms | "
          f"p95 {stats['p95_latency_s'] * 1e3:.1f}ms | "
          f"recall@{args.k} vs brute force {recall:.3f}")
    if "descent" in stats:
        d = stats["descent"]
        n_served = max(stats["served"], 1)
        line = (f"[serve] descent: {d['scored_lanes']} lanes scored "
                f"({d['scored_lanes'] / n_served:.0f}/query)")
        if d["dma_bytes"]:
            moved, saved = d["dma_bytes"], d["bytes_saved"]
            line += (f" | dma {moved / 1e6:.2f} MB moved "
                     f"({moved / n_served / 1e3:.1f} KB/query), "
                     f"{saved / 1e6:.2f} MB skipped "
                     f"({saved / (moved + saved):.0%} of gather traffic)")
        print(line)
    if args.admission == "slo":
        print(f"[serve] slo: served {stats['served']}, "
              f"shed {stats['shed']} "
              f"(priority split {n_high}/{len(profiles) - n_high}, "
              f"deadline {args.deadline_ms:.0f}ms)")
    if "cache" in stats:
        c = stats["cache"]
        print(f"[serve] cache: {c['hits']} hits / "
              f"{c['hits'] + c['misses']} lookups "
              f"(rate {c['hit_rate']:.2f}), {c['entries']}/{c['capacity']} "
              f"entries, {c['flushes']} flushes")
    if "faults" in stats:
        f = stats["faults"]
        degraded = [r for r in engine.done if getattr(r, "degraded", False)]
        deg_recall = (engine.recall_vs_brute_force(degraded)
                      if degraded else None)
        print(f"[serve] faults: {f.get('shards_down', 0)} shards down, "
              f"{f.get('deaths', 0)} deaths, "
              f"{f.get('retries', 0)} retries, "
              f"{f.get('backoff_steps', 0)} backoff steps, "
              f"{f.get('failovers', 0)} failovers | "
              f"{len(degraded)} served degraded"
              + (f" (degraded recall@{args.k} {deg_recall:.3f})"
                 if deg_recall is not None else ""))
    if "store" in stats:
        s = stats["store"]
        print(f"[serve] store: {s['snapshots']} snapshots, "
              f"{s['wal_records']} WAL records since last "
              f"(cadence {s['every']})")
    return stats, recall


if __name__ == "__main__":
    main()
