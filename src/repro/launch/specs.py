"""Abstract input specs (ShapeDtypeStruct stand-ins) for every
(architecture × shape) dry-run cell — weak-type-correct, shardable, zero
allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.model import abstract_cache, abstract_params
from repro.train.optimizer import OptConfig, init_opt_state

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    """The data batch for one step (train/prefill/decode)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        labels = SDS((B, S), jnp.int32)
        if cfg.frontend:  # stub frontend: precomputed frame/patch embeddings
            return {"embeddings": SDS((B, S, cfg.d_model), dt),
                    "labels": labels}
        return {"tokens": SDS((B, S), jnp.int32), "labels": labels}
    if shape.kind == "prefill":
        if cfg.frontend:
            return {"embeddings": SDS((B, S, cfg.d_model), dt)}
        return {"tokens": SDS((B, S), jnp.int32)}
    # decode: one new token against a seq_len cache.
    return {"tokens": SDS((B, 1), jnp.int32),
            "cur_index": SDS((), jnp.int32)}


def abstract_state(cfg: ModelConfig, oc: OptConfig):
    """Abstract (params, opt_state) for train cells."""
    params = abstract_params(cfg)
    opt = jax.eval_shape(lambda p: init_opt_state(p, oc), params)
    return params, opt


def abstract_decode_cache(cfg: ModelConfig, shape: ShapeSpec):
    return abstract_cache(cfg, shape.global_batch, shape.seq_len)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                oc: OptConfig | None = None):
    """Everything the cell's step function consumes, abstract."""
    oc = oc or OptConfig()
    out = {"batch": batch_specs(cfg, shape)}
    if shape.kind == "train":
        out["params"], out["opt_state"] = abstract_state(cfg, oc)
    else:
        out["params"] = abstract_params(cfg)
        if shape.kind == "decode":
            out["cache"] = abstract_decode_cache(cfg, shape)
    return out
