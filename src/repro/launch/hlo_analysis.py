"""Post-SPMD HLO cost model: matmul FLOPs, HBM-traffic proxy, and
collective bytes — with while-loop bodies multiplied by their trip counts.

Why not ``compiled.cost_analysis()``: XLA's analysis counts each while
body ONCE, so a scanned 61-layer model reports ~1/61 of its real FLOPs.
This module parses the partitioned HLO text (per-device shapes), resolves
operand shapes through per-computation symbol tables, walks the call graph
(fusion/call/while) and multiplies while bodies by the trip count parsed
from their condition computations.

Scope notes (documented in EXPERIMENTS.md):
* FLOPs counts dot ops only (elementwise/transcendental excluded — the
  MFU convention).
* Bytes counts operands+results at fusion boundaries (fusion internals
  never touch HBM); control ops (tuple/gte/parameter/bitcast/copy) are
  excluded.
* Collective bytes are per-device operand bytes (post-SPMD shapes); the
  wire-time estimate divides by the per-chip ICI link bandwidth.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
                "opaque": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^=]*?\))|(?:\w+\[[\d,]*\][^\s]*))\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_CONTROL_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                "bitcast", "copy", "copy-start", "copy-done", "after-all",
                "partition-id", "replica-id", "iota", "reshape",
                "broadcast", "transpose"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(text: str):
    """All (dtype, dims) array shapes in a type string; bytes + numel."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _nbytes(shapes):
    return sum(n * _DTYPE_BYTES[dt] for dt, n in shapes)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


@dataclass
class Instr:
    name: str
    op: str
    result_text: str
    rest: str
    operands: list = field(default_factory=list)
    rhs: str = ""


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # %name → result type text


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        # Computation header: "%name (args) -> type {" or "ENTRY %name ...".
        if s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0]):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            if m:
                cur = Computation(name=m.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OP_RE.match(rhs)
        if not om:
            continue
        result_text, op = om.group(1), om.group(2)
        rest = rhs[om.end():]
        # Operand names: inside the first (...) — up to the matching paren.
        depth, i0, i1 = 1, 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    i1 = i
                    break
        operands = _OPERAND_RE.findall(rest[:i1])
        attrs = rest[i1:]
        cur.shapes[name] = result_text
        cur.instrs.append(Instr(name=name, op=op, result_text=result_text,
                                rest=attrs, operands=operands, rhs=rhs))
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation: the constant compared
    against the induction variable (max s32 constant as fallback)."""
    consts = []
    for ins in cond.instrs:
        if ins.op == "constant" and ins.result_text.startswith("s32"):
            m = _CONST_RE.search(ins.rhs)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_ops: dict = None

    def __post_init__(self):
        if self.coll_ops is None:
            self.coll_ops = {c: 0.0 for c in _COLLECTIVES}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.coll_bytes += mult * other.coll_bytes
        for c in _COLLECTIVES:
            self.coll_ops[c] += mult * other.coll_ops[c]


def _operand_shape_text(comp: Computation, name: str) -> str:
    return comp.shapes.get(name, "")


_PARAM_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_operand_charges(comp_f: Computation) -> dict[int, float]:
    """Per-operand byte charge for a fusion: parameters that are ONLY
    sliced/gathered inside are charged at slice size, not full size
    (a loop body fusion reading one slice of stacked scan inputs must not
    be charged the whole stack every iteration)."""
    params: dict[int, str] = {}
    for ins in comp_f.instrs:
        if ins.op == "parameter":
            m = _PARAM_RE.search(ins.rhs)
            if m:
                params[int(m.group(1))] = ins.name
    charges: dict[int, float] = {}
    for idx, pname in params.items():
        uses = [i2 for i2 in comp_f.instrs if pname in i2.operands]
        if uses and all(u.op in ("dynamic-slice", "slice", "gather")
                        for u in uses):
            charges[idx] = float(sum(
                _nbytes(_parse_shapes(u.result_text)) for u in uses))
        else:
            charges[idx] = -1.0  # full operand bytes
    return charges


def analyze(hlo: str) -> dict:
    comps = parse_module(hlo)
    memo: dict[str, Cost] = {}
    charge_memo: dict[str, dict[int, float]] = {}

    def eval_comp(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = Cost()
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                res = _parse_shapes(ins.result_text)
                numel = sum(n for _, n in res)
                lhs_shape = _parse_shapes(
                    _operand_shape_text(comp, ins.operands[0]))
                m = _LHS_CDIMS_RE.search(ins.rest)
                contract = 1
                if m and lhs_shape:
                    dims_txt = _SHAPE_RE.search(
                        _operand_shape_text(comp, ins.operands[0]))
                    if dims_txt:
                        dims = [int(d) for d in dims_txt.group(2).split(",")
                                if d]
                        for ci in m.group(1).split(","):
                            if ci:
                                contract *= dims[int(ci)]
                c.flops += 2.0 * numel * contract
                c.bytes += _nbytes(res) + sum(
                    _nbytes(_parse_shapes(_operand_shape_text(comp, o)))
                    for o in ins.operands)
                continue
            is_coll = False
            for cname in _COLLECTIVES:
                if op == cname or op == cname + "-start":
                    nb = sum(_nbytes(_parse_shapes(
                        _operand_shape_text(comp, o)))
                        for o in ins.operands)
                    if nb == 0:  # fallback: result bytes
                        nb = _nbytes(_parse_shapes(ins.result_text))
                    c.coll_bytes += nb
                    c.coll_ops[cname] += nb
                    c.bytes += nb
                    is_coll = True
                    break
            if is_coll:
                continue
            if op == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trip = _trip_count(comps[cond.group(1)]) if cond else 1
                if body:
                    c.add(eval_comp(body.group(1)), mult=max(trip, 1))
                if cond:
                    c.add(eval_comp(cond.group(1)), mult=max(trip, 1))
                continue
            if op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                charges = {}
                if m:
                    inner = eval_comp(m.group(1))
                    # FLOPs/collectives from inside; bytes at the boundary.
                    c.flops += inner.flops
                    c.coll_bytes += inner.coll_bytes
                    for cn in _COLLECTIVES:
                        c.coll_ops[cn] += inner.coll_ops[cn]
                    if m.group(1) not in charge_memo:
                        charge_memo[m.group(1)] = _fusion_operand_charges(
                            comps.get(m.group(1)) or Computation(""))
                    charges = charge_memo[m.group(1)]
                c.bytes += _nbytes(_parse_shapes(ins.result_text))
                for k, o in enumerate(ins.operands):
                    ch = charges.get(k, -1.0)
                    c.bytes += (ch if ch >= 0 else _nbytes(
                        _parse_shapes(_operand_shape_text(comp, o))))
                continue
            if op in ("call", "custom-call", "conditional"):
                m = _TO_APPLY_RE.search(ins.rest)
                if m:
                    c.add(eval_comp(m.group(1)))
                continue
            if op in _CONTROL_OPS:
                continue
            # Slicing ops read/write only the slice, not the full operand
            # (a while body dynamic-slicing stacked scan inputs would
            # otherwise be charged the full stack every iteration).
            if op in ("dynamic-slice", "slice", "gather"):
                c.bytes += 2 * _nbytes(_parse_shapes(ins.result_text))
                continue
            if op in ("dynamic-update-slice", "scatter"):
                upd = (_operand_shape_text(comp, ins.operands[1])
                       if len(ins.operands) > 1 else ins.result_text)
                c.bytes += 2 * _nbytes(_parse_shapes(upd))
                continue
            # Generic op: boundary bytes only.
            c.bytes += _nbytes(_parse_shapes(ins.result_text)) + sum(
                _nbytes(_parse_shapes(_operand_shape_text(comp, o)))
                for o in ins.operands)
        memo[name] = c
        return c

    entry = comps.get("__entry__")
    if entry is None:
        return {"error": "no ENTRY computation found"}
    c = eval_comp(entry.name)
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "collective_bytes_per_device": c.coll_bytes,
        "collective_per_op": {k: v for k, v in c.coll_ops.items()},
    }
