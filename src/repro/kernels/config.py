# One switch for every kernel package: interpret vs compiled Pallas.
#
# All three kernel wrappers (`descent_score.ops`, `goldfinger_knn.ops`,
# `frh_minhash.ops`) resolve their `interpret=` argument through
# `interpret_mode()` at trace time, so the whole repo flips between the
# interpret-mode emulator (bitwise-checked against each package's
# `ref.py`, runs anywhere including CPU CI) and compiled TPU kernels
# with a single environment variable:
#
#   REPRO_PALLAS_INTERPRET=1   interpret mode (the default — CPU CI)
#   REPRO_PALLAS_INTERPRET=0   compile for the attached accelerator
#
# Accepted falsy spellings: 0 / false / no / off (case-insensitive);
# anything else — including unset — means interpret mode. Tests (and
# callers that must not depend on ambient env) can pin the mode
# programmatically with `set_interpret(True/False)`, which overrides the
# environment until `set_interpret(None)` restores env-driven behavior.

from __future__ import annotations

import os

ENV_VAR = "REPRO_PALLAS_INTERPRET"
_FALSY = frozenset({"0", "false", "no", "off"})

_override: bool | None = None


def set_interpret(value: bool | None) -> None:
    """Pin interpret mode (True/False), or None to follow the env var."""
    global _override
    _override = None if value is None else bool(value)


def interpret_mode() -> bool:
    """Resolve the interpret flag: override first, then REPRO_PALLAS_INTERPRET."""
    if _override is not None:
        return _override
    raw = os.environ.get(ENV_VAR)
    if raw is None:
        return True
    return raw.strip().lower() not in _FALSY
