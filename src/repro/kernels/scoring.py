"""Shared bounded-VMEM GoldFinger scoring tiles for the Pallas kernels.

Every kernel that estimates Jaccard similarities — the descent hop's
gathered-lane scoring (VMEM and DMA variants) and the build-time
``goldfinger_knn`` all-pairs sweep — runs the same estimator:

    inter = popcount(fp_u & fp_v)            (exact integer, two layouts)
    union = card_u + card_v - inter
    sim   = inter / max(union, 1)  if union > 0 else 0

These helpers are the *single* implementation of that chunk-shaped
epilogue, so the kernels stay bitwise-interchangeable with each other and
with ``sketch.goldfinger.jaccard_pairwise_auto``: the intersection is an
exact int32 either way (VPU popcount or int8 bit-plane MXU matmul) and
the f32 epilogue is the same ops in the same order. Both helpers score a
bounded tile — ``[bq, chunk]`` lanes or ``[bq, bd_chunk]`` pairs — so no
caller ever materializes an ``[n, n]``-scale interaction tensor in VMEM;
chunking a scoring loop over either helper is bitwise-invisible because
each output element depends only on its own (query, candidate) pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sketch.goldfinger import unpack_bits_int8
from repro.types import NEG_INF


def score_gathered_chunk(qw, qcf, q_bits, cw, ccf, need_c, *, mxu: bool):
    """Score one chunk of per-lane gathered candidate fingerprints.

    qw u32[bq, W] query fingerprints; qcf f32[bq, 1] query cardinalities;
    q_bits int8[bq, W·32] pre-unpacked bit planes (only read when
    ``mxu``); cw u32[bq·ch, W] gathered candidate rows, lane-major;
    ccf f32[bq, ch] candidate cardinalities (0 on suppressed lanes);
    need_c bool[bq, ch] surviving-lane mask. Returns f32[bq, ch] sims
    with ``NEG_INF`` on suppressed lanes. Suppressed lanes may hold
    arbitrary garbage in ``cw``/``ccf`` — each lane's score depends only
    on its own row (the MXU path keeps the per-row diagonal), so garbage
    never leaks into surviving lanes, and the final ``where`` retires it.
    """
    bq, ch = need_c.shape
    W = qw.shape[1]
    if mxu:
        # Tile-dense bit-plane matmul: chunk candidates × ALL tile
        # queries on the MXU, keep the per-row diagonal.
        c_bits = unpack_bits_int8(cw)                   # [bq·ch, W·32]
        inter3 = jax.lax.dot_general(
            c_bits, q_bits, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).reshape(bq, ch, bq)
        own = jax.lax.broadcasted_iota(jnp.int32, (bq, ch, bq), 0)
        qid = jax.lax.broadcasted_iota(jnp.int32, (bq, ch, bq), 2)
        inter = jnp.sum(jnp.where(own == qid, inter3, 0),
                        axis=-1).astype(jnp.float32)
    else:
        inter = jnp.sum(
            jax.lax.population_count(qw[:, None, :]
                                     & cw.reshape(bq, ch, W)),
            axis=-1).astype(jnp.float32)                # [bq, ch]
    union = qcf + ccf - inter
    s_c = jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)
    return jnp.where(need_c, s_c, NEG_INF)


def jaccard_bitplane_tile(q_bits, q_card_col, d_bits, d_card_row):
    """Dense Jaccard tile from pre-unpacked bit planes (build-time sweep).

    q_bits int8[bq, B] {0,1}; q_card_col f32[bq, 1];
    d_bits int8[ch, B]; d_card_row f32[1, ch]. Returns f32[bq, ch].
    ``ch`` is a *chunk* of the database block — callers loop chunks so
    the interaction tile stays bounded instead of one [bq, bd] matmul.
    """
    inter = jax.lax.dot_general(
        q_bits, d_bits, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)                               # [bq, ch]
    union = q_card_col + d_card_row - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)
