"""Fused multi-seed FastRandomHash — Pallas TPU kernel (Step 1 hot loop).

Computes H_i(u) = min_{item∈P_u} h_i(item) for all t hash functions in one
pass over the padded profile matrix: the murmur3 finalizer is 4 VPU ops per
(item, seed), the min-reduce stays in VREGs, and each profile row is read
from HBM exactly once for all t seeds (the CPU implementation reads it t
times). b must be a power of two so the modulo is a bitwise AND.

Block = (bn users × P items); the t-seed loop is unrolled inside the kernel
(t ≤ 16 in all paper configurations).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import NO_HASH
from repro.types import PAD_ID


def _fmix32(x):
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EB_CA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2_AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _minhash_kernel(items_ref, out_ref, *, seeds: tuple[int, ...], b: int):
    items = items_ref[...]                       # i32[bn, P]
    pad = items == PAD_ID
    items_u = items.astype(jnp.uint32)
    mins = []
    for s in seeds:  # unrolled: t is a small static constant
        mix = jnp.uint32((int(s) + 1) * 0x9E37_79B9 & 0xFFFF_FFFF)
        h = (_fmix32(items_u ^ mix) & jnp.uint32(b - 1)).astype(jnp.int32)
        h = jnp.where(pad, NO_HASH, h)
        mins.append(jnp.min(h, axis=1))          # [bn]
    out_ref[...] = jnp.stack(mins, axis=1)       # [bn, t]


@functools.partial(jax.jit, static_argnames=("seeds", "b", "block_n",
                                             "interpret"))
def minhash_pallas(padded_items, seeds: tuple[int, ...], b: int,
                   block_n: int = 256, interpret: bool = True):
    """int32[n, P] padded profiles → int32[n, t] FastRandomHash values."""
    assert b & (b - 1) == 0, "b must be a power of two for the kernel path"
    n, P = padded_items.shape
    t = len(seeds)
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        functools.partial(_minhash_kernel, seeds=seeds, b=b),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, P), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t), jnp.int32),
        interpret=interpret,
    )(padded_items)
