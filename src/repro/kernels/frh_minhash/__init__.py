from repro.kernels.frh_minhash import ops, ref  # noqa: F401
