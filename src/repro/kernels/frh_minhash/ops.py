"""jit'd public wrapper for the frh_minhash kernel.

Interpret-vs-compiled resolves per call through
``repro.kernels.config`` (``$REPRO_PALLAS_INTERPRET``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import config
from repro.kernels.frh_minhash.frh_minhash import minhash_pallas
from repro.types import PAD_ID, Dataset


def minhash(padded_items, seeds, b: int, block_n: int = 256):
    """int32[n, P] (PAD_ID padded) → int32[n, t] FastRandomHash values."""
    n, P = padded_items.shape
    bn = min(block_n, max(8, n))
    pad = (-n) % bn
    if pad:
        padded_items = jnp.concatenate(
            [jnp.asarray(padded_items),
             jnp.full((pad, P), PAD_ID, jnp.int32)], axis=0)
    out = minhash_pallas(jnp.asarray(padded_items),
                         tuple(int(s) for s in seeds), b,
                         block_n=bn, interpret=config.interpret_mode())
    return out[:n]


def dataset_minhash(ds: Dataset, seeds, b: int) -> np.ndarray:
    """Host entry: returns int32[t, n] to match hashing.user_min_hash_np."""
    padded, _ = ds.padded_profiles()
    out = minhash(jnp.asarray(padded), seeds, b)
    return np.asarray(out).T.copy()
