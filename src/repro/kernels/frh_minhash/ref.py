"""Pure-jnp oracle for the fused FastRandomHash kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hashing import NO_HASH, fmix32
from repro.types import PAD_ID


def minhash_ref(padded_items, seeds, b: int):
    """H_i(u) for every (user, seed): int32[n, t].

    padded_items int32[n, P] (PAD_ID padded); seeds int32[t]; b the hash
    space size. Empty profiles yield NO_HASH.
    """
    items = padded_items.astype(jnp.uint32)
    s = seeds.astype(jnp.uint32)
    x = items[:, :, None] ^ ((s[None, None, :] + jnp.uint32(1))
                             * jnp.uint32(0x9E37_79B9))
    h = (fmix32(x) % jnp.uint32(b)).astype(jnp.int32)  # [n, P, t]
    h = jnp.where((padded_items == PAD_ID)[:, :, None], NO_HASH, h)
    return jnp.min(h, axis=1)
