# Compute hot-spots the paper optimizes, as Pallas TPU kernels.
#
# goldfinger_knn/  — fused blocked GoldFinger-Jaccard + streaming top-k
#                    (Step 2's similarity computations: the paper's
#                    dominant cost, "most of the total computation time").
# frh_minhash/     — fused multi-seed FastRandomHash min-reduce (Step 1).
# descent_score/   — fused serving hop (query hot path): beam adjacency
#                    gather + dedup-before-scoring + GoldFinger
#                    estimator + in-register top-k merge.
#
# Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper) and ref.py (pure-jnp oracle); tests sweep shapes/dtypes in
# interpret mode against the oracle.
