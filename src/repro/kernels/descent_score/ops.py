"""Public wrappers for the descent_score kernel.

Handles query-row padding to block multiples, card reshaping to the
kernel's 2-D layout, the popcount-vs-MXU layout choice by sketch width,
and the VMEM-vs-DMA placement choice (``dma=``). Launch parameters are
resolved at plain-Python level — interpret mode through
``repro.kernels.config`` (``$REPRO_PALLAS_INTERPRET``), DMA tile shapes
through the shape-keyed ``tune`` cache — then handed to an inner jit as
static arguments. ``descent_hop`` itself is *not* jitted: it runs at
trace time of whatever jitted program calls it (wave scan, slot hop,
sharded vmap), so the resolution happens once per outer trace and the
tuner memo keeps repeated shapes from ever re-tracing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import config
from repro.kernels.descent_score import tune
from repro.kernels.descent_score.descent_score import (hop_pallas,
                                                       hop_pallas_dma)
from repro.sketch.goldfinger import MXU_MIN_WORDS
from repro.types import NEG_INF, PAD_ID


def _pad_rows(x, to: int, fill):
    n = x.shape[0]
    if n % to == 0:
        return x
    pad = to - n % to
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "chunk", "mxu", "dma",
                                    "n_buffers", "with_counts",
                                    "interpret"))
def _hop_jit(graph_ids, rev_ids, words, card, t2d, q_words, q_card,
             beam_ids, beam_sims, *, block_q: int, chunk: int, mxu: bool,
             dma: bool, n_buffers: int, with_counts: bool,
             interpret: bool):
    q = beam_ids.shape[0]
    qw = _pad_rows(jnp.asarray(q_words), block_q, 0)
    qc = _pad_rows(jnp.asarray(q_card).reshape(-1, 1).astype(jnp.int32),
                   block_q, 0)
    bi = _pad_rows(beam_ids, block_q, PAD_ID)
    bs = _pad_rows(beam_sims, block_q, NEG_INF)
    tables = (jnp.asarray(graph_ids), jnp.asarray(rev_ids),
              jnp.asarray(words),
              jnp.asarray(card).reshape(-1, 1).astype(jnp.int32), t2d)
    if dma:
        out_ids, out_sims, n_scored, dma_bytes, bytes_saved = hop_pallas_dma(
            *tables, qw, qc, bi, bs,
            block_q=block_q, chunk=chunk, mxu=mxu, n_buffers=n_buffers,
            interpret=interpret)
    else:
        out_ids, out_sims, n_scored = hop_pallas(
            *tables, qw, qc, bi, bs,
            block_q=block_q, chunk=chunk, mxu=mxu, interpret=interpret)
        # The VMEM placement moves whole tables as operands — no per-row
        # DMA happens, so the byte counters are identically zero.
        dma_bytes = jnp.zeros_like(n_scored)
        bytes_saved = jnp.zeros_like(n_scored)
    if with_counts:
        return (out_ids[:q], out_sims[:q], n_scored[:q, 0],
                dma_bytes[:q, 0], bytes_saved[:q, 0])
    return out_ids[:q], out_sims[:q]


def descent_hop(graph_ids, rev_ids, words, card, q_words, q_card,
                beam_ids, beam_sims, *, block_q: int | None = None,
                mxu: bool | None = None, with_counts: bool = False,
                tomb=None, dma: bool = False,
                score_chunk: int | None = None,
                n_buffers: int | None = None):
    """One fused descent hop; same contract as ref.descent_hop_ref.

    Padded query rows (PAD beams) produce PAD/−inf rows and score
    nothing; they are sliced off before returning. ``tomb`` (bool[n] or
    None) marks tombstoned index rows: their lanes retire with the
    PAD/in-beam suppression, before the estimator — None synthesizes an
    all-live mask, which is bitwise a no-op.

    ``dma=True`` selects the HBM-resident placement
    (:func:`~.descent_score.hop_pallas_dma`): tables stay in ANY/HBM
    memory and only surviving lanes' fingerprint rows are DMA'd, with
    ``(block_q, score_chunk, n_buffers)`` resolved per index shape by
    ``tune.hop_params`` unless overridden. Results are bitwise-identical
    to the VMEM placement and the jnp reference either way.

    With ``with_counts`` returns a 5-tuple ``(ids, sims, n_scored,
    dma_bytes, bytes_saved)``, each i32[q] per query for this hop:
    lanes that survived in-tile suppression and were scored (the
    unfused path always scores ``beam·(kg+kr)``), fingerprint bytes
    DMA'd (``n_scored·W·4`` for the DMA placement, 0 for VMEM), and
    fingerprint bytes the suppression skipped at the DMA level.
    """
    q = beam_ids.shape[0]
    B = beam_ids.shape[1]
    n, W = words.shape
    kg, kr = graph_ids.shape[1], rev_ids.shape[1]
    if tomb is None:
        t2d = jnp.zeros((n, 1), jnp.int32)
    else:
        t2d = jnp.asarray(tomb).astype(jnp.int32).reshape(-1, 1)
    if mxu is None:
        mxu = W >= MXU_MIN_WORDS
    if dma:
        p = tune.hop_params(n, W, B, kg + kr, q)
        if block_q is None:
            block_q = min(p.block_q, max(q, 1))
        if score_chunk is None:
            score_chunk = p.score_chunk
        if n_buffers is None:
            n_buffers = p.n_buffers
    else:
        if block_q is None:
            # Wide sketches blow up 8× when unpacked to bit-planes —
            # keep the per-tile candidate block small; narrow sketches
            # amortize grid overhead with bigger tiles. Capped at the
            # actual row count so small waves / slot arrays (continuous
            # serving runs q = n_slots every tick) never do dense
            # estimator work on padding.
            block_q = min(8 if mxu else 64, max(q, 1))
        if score_chunk is None:
            score_chunk = 256
        n_buffers = 1
    return _hop_jit(graph_ids, rev_ids, words, card, t2d, q_words, q_card,
                    beam_ids, beam_sims, block_q=block_q,
                    chunk=score_chunk, mxu=mxu, dma=dma,
                    n_buffers=n_buffers, with_counts=with_counts,
                    interpret=config.interpret_mode())
