"""jit'd public wrappers for the descent_score kernel.

Handles query-row padding to block multiples, card reshaping to the
kernel's 2-D layout, and the popcount-vs-MXU layout choice by sketch
width. ``interpret`` defaults to True (this container is CPU; on TPU
pass interpret=False), mirroring ``goldfinger_knn/ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.descent_score.descent_score import hop_pallas
from repro.sketch.goldfinger import MXU_MIN_WORDS
from repro.types import NEG_INF, PAD_ID

INTERPRET = True  # flipped to False on real TPU deployments


def _pad_rows(x, to: int, fill):
    n = x.shape[0]
    if n % to == 0:
        return x
    pad = to - n % to
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "mxu", "with_counts"))
def descent_hop(graph_ids, rev_ids, words, card, q_words, q_card,
                beam_ids, beam_sims, *, block_q: int | None = None,
                mxu: bool | None = None, with_counts: bool = False,
                tomb=None):
    """One fused descent hop; same contract as ref.descent_hop_ref.

    Padded query rows (PAD beams) produce PAD/−inf rows and score
    nothing; they are sliced off before returning. With ``with_counts``
    also returns n_scored i32[q] — candidate lanes that survived
    in-tile suppression and were actually scored (the unfused path
    always scores ``beam·(kg+kr)`` per query). ``tomb`` (bool[n] or
    None) marks tombstoned index rows: their lanes retire with the
    PAD/in-beam suppression, before the estimator — None synthesizes an
    all-live mask, which is bitwise a no-op.
    """
    q = beam_ids.shape[0]
    W = words.shape[1]
    if tomb is None:
        t2d = jnp.zeros((words.shape[0], 1), jnp.int32)
    else:
        t2d = jnp.asarray(tomb).astype(jnp.int32).reshape(-1, 1)
    if mxu is None:
        mxu = W >= MXU_MIN_WORDS
    if block_q is None:
        # Wide sketches blow up 8× when unpacked to bit-planes — keep
        # the per-tile candidate block small; narrow sketches amortize
        # grid overhead with bigger tiles. Capped at the actual row
        # count so small waves / slot arrays (continuous serving runs
        # q = n_slots every tick) never do dense estimator work on
        # padding.
        block_q = min(8 if mxu else 64, max(q, 1))
    qw = _pad_rows(jnp.asarray(q_words), block_q, 0)
    qc = _pad_rows(jnp.asarray(q_card).reshape(-1, 1).astype(jnp.int32),
                   block_q, 0)
    bi = _pad_rows(beam_ids, block_q, PAD_ID)
    bs = _pad_rows(beam_sims, block_q, NEG_INF)
    out_ids, out_sims, n_scored = hop_pallas(
        jnp.asarray(graph_ids), jnp.asarray(rev_ids), jnp.asarray(words),
        jnp.asarray(card).reshape(-1, 1).astype(jnp.int32), t2d,
        qw, qc, bi, bs,
        block_q=block_q, mxu=mxu, interpret=INTERPRET)
    if with_counts:
        return out_ids[:q], out_sims[:q], n_scored[:q, 0]
    return out_ids[:q], out_sims[:q]
