"""Shape-keyed autotuner for the DMA descent hop.

The DMA hop (`descent_score.hop_pallas_dma`) has three launch knobs —
``block_q`` (queries per tile), ``score_chunk`` (candidate lanes per
DMA/score round) and ``n_buffers`` (rotating VMEM row-buffer depth) —
whose good values depend on the *index* shape, not the call site:
``(n, W, beam, kg+kr)`` fixes the candidate count, row width and VMEM
pressure. This module replaces the fixed constants with a small tuner:

* ``hop_params(n, W, beam, kdeg, q)`` → :class:`HopParams`, resolved in
  priority order: in-process memo → on-disk cache (JSON at
  ``$REPRO_TUNE_CACHE``, if set) → measured table (entries recorded by
  :func:`record`) → the VMEM-budget heuristic. Every resolution is
  memoized, so a serving plan asks exactly once per index shape — that
  is what keeps jit from re-tracing across admissions and reshards
  (same shape → same params → same trace; the compile-once regression
  in ``tests/test_descent_dma.py`` pins this).
* ``record(key, params)`` lets a measuring caller (``kernel_bench.py``)
  write a winner back; with ``$REPRO_TUNE_CACHE`` set it persists.
* ``stats`` counts hits/misses for CI gates.

The heuristic targets a scratch budget: the rotating row buffers cost
``n_buffers·block_q·score_chunk·(W+1)·4`` bytes and must leave room for
the adjacency staging (``block_q·beam·(kg+kr+2)·4``) and the staged
tombstone column (``n·4``) inside a few MB of VMEM.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading

from repro.sketch.goldfinger import MXU_MIN_WORDS

ENV_CACHE = "REPRO_TUNE_CACHE"

# Rotating-row-buffer budget for the heuristic (bytes). Deliberately far
# under real VMEM (16 MB) — the tables' staging and the compiler's own
# spills need the rest.
_SCRATCH_BUDGET = 2 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class HopParams:
    """Launch configuration for one (n, W, beam, kdeg) index shape."""
    block_q: int
    score_chunk: int
    n_buffers: int


stats = {"hits": 0, "misses": 0, "disk_hits": 0}

_lock = threading.Lock()
_memo: dict[tuple[int, int, int, int], HopParams] = {}
_measured: dict[tuple[int, int, int, int], HopParams] = {}
_disk_loaded = False


def shape_key(n: int, W: int, beam: int, kdeg: int) -> tuple[int, int, int, int]:
    return (int(n), int(W), int(beam), int(kdeg))


def _heuristic(n: int, W: int, beam: int, kdeg: int) -> HopParams:
    C = max(1, beam * kdeg)
    mxu = W >= MXU_MIN_WORDS
    # MXU tiles keep bq small (the bit-plane matmul is bq-quadratic in
    # the diagonal trick); popcount tiles amortize the fori_loop better
    # with more queries per tile.
    block_q = 8 if mxu else 16
    # Largest power-of-two chunk that fits the double-buffered budget.
    row_bytes = (W + 1) * 4
    chunk = 128
    while chunk > 16 and 2 * block_q * chunk * row_bytes > _SCRATCH_BUDGET:
        chunk //= 2
    chunk = min(chunk, max(16, C))
    n_buffers = 1 if C <= chunk else 2
    return HopParams(block_q=block_q, score_chunk=chunk,
                     n_buffers=n_buffers)


def _cache_path() -> str | None:
    return os.environ.get(ENV_CACHE) or None


def _load_disk() -> None:
    global _disk_loaded
    if _disk_loaded:
        return
    _disk_loaded = True
    path = _cache_path()
    if not path or not os.path.exists(path):
        return
    try:
        with open(path) as fh:
            raw = json.load(fh)
    except (OSError, ValueError):
        return
    for skey, p in raw.items():
        try:
            key = tuple(int(x) for x in skey.split(","))
            if len(key) != 4:
                continue
            _measured[key] = HopParams(int(p["block_q"]),
                                       int(p["score_chunk"]),
                                       int(p["n_buffers"]))
        except (KeyError, TypeError, ValueError):
            continue


def _save_disk() -> None:
    path = _cache_path()
    if not path:
        return
    payload = {
        ",".join(str(x) for x in key): dataclasses.asdict(p)
        for key, p in sorted(_measured.items())
    }
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass


def record(key: tuple[int, int, int, int], params: HopParams) -> None:
    """Record a measured winner for an index shape (and persist it)."""
    with _lock:
        _load_disk()
        _measured[key] = params
        _memo[key] = params
        _save_disk()


def hop_params(n: int, W: int, beam: int, kdeg: int,
               q: int | None = None) -> HopParams:
    """Resolve launch params for one index shape (memoized per process).

    ``q`` (the wave width) only clamps ``block_q`` — it is *not* part of
    the cache key, so admissions of different wave widths against the
    same index reuse one resolution.
    """
    key = shape_key(n, W, beam, kdeg)
    with _lock:
        p = _memo.get(key)
        if p is None:
            _load_disk()
            p = _measured.get(key)
            if p is not None:
                stats["disk_hits"] += 1
            else:
                p = _heuristic(*key)
            stats["misses"] += 1
            _memo[key] = p
        else:
            stats["hits"] += 1
    if q is not None and q > 0 and p.block_q > q:
        p = dataclasses.replace(p, block_q=max(1, q))
    return p


def clear(reset_stats: bool = True) -> None:
    """Drop all in-process state (tests; does not touch the disk cache)."""
    global _disk_loaded
    with _lock:
        _memo.clear()
        _measured.clear()
        _disk_loaded = False
        if reset_stats:
            for k in stats:
                stats[k] = 0
