"""Fused descent-scoring hop (ops) + its jnp oracle (ref).

``ops.descent_hop`` is one ``pallas_call`` per hop — adjacency gather,
dedup-before-scoring lane suppression, GoldFinger popcount / MXU
bit-plane scoring, in-register top-k merge — bitwise-identical to
``ref.descent_hop_ref``. Both are selected by the plan's *scorer* axis
(``query/plan.py``) and compose with the other two axes through the
hop's row independence: the wave AND continuous slot programs call it
directly, and the sharded placement vmaps it over the shard axis (the
pallas_call batching rule) in both ``sharded._vmapped_descent`` and
the per-shard slot programs ``search.shard_slot_admit`` /
``search.shard_slot_hop``.
"""
from repro.kernels.descent_score import ops, ref  # noqa: F401
