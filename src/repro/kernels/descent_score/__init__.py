from repro.kernels.descent_score import ops, ref  # noqa: F401
