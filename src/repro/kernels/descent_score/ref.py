"""Pure-jnp oracle for the fused descent-hop kernel.

This is the historical ``query/search.descent_step`` body, verbatim
semantics: gather forward + reverse neighbors of the beam, score every
candidate lane with the GoldFinger estimator, then let ``merge_topk``
mask duplicates/PADs and run one wide ``lax.top_k``. The fused kernel
must match it bit for bit (ids and sims); ``query/search`` also serves
through it when ``QueryConfig(kernel=False)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.knn.topk import merge_topk
from repro.sketch.goldfinger import jaccard_pairwise_auto
from repro.types import NEG_INF, PAD_ID


def row_scorer(words, card):
    """Row scorer: sims of one query against a PAD_ID-padded id list.

    The estimator layout is width-dispatched (``jaccard_pairwise_auto``):
    VPU popcount for narrow sketches, int8 bit-plane MXU matmul for wide
    raw-incidence ones — bitwise-identical results either way.
    """

    def score_row(qw, qc, cids):
        safe = jnp.where(cids == PAD_ID, 0, cids)
        cw = words[safe]
        cc = jnp.where(cids == PAD_ID, 0, card[safe])
        s = jaccard_pairwise_auto(qw[None], qc[None], cw, cc)[0]
        return jnp.where(cids == PAD_ID, NEG_INF, s)

    return jax.vmap(score_row)


def mask_dead(tomb, ids, sims=None):
    """PAD out lanes naming tombstoned rows (``tomb`` bool[n]), in place
    positionally — no compaction, so lane order (and therefore every
    downstream tie-break) is exactly what an index with those references
    excised would produce. With ``sims``, masked lanes also drop to
    −inf (beam lanes carry a sim; candidate lanes are scored later)."""
    t = jnp.asarray(tomb)
    safe = jnp.where(ids == PAD_ID, 0, ids)
    dead = (ids != PAD_ID) & t[safe]
    out_ids = jnp.where(dead, PAD_ID, ids)
    if sims is None:
        return out_ids
    return out_ids, jnp.where(dead, NEG_INF, sims)


def descent_hop_ref(graph_ids, rev_ids, words, card,
                    q_words, q_card, beam_ids, beam_sims, tomb=None):
    """One friend-of-a-friend hop, unfused: gather → score ALL lanes →
    dedup after the fact → wide top-k. Returns (beam_ids, beam_sims).

    ``tomb`` (bool[n] or None) masks tombstoned rows out *before* any
    scoring: dead beam lanes become PAD/−inf (a row deleted mid-descent
    leaves the beam) and dead candidate lanes become PAD (stale edges to
    deleted rows score nothing) — the same pre-masking the fused kernel
    applies, so the bitwise ref↔kernel equivalence is unchanged.
    """
    if tomb is not None:
        beam_ids, beam_sims = mask_dead(tomb, beam_ids, beam_sims)
    nq = q_words.shape[0]
    kg, kr = graph_ids.shape[1], rev_ids.shape[1]
    score = row_scorer(words, card)
    safe = jnp.where(beam_ids == PAD_ID, 0, beam_ids)
    fwd = graph_ids[safe].reshape(nq, -1)
    fwd = jnp.where((beam_ids == PAD_ID).repeat(kg, axis=1), PAD_ID, fwd)
    rev = rev_ids[safe].reshape(nq, -1)
    rev = jnp.where((beam_ids == PAD_ID).repeat(kr, axis=1), PAD_ID, rev)
    cand = jnp.concatenate([fwd, rev], axis=1)      # [q, beam·(kg+kr)]
    if tomb is not None:
        cand = mask_dead(tomb, cand)
    cand_sims = score(q_words, q_card, cand)
    return merge_topk(
        jnp.concatenate([beam_ids, cand], axis=1),
        jnp.concatenate([beam_sims, cand_sims], axis=1),
        beam_ids.shape[1])
