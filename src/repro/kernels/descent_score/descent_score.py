"""Fused descent-hop (gather → suppress → score → merge) — Pallas TPU kernel.

Serving's hot loop (``query/search.descent_step``) was an unfused chain:
two adjacency gathers materialize a ``[q, beam·(kg+kr)]`` candidate
tensor in HBM, the GoldFinger estimator scores *every* lane, a
double-argsort ``dedup_mask`` then throws most of those scores away, and
a wide ``lax.top_k`` re-sorts the lot. The friend-of-a-friend expansion
is heavily duplicated — most popcounts re-score candidates already in
the beam ("A Note on Graph-Based Nearest Neighbor Search": distance
evaluations on revisited candidates dominate graph-search cost). This
kernel does one hop per query-tile entirely in VMEM:

* **Gather (a):** forward + reverse neighbor ids of the current beam —
  ids only (``[bq, beam·(kg+kr)]`` int32); fingerprints are fetched per
  score chunk, so the full candidate-fingerprint tensor never exists.
* **Suppress before scoring (b):** PAD lanes, lanes under PAD beam rows,
  and lanes already in the beam are retired in-tile *before* the
  estimator runs. Suppressed lanes have their gather index zeroed (no
  stray HBM row touch) and are excluded from the scored-lane count the
  kernel reports (``n_scored``), which quantifies the dedup win per hop
  against the unfused ``beam·(kg+kr)``.
* **Score (c):** GoldFinger AND-popcount on the VPU in candidate chunks;
  for wide sketches (raw-incidence mode) an int8 bit-plane variant
  (``unpack_bits_int8``) turns the intersection into an MXU
  ``dot_general`` — tile-dense: the chunk's candidates score against the
  whole query tile in one matmul and the matching diagonal is kept
  (redundant flops on the systolic array beat per-lane popcount loops
  once W is thousands of words).
* **Merge (d):** in-register top-``beam`` via
  :func:`repro.knn.topk.select_topk` with winner-id retirement over
  ``[beam | fwd | rev]`` in the reference column order. Retiring every
  lane of a round's winning id also resolves duplicates *between*
  candidate lanes exactly like ``dedup_mask`` + ``lax.top_k`` would:
  duplicate lanes of an id carry identical sims, so the selected column
  is always the id's first occurrence.

Results are bitwise identical to ``ref.descent_hop_ref`` (the historical
jnp path): same ids, same sims, same tie-breaks — asserted across PAD
patterns and beam widths by ``tests/test_descent_kernel.py``. One
precondition, which every real beam satisfies by construction (beams are
``merge_topk``/``select_topk`` outputs): a beam row never repeats an id.
A repeated beam id at two different sims would be ranked at its *first*
lane by the reference's dedup and at its *max* lane here.

The index arrays ride in whole (index_map pins block 0): the descent
touches the fingerprint table essentially at random anyway, and at this
repo's serving capacities it fits VMEM (n·W·4 bytes ≈ 0.2 MB at
n=1600, W=32). A >VMEM-scale deployment would switch them to HBM
refs with per-chunk DMA of the gathered rows — the chunked scoring loop
is already shaped for that split.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.knn.topk import select_topk
from repro.sketch.goldfinger import unpack_bits_int8
from repro.types import NEG_INF, PAD_ID


def _hop_kernel(graph_ref, rev_ref, words_ref, card_ref, tomb_ref,
                qw_ref, qc_ref, bi_ref, bs_ref,
                out_ids_ref, out_sims_ref, nsc_ref,
                *, chunk: int, mxu: bool):
    beam_ids = bi_ref[...]                              # [bq, B] i32
    beam_sims = bs_ref[...]                             # [bq, B] f32
    bq, B = beam_ids.shape
    kg = graph_ref.shape[1]
    kr = rev_ref.shape[1]
    W = words_ref.shape[1]
    tomb = tomb_ref[...][:, 0]                          # [n] i32 (0|1)

    # (a0) tombstone masking of the beam itself, mirroring the ref's
    # pre-masking: lanes naming deleted rows drop to PAD/−inf before the
    # gather, so a dead beam entry contributes no candidates this hop.
    b_dead = (beam_ids != PAD_ID) & (jnp.take(
        tomb, jnp.where(beam_ids == PAD_ID, 0, beam_ids).reshape(-1)
    ).reshape(bq, B) > 0)
    beam_ids = jnp.where(b_dead, PAD_ID, beam_ids)
    beam_sims = jnp.where(b_dead, NEG_INF, beam_sims)

    # (a) adjacency gather — candidate *ids* only.
    flat = jnp.where(beam_ids == PAD_ID, 0, beam_ids).reshape(-1)
    dead = beam_ids[:, :, None] == PAD_ID               # [bq, B, 1]
    fwd = jnp.take(graph_ref[...], flat, axis=0).reshape(bq, B, kg)
    fwd = jnp.where(dead, PAD_ID, fwd).reshape(bq, B * kg)
    rev = jnp.take(rev_ref[...], flat, axis=0).reshape(bq, B, kr)
    rev = jnp.where(dead, PAD_ID, rev).reshape(bq, B * kr)
    cand = jnp.concatenate([fwd, rev], axis=1)          # [bq, C]
    C = cand.shape[1]

    # (a1) tombstoned candidates become PAD lanes *here*, upstream of the
    # `need` mask — so stale edges to deleted rows are suppressed before
    # the estimator exactly like PAD/in-beam lanes (they are excluded
    # from n_scored, which is how tests observe the suppression).
    c_dead = (cand != PAD_ID) & (jnp.take(
        tomb, jnp.where(cand == PAD_ID, 0, cand).reshape(-1)
    ).reshape(bq, C) > 0)
    cand = jnp.where(c_dead, PAD_ID, cand)

    # (b) suppression BEFORE scoring: PAD lanes and lanes already in the
    # beam (merge would retire them as duplicates of columns 0..B-1 —
    # scoring them first is the waste this kernel removes).
    need = (cand != PAD_ID) & ~jnp.any(
        cand[:, :, None] == beam_ids[:, None, :], axis=-1)
    nsc_ref[...] = jnp.sum(need, axis=1, dtype=jnp.int32).reshape(bq, 1)

    # (c) score surviving lanes, in chunks — the gathered fingerprint
    # block is [bq, chunk, W], never [bq, C, W].
    qw = qw_ref[...]                                    # [bq, W] u32
    qcf = qc_ref[...].astype(jnp.float32)               # [bq, 1]
    words = words_ref[...]
    card = card_ref[...]                                # [n, 1] i32
    if mxu:
        q_bits = unpack_bits_int8(qw)                   # [bq, W·32] i8
    sims_chunks = []
    for s in range(0, C, chunk):
        ids_c = cand[:, s:s + chunk]
        need_c = need[:, s:s + chunk]
        ch = ids_c.shape[1]
        safe = jnp.where(need_c, ids_c, 0).reshape(-1)
        cw = jnp.take(words, safe, axis=0)              # [bq·ch, W]
        cc = jnp.where(need_c,
                       jnp.take(card, safe, axis=0).reshape(bq, ch),
                       0).astype(jnp.float32)
        if mxu:
            # Tile-dense bit-plane matmul: chunk candidates × ALL tile
            # queries on the MXU, keep the per-row diagonal.
            c_bits = unpack_bits_int8(cw)               # [bq·ch, W·32]
            inter3 = jax.lax.dot_general(
                c_bits, q_bits, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            ).reshape(bq, ch, bq)
            own = jax.lax.broadcasted_iota(jnp.int32, (bq, ch, bq), 0)
            qid = jax.lax.broadcasted_iota(jnp.int32, (bq, ch, bq), 2)
            inter = jnp.sum(jnp.where(own == qid, inter3, 0),
                            axis=-1).astype(jnp.float32)
        else:
            inter = jnp.sum(
                jax.lax.population_count(qw[:, None, :]
                                         & cw.reshape(bq, ch, W)),
                axis=-1).astype(jnp.float32)            # [bq, ch]
        union = qcf + cc - inter
        s_c = jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)
        sims_chunks.append(jnp.where(need_c, s_c, NEG_INF))
    cand_sims = jnp.concatenate(sims_chunks, axis=1)

    # (d) in-register merge over [beam | fwd | rev] — the reference
    # column order, so tie-breaks land exactly where lax.top_k puts them.
    top_sims, top_ids = select_topk(
        jnp.concatenate([beam_sims, cand_sims], axis=1),
        jnp.concatenate([beam_ids, cand], axis=1),
        B, dedup_ids=True)
    out_ids_ref[...] = jnp.where(top_sims == NEG_INF, PAD_ID, top_ids)
    out_sims_ref[...] = top_sims


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "chunk", "mxu", "interpret"),
)
def hop_pallas(graph_ids, rev_ids, words, card, tomb, q_words, q_card,
               beam_ids, beam_sims, *,
               block_q: int = 64, chunk: int = 256,
               mxu: bool = False, interpret: bool = True):
    """One fused descent hop for a wave of queries (see ref.descent_hop_ref).

    graph_ids i32[n, kg], rev_ids i32[n, kr]; words u32[n, W],
    card i32[n, 1]; tomb i32[n, 1] (1 = tombstoned row — all-zeros for a
    delete-free index); q_words u32[q, W], q_card i32[q, 1];
    beam_ids i32[q, B], beam_sims f32[q, B]. q % block_q == 0 (ops.py
    pads). Returns (beam_ids i32[q, B], beam_sims f32[q, B],
    n_scored i32[q, 1]) — the beam after the hop plus the per-query count
    of candidate lanes that survived suppression (PAD / in-beam /
    tombstoned all retire first) and were scored.
    """
    q, B = beam_ids.shape
    n, W = words.shape
    kg, kr = graph_ids.shape[1], rev_ids.shape[1]
    bq = min(block_q, q)
    assert q % bq == 0, (q, bq)
    grid = (q // bq,)

    out_ids, out_sims, n_scored = pl.pallas_call(
        functools.partial(_hop_kernel, chunk=chunk, mxu=mxu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, kg), lambda i: (0, 0)),
            pl.BlockSpec((n, kr), lambda i: (0, 0)),
            pl.BlockSpec((n, W), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((bq, W), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, B), jnp.int32),
            jax.ShapeDtypeStruct((q, B), jnp.float32),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(graph_ids, rev_ids, words, card, tomb, q_words, q_card,
      beam_ids, beam_sims)
    return out_ids, out_sims, n_scored
