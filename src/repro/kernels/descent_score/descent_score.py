"""Fused descent-hop (gather → suppress → score → merge) — Pallas TPU kernel.

Serving's hot loop (``query/search.descent_step``) was an unfused chain:
two adjacency gathers materialize a ``[q, beam·(kg+kr)]`` candidate
tensor in HBM, the GoldFinger estimator scores *every* lane, a
double-argsort ``dedup_mask`` then throws most of those scores away, and
a wide ``lax.top_k`` re-sorts the lot. The friend-of-a-friend expansion
is heavily duplicated — most popcounts re-score candidates already in
the beam ("A Note on Graph-Based Nearest Neighbor Search": distance
evaluations on revisited candidates dominate graph-search cost). This
kernel does one hop per query-tile entirely in VMEM:

* **Gather (a):** forward + reverse neighbor ids of the current beam —
  ids only (``[bq, beam·(kg+kr)]`` int32); fingerprints are fetched per
  score chunk, so the full candidate-fingerprint tensor never exists.
* **Suppress before scoring (b):** PAD lanes, lanes under PAD beam rows,
  and lanes already in the beam are retired in-tile *before* the
  estimator runs. Suppressed lanes have their gather index zeroed (no
  stray HBM row touch) and are excluded from the scored-lane count the
  kernel reports (``n_scored``), which quantifies the dedup win per hop
  against the unfused ``beam·(kg+kr)``.
* **Score (c):** GoldFinger AND-popcount on the VPU in candidate chunks;
  for wide sketches (raw-incidence mode) an int8 bit-plane variant
  (``unpack_bits_int8``) turns the intersection into an MXU
  ``dot_general`` — tile-dense: the chunk's candidates score against the
  whole query tile in one matmul and the matching diagonal is kept
  (redundant flops on the systolic array beat per-lane popcount loops
  once W is thousands of words).
* **Merge (d):** in-register top-``beam`` via
  :func:`repro.knn.topk.select_topk` with winner-id retirement over
  ``[beam | fwd | rev]`` in the reference column order. Retiring every
  lane of a round's winning id also resolves duplicates *between*
  candidate lanes exactly like ``dedup_mask`` + ``lax.top_k`` would:
  duplicate lanes of an id carry identical sims, so the selected column
  is always the id's first occurrence.

Results are bitwise identical to ``ref.descent_hop_ref`` (the historical
jnp path): same ids, same sims, same tie-breaks — asserted across PAD
patterns and beam widths by ``tests/test_descent_kernel.py``. One
precondition, which every real beam satisfies by construction (beams are
``merge_topk``/``select_topk`` outputs): a beam row never repeats an id.
A repeated beam id at two different sims would be ranked at its *first*
lane by the reference's dedup and at its *max* lane here.

Two memory placements share this hop body:

* :func:`hop_pallas` — the PR 4 layout: index arrays ride in whole as
  VMEM-style operands (index_map pins block 0). Fine while the tables
  fit VMEM (n·W·4 bytes ≈ 0.2 MB at n=1600, W=32).
* :func:`hop_pallas_dma` — the memory-hierarchy-aware layout: all five
  tables (adjacency fwd/rev, fingerprints, cardinalities, tombstones)
  stay HBM/ANY-memory refs. Candidate *ids* are still gathered in VMEM,
  but fingerprint/cardinality rows are fetched per score chunk by
  double-buffered async-copy DMA into scoped VMEM scratch — copy-in of
  chunk c+1 overlaps scoring of chunk c — and lanes the suppression mask
  retired never issue a DMA at all, so the scored-lane counter directly
  measures bytes not moved. The kernel emits per-query ``dma_bytes`` /
  ``bytes_saved`` outputs (fingerprint bytes; the invariant
  ``dma_bytes == n_scored·W·4`` is test-enforced).

Both are bitwise-identical to each other and to the reference: they
share the suppression mask, the chunked estimator
(:func:`repro.kernels.scoring.score_gathered_chunk`) and the merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.scoring import score_gathered_chunk
from repro.knn.topk import select_topk
from repro.sketch.goldfinger import unpack_bits_int8
from repro.types import NEG_INF, PAD_ID


def _mask_dead_beam(beam_ids, beam_sims, tomb):
    """(a0) tombstone masking of the beam itself, mirroring the ref's
    pre-masking: lanes naming deleted rows drop to PAD/−inf before the
    gather, so a dead beam entry contributes no candidates this hop."""
    bq, B = beam_ids.shape
    b_dead = (beam_ids != PAD_ID) & (jnp.take(
        tomb, jnp.where(beam_ids == PAD_ID, 0, beam_ids).reshape(-1)
    ).reshape(bq, B) > 0)
    return (jnp.where(b_dead, PAD_ID, beam_ids),
            jnp.where(b_dead, NEG_INF, beam_sims))


def _suppress(cand, beam_ids, tomb):
    """(a1)+(b) pre-scoring suppression.

    Tombstoned candidates become PAD lanes *upstream* of the `need`
    mask — stale edges to deleted rows retire exactly like PAD/in-beam
    lanes (and are excluded from n_scored, which is how tests observe
    the suppression). `need` then drops PAD lanes and lanes already in
    the beam (merge would retire them as duplicates of columns 0..B-1 —
    scoring them first is the waste this kernel removes)."""
    bq, C = cand.shape
    c_dead = (cand != PAD_ID) & (jnp.take(
        tomb, jnp.where(cand == PAD_ID, 0, cand).reshape(-1)
    ).reshape(bq, C) > 0)
    cand = jnp.where(c_dead, PAD_ID, cand)
    need = (cand != PAD_ID) & ~jnp.any(
        cand[:, :, None] == beam_ids[:, None, :], axis=-1)
    return cand, need


def _merge(beam_ids, beam_sims, cand, cand_sims, out_ids_ref, out_sims_ref):
    """(d) in-register merge over [beam | fwd | rev] — the reference
    column order, so tie-breaks land exactly where lax.top_k puts them."""
    B = beam_ids.shape[1]
    top_sims, top_ids = select_topk(
        jnp.concatenate([beam_sims, cand_sims], axis=1),
        jnp.concatenate([beam_ids, cand], axis=1),
        B, dedup_ids=True)
    out_ids_ref[...] = jnp.where(top_sims == NEG_INF, PAD_ID, top_ids)
    out_sims_ref[...] = top_sims


def _hop_kernel(graph_ref, rev_ref, words_ref, card_ref, tomb_ref,
                qw_ref, qc_ref, bi_ref, bs_ref,
                out_ids_ref, out_sims_ref, nsc_ref,
                *, chunk: int, mxu: bool):
    beam_ids = bi_ref[...]                              # [bq, B] i32
    beam_sims = bs_ref[...]                             # [bq, B] f32
    bq, B = beam_ids.shape
    kg = graph_ref.shape[1]
    kr = rev_ref.shape[1]
    tomb = tomb_ref[...][:, 0]                          # [n] i32 (0|1)

    beam_ids, beam_sims = _mask_dead_beam(beam_ids, beam_sims, tomb)

    # (a) adjacency gather — candidate *ids* only.
    flat = jnp.where(beam_ids == PAD_ID, 0, beam_ids).reshape(-1)
    dead = beam_ids[:, :, None] == PAD_ID               # [bq, B, 1]
    fwd = jnp.take(graph_ref[...], flat, axis=0).reshape(bq, B, kg)
    fwd = jnp.where(dead, PAD_ID, fwd).reshape(bq, B * kg)
    rev = jnp.take(rev_ref[...], flat, axis=0).reshape(bq, B, kr)
    rev = jnp.where(dead, PAD_ID, rev).reshape(bq, B * kr)
    cand = jnp.concatenate([fwd, rev], axis=1)          # [bq, C]
    C = cand.shape[1]

    cand, need = _suppress(cand, beam_ids, tomb)
    nsc_ref[...] = jnp.sum(need, axis=1, dtype=jnp.int32).reshape(bq, 1)

    # (c) score surviving lanes, in chunks — the gathered fingerprint
    # block is [bq, chunk, W], never [bq, C, W].
    qw = qw_ref[...]                                    # [bq, W] u32
    qcf = qc_ref[...].astype(jnp.float32)               # [bq, 1]
    words = words_ref[...]
    card = card_ref[...]                                # [n, 1] i32
    q_bits = unpack_bits_int8(qw) if mxu else None      # [bq, W·32] i8
    sims_chunks = []
    for s in range(0, C, chunk):
        ids_c = cand[:, s:s + chunk]
        need_c = need[:, s:s + chunk]
        ch = ids_c.shape[1]
        safe = jnp.where(need_c, ids_c, 0).reshape(-1)
        cw = jnp.take(words, safe, axis=0)              # [bq·ch, W]
        cc = jnp.where(need_c,
                       jnp.take(card, safe, axis=0).reshape(bq, ch),
                       0).astype(jnp.float32)
        sims_chunks.append(
            score_gathered_chunk(qw, qcf, q_bits, cw, cc, need_c, mxu=mxu))
    cand_sims = jnp.concatenate(sims_chunks, axis=1)

    _merge(beam_ids, beam_sims, cand, cand_sims, out_ids_ref, out_sims_ref)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "chunk", "mxu", "interpret"),
)
def hop_pallas(graph_ids, rev_ids, words, card, tomb, q_words, q_card,
               beam_ids, beam_sims, *,
               block_q: int = 64, chunk: int = 256,
               mxu: bool = False, interpret: bool = True):
    """One fused descent hop for a wave of queries (see ref.descent_hop_ref).

    graph_ids i32[n, kg], rev_ids i32[n, kr]; words u32[n, W],
    card i32[n, 1]; tomb i32[n, 1] (1 = tombstoned row — all-zeros for a
    delete-free index); q_words u32[q, W], q_card i32[q, 1];
    beam_ids i32[q, B], beam_sims f32[q, B]. q % block_q == 0 (ops.py
    pads). Returns (beam_ids i32[q, B], beam_sims f32[q, B],
    n_scored i32[q, 1]) — the beam after the hop plus the per-query count
    of candidate lanes that survived suppression (PAD / in-beam /
    tombstoned all retire first) and were scored.
    """
    q, B = beam_ids.shape
    n, W = words.shape
    kg, kr = graph_ids.shape[1], rev_ids.shape[1]
    bq = min(block_q, q)
    assert q % bq == 0, (q, bq)
    grid = (q // bq,)

    out_ids, out_sims, n_scored = pl.pallas_call(
        functools.partial(_hop_kernel, chunk=chunk, mxu=mxu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, kg), lambda i: (0, 0)),
            pl.BlockSpec((n, kr), lambda i: (0, 0)),
            pl.BlockSpec((n, W), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((bq, W), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, B), jnp.int32),
            jax.ShapeDtypeStruct((q, B), jnp.float32),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(graph_ids, rev_ids, words, card, tomb, q_words, q_card,
      beam_ids, beam_sims)
    return out_ids, out_sims, n_scored


def _hop_kernel_dma(graph_hbm, rev_hbm, words_hbm, card_hbm, tomb_hbm,
                    qw_ref, qc_ref, bi_ref, bs_ref,
                    out_ids_ref, out_sims_ref, nsc_ref, dmab_ref, save_ref,
                    tomb_s, bidx_s, adj_f, adj_r, cand_s, need_s,
                    cw_buf, cc_buf, sem_t, sem_a, sem_c,
                    *, chunk: int, mxu: bool, n_buffers: int):
    """HBM-resident variant of :func:`_hop_kernel`.

    The five table refs live in ANY/HBM memory and are never read as
    whole-array values. Per tile the kernel stages (1) the tombstone
    column once, (2) the beam rows' adjacency lists (one row-DMA per
    live beam lane), then (3) runs the chunked scoring loop with each
    chunk's surviving lanes' fingerprint+cardinality rows DMA'd into a
    rotating ``n_buffers``-deep VMEM scratch buffer — chunk c+1's
    copies are in flight while chunk c scores. Every DMA start/wait is
    guarded by the *same* predicate as the suppression mask, so
    suppressed lanes move zero bytes; the per-row fetched-lane counter
    rides the loop carry under that predicate, making the emitted
    ``dma_bytes`` accounting exact by construction.
    """
    beam_ids = bi_ref[...]                              # [bq, B] i32
    beam_sims = bs_ref[...]                             # [bq, B] f32
    bq, B = beam_ids.shape
    kg = graph_hbm.shape[1]
    kr = rev_hbm.shape[1]
    W = words_hbm.shape[1]
    row_bytes = W * 4                                   # fingerprint row

    # (t) stage the tombstone column — one contiguous copy per tile.
    cp = pltpu.make_async_copy(tomb_hbm, tomb_s, sem_t)
    cp.start()
    cp.wait()
    tomb = tomb_s[...][:, 0]                            # [n] i32 (0|1)

    beam_ids, beam_sims = _mask_dead_beam(beam_ids, beam_sims, tomb)

    # (a) adjacency rows by per-lane DMA — PAD/dead beam lanes skipped.
    # Ids go through scratch so the loop bodies read scalars from a ref.
    bidx_s[...] = beam_ids.reshape(-1, 1)
    n_lanes = bq * B

    def _adj_copies(t):
        v = bidx_s[t, 0]
        ok = v != PAD_ID
        row = jnp.where(ok, v, 0)
        return ok, (pltpu.make_async_copy(graph_hbm.at[row], adj_f.at[t],
                                          sem_a),
                    pltpu.make_async_copy(rev_hbm.at[row], adj_r.at[t],
                                          sem_a))

    def _adj_start(t, _):
        ok, (cf, cr) = _adj_copies(t)

        @pl.when(ok)
        def _():
            cf.start()
            cr.start()
        return 0

    def _adj_wait(t, _):
        ok, (cf, cr) = _adj_copies(t)

        @pl.when(ok)
        def _():
            cf.wait()
            cr.wait()
        return 0

    jax.lax.fori_loop(0, n_lanes, _adj_start, 0)
    jax.lax.fori_loop(0, n_lanes, _adj_wait, 0)

    dead = beam_ids[:, :, None] == PAD_ID               # [bq, B, 1]
    fwd = jnp.where(dead, PAD_ID,
                    adj_f[...].reshape(bq, B, kg)).reshape(bq, B * kg)
    rev = jnp.where(dead, PAD_ID,
                    adj_r[...].reshape(bq, B, kr)).reshape(bq, B * kr)
    cand = jnp.concatenate([fwd, rev], axis=1)          # [bq, C]
    C = cand.shape[1]

    cand, need = _suppress(cand, beam_ids, tomb)
    nsc_ref[...] = jnp.sum(need, axis=1, dtype=jnp.int32).reshape(bq, 1)
    cand_s[...] = cand
    need_s[...] = need.astype(jnp.int32)

    # (c) chunked scoring with double-buffered candidate-row DMA. The
    # start/wait bodies rebuild identical copy descriptors under the
    # identical `ok` guard, so every started copy is waited exactly once;
    # per-slot semaphores keep chunk c+1's signals from satisfying chunk
    # c's waits. Skipped buffer lanes keep whatever bytes a previous
    # chunk left there — harmless, `score_gathered_chunk` masks by need.
    qw = qw_ref[...]                                    # [bq, W] u32
    qcf = qc_ref[...].astype(jnp.float32)               # [bq, 1]
    q_bits = unpack_bits_int8(qw) if mxu else None
    n_chunks = -(-C // chunk)

    def _lane_copies(t, s, ch, slot):
        i = t // ch
        j = t % ch
        ok = need_s[i, s + j] > 0
        row = jnp.where(ok, cand_s[i, s + j], 0)
        return i, ok, (
            pltpu.make_async_copy(words_hbm.at[row],
                                  cw_buf.at[slot, i, j], sem_c.at[slot]),
            pltpu.make_async_copy(card_hbm.at[row],
                                  cc_buf.at[slot, i, j], sem_c.at[slot]))

    def start_chunk(ci, slot, cnt):
        s = ci * chunk
        ch = min(chunk, C - s)

        def body(t, acc):
            i, ok, (cw, cc) = _lane_copies(t, s, ch, slot)

            @pl.when(ok)
            def _():
                cw.start()
                cc.start()
            return acc.at[i].add(ok.astype(jnp.int32))

        return jax.lax.fori_loop(0, bq * ch, body, cnt)

    def wait_chunk(ci, slot):
        s = ci * chunk
        ch = min(chunk, C - s)

        def body(t, _):
            _, ok, (cw, cc) = _lane_copies(t, s, ch, slot)

            @pl.when(ok)
            def _():
                cw.wait()
                cc.wait()
            return 0

        jax.lax.fori_loop(0, bq * ch, body, 0)

    def score_chunk(ci, slot):
        s = ci * chunk
        ch = min(chunk, C - s)
        need_c = need[:, s:s + ch]
        cw = cw_buf[slot, :, :ch].reshape(bq * ch, W)
        cc = jnp.where(need_c, cc_buf[slot, :, :ch, 0],
                       0).astype(jnp.float32)
        return score_gathered_chunk(qw, qcf, q_bits, cw, cc, need_c,
                                    mxu=mxu)

    fetched = jnp.zeros((bq,), jnp.int32)
    sims_chunks = []
    if n_buffers > 1:
        fetched = start_chunk(0, 0, fetched)
        for ci in range(n_chunks):
            if ci + 1 < n_chunks:
                fetched = start_chunk(ci + 1, (ci + 1) % n_buffers, fetched)
            wait_chunk(ci, ci % n_buffers)
            sims_chunks.append(score_chunk(ci, ci % n_buffers))
    else:
        # n_buffers == 1: no overlap — a degenerate tuning point kept
        # for the autotuner's smallest-VMEM configurations.
        for ci in range(n_chunks):
            fetched = start_chunk(ci, 0, fetched)
            wait_chunk(ci, 0)
            sims_chunks.append(score_chunk(ci, 0))
    cand_sims = jnp.concatenate(sims_chunks, axis=1)

    # Byte accounting: fingerprint bytes only (the cardinality scalar
    # rides the same guard but is excluded — W·4 per row is the traffic
    # the memory hierarchy cares about). `fetched == n_scored` holds by
    # construction; tests assert dma_bytes == n_scored·W·4.
    dmab_ref[...] = (fetched * row_bytes).reshape(bq, 1)
    save_ref[...] = ((C - fetched) * row_bytes).reshape(bq, 1)

    _merge(beam_ids, beam_sims, cand, cand_sims, out_ids_ref, out_sims_ref)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "chunk", "mxu", "n_buffers", "interpret"),
)
def hop_pallas_dma(graph_ids, rev_ids, words, card, tomb, q_words, q_card,
                   beam_ids, beam_sims, *,
                   block_q: int = 16, chunk: int = 64,
                   mxu: bool = False, n_buffers: int = 2,
                   interpret: bool = True):
    """Memory-hierarchy-aware fused hop: HBM tables, per-chunk DMA.

    Same contract as :func:`hop_pallas` (and bitwise-identical to it and
    to ``ref.descent_hop_ref``), plus two extra outputs:
    ``dma_bytes i32[q, 1]`` — fingerprint bytes actually DMA'd for this
    hop per query — and ``bytes_saved i32[q, 1]`` — bytes the
    suppressed lanes did *not* move vs the unfused ``beam·(kg+kr)``
    gather. ``(block_q, chunk, n_buffers)`` come from
    ``tune.hop_params`` via ops.py; VMEM scratch is
    ``n_buffers·block_q·chunk·(W+1)·4`` bytes for the rotating row
    buffers plus the adjacency/id staging (see README "Kernels").
    """
    q, B = beam_ids.shape
    n, W = words.shape
    kg, kr = graph_ids.shape[1], rev_ids.shape[1]
    C = B * (kg + kr)
    bq = min(block_q, q)
    assert q % bq == 0, (q, bq)
    nb = max(1, min(n_buffers, -(-C // chunk)))
    grid = (q // bq,)

    outs = pl.pallas_call(
        functools.partial(_hop_kernel_dma, chunk=chunk, mxu=mxu,
                          n_buffers=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),       # graph_ids
            pl.BlockSpec(memory_space=pltpu.ANY),       # rev_ids
            pl.BlockSpec(memory_space=pltpu.ANY),       # words
            pl.BlockSpec(memory_space=pltpu.ANY),       # card
            pl.BlockSpec(memory_space=pltpu.ANY),       # tomb
            pl.BlockSpec((bq, W), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, B), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, B), jnp.int32),
            jax.ShapeDtypeStruct((q, B), jnp.float32),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.int32),              # tomb_s
            pltpu.VMEM((bq * B, 1), jnp.int32),         # bidx_s
            pltpu.VMEM((bq * B, kg), jnp.int32),        # adj_f
            pltpu.VMEM((bq * B, kr), jnp.int32),        # adj_r
            pltpu.VMEM((bq, C), jnp.int32),             # cand_s
            pltpu.VMEM((bq, C), jnp.int32),             # need_s
            pltpu.VMEM((nb, bq, min(chunk, C), W), jnp.uint32),  # cw_buf
            pltpu.VMEM((nb, bq, min(chunk, C), 1), jnp.int32),   # cc_buf
            pltpu.SemaphoreType.DMA,                    # sem_t
            pltpu.SemaphoreType.DMA,                    # sem_a
            pltpu.SemaphoreType.DMA((nb,)),             # sem_c
        ],
        interpret=interpret,
    )(graph_ids, rev_ids, words, card, tomb, q_words, q_card,
      beam_ids, beam_sims)
    return outs
