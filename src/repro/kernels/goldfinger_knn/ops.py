"""Public wrappers for the goldfinger_knn kernel.

Handles bit-plane unpacking, padding to block multiples, and the batched
per-cluster entry point used by core/local_knn. Interpret-vs-compiled is
resolved per call through ``repro.kernels.config``
(``$REPRO_PALLAS_INTERPRET``, default interpret — this container is
CPU); the flag is a static arg of the inner jit, so flipping it
re-traces instead of reusing a stale cache entry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import config
from repro.kernels.goldfinger_knn.goldfinger_knn import knn_pallas
from repro.sketch.goldfinger import unpack_bits_int8
from repro.types import NEG_INF, PAD_ID


def _pad_rows(x, to: int, fill):
    n = x.shape[0]
    if n % to == 0:
        return x
    pad = to - n % to
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_d",
                                    "score_chunk", "interpret"))
def _knn_jit(q_words, q_card, q_ids, d_words, d_card, d_ids, *, k: int,
             block_q: int, block_d: int, score_chunk: int,
             interpret: bool):
    nq = q_words.shape[0]
    q_bits = _pad_rows(unpack_bits_int8(q_words), block_q, 0)
    d_bits = _pad_rows(unpack_bits_int8(d_words), block_d, 0)
    qc = _pad_rows(q_card.reshape(-1, 1).astype(jnp.int32), block_q, 0)
    qi = _pad_rows(q_ids.reshape(-1, 1).astype(jnp.int32), block_q, PAD_ID)
    dc = _pad_rows(d_card.reshape(-1, 1).astype(jnp.int32), block_d, 0)
    di = _pad_rows(d_ids.reshape(-1, 1).astype(jnp.int32), block_d, PAD_ID)
    out_ids, out_sims = knn_pallas(
        q_bits, qc, qi, d_bits, dc, di, k,
        block_q=block_q, block_d=block_d, score_chunk=score_chunk,
        interpret=interpret)
    return out_ids[:nq], out_sims[:nq]


def knn(q_words, q_card, q_ids, d_words, d_card, d_ids, k: int,
        block_q: int = 128, block_d: int = 512, score_chunk: int = 128):
    """Top-k neighbors of each query among the database rows.

    Same contract as ref.knn_ref but words are packed uint32[n, W];
    unpacking to MXU bit-planes happens here (fused by jit).
    ``score_chunk`` bounds the per-round interaction tile at
    [block_q, score_chunk] — the same bounded-VMEM scoring-loop shape as
    the descent hop — and is bitwise-invisible (streaming chunk merges
    equal one block-wide merge).
    """
    return _knn_jit(jnp.asarray(q_words), jnp.asarray(q_card),
                    jnp.asarray(q_ids), jnp.asarray(d_words),
                    jnp.asarray(d_card), jnp.asarray(d_ids), k=k,
                    block_q=block_q, block_d=block_d,
                    score_chunk=score_chunk,
                    interpret=config.interpret_mode())


@functools.partial(jax.jit, static_argnames=("k",))
def cluster_knn(words, card, member_ids, k: int):
    """Batched per-cluster KNN: words uint32[m, cap, W] → ([m, cap, k] ×2).

    Matches core/local_knn._group_knn's contract: PAD rows yield PAD/−inf.
    Caps are powers of two ≥ 32, so blocks divide evenly.
    """
    m, cap, _ = words.shape
    bq = min(128, cap)
    bd = min(512, cap)

    def one(w, c, ids):
        oi, os = knn(w, c, ids, w, c, ids, k, block_q=bq, block_d=bd)
        # Dead (PAD) query rows: normalize sims to −inf for the caller.
        dead = (ids == PAD_ID)[:, None]
        return (jnp.where(dead, PAD_ID, oi),
                jnp.where(dead, NEG_INF, os))

    return jax.vmap(one)(words, card, member_ids)
