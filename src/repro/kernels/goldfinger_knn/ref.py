"""Pure-jnp oracle for the fused GoldFinger-Jaccard + top-k kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sketch.goldfinger import jaccard_pairwise
from repro.types import NEG_INF, PAD_ID


def knn_ref(q_words, q_card, q_ids, d_words, d_card, d_ids, k: int):
    """Top-k database neighbors per query row.

    q_words uint32[nq, W], q_card int32[nq], q_ids int32[nq] (PAD_ID = dead
    row); d_* likewise for the database side. Self-pairs (q_id == d_id) and
    PAD rows are excluded. Returns (ids int32[nq, k], sims float32[nq, k]).
    """
    sims = jaccard_pairwise(q_words, q_card, d_words, d_card)
    valid = ((d_ids[None, :] != PAD_ID)
             & (q_ids[:, None] != PAD_ID)
             & (q_ids[:, None] != d_ids[None, :]))
    sims = jnp.where(valid, sims, NEG_INF)
    top_sims, pos = jax.lax.top_k(sims, k)
    top_ids = jnp.where(top_sims == NEG_INF, PAD_ID,
                        d_ids[pos].astype(jnp.int32))
    return top_ids, top_sims


def cluster_knn_ref(words, card, member_ids, k: int):
    """Per-cluster oracle: words uint32[m, cap, W] → ([m, cap, k] ids, sims)."""
    def one(w, c, ids):
        return knn_ref(w, c, ids, w, c, ids, k)

    return jax.vmap(one)(words, card, member_ids)
