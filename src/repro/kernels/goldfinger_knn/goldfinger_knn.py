"""Fused GoldFinger-Jaccard × streaming top-k — Pallas TPU kernel.

The paper's dominant cost is Step 2's similarity computations. On TPU we
fuse the three stages the CPU code runs separately (popcount-AND, union,
heap insertion) into one kernel that never materializes the similarity
matrix in HBM:

* **MXU mapping (DESIGN.md §3):** fingerprints are pre-unpacked to {0,1}
  int8 bit-planes, so ``popcount(fp_u & fp_v) = ⟨bits_u, bits_v⟩`` becomes
  an int8 matmul on the 128×128 systolic array — 1024-bit sketches give a
  contraction dim of 1024 (8 MXU tiles). The union needs no second matmul:
  ``|A∪B| = card_u + card_v − |A∩B|`` with per-user popcounts precomputed.
* **Streaming top-k:** grid is (query blocks × database blocks), database
  innermost; the output block (revisited across the database axis) carries
  the running top-k, merged in VMEM each step via k rounds of
  max-reduce + first-occurrence selection (iota/min trick — no gather,
  no sort, so everything lowers to plain VPU reduce/eltwise ops).

VMEM working set per step (bq=128, bd=512, B=1024, k≤64):
q bits 128·1024 + d bits 512·1024 int8 ≈ 0.66 MB; the interaction is
scored in bounded [bq, score_chunk] tiles (128·128 f32 = 64 KB — shared
shape with the descent hop's scoring loop, so the [bq, bd] similarity
tile never materializes at once), running top-k 2·128·64 ≈ 64 KB —
comfortably inside 16 MB VMEM with double buffering; matmul dims
(128, 1024, score_chunk) stay MXU-aligned for the default chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.scoring import jaccard_bitplane_tile
from repro.knn.topk import select_topk
from repro.types import NEG_INF, PAD_ID


def _knn_kernel(q_bits_ref, q_card_ref, q_ids_ref,
                d_bits_ref, d_card_ref, d_ids_ref,
                out_ids_ref, out_sims_ref, *, k: int, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_sims_ref[...] = jnp.full_like(out_sims_ref, NEG_INF)
        out_ids_ref[...] = jnp.full_like(out_ids_ref, PAD_ID)

    q_bits = q_bits_ref[...]                                # [bq, B] i8
    q_card = q_card_ref[...].astype(jnp.float32)            # [bq, 1]
    q_ids = q_ids_ref[...]                                  # [bq, 1] i32
    d_bits = d_bits_ref[...]                                # [bd, B] i8
    d_card = d_card_ref[...]                                # [bd, 1]
    d_ids = d_ids_ref[...]                                  # [bd, 1] i32
    bd = d_bits.shape[0]

    # Score the database block in bounded [bq, chunk] tiles (the same
    # bounded-VMEM scoring-loop shape as the descent hop — the [bq, bd]
    # interaction never materializes at once) and stream each tile into
    # the running top-k carried by the output block. Chunk-wise merges
    # are bitwise-equal to one block-wide merge: the running set is
    # concatenated first, so equal-sim ties keep resolving to the
    # earliest database column, exactly as the single merge would.
    for s in range(0, bd, chunk):
        e = min(s + chunk, bd)
        d_bits_c = d_bits[s:e]                              # [ch, B] i8
        d_card_c = d_card[s:e].astype(jnp.float32)
        d_ids_c = d_ids[s:e]                                # [ch, 1] i32
        sims = jaccard_bitplane_tile(q_bits, q_card,
                                     d_bits_c, d_card_c.T)  # [bq, ch]
        valid = ((d_ids_c.T != PAD_ID) & (q_ids != PAD_ID)
                 & (q_ids != d_ids_c.T))
        sims = jnp.where(valid, sims, NEG_INF)
        cand_sims = jnp.concatenate([out_sims_ref[...], sims], axis=1)
        cand_ids = jnp.concatenate(
            [out_ids_ref[...],
             jnp.broadcast_to(d_ids_c.T, sims.shape)], axis=1)
        new_sims, new_ids = select_topk(cand_sims, cand_ids, k)
        # Normalize filler slots to PAD before the next re-merge: in a
        # round where every remaining lane is −inf, select_topk falls
        # back to column 0 — which in a RE-merge is the running set's
        # (already-selected, killed) top entry, so without this a row
        # with fewer than k valid neighbors would duplicate its best id
        # into the filler slots instead of PAD-padding them the way the
        # one-shot merge (whose column 0 is an init PAD) and ref do.
        out_sims_ref[...] = new_sims
        out_ids_ref[...] = jnp.where(new_sims == NEG_INF, PAD_ID, new_ids)


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_q", "block_d", "score_chunk",
                     "interpret"),
)
def knn_pallas(q_bits, q_card, q_ids, d_bits, d_card, d_ids, k: int,
               block_q: int = 128, block_d: int = 512,
               score_chunk: int = 128, interpret: bool = True):
    """Top-k database neighbors per query row (see ref.knn_ref).

    q_bits int8[nq, B] {0,1} bit-planes; q_card/q_ids int32[nq, 1];
    d_* likewise. nq % block_q == nd % block_d == 0 (ops.py pads).
    ``score_chunk`` bounds the per-round interaction tile (bitwise
    invisible; need not divide ``block_d``).
    """
    nq, B = q_bits.shape
    nd = d_bits.shape[0]
    bq = min(block_q, nq)
    bd = min(block_d, nd)
    assert nq % bq == 0 and nd % bd == 0, (nq, bq, nd, bd)
    grid = (nq // bq, nd // bd)

    out_ids, out_sims = pl.pallas_call(
        functools.partial(_knn_kernel, k=k, chunk=min(score_chunk, bd)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, B), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bd, B), lambda i, j: (j, 0)),
            pl.BlockSpec((bd, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bd, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
        ],
        interpret=interpret,
    )(q_bits, q_card, q_ids, d_bits, d_card, d_ids)
    return out_ids, out_sims
