"""Fused GoldFinger-Jaccard × streaming top-k — Pallas TPU kernel.

The paper's dominant cost is Step 2's similarity computations. On TPU we
fuse the three stages the CPU code runs separately (popcount-AND, union,
heap insertion) into one kernel that never materializes the similarity
matrix in HBM:

* **MXU mapping (DESIGN.md §3):** fingerprints are pre-unpacked to {0,1}
  int8 bit-planes, so ``popcount(fp_u & fp_v) = ⟨bits_u, bits_v⟩`` becomes
  an int8 matmul on the 128×128 systolic array — 1024-bit sketches give a
  contraction dim of 1024 (8 MXU tiles). The union needs no second matmul:
  ``|A∪B| = card_u + card_v − |A∩B|`` with per-user popcounts precomputed.
* **Streaming top-k:** grid is (query blocks × database blocks), database
  innermost; the output block (revisited across the database axis) carries
  the running top-k, merged in VMEM each step via k rounds of
  max-reduce + first-occurrence selection (iota/min trick — no gather,
  no sort, so everything lowers to plain VPU reduce/eltwise ops).

VMEM working set per step (bq=128, bd=512, B=1024, k≤64):
q bits 128·1024 + d bits 512·1024 int8 ≈ 0.66 MB, sims 128·512 f32 = 0.25 MB,
running top-k 2·128·64 ≈ 64 KB — comfortably inside 16 MB VMEM with double
buffering; matmul dims (128, 1024, 512) are MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.knn.topk import select_topk
from repro.types import NEG_INF, PAD_ID


def _knn_kernel(q_bits_ref, q_card_ref, q_ids_ref,
                d_bits_ref, d_card_ref, d_ids_ref,
                out_ids_ref, out_sims_ref, *, k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_sims_ref[...] = jnp.full_like(out_sims_ref, NEG_INF)
        out_ids_ref[...] = jnp.full_like(out_ids_ref, PAD_ID)

    # |A∩B| as an int8 bit-plane matmul (MXU), f32 epilogue on VPU.
    inter = jax.lax.dot_general(
        q_bits_ref[...], d_bits_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)                                   # [bq, bd]
    q_card = q_card_ref[...].astype(jnp.float32)            # [bq, 1]
    d_card = d_card_ref[...].astype(jnp.float32)            # [bd, 1]
    union = q_card + d_card.T - inter
    sims = jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)

    q_ids = q_ids_ref[...]                                  # [bq, 1] i32
    d_ids = d_ids_ref[...]                                  # [bd, 1] i32
    valid = ((d_ids.T != PAD_ID) & (q_ids != PAD_ID) & (q_ids != d_ids.T))
    sims = jnp.where(valid, sims, NEG_INF)

    # Merge the block into the running top-k carried by the output block.
    cand_sims = jnp.concatenate([out_sims_ref[...], sims], axis=1)
    cand_ids = jnp.concatenate(
        [out_ids_ref[...], jnp.broadcast_to(d_ids.T, sims.shape)], axis=1)
    new_sims, new_ids = select_topk(cand_sims, cand_ids, k)
    out_sims_ref[...] = new_sims
    out_ids_ref[...] = new_ids


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_q", "block_d", "interpret"),
)
def knn_pallas(q_bits, q_card, q_ids, d_bits, d_card, d_ids, k: int,
               block_q: int = 128, block_d: int = 512,
               interpret: bool = True):
    """Top-k database neighbors per query row (see ref.knn_ref).

    q_bits int8[nq, B] {0,1} bit-planes; q_card/q_ids int32[nq, 1];
    d_* likewise. nq % block_q == nd % block_d == 0 (ops.py pads).
    """
    nq, B = q_bits.shape
    nd = d_bits.shape[0]
    bq = min(block_q, nq)
    bd = min(block_d, nd)
    assert nq % bq == 0 and nd % bd == 0, (nq, bq, nd, bd)
    grid = (nq // bq, nd // bd)

    out_ids, out_sims = pl.pallas_call(
        functools.partial(_knn_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, B), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bd, B), lambda i, j: (j, 0)),
            pl.BlockSpec((bd, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bd, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
        ],
        interpret=interpret,
    )(q_bits, q_card, q_ids, d_bits, d_card, d_ids)
    return out_ids, out_sims
