from repro.kernels.goldfinger_knn import ops, ref  # noqa: F401
