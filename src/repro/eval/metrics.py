"""Evaluation metrics: KNN quality (paper Eq. 1/2) and recommendation recall
(paper §V-B).
"""
from __future__ import annotations

import numpy as np

from repro.sketch.exact import edge_jaccard
from repro.types import PAD_ID, Dataset, KNNGraph


def exact_avg_sim(ds: Dataset, graph: KNNGraph) -> float:
    """avg_sim (Eq. 1) with *exact* Jaccard on raw profiles."""
    n, k = graph.ids.shape
    src = np.repeat(np.arange(n, dtype=np.int32), k)
    dst = graph.ids.reshape(-1)
    sims = edge_jaccard(ds, src, dst)
    return float(sims.sum() / (n * k))


def quality(ds: Dataset, approx: KNNGraph, exact: KNNGraph) -> float:
    """Eq. 2: avg_sim(approx) / avg_sim(exact), both exact-Jaccard-scored."""
    denom = exact_avg_sim(ds, exact)
    if denom == 0:
        return 1.0
    return exact_avg_sim(ds, approx) / denom


def knn_recall(approx_ids: np.ndarray, exact_ids: np.ndarray) -> float:
    """Mean per-row recall@k of approximate KNN ids vs exact ids.

    Rows are id lists (PAD_ID = absent); each row scores
    |approx ∩ exact| / |exact|. Used by the query-serving recall metric.
    """
    vals = []
    for a, e in zip(approx_ids, exact_ids):
        e = e[e != PAD_ID]
        if len(e) == 0:
            continue
        a = a[a != PAD_ID]
        vals.append(len(np.intersect1d(a, e)) / len(e))
    return float(np.mean(vals)) if vals else 0.0


def recommend(train: Dataset, graph: KNNGraph, n_rec: int = 30) -> list[np.ndarray]:
    """Simple user-based CF (paper §V-B): score items by the summed
    similarity of neighbors who have them; recommend top ``n_rec`` unseen."""
    recs = []
    for u in range(train.n_users):
        scores: dict[int, float] = {}
        seen = set(train.profile(u).tolist())
        for v, s in zip(graph.ids[u], graph.sims[u]):
            if v == PAD_ID or s <= 0:
                continue
            for it in train.profile(int(v)):
                if int(it) not in seen:
                    scores[int(it)] = scores.get(int(it), 0.0) + float(s)
        top = sorted(scores.items(), key=lambda kv: -kv[1])[:n_rec]
        recs.append(np.array([it for it, _ in top], dtype=np.int32))
    return recs


def recall(recs: list[np.ndarray], test_rows: list[np.ndarray]) -> float:
    """Mean per-user recall of held-out items."""
    vals = []
    for rec, test in zip(recs, test_rows):
        if len(test) == 0:
            continue
        vals.append(len(np.intersect1d(rec, test)) / len(test))
    return float(np.mean(vals)) if vals else 0.0
