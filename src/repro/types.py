"""Core data types shared across the repro framework.

The KNN side of the framework operates on *item-based datasets*: a set of
users, each associated with a sparse set of items (its *profile*), per the
paper's §II-A. Profiles are stored CSR on host (numpy) and padded/packed on
their way into JAX kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

PAD_ID = -1  # padding sentinel for user/item ids
NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class Dataset:
    """An item-based dataset (users × items) in CSR form.

    ``items[offsets[u]:offsets[u+1]]`` is user ``u``'s profile P_u
    (sorted, deduplicated item ids in ``[0, n_items)``).
    """

    name: str
    n_users: int
    n_items: int
    items: np.ndarray    # int32[nnz]
    offsets: np.ndarray  # int64[n_users + 1]

    def __post_init__(self):
        assert self.offsets.shape == (self.n_users + 1,)
        assert self.offsets[0] == 0 and self.offsets[-1] == len(self.items)

    @property
    def nnz(self) -> int:
        return int(len(self.items))

    @property
    def profile_sizes(self) -> np.ndarray:
        return np.diff(self.offsets).astype(np.int32)

    @property
    def density(self) -> float:
        return self.nnz / (self.n_users * self.n_items)

    def profile(self, u: int) -> np.ndarray:
        return self.items[self.offsets[u]:self.offsets[u + 1]]

    def padded_profiles(self, pad_to: Optional[int] = None):
        """Return ``(padded int32[n_users, P], mask bool[n_users, P])``.

        Padded entries hold ``PAD_ID``. Rows are sorted ascending (CSR order),
        which downstream exact-Jaccard evaluation relies on.
        """
        sizes = self.profile_sizes
        P = int(pad_to if pad_to is not None else (sizes.max() if len(sizes) else 1))
        P = max(P, 1)
        out = np.full((self.n_users, P), PAD_ID, dtype=np.int32)
        for u in range(self.n_users):
            p = self.profile(u)[:P]
            out[u, : len(p)] = p
        return out, out != PAD_ID

    def subset(self, user_ids: np.ndarray, name: Optional[str] = None) -> "Dataset":
        """Restrict to a subset of users (item universe unchanged)."""
        user_ids = np.asarray(user_ids)
        sizes = self.profile_sizes[user_ids]
        offsets = np.zeros(len(user_ids) + 1, dtype=np.int64)
        np.cumsum(sizes, out=offsets[1:])
        items = np.concatenate(
            [self.profile(int(u)) for u in user_ids]
            or [np.zeros((0,), np.int32)]
        ).astype(np.int32)
        return Dataset(
            name=name or f"{self.name}:subset{len(user_ids)}",
            n_users=len(user_ids),
            n_items=self.n_items,
            items=items,
            offsets=offsets,
        )


def dataset_from_profiles(name: str, profiles, n_items: int) -> Dataset:
    """Build a Dataset from a list of item-id iterables."""
    rows = [np.unique(np.asarray(sorted(set(int(i) for i in p)), dtype=np.int32))
            for p in profiles]
    sizes = np.array([len(r) for r in rows], dtype=np.int64)
    offsets = np.zeros(len(rows) + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    items = (np.concatenate(rows) if rows else np.zeros((0,), np.int32)).astype(np.int32)
    return Dataset(name=name, n_users=len(rows), n_items=n_items,
                   items=items, offsets=offsets)


@dataclasses.dataclass(frozen=True)
class KNNGraph:
    """An (approximate) KNN graph: for each user, k neighbor ids + similarities.

    ``ids[u, j] == PAD_ID`` marks an absent edge; its sim is ``-inf``.
    Neighbors are sorted by decreasing similarity.
    """

    ids: np.ndarray   # int32[n, k]
    sims: np.ndarray  # float32[n, k]

    @property
    def n(self) -> int:
        return self.ids.shape[0]

    @property
    def k(self) -> int:
        return self.ids.shape[1]

    def avg_sim(self) -> float:
        """Paper Eq. (1): mean similarity over the graph's edges (absent
        edges contribute 0, divisor is k·n, matching the paper)."""
        s = np.where(self.ids != PAD_ID, self.sims, 0.0)
        return float(s.sum() / (self.n * self.k))
