"""Exact Jaccard over raw profiles — used to *evaluate* graph quality.

All KNN algorithms in the paper estimate similarities via GoldFinger; the
quality metric (Eq. 2) compares graphs by the similarity of their edges. We
evaluate edges with the exact set Jaccard so estimator error is charged to
the algorithm, matching the paper's setup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.types import PAD_ID, Dataset


def _pair_jaccard(prof_u, prof_v, size_u, size_v):
    """Exact Jaccard of two padded *sorted* profiles (PAD_ID = -1 padding).

    Uses searchsorted membership counting: |A∩B| = Σ_{a∈A} [a ∈ B].
    """
    idx = jnp.searchsorted(prof_v, prof_u)
    idx = jnp.clip(idx, 0, prof_v.shape[0] - 1)
    hit = (prof_v[idx] == prof_u) & (prof_u != PAD_ID)
    inter = jnp.sum(hit).astype(jnp.float32)
    union = size_u.astype(jnp.float32) + size_v.astype(jnp.float32) - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)


@jax.jit
def _edge_sims(padded_u, padded_v_sorted, sizes, src, dst):
    def one(s, d):
        return _pair_jaccard(padded_u[s], padded_v_sorted[d], sizes[s], sizes[d])
    return jax.vmap(one)(src, dst)


def edge_jaccard(ds: Dataset, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Exact Jaccard for an edge list (host API). PAD_ID dst → 0."""
    padded, _ = ds.padded_profiles()
    # Search side: PAD_ID (-1) entries become a +maxint sentinel so each row
    # stays sorted ascending and the sentinel never matches a real item id.
    padded_sorted = np.sort(
        np.where(padded == PAD_ID, np.int32(2**31 - 1), padded), axis=1)
    sizes = ds.profile_sizes
    dst_safe = np.where(dst == PAD_ID, 0, dst)
    sims = np.asarray(_edge_sims(
        jnp.asarray(padded),
        jnp.asarray(padded_sorted),
        jnp.asarray(sizes),
        jnp.asarray(src), jnp.asarray(dst_safe),
    ))
    return np.where(dst == PAD_ID, 0.0, sims)
