"""GoldFinger compact profile fingerprints (paper §II-F, refs [19]/[40]).

GoldFinger summarizes each user's profile into a B-bit vector (64–8096 bits;
the paper's experiments use 1024). Bit ``hash(item) mod B`` is set for every
item in the profile. The Jaccard similarity of two profiles is then estimated
from the fingerprints as::

    J(u, v) ≈ |fp_u ∧ fp_v| / |fp_u ∨ fp_v|
            = popcount(fp_u & fp_v) / (card_u + card_v − popcount(fp_u & fp_v))

where ``card_u = popcount(fp_u)`` is precomputed once per user. Keeping the
union in terms of precomputed cardinalities is what lets the TPU kernel turn
the intersection into a single matmul (see kernels/goldfinger_knn).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import fmix32
from repro.types import Dataset

DEFAULT_BITS = 1024


@dataclasses.dataclass(frozen=True)
class GoldFinger:
    """Fingerprints for a set of users: ``words`` uint32[n, W], ``card`` int32[n]."""

    words: np.ndarray | jax.Array  # uint32[n, W]
    card: np.ndarray | jax.Array   # int32[n]  (popcount of each row)

    @property
    def n(self) -> int:
        return self.words.shape[0]

    @property
    def n_bits(self) -> int:
        return self.words.shape[1] * 32

    def take(self, idx) -> "GoldFinger":
        return GoldFinger(words=self.words[idx], card=self.card[idx])


def item_bit_positions(items: np.ndarray, n_bits: int, seed: int) -> np.ndarray:
    """Map item ids to bit positions in [0, n_bits) with a mixed hash."""
    x = (items.astype(np.uint32) + np.uint32(0x9E3779B9)) ^ np.uint32(seed * 0x85EBCA6B + 1)
    return (fmix32(x) % np.uint32(n_bits)).astype(np.int64)


def fingerprint_dataset(ds: Dataset, n_bits: int = DEFAULT_BITS, seed: int = 0) -> GoldFinger:
    """Build GoldFinger fingerprints for every user of ``ds`` (host-side)."""
    assert n_bits % 32 == 0, "n_bits must be a multiple of 32"
    W = n_bits // 32
    pos = item_bit_positions(ds.items, n_bits, seed)
    word_idx = (pos // 32).astype(np.int64)
    bit = np.uint32(1) << (pos % 32).astype(np.uint32)
    words = np.zeros((ds.n_users, W), dtype=np.uint32)
    # Scatter-OR each item's bit into its user's row.
    user_of = np.repeat(np.arange(ds.n_users, dtype=np.int64), ds.profile_sizes)
    np.bitwise_or.at(words, (user_of, word_idx), bit)
    card = popcount_rows(words)
    return GoldFinger(words=words, card=card)


def popcount_rows(words: np.ndarray) -> np.ndarray:
    """Row-wise popcount on host (numpy)."""
    return np.unpackbits(words.view(np.uint8), axis=-1).sum(axis=-1).astype(np.int32)


def incidence_fingerprint(ds: Dataset) -> GoldFinger:
    """Full-universe incidence vectors ("raw data" mode, Table V).

    One bit per item of the universe — popcount Jaccard over these is the
    *exact* set Jaccard (no hashing collisions), at |I|/n_bits times the
    memory and compute of a GoldFinger sketch. This is the paper's
    raw-data baseline expressed in the same kernel-friendly layout.
    """
    W = (ds.n_items + 31) // 32
    words = np.zeros((ds.n_users, W), dtype=np.uint32)
    user_of = np.repeat(np.arange(ds.n_users, dtype=np.int64),
                        ds.profile_sizes)
    pos = ds.items.astype(np.int64)
    np.bitwise_or.at(words, (user_of, pos // 32),
                     np.uint32(1) << (pos % 32).astype(np.uint32))
    return GoldFinger(words=words, card=popcount_rows(words))


# --------------------------------------------------------------------------
# Pure-jnp pairwise similarity (also the oracle for the Pallas kernel).
# --------------------------------------------------------------------------

def jaccard_pairwise(words_a: jax.Array, card_a: jax.Array,
                     words_b: jax.Array, card_b: jax.Array,
                     word_chunk: int = 64) -> jax.Array:
    """Estimated Jaccard sims for all pairs: float32[n_a, n_b].

    Pure-jnp reference: popcount of ANDed words, union from cardinalities.
    Wide sketches (raw-incidence mode: W = |I|/32 can be thousands of
    words) are scanned in word chunks so the [n_a, n_b, W] AND tensor is
    never materialized.
    """
    W = words_a.shape[-1]
    if W <= word_chunk:
        inter = jnp.sum(
            jax.lax.population_count(
                words_a[:, None, :] & words_b[None, :, :]),
            axis=-1,
        ).astype(jnp.float32)
    else:
        pad = (-W) % word_chunk
        wa = jnp.pad(words_a, ((0, 0), (0, pad)))
        wb = jnp.pad(words_b, ((0, 0), (0, pad)))
        nc = wa.shape[-1] // word_chunk
        wa = jnp.moveaxis(wa.reshape(-1, nc, word_chunk), 1, 0)
        wb = jnp.moveaxis(wb.reshape(-1, nc, word_chunk), 1, 0)

        def body(acc, ab):
            a, b = ab
            p = jnp.sum(jax.lax.population_count(
                a[:, None, :] & b[None, :, :]), axis=-1, dtype=jnp.int32)
            return acc + p, None

        acc0 = jnp.zeros((words_a.shape[0], words_b.shape[0]), jnp.int32)
        inter, _ = jax.lax.scan(body, acc0, (wa, wb))
        inter = inter.astype(jnp.float32)
    union = card_a[:, None].astype(jnp.float32) + card_b[None, :].astype(jnp.float32) - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)


def unpack_bits_int8(words: jax.Array) -> jax.Array:
    """uint32[n, W] → int8[n, W·32] {0,1} bit planes (LSB-first per word).

    This is the MXU path: ``popcount(a & b) == unpack(a) @ unpack(b).T``,
    turning bit intersection into an int8 matmul (DESIGN.md §3).
    """
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(words.shape[0], -1).astype(jnp.int8)


@jax.jit
def jaccard_pairwise_mxu(words_a, card_a, words_b, card_b):
    """MXU-friendly variant of :func:`jaccard_pairwise` (bit-plane matmul)."""
    ba = unpack_bits_int8(words_a)
    bb = unpack_bits_int8(words_b)
    inter = jax.lax.dot_general(
        ba, bb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    union = card_a[:, None].astype(jnp.float32) + card_b[None, :].astype(jnp.float32) - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)


# Sketches at least this many uint32 words wide score through the MXU
# bit-plane matmul instead of the VPU popcount loop. 64 words = 2048 bits
# is where jaccard_pairwise starts chunk-scanning the AND tensor — beyond
# it the raw-incidence layouts (W = |I|/32, thousands of words) amortize
# the 8× unpack blow-up against the systolic array's throughput.
MXU_MIN_WORDS = 64


def jaccard_pairwise_auto(words_a, card_a, words_b, card_b):
    """Width-dispatched estimator: popcount for narrow sketches, bit-plane
    MXU matmul for wide (raw-incidence) ones.

    Results are bitwise identical either way — the intersection is an
    exact integer in both layouts and the f32 epilogue is the same ops in
    the same order — so callers (descent scoring, ``_group_knn``) switch
    purely on the compute layout.
    """
    if words_a.shape[-1] >= MXU_MIN_WORDS:
        return jaccard_pairwise_mxu(words_a, card_a, words_b, card_b)
    return jaccard_pairwise(words_a, card_a, words_b, card_b)
