from repro.sketch.goldfinger import GoldFinger, fingerprint_dataset  # noqa: F401
