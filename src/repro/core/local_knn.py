"""Step 2 of C²: per-cluster partial KNN graphs (paper Alg. 2).

The paper hands each cluster to a thread and switches between brute force
(|C| < ρk²) and Hyrec. The TPU-native version batches clusters of similar
size into padded capacity groups and vmaps one fused similarity+top-k over
each group — every cluster in a group is processed by the same program, so
there is no divergence and no synchronization (DESIGN.md §3).

Capacity groups are powers of two ≥ 32, so padding waste is < 2× and each
group compiles once per (capacity, k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import ClusterPlan
from repro.core.params import C2Params
from repro.sketch.goldfinger import GoldFinger, jaccard_pairwise_auto
from repro.types import NEG_INF, PAD_ID


def capacity_of(size: int, minimum: int = 32) -> int:
    c = minimum
    while c < size:
        c *= 2
    return c


@functools.partial(jax.jit, static_argnames=("k",))
def _group_knn(words, card, member_ids, k: int):
    """Brute-force KNN inside each padded cluster of one capacity group.

    words: uint32[m, cap, W]; card: int32[m, cap];
    member_ids: int32[m, cap] global user ids (PAD_ID padded).
    Returns (nbr_ids int32[m, cap, k] global ids, sims float32[m, cap, k]).
    """

    def one_cluster(w, c, ids):
        # Width-dispatched estimator: VPU popcount for GoldFinger-width
        # sketches, MXU bit-plane matmul for raw-incidence widths —
        # identical results, different compute layout.
        sims = jaccard_pairwise_auto(w, c, w, c)  # [cap, cap]
        valid = ids != PAD_ID
        cap = ids.shape[0]
        eye = jnp.eye(cap, dtype=bool)
        mask = valid[None, :] & valid[:, None] & ~eye
        sims = jnp.where(mask, sims, NEG_INF)
        top_sims, pos = jax.lax.top_k(sims, k)
        nbr = jnp.where(top_sims == NEG_INF, PAD_ID, ids[pos])
        return nbr, top_sims

    return jax.vmap(one_cluster)(words, card, member_ids)


def _pallas_group_knn(words, card, member_ids, k: int):
    """Same contract as :func:`_group_knn`, through the Pallas kernel."""
    from repro.kernels.goldfinger_knn import ops as gk_ops

    return gk_ops.cluster_knn(words, card, member_ids, k)


def _hyrec_cluster(members: np.ndarray, gf: GoldFinger, k: int,
                   max_iters: int):
    """Alg. 2's greedy branch: Hyrec restricted to one (huge) cluster."""
    from repro.knn.greedy import hyrec  # local import: avoids cycle

    sub = GoldFinger(words=np.asarray(gf.words)[members],
                     card=np.asarray(gf.card)[members])
    graph, _ = hyrec(sub, k=min(k, len(members) - 1), max_iters=max_iters)
    # Map local indices back to global user ids.
    nbr = np.where(graph.ids == PAD_ID, PAD_ID,
                   members[np.where(graph.ids == PAD_ID, 0, graph.ids)])
    sims = graph.sims
    if nbr.shape[1] < k:  # pad narrow neighborhoods up to k
        pad = k - nbr.shape[1]
        nbr = np.pad(nbr, ((0, 0), (0, pad)), constant_values=PAD_ID)
        sims = np.pad(sims, ((0, 0), (0, pad)), constant_values=NEG_INF)
    return nbr.astype(np.int32), sims.astype(np.float32)


def local_knn(plan: ClusterPlan, gf: GoldFinger, params: C2Params):
    """Compute partial KNNs for every cluster; scatter per configuration.

    Implements Alg. 2's hybrid: clusters with |C| < ρk² go through the
    batched brute-force path (the common case — the paper picks N < ρk²
    deliberately); larger ones run Hyrec restricted to the cluster.

    Returns (ids int32[t, n, k], sims float32[t, n, k]) — for each hash
    configuration, each user's neighbors within its cluster (PAD_ID where
    the cluster was smaller than k+1 or the user was unclustered).
    """
    t, n, k = plan.t, plan.n_users, params.k
    out_ids = np.full((t, n, k), PAD_ID, dtype=np.int32)
    out_sims = np.full((t, n, k), NEG_INF, dtype=np.float32)

    sizes = plan.sizes
    # Alg. 2 switch: brute force iff |C| < ρk².
    greedy_idx = np.flatnonzero(sizes >= params.bf_threshold)
    for ci in greedy_idx:
        cfg = plan.config_of[ci]
        users = plan.members[ci]
        nbr, sims = _hyrec_cluster(users, gf, k, max_iters=params.rho)
        out_ids[cfg, users] = nbr
        out_sims[cfg, users] = sims

    brute = np.ones(len(sizes), dtype=bool)
    brute[greedy_idx] = False
    caps = np.array([capacity_of(int(s)) for s in sizes], dtype=np.int64)
    caps = np.where(brute, caps, -1)  # exclude greedy clusters below
    words_h = np.asarray(gf.words)
    card_h = np.asarray(gf.card)
    W = words_h.shape[1]

    # Bound per-group batch memory: sims [m, cap, cap] f32 AND the
    # gathered fingerprints [m, cap, W] (wide in raw-incidence mode).
    sim_budget = 256 << 20  # 256 MB

    for cap in np.unique(caps):
        if cap < 0:
            continue
        idx = np.flatnonzero(caps == cap)
        m_max = max(1, int(sim_budget // max(cap * cap * 4,
                                             cap * W * 4 * 4)))
        for s in range(0, len(idx), m_max):
            batch = idx[s:s + m_max]
            # Pad the cluster count to a power of two so each (capacity, m)
            # group shape compiles once, not once per batch remainder.
            m = capacity_of(len(batch), minimum=1)
            mem = np.full((m, cap), PAD_ID, dtype=np.int32)
            for j, ci in enumerate(batch):
                mem[j, : sizes[ci]] = plan.members[ci]
            gmem = np.where(mem == PAD_ID, 0, mem)
            w = words_h[gmem].reshape(m, cap, W)
            c = np.where(mem == PAD_ID, 0, card_h[gmem])
            fn = _pallas_group_knn if params.use_pallas else _group_knn
            nbr, sims = fn(jnp.asarray(w), jnp.asarray(c), jnp.asarray(mem), k)
            nbr = np.asarray(nbr)[: len(batch)]
            sims = np.asarray(sims)[: len(batch)]
            # Scatter back per configuration (each user appears in exactly
            # one cluster per configuration).
            for j, ci in enumerate(batch):
                cfg = plan.config_of[ci]
                users = plan.members[ci]
                out_ids[cfg, users] = nbr[j, : len(users)]
                out_sims[cfg, users] = sims[j, : len(users)]
    return out_ids, out_sims
