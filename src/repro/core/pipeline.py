"""Cluster-and-Conquer end-to-end pipeline (paper §II-C).

Step 1 cluster (FastRandomHash + recursive split) → Step 2 per-cluster
partial KNNs → Step 3 merge. Returns the approximate KNN graph plus a
stats record (timings, similarity counts, cluster histogram) that the
benchmarks consume.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.clustering import ClusterPlan, build_plan
from repro.core.local_knn import local_knn
from repro.core.merge import merge_partial
from repro.core.params import C2Params
from repro.sketch.goldfinger import GoldFinger, fingerprint_dataset
from repro.types import Dataset, KNNGraph


@dataclasses.dataclass
class C2Stats:
    t_cluster: float
    t_local: float
    t_merge: float
    n_clusters: int
    n_sims: int            # Σ |C|(|C|−1)/2 — Step 2 similarity budget
    max_cluster: int
    cluster_sizes: np.ndarray

    @property
    def total(self) -> float:
        return self.t_cluster + self.t_local + self.t_merge


def cluster_and_conquer(
    ds: Dataset,
    params: C2Params | None = None,
    gf: GoldFinger | None = None,
) -> tuple[KNNGraph, C2Stats]:
    params = params or C2Params()

    t0 = time.perf_counter()
    if gf is None:
        gf = fingerprint_dataset(ds, n_bits=params.n_bits, seed=params.seed)
    plan: ClusterPlan = build_plan(ds, params)
    t1 = time.perf_counter()

    ids, sims = local_knn(plan, gf, params)
    t2 = time.perf_counter()

    graph = merge_partial(ids, sims, params.k)
    t3 = time.perf_counter()

    sizes = plan.sizes
    stats = C2Stats(
        t_cluster=t1 - t0,
        t_local=t2 - t1,
        t_merge=t3 - t2,
        n_clusters=plan.n_clusters,
        n_sims=plan.brute_force_sims(),
        max_cluster=int(sizes.max()) if len(sizes) else 0,
        cluster_sizes=sizes,
    )
    return graph, stats
