"""Step 1 of C²: FastRandomHash clustering into t configurations (Alg. 1).

Produces a :class:`ClusterPlan` — a *static* description of every cluster
(member lists, sizes, originating hash configuration) that downstream steps
(local KNN, distributed shard_map scheduling) consume. Hash values are
computed vectorized; the recursive split is host-side bookkeeping
(DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hashing
from repro.core.params import C2Params
from repro.core.splitting import SplitResult, split_config
from repro.types import Dataset


@dataclasses.dataclass
class ClusterPlan:
    """Static cluster plan: every cluster across all t configurations."""

    members: list[np.ndarray]    # user ids per cluster
    config_of: np.ndarray        # int32[n_clusters] — hash config index
    n_users: int
    t: int
    # Split path (η₁..η_d) per cluster, when retained by the builder.
    # The query router replays these paths to place an unseen profile in
    # its cluster per configuration (repro/query/router.py).
    paths: list[tuple[int, ...]] | None = None

    @property
    def n_clusters(self) -> int:
        return len(self.members)

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(m) for m in self.members], dtype=np.int64)

    def brute_force_sims(self) -> int:
        """Σ |C|(|C|−1)/2 — the similarity budget of Step 2 (paper §II-F)."""
        s = self.sizes
        return int((s * (s - 1) // 2).sum())


def frh_seeds(params: C2Params) -> np.ndarray:
    """Per-configuration FastRandomHash seeds (shared with the query router)."""
    return np.arange(params.t, dtype=np.int32) + np.int32(params.seed * 1009)


def build_plan(ds: Dataset, params: C2Params) -> ClusterPlan:
    """Cluster all users under t FastRandomHash functions + recursive split."""
    seeds = frh_seeds(params)
    item_h = hashing.item_hashes(ds.items, seeds, params.b)  # [t, nnz]
    cands = hashing.user_distinct_hashes_np(item_h, ds.offsets, params.split_depth)

    members: list[np.ndarray] = []
    config_of: list[int] = []
    paths: list[tuple[int, ...]] = []
    for i in range(params.t):
        res: SplitResult = split_config(cands[i], params.max_cluster)
        for mem, path in zip(res.members, res.paths):
            if len(mem) >= 2:  # singleton clusters yield no edges
                members.append(mem)
                config_of.append(i)
                paths.append(path)
    return ClusterPlan(
        members=members,
        config_of=np.array(config_of, dtype=np.int32),
        n_users=ds.n_users,
        t=params.t,
        paths=paths,
    )
