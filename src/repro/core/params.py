"""Cluster-and-Conquer parameters (paper §IV-C defaults)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class C2Params:
    k: int = 30                # neighborhood size (paper: 30)
    b: int = 4096              # clusters per hash function
    t: int = 8                 # number of hash functions (15 for DBLP/GW)
    max_cluster: int = 2000    # N, recursive-split threshold (4000 for ml20M)
    rho: int = 5               # Hyrec iteration bound in the ρk² switch
    n_bits: int = 1024         # GoldFinger width (paper experiments: 1024)
    seed: int = 0
    split_depth: int = 6       # precomputed distinct-hash depth for splitting
    use_goldfinger: bool = True  # Table V ablation: False → exact Jaccard
    use_pallas: bool = False   # route local brute force through the kernel

    @property
    def bf_threshold(self) -> int:
        """Brute-force-vs-Hyrec switch: |C| < ρ·k² → brute force (§II-F)."""
        return self.rho * self.k * self.k


# Per-dataset overrides from §IV-C.
PAPER_PARAMS = {
    "ml1M": C2Params(),
    "ml10M": C2Params(),
    "ml20M": C2Params(max_cluster=4000),
    "AM": C2Params(),
    "DBLP": C2Params(t=15),
    "GW": C2Params(t=15),
}


def params_for(dataset_name: str, **overrides) -> C2Params:
    base = PAPER_PARAMS.get(dataset_name.split("@")[0], C2Params())
    return dataclasses.replace(base, **overrides)
