"""FastRandomHash (paper §II-D), JAX/numpy vectorized.

A *generative* hash function h_i maps item ids onto the bounded interval
[0, b). The FastRandomHash of a user is the minimum hash over her profile::

    H_i(u) = min_{item ∈ P_u} h_i(item)                      (paper Eq. 3)

The paper uses Jenkins' hash; any approximately-random h satisfies Theorems
1/2, so we use the murmur3 ``fmix32`` finalizer (4 vector ops on the VPU),
which vectorizes over both numpy and jnp uint32 arrays.

Splitting support: ``H\\η(u) = min_{item ∈ P_u, h(item) > η} h(item)`` is what
recursive splitting (§II-D) evaluates; we expose per-user *sorted distinct
hash values* so the split planner can walk down each user's candidate
sequence without rehashing (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NO_HASH = np.int32(2**31 - 1)  # "H undefined" sentinel (empty masked min)


def fmix32(x):
    """Murmur3 finalizer. Works on numpy and jnp uint32 arrays (wrapping)."""
    is_np = isinstance(x, np.ndarray)
    u32 = (lambda v: np.uint32(v)) if is_np else (lambda v: jnp.uint32(v))
    x = x ^ (x >> u32(16))
    x = x * u32(0x85EB_CA6B)
    x = x ^ (x >> u32(13))
    x = x * u32(0xC2B2_AE35)
    x = x ^ (x >> u32(16))
    return x


def item_hashes(items, seeds, b: int):
    """h_i(item) for every (hash function i, item): int32[t, nnz] in [0, b).

    ``items``: int32[nnz]; ``seeds``: int32[t]. numpy in → numpy out,
    jnp in → jnp out (the device path is used by the fused Pallas kernel's
    reference and by distributed hashing).
    """
    is_np = isinstance(items, np.ndarray)
    xp = np if is_np else jnp
    items_u = items.astype(xp.uint32)
    seeds_u = xp.asarray(seeds).astype(xp.uint32)
    # Distinct stream per hash function: mix(item ⊕ golden·(seed+1)).
    x = items_u[None, :] ^ ((seeds_u[:, None] + xp.uint32(1)) * xp.uint32(0x9E37_79B9))
    return (fmix32(x) % xp.uint32(b)).astype(xp.int32)


def user_min_hash_np(item_h: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """H_i(u) per (function, user): int32[t, n]. Host CSR segment-min."""
    t, _ = item_h.shape
    n = len(offsets) - 1
    out = np.full((t, n), NO_HASH, dtype=np.int32)
    nonempty = np.diff(offsets) > 0
    starts = offsets[:-1][nonempty]
    for i in range(t):
        mins = np.minimum.reduceat(item_h[i], starts)
        out[i, nonempty] = mins
    return out


def user_min_hash_jnp(item_h: jax.Array, user_of: jax.Array, n_users: int) -> jax.Array:
    """Device segment-min: item_h int32[t, nnz], user_of int32[nnz] → [t, n]."""
    return jax.vmap(
        lambda h: jax.ops.segment_min(h, user_of, num_segments=n_users)
    )(item_h).astype(jnp.int32)


def user_hash_above_np(item_h_row: np.ndarray, offsets: np.ndarray,
                       eta: int, user_ids: np.ndarray) -> np.ndarray:
    """H\\η for a subset of users under one hash function (host).

    Returns int32[len(user_ids)]; NO_HASH where no item hash exceeds η
    (the "single item" case of §II-D — those users remain in the cluster).
    """
    out = np.full(len(user_ids), NO_HASH, dtype=np.int32)
    for j, u in enumerate(user_ids):
        h = item_h_row[offsets[u]:offsets[u + 1]]
        h = h[h > eta]
        if len(h):
            out[j] = h.min()
    return out


def user_distinct_hashes_np(item_h: np.ndarray, offsets: np.ndarray,
                            depth: int) -> np.ndarray:
    """Per (function, user): the ``depth`` smallest *distinct* hash values,
    ascending, padded with NO_HASH — int32[t, n, depth].

    Recursive splitting only ever moves a user to its next distinct hash
    value above the current cluster index, so this table fully determines
    every split decision (DESIGN.md §3).

    Implementation (§Perf C² iteration 2): ``depth`` passes of masked
    ``minimum.reduceat`` — O(depth·nnz) with no sort. The previous
    lexsort formulation (kept below as the test oracle) was 68% of C²'s
    end-to-end wall time on the ml10M benchmark.
    """
    t, nnz = item_h.shape
    n = len(offsets) - 1
    out = np.full((t, n, depth), NO_HASH, dtype=np.int32)
    sizes = np.diff(offsets)
    nonempty = sizes > 0
    starts = offsets[:-1][nonempty]
    user_of = np.repeat(np.arange(n, dtype=np.int64), sizes)
    for i in range(t):
        h = item_h[i].copy()
        for d in range(depth):
            mins = np.minimum.reduceat(h, starts)
            out[i, nonempty, d] = mins
            if d + 1 == depth:
                break
            # Mask out the level-d minimum everywhere it occurs, so the
            # next pass yields the next *distinct* value.
            cur = out[i][user_of, d]
            h[h == cur] = NO_HASH
            if (out[i, nonempty, d] == NO_HASH).all():
                break
    return out


def user_distinct_hashes_np_ref(item_h: np.ndarray, offsets: np.ndarray,
                                depth: int) -> np.ndarray:
    """Lexsort-based oracle for :func:`user_distinct_hashes_np` (tests)."""
    t, nnz = item_h.shape
    n = len(offsets) - 1
    out = np.full((t, n, depth), NO_HASH, dtype=np.int32)
    sizes = np.diff(offsets)
    user_of = np.repeat(np.arange(n, dtype=np.int64), sizes)
    for i in range(t):
        row = item_h[i]
        order = np.lexsort((row, user_of))
        uu, hh = user_of[order], row[order]
        keep = np.ones(nnz, dtype=bool)
        keep[1:] = (uu[1:] != uu[:-1]) | (hh[1:] != hh[:-1])
        du, dh = uu[keep], hh[keep]
        seg_start = np.zeros(len(du), dtype=np.int64)
        new_seg = np.ones(len(du), dtype=bool)
        new_seg[1:] = du[1:] != du[:-1]
        seg_idx = np.flatnonzero(new_seg)
        seg_start[seg_idx] = seg_idx
        seg_start = np.maximum.accumulate(seg_start)
        rank = np.arange(len(du)) - seg_start
        sel = rank < depth
        out[i, du[sel], rank[sel]] = dh[sel]
    return out
