"""Recursive cluster splitting (paper §II-D, Fig. 3).

FastRandomHash's min introduces a bias towards low cluster indices; clusters
larger than N are recursively split with H\\η (min over item hashes > η).

Key observation (DESIGN.md §3): a user u in a depth-d cluster followed the
path (η₁ < η₂ < … < η_d) of its d smallest *distinct* item-hash values, so
every split decision is determined by the per-user ascending distinct-hash
table computed once on device. The split loop below is therefore pure
bookkeeping (host-side scheduling), with zero re-hashing.

Paper's two exceptions are honored: users with no next hash value
("single item" users) and users alone in their tentative child cluster
remain in the parent.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hashing import NO_HASH


@dataclasses.dataclass
class SplitResult:
    """Clusters of ONE hash configuration after recursive splitting.

    ``members[c]`` — user ids of cluster c; ``paths[c]`` — the (η₁..η_d)
    split path identifying it.
    """

    members: list[np.ndarray]
    paths: list[tuple[int, ...]]

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(m) for m in self.members], dtype=np.int64)


def split_config(cands: np.ndarray, max_cluster: int) -> SplitResult:
    """Split one configuration.

    cands: int32[n_users, depth] — ascending distinct item-hash values per
    user (NO_HASH padded), from ``user_distinct_hashes_np``.
    """
    n, depth = cands.shape
    valid = cands[:, 0] != NO_HASH  # users with non-empty profiles
    members: list[np.ndarray] = []
    paths: list[tuple[int, ...]] = []

    # Initial clustering: bucket by H(u) = first distinct hash.
    users = np.arange(n, dtype=np.int64)[valid]
    order = np.argsort(cands[valid, 0], kind="stable")
    sorted_users = users[order]
    sorted_h = cands[valid, 0][order]
    bounds = np.flatnonzero(np.diff(sorted_h, prepend=-1) != 0)
    queue: list[tuple[np.ndarray, tuple[int, ...], int]] = []  # (members, path, depth)
    for s, e in zip(bounds, np.append(bounds[1:], len(sorted_users))):
        queue.append((sorted_users[s:e], (int(sorted_h[s]),), 1))

    while queue:
        mem, path, d = queue.pop()
        if len(mem) <= max_cluster or d >= depth:
            members.append(mem)
            paths.append(path)
            continue
        nxt = cands[mem, d]  # next distinct hash above path[-1]
        movable = nxt != NO_HASH
        # Group movers by their next hash; singleton children stay (§II-D).
        mv = mem[movable]
        mh = nxt[movable]
        stay = [mem[~movable]]
        if len(mv):
            o = np.argsort(mh, kind="stable")
            mv, mh = mv[o], mh[o]
            b2 = np.flatnonzero(np.diff(mh, prepend=-1) != 0)
            ends = np.append(b2[1:], len(mv))
            for s, e in zip(b2, ends):
                child = mv[s:e]
                if len(child) == 1:
                    stay.append(child)
                else:
                    queue.append((child, path + (int(mh[s]),), d + 1))
        remaining = np.concatenate(stay)
        if len(remaining) == len(mem):
            # No progress possible — accept the oversized cluster.
            members.append(mem)
            paths.append(path)
        elif len(remaining):
            # The parent keeps its stayers; it cannot shrink further by
            # re-splitting (stayers are exhausted or singleton-children).
            members.append(remaining)
            paths.append(path)
    return SplitResult(members=members, paths=paths)
