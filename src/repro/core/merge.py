"""Step 3 of C²: merging the t partial KNN graphs (paper Alg. 3).

The paper inserts each partial neighborhood into per-user bounded heaps,
reusing similarity values. The vectorized equivalent: concatenate each
user's t×k candidates, mask duplicates (reuse, not recompute), and take one
wide top-k (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.knn.topk import merge_topk
from repro.types import KNNGraph


@functools.partial(jax.jit, static_argnames=("k",))
def _merge(ids_tkn, sims_tkn, k: int):
    t, n, _ = ids_tkn.shape
    ids = jnp.transpose(ids_tkn, (1, 0, 2)).reshape(n, -1)
    sims = jnp.transpose(sims_tkn, (1, 0, 2)).reshape(n, -1)
    self_ids = jnp.arange(n, dtype=ids.dtype)
    return merge_topk(ids, sims, k, self_ids)


def merge_partial(ids: np.ndarray, sims: np.ndarray, k: int) -> KNNGraph:
    """ids/sims: [t, n, k'] per-configuration partial KNNs → final graph."""
    out_ids, out_sims = _merge(jnp.asarray(ids), jnp.asarray(sims), k)
    return KNNGraph(ids=np.asarray(out_ids), sims=np.asarray(out_sims))
