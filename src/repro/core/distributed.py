"""Distributed C² Step 2: shard_map over the mesh's data axis.

The paper's thread pool + synchronized priority queue becomes a *static*
LPT (longest-processing-time) bin-packing of clusters onto devices —
identical straggler protection (cluster cost is capped by N, the paper's
own knob) with zero runtime synchronization. Inside the shard_map there
are NO collectives: each device computes the partial KNNs of its bin,
exactly the paper's "computed independently, without any synchronization"
property, realized as SPMD (DESIGN.md §3).

The merge (Step 3) is the reduce phase: partial results return to host
sharded by device and are merged per hash configuration.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import ClusterPlan
from repro.core.local_knn import _group_knn, capacity_of
from repro.core.params import C2Params
from repro.sketch.goldfinger import GoldFinger
from repro.types import NEG_INF, PAD_ID


def lpt_assign(costs: np.ndarray, n_bins: int) -> np.ndarray:
    """Longest-processing-time assignment: returns bin id per item."""
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_bins, dtype=np.float64)
    assign = np.zeros(len(costs), dtype=np.int64)
    for i in order:
        b = int(np.argmin(loads))
        assign[i] = b
        loads[b] += costs[i]
    return assign


def lpt_loads(costs: np.ndarray, assign: np.ndarray,
              n_bins: int) -> np.ndarray:
    """Per-bin load of an assignment (shared by build + serving shards)."""
    loads = np.zeros(n_bins, dtype=np.float64)
    np.add.at(loads, assign, np.asarray(costs, dtype=np.float64))
    return loads


@dataclasses.dataclass
class DistPlan:
    """Static per-capacity-group member tensors: [n_dev, m_max, cap]."""

    groups: list[np.ndarray]
    caps: list[int]
    cluster_of: list[np.ndarray]  # (dev, slot) → cluster index (−1 pad)
    imbalance: float              # max/mean device load


def build_dist_plan(plan: ClusterPlan, n_dev: int) -> DistPlan:
    sizes = plan.sizes
    costs = sizes.astype(np.float64) ** 2  # brute force is O(|C|²)
    assign = lpt_assign(costs, n_dev)
    loads = lpt_loads(costs, assign, n_dev)
    imbalance = float(loads.max() / max(loads.mean(), 1e-9))

    caps_all = np.array([capacity_of(int(s)) for s in sizes])
    groups, caps, cluster_of = [], [], []
    for cap in np.unique(caps_all):
        idx = np.flatnonzero(caps_all == cap)
        m_max = max(int(np.max(np.bincount(assign[idx], minlength=n_dev))), 1)
        mem = np.full((n_dev, m_max, cap), PAD_ID, dtype=np.int32)
        cof = np.full((n_dev, m_max), -1, dtype=np.int64)
        slot = np.zeros(n_dev, dtype=np.int64)
        for ci in idx:
            d = assign[ci]
            s = slot[d]
            mem[d, s, : sizes[ci]] = plan.members[ci]
            cof[d, s] = ci
            slot[d] += 1
        groups.append(mem)
        caps.append(int(cap))
        cluster_of.append(cof)
    return DistPlan(groups=groups, caps=caps, cluster_of=cluster_of,
                    imbalance=imbalance)


def distributed_local_knn(plan: ClusterPlan, gf: GoldFinger,
                          params: C2Params, mesh,
                          data_axis: str = "data"):
    """Step 2 on a mesh: each device brute-forces its LPT bin of clusters.

    Returns (ids, sims) int32/float32 [t, n, k] as local_knn does.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_dev = int(mesh.shape[data_axis])
    dp = build_dist_plan(plan, n_dev)
    words = jnp.asarray(np.asarray(gf.words))
    card = jnp.asarray(np.asarray(gf.card))
    k = params.k

    def device_fn(*mems):
        # mems: per capacity group [1, m_max, cap] member ids (local bin).
        outs = []
        for mem in mems:
            mem = mem[0]
            gmem = jnp.where(mem == PAD_ID, 0, mem)
            w = words[gmem]                       # gather from replicated
            c = jnp.where(mem == PAD_ID, 0, card[gmem])
            nbr, sims = _group_knn(w, c, mem, k)
            outs.append((nbr[None], sims[None]))
        return tuple(outs)

    in_specs = tuple(P(data_axis, None, None) for _ in dp.groups)
    out_specs = tuple((P(data_axis, None, None, None),
                       P(data_axis, None, None, None))
                      for _ in dp.groups)
    results = shard_map(device_fn, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)(
        *[jnp.asarray(g) for g in dp.groups])

    t, n = plan.t, plan.n_users
    out_ids = np.full((t, n, k), PAD_ID, dtype=np.int32)
    out_sims = np.full((t, n, k), NEG_INF, dtype=np.float32)
    for (nbr, sims), mem, cof in zip(results, dp.groups, dp.cluster_of):
        nbr = np.asarray(nbr)
        sims = np.asarray(sims)
        for d in range(mem.shape[0]):
            for s in range(mem.shape[1]):
                ci = cof[d, s]
                if ci < 0:
                    continue
                users = plan.members[ci]
                cfg = plan.config_of[ci]
                out_ids[cfg, users] = nbr[d, s, : len(users)]
                out_sims[cfg, users] = sims[d, s, : len(users)]
    return out_ids, out_sims, dp


def distributed_c2(ds, params: C2Params, mesh, gf: GoldFinger | None = None,
                   data_axis: str = "data"):
    """Full distributed pipeline: host plan → mesh Step 2 → merge."""
    import time

    from repro.core.clustering import build_plan
    from repro.core.merge import merge_partial
    from repro.sketch.goldfinger import fingerprint_dataset

    t0 = time.perf_counter()
    if gf is None:
        gf = fingerprint_dataset(ds, n_bits=params.n_bits, seed=params.seed)
    plan = build_plan(ds, params)
    t1 = time.perf_counter()
    ids, sims, dp = distributed_local_knn(plan, gf, params, mesh, data_axis)
    t2 = time.perf_counter()
    graph = merge_partial(ids, sims, params.k)
    t3 = time.perf_counter()
    stats = {
        "t_cluster": t1 - t0, "t_local": t2 - t1, "t_merge": t3 - t2,
        "n_clusters": plan.n_clusters,
        "n_sims": plan.brute_force_sims(),
        "lpt_imbalance": dp.imbalance,
        "n_devices": int(mesh.shape[data_axis]),
    }
    return graph, stats
