"""Per-shard health state machine: healthy → suspect → dead → recovering.

Pure host bookkeeping, advanced once per scheduler step by
``FailoverManager.observe`` with a boolean down-vector from the fault
injector (in a multi-process deployment the same vector would come from
RPC probe timeouts — the machine doesn't care where probes come from).

Transitions:

* **healthy → suspect** on the first failed probe. Suspect shards are
  immediately masked out of serving (their seeds are dropped, their
  merge lanes neutralized) — answering from survivors with bounded
  recall loss beats blocking on a shard that may never come back.
* **suspect → healthy** when a re-probe at a backoff boundary succeeds
  (transient failure cleared itself; no rebuild needed).
* **suspect → dead** after ``max_retries`` consecutive failed
  re-probes. Re-probes happen at capped exponential backoff — 1, 2, 4,
  … ``backoff_cap`` steps apart — so a flapping shard doesn't burn a
  probe per step, and the time-to-declare-dead is a deterministic
  function of the config.
* **dead → recovering** once the shard has been dead
  ``recover_after`` steps: the failover manager rebuilds its resident
  tensors from survivors + the index and blue/green-swaps them in.
* **recovering → healthy** when the swap lands.

Everything is counted (probes, retries, backoff steps, deaths,
recoveries) so the serving stats line can report the degraded window.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"
RECOVERING = "recovering"
STATES = (HEALTHY, SUSPECT, DEAD, RECOVERING)


@dataclass(frozen=True)
class HealthConfig:
    """Knobs of the health machine (all in scheduler steps)."""
    max_retries: int = 3   # consecutive failed re-probes before dead
    backoff_cap: int = 8   # max steps between suspect re-probes
    recover_after: int = 4 # steps a shard stays dead before rebuild


class FleetHealth:
    """Health state for ``n_shards`` shards, one observe() per step."""

    def __init__(self, n_shards: int, cfg: HealthConfig = None):
        self.n_shards = n_shards
        self.cfg = cfg or HealthConfig()
        self.state = [HEALTHY] * n_shards
        self.retries = np.zeros(n_shards, dtype=np.int64)
        self.backoff = np.ones(n_shards, dtype=np.int64)
        self.next_probe = np.zeros(n_shards, dtype=np.int64)
        self.dead_since = np.full(n_shards, -1, dtype=np.int64)
        self.step = -1
        self.n_probes = 0
        self.n_retries = 0
        self.backoff_steps = 0   # steps spent waiting between re-probes
        self.n_deaths = 0
        self.n_recoveries = 0

    def observe(self, down) -> None:
        """Advance one step with this step's probe outcomes."""
        down = np.asarray(down, dtype=bool)
        assert down.shape == (self.n_shards,), down.shape
        self.step += 1
        cfg = self.cfg
        for s in range(self.n_shards):
            st = self.state[s]
            if st in (DEAD, RECOVERING):
                continue  # only a failover swap moves these on
            if st == HEALTHY:
                self.n_probes += 1
                if down[s]:
                    self.state[s] = SUSPECT
                    self.retries[s] = 0
                    self.backoff[s] = 1
                    self.next_probe[s] = self.step + 1
                continue
            # SUSPECT: re-probe only at the backoff boundary.
            if self.step < self.next_probe[s]:
                self.backoff_steps += 1
                continue
            self.n_probes += 1
            self.n_retries += 1
            if not down[s]:
                self._reset(s)  # transient failure cleared itself
                continue
            self.retries[s] += 1
            if self.retries[s] >= cfg.max_retries:
                self.state[s] = DEAD
                self.dead_since[s] = self.step
                self.n_deaths += 1
            else:
                self.backoff[s] = min(2 * self.backoff[s], cfg.backoff_cap)
                self.next_probe[s] = self.step + self.backoff[s]

    def _reset(self, s: int) -> None:
        self.state[s] = HEALTHY
        self.retries[s] = 0
        self.backoff[s] = 1
        self.next_probe[s] = 0
        self.dead_since[s] = -1

    # -- queries -----------------------------------------------------------

    def serving_mask(self) -> np.ndarray:
        """bool[n_shards]: True where the shard must NOT serve
        (suspect, dead or mid-recovery)."""
        return np.array([st != HEALTHY for st in self.state], dtype=bool)

    def ready_for_recovery(self) -> list[int]:
        """Dead shards whose grace period elapsed — rebuild these now."""
        return [s for s in range(self.n_shards)
                if self.state[s] == DEAD
                and self.step - self.dead_since[s] >= self.cfg.recover_after]

    # -- failover transitions ----------------------------------------------

    def mark_recovering(self, s: int) -> None:
        assert self.state[s] == DEAD, self.state[s]
        self.state[s] = RECOVERING

    def mark_healthy(self, s: int) -> None:
        if self.state[s] == RECOVERING:
            self.n_recoveries += 1
        self._reset(s)

    def stats(self) -> dict:
        return {
            "states": list(self.state),
            "shards_down": int(self.serving_mask().sum()),
            "probes": self.n_probes,
            "retries": self.n_retries,
            "backoff_steps": self.backoff_steps,
            "deaths": self.n_deaths,
            "recoveries": self.n_recoveries,
        }
