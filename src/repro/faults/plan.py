"""Deterministic, seeded fault injection at the plan-step boundary.

Fault tolerance is only testable if the failures themselves are
reproducible, so every fault here is *scheduled*, never sampled at
serve time: a :class:`FaultPlan` is an explicit list of
:class:`FaultEvent` rows (parsed from a compact spec string or
generated from a seed), and a :class:`FaultInjector` replays it against
a monotone step counter that the engine advances once per scheduler
step (``engine.step → injector.begin_step → plan.step``). Running the
same plan against the same engine twice produces the same probe
outcomes, the same health transitions, and the same degraded answers —
which is what lets the test batteries pin failover behavior bitwise.

Event kinds:

* ``kill:S@T``      — shard S fails permanently from step T (until a
  failover rebuild clears it via :meth:`FaultInjector.clear_shard`);
* ``fail:S@T+D``    — shard S fails transiently for D steps starting
  at T, then comes back on its own (exercises the suspect → healthy
  path of the health machine without a rebuild);
* ``slow:S@T+D:MS`` — shard S is slow for D steps: MS milliseconds of
  injected latency per step (advances an injected ``ManualClock``
  deterministically, falls back to ``time.sleep`` on a real clock);
* ``crash@T``       — raise :class:`EngineCrash` at the *start* of
  step T, before any descent work: the crash always lands between
  scheduler steps, which is the granularity the WAL + snapshot
  recovery path guarantees consistency at.

Events are separated by ``;`` or ``,``: ``"fail:0@3+2;kill:1@8"``.
"""
from __future__ import annotations

import re
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.faults.health import HealthConfig

KINDS = ("kill", "fail", "slow", "crash")

_EVENT_RE = re.compile(
    r"^(?:"
    r"kill:(?P<kshard>\d+)@(?P<kstep>\d+)"
    r"|fail:(?P<fshard>\d+)@(?P<fstep>\d+)\+(?P<fdur>\d+)"
    r"|slow:(?P<sshard>\d+)@(?P<sstep>\d+)\+(?P<sdur>\d+):(?P<sms>\d+(?:\.\d+)?)"
    r"|crash@(?P<cstep>\d+)"
    r")$")


class EngineCrash(RuntimeError):
    """Injected process death between scheduler steps.

    Raised by :meth:`FaultInjector.begin_step` before any work of the
    step runs. Whatever mutations the engine applied in earlier steps
    are already in the write-ahead log; in-flight continuous slots and
    the pending insert cohort are lost (documented failure model —
    clients re-submit), and ``QueryEngine.recover`` restores everything
    durable bitwise.
    """


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``step`` counts armed scheduler steps from
    0; ``duration`` is in steps (ignored for kill/crash); ``latency_s``
    is per-step injected latency (slow only)."""
    kind: str
    step: int
    shard: int = -1
    duration: int = 0
    latency_s: float = 0.0

    def active(self, step: int) -> bool:
        if self.kind == "kill":
            return step >= self.step
        if self.kind in ("fail", "slow"):
            return self.step <= step < self.step + self.duration
        return step == self.step  # crash

    def describe(self) -> str:
        if self.kind == "kill":
            return f"kill:{self.shard}@{self.step}"
        if self.kind == "fail":
            return f"fail:{self.shard}@{self.step}+{self.duration}"
        if self.kind == "slow":
            return (f"slow:{self.shard}@{self.step}+{self.duration}"
                    f":{self.latency_s * 1e3:g}")
        return f"crash@{self.step}"


@dataclass(frozen=True)
class FaultPlan:
    """An explicit, ordered fault schedule (pure data, reusable)."""
    events: tuple[FaultEvent, ...] = ()

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a compact spec: ``kill:S@T``, ``fail:S@T+D``,
        ``slow:S@T+D:MS``, ``crash@T``, separated by ``;`` or ``,``."""
        events = []
        for part in re.split(r"[;,]", spec):
            part = part.strip()
            if not part:
                continue
            m = _EVENT_RE.match(part)
            if m is None:
                raise ValueError(
                    f"bad fault event {part!r}; expected kill:S@T, "
                    f"fail:S@T+D, slow:S@T+D:MS or crash@T")
            g = m.groupdict()
            if g["kshard"] is not None:
                events.append(FaultEvent("kill", int(g["kstep"]),
                                         shard=int(g["kshard"])))
            elif g["fshard"] is not None:
                events.append(FaultEvent("fail", int(g["fstep"]),
                                         shard=int(g["fshard"]),
                                         duration=int(g["fdur"])))
            elif g["sshard"] is not None:
                events.append(FaultEvent("slow", int(g["sstep"]),
                                         shard=int(g["sshard"]),
                                         duration=int(g["sdur"]),
                                         latency_s=float(g["sms"]) / 1e3))
            else:
                events.append(FaultEvent("crash", int(g["cstep"])))
        return cls(events=tuple(sorted(events, key=lambda e: (e.step,
                                                              e.kind,
                                                              e.shard))))

    @classmethod
    def random(cls, n_shards: int, n_steps: int, seed: int,
               n_events: int = 3,
               kinds: Sequence[str] = ("kill", "fail", "slow")) -> "FaultPlan":
        """Seeded random schedule — same (seed, shape) ⇒ same plan."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(1, max(2, n_steps)))
            if kind == "crash":
                events.append(FaultEvent("crash", step))
                continue
            shard = int(rng.integers(n_shards))
            dur = int(rng.integers(1, 5))
            if kind == "kill":
                events.append(FaultEvent("kill", step, shard=shard))
            elif kind == "fail":
                events.append(FaultEvent("fail", step, shard=shard,
                                         duration=dur))
            else:
                events.append(FaultEvent(
                    "slow", step, shard=shard, duration=dur,
                    latency_s=float(rng.integers(1, 20)) / 1e3))
        return cls(events=tuple(sorted(events, key=lambda e: (e.step,
                                                              e.kind,
                                                              e.shard))))

    def describe(self) -> str:
        return ";".join(e.describe() for e in self.events) or "(empty)"


@dataclass
class FaultInjector:
    """Replays a :class:`FaultPlan` against the engine's step counter.

    The engine calls :meth:`begin_step` once per scheduler step (before
    descent work) and the failover manager probes shard liveness with
    :meth:`shard_down`. ``armed=False`` constructs the injector inert —
    warm-up and pre-failure measurement run fault-free, then
    :meth:`arm` starts the schedule from step 0 (benchmarks use this so
    event steps count from the measured window, not from compilation
    waves).

    ``health`` carries the :class:`~repro.faults.health.HealthConfig`
    the engine's failover manager should run with, so one CLI flag /
    one constructor argument configures the whole failure pipeline.
    """
    plan: FaultPlan
    clock: Optional[Callable[[], float]] = None
    armed: bool = True
    health: Optional[HealthConfig] = None
    step: int = field(default=-1, init=False)
    injected_latency_s: float = field(default=0.0, init=False)
    n_slow_steps: int = field(default=0, init=False)
    n_crashes: int = field(default=0, init=False)
    _cleared: set = field(default_factory=set, init=False)

    def arm(self) -> None:
        """(Re)start the schedule: step counting begins at the next
        ``begin_step`` and previously cleared events stay cleared only
        if they already fired — a fresh arm replays everything."""
        self.armed = True
        self.step = -1
        self._cleared.clear()

    def begin_step(self) -> None:
        """Advance the fault clock; raise :class:`EngineCrash` or
        inject slow-shard latency if the schedule says so."""
        if not self.armed:
            return
        self.step += 1
        lat = 0.0
        for ev in self.plan.events:
            if ev.kind == "crash" and ev.active(self.step):
                self.n_crashes += 1
                raise EngineCrash(
                    f"injected crash at step {self.step} "
                    f"({ev.describe()})")
            if ev.kind == "slow" and ev.active(self.step):
                lat += ev.latency_s
        if lat > 0.0:
            self.n_slow_steps += 1
            self.injected_latency_s += lat
            advance = getattr(self.clock, "advance", None)
            if advance is not None:
                advance(lat)
            else:
                time.sleep(lat)

    def shard_down(self, shard: int) -> bool:
        """Liveness probe: True while any uncleared kill or an active
        transient failure covers ``shard`` at the current step."""
        if not self.armed:
            return False
        for ev in self.plan.events:
            if ev.shard != shard:
                continue
            if ev.kind == "kill" and ev.active(self.step) \
                    and ev not in self._cleared:
                return True
            if ev.kind == "fail" and ev.active(self.step):
                return True
        return False

    def clear_shard(self, shard: int) -> None:
        """Failover completed: permanent kills of ``shard`` that already
        fired stop applying (a later kill event re-kills it)."""
        for ev in self.plan.events:
            if ev.kind == "kill" and ev.shard == shard \
                    and ev.step <= self.step:
                self._cleared.add(ev)

    def stats(self) -> dict:
        return {
            "plan": self.plan.describe(),
            "step": self.step,
            "armed": self.armed,
            "crashes": self.n_crashes,
            "slow_steps": self.n_slow_steps,
            "injected_latency_s": round(self.injected_latency_s, 6),
            "cleared": sorted(e.describe() for e in self._cleared),
        }
