"""Crash consistency: snapshot + write-ahead journal replay.

The failure model is a process crash *between scheduler steps* (the
:class:`~repro.faults.plan.FaultInjector`'s ``crash@T`` lands at the
step boundary, before any compiled program of step T runs). Index
mutations are host-side and atomic with respect to that boundary, so
crash recovery reduces to: load the last snapshot, replay the journal
suffix. Two pieces make the replayed engine *bitwise*-equal — tensors
AND answers — to one that never crashed:

* **Record-before-apply** — every :class:`~repro.query.index.KNNIndex`
  mutator writes its WAL record *before* touching state, and the crash
  only fires between steps, so the journal either contains a mutation
  in full or the mutation never happened. No torn writes to reason
  about.
* **Resolved arguments** — records carry the mutation's arguments
  RESOLVED, not as intents: ``refresh_cohort`` logs the concrete
  ``max_cluster`` it computed (the default depends on consolidation
  state, which differs between a freshly-loaded snapshot and the live
  index), and float sims round-trip exactly because float32 → Python
  float → JSON repr → float32 is lossless (the repr of a double that
  came from a float32 has enough digits to recover it bitwise).

What is deliberately NOT persisted: in-flight continuous slots and the
pending insert cohort. A crash loses requests that were in flight —
that is the documented contract (clients retry); what recovery
guarantees is that the *index* (and therefore every answer computed
after recovery) is bitwise-identical to the never-crashed engine's.

:class:`WriteAheadLog` is a JSON-lines file, one record per mutation,
flushed per record (the crash model is in-process — the injector raises
between steps — so a host ``fsync`` per record would buy durability
this model doesn't claim while costing real latency).
:class:`CrashStore` owns the snapshot cadence: each snapshot persists
the index (journals included — see ``KNNIndex.save``) plus a sidecar of
the sharded placement's frozen *base* plan, then starts a fresh WAL —
compaction is snapshotting, which bounds replay work by the cadence.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.sched import Cadence


def _jsonable(v):
    """Encode a record argument as JSON-representable, losslessly."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


class WriteAheadLog:
    """Append-only JSON-lines journal of index mutations."""

    def __init__(self, path: str | Path, append: bool = True):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "a" if append else "w")
        self.n_records = 0

    def record(self, op: str, **args):
        rec = {"op": op}
        rec.update({k: _jsonable(v) for k, v in args.items()})
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()
        self.n_records += 1

    def close(self):
        if not self._fh.closed:
            self._fh.close()

    @staticmethod
    def read(path: str | Path) -> list[dict]:
        """All records of a journal file (missing file → empty journal:
        a crash can land before the first post-snapshot mutation)."""
        path = Path(path)
        if not path.exists():
            return []
        with open(path) as fh:
            return [json.loads(line) for line in fh if line.strip()]


def _apply(index, rec: dict):
    """Replay ONE journal record onto ``index``.

    Arguments are coerced back to the exact dtypes the live mutators
    received — the mutators cast internally, but replay must not depend
    on that staying true.
    """
    op = rec["op"]
    if op == "append_user":
        index.append_user(
            np.asarray(rec["words_row"], dtype=np.uint32),
            int(rec["card_row"]),
            np.asarray(rec["nbr_ids"], dtype=np.int32),
            np.asarray(rec["nbr_sims"], dtype=np.float32))
    elif op == "remove_user":
        index.remove_user(int(rec["u"]))
    elif op == "swap_profile":
        index.swap_profile(int(rec["u"]),
                           np.asarray(rec["words_row"], dtype=np.uint32),
                           int(rec["card_row"]))
    elif op == "relink_user":
        index.relink_user(int(rec["u"]),
                          np.asarray(rec["nbr_ids"], dtype=np.int32),
                          np.asarray(rec["nbr_sims"], dtype=np.float32))
    elif op == "touch_row":
        index.touch_row(int(rec["u"]), int(rec["clock"]))
    elif op == "add_cluster_member":
        index.add_cluster_member(int(rec["ci"]), int(rec["user"]))
    elif op == "refresh_cohort":
        index.refresh_cohort(
            np.asarray(rec["items"], dtype=np.int32),
            np.asarray(rec["offsets"], dtype=np.int64),
            np.asarray(rec["user_ids"], dtype=np.int32),
            max_cluster=int(rec["max_cluster"]))
    else:
        raise ValueError(f"unknown WAL op {op!r}")


def replay(index, records) -> int:
    """Replay a journal suffix onto a snapshot-loaded index; returns the
    record count. The index must have NO WAL attached (replaying into a
    live journal would duplicate every record)."""
    assert index._wal is None, "detach the WAL before replaying into it"
    n = 0
    for rec in records:
        _apply(index, rec)
        n += 1
    return n


def _save_plan_sidecar(path: Path, plan):
    res = ([np.asarray(r, dtype=np.int64) for r in plan.residents]
           or [np.zeros(0, dtype=np.int64)])
    offsets = np.zeros(len(plan.residents) + 1, dtype=np.int64)
    np.cumsum([len(r) for r in plan.residents], out=offsets[1:])
    np.savez(path,
             n_shards=np.int64(plan.n_shards),
             cluster_shard=np.asarray(plan.cluster_shard, dtype=np.int64),
             residents=np.concatenate(res),
             resident_offsets=offsets,
             owner=np.asarray(plan.owner, dtype=np.int64),
             imbalance=np.float64(plan.imbalance),
             version=np.int64(plan.version),
             resident_configs=np.int64(plan.resident_configs))


def _load_plan_sidecar(path: Path):
    from repro.query.sharded import ShardPlan
    z = np.load(path)
    offsets = z["resident_offsets"]
    flat = z["residents"]
    residents = [flat[offsets[s]:offsets[s + 1]]
                 for s in range(int(z["n_shards"]))]
    return ShardPlan(n_shards=int(z["n_shards"]),
                     cluster_shard=z["cluster_shard"],
                     residents=residents,
                     owner=z["owner"],
                     imbalance=float(z["imbalance"]),
                     version=int(z["version"]),
                     resident_configs=int(z["resident_configs"]))


class CrashStore:
    """Periodic snapshots + the live WAL, rooted at one directory.

    ``every`` is the snapshot cadence in scheduler steps (0 = snapshot
    only at attach; the WAL then grows unboundedly — fine for tests,
    not for serving). A snapshot also fires whenever the sharded
    placement's generation moved (failover / re-balance swapped the
    base plan — the sidecar must track it, or recovery would restore a
    pre-swap partition and extend it divergently).

    Layout under ``root``::

        manifest.json         -> {snapshot, wal, plan, ...}   (atomic)
        snap_000000.npz       -> KNNIndex.save (journals included)
        snap_000000.plan.npz  -> frozen base ShardPlan (sharded only)
        wal_000000.jsonl      -> mutations since snap_000000
    """

    def __init__(self, root: str | Path, every: int = 0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.cadence = Cadence(every)
        self.every = every
        self.n_snapshots = 0
        self.wal: WriteAheadLog | None = None
        self._last_generation = -1

    # -- live side ---------------------------------------------------------

    def attach(self, engine):
        """Take the initial snapshot and start journaling ``engine``'s
        index. Called by ``QueryEngine.__init__`` / ``recover``."""
        self.snapshot(engine)

    def snapshot(self, engine):
        """Persist index + base plan, then start a fresh WAL (this IS
        journal compaction: replay work is bounded by the cadence)."""
        ix = engine.index
        ix.detach_wal()
        if self.wal is not None:
            self.wal.close()
        n = self.n_snapshots
        snap = f"snap_{n:06d}.npz"
        ix.save(self.root / snap)
        manifest = {
            "snapshot": snap,
            "wal": f"wal_{n:06d}.jsonl",
            "plan": None,
            "shards": engine.qc.shards,
            "lifecycle_clock": int(engine.lifecycle.clock),
            "n_snapshots": n + 1,
        }
        sd = engine.plan._sharded  # peek: do NOT build on demand here
        if sd is not None:
            plan_name = f"snap_{n:06d}.plan.npz"
            _save_plan_sidecar(self.root / plan_name, sd.base_plan)
            manifest["plan"] = plan_name
            self._last_generation = sd.generation
        tmp = self.root / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest, indent=2))
        tmp.rename(self.root / "manifest.json")  # atomic publish
        self.wal = WriteAheadLog(self.root / manifest["wal"], append=False)
        ix.attach_wal(self.wal)
        self.n_snapshots = n + 1

    def maintain(self, engine):
        """Between-steps tick: snapshot on cadence, or immediately when
        the sharded generation moved (plan swap → sidecar is stale)."""
        sd = engine.plan._sharded
        swapped = sd is not None and sd.generation != self._last_generation
        if self.cadence.tick() or swapped:
            self.snapshot(engine)

    def stats(self) -> dict:
        return {
            "every": self.every,
            "snapshots": self.n_snapshots,
            "wal_records": self.wal.n_records if self.wal else 0,
        }

    # -- recovery side -----------------------------------------------------

    @staticmethod
    def load(root: str | Path):
        """Recover ``(index, base_plan | None, manifest)`` from ``root``:
        load the last published snapshot, replay its WAL suffix."""
        from repro.query.index import KNNIndex
        root = Path(root)
        manifest = json.loads((root / "manifest.json").read_text())
        index = KNNIndex.load(root / manifest["snapshot"])
        replay(index, WriteAheadLog.read(root / manifest["wal"]))
        base_plan = None
        if manifest.get("plan"):
            base_plan = _load_plan_sidecar(root / manifest["plan"])
        return index, base_plan, manifest
