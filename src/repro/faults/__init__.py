"""Fault injection, degraded serving, failover, and crash recovery.

Submodules (importable individually to keep import graphs shallow):

* ``plan``     — :class:`FaultPlan` / :class:`FaultInjector` /
  :class:`EngineCrash`: seeded, scheduled faults at the plan-step
  boundary (``kill:S@T``, ``fail:S@T+D``, ``slow:S@T+D:MS``,
  ``crash@T``).
* ``health``   — per-shard health state machine (healthy → suspect →
  dead → recovering) with capped exponential-backoff probing.
* ``failover`` — :class:`FailoverManager`: masks dead shards out of
  serving, rebuilds their tensors from survivors, blue/green-swaps.
* ``wal``      — :class:`WriteAheadLog` / :class:`CrashStore`: snapshot
  + journal replay, bitwise crash recovery.
"""
from repro.faults.failover import FailoverManager
from repro.faults.health import (DEAD, HEALTHY, RECOVERING, SUSPECT,
                                 FleetHealth, HealthConfig)
from repro.faults.plan import (EngineCrash, FaultEvent, FaultInjector,
                               FaultPlan)
from repro.faults.wal import CrashStore, WriteAheadLog, replay

__all__ = [
    "EngineCrash", "FaultEvent", "FaultPlan", "FaultInjector",
    "HEALTHY", "SUSPECT", "DEAD", "RECOVERING",
    "HealthConfig", "FleetHealth", "FailoverManager",
    "WriteAheadLog", "CrashStore", "replay",
]
