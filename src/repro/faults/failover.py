"""Degraded-mode serving + failover rebuild for the sharded placement.

One manager per engine glues three mechanisms together around the
scheduler-step boundary:

* **Detection** (:meth:`FailoverManager.observe`, BEFORE the plan
  step) — asks the injector which shards are down this step, feeds the
  per-shard health machine (healthy → suspect → dead, with capped
  exponential-backoff probing — see repro/faults/health.py), and masks
  every non-healthy shard out of serving: its owned seeds are dropped
  (``ShardedDescent.set_dead``), its merge contribution is wiped, and
  its in-flight continuous beams are cleared
  (``DescentPlan.mask_shard_slots``) so survivors keep answering with a
  bounded recall loss instead of the fleet stalling.
* **Recovery** (:meth:`FailoverManager.maintain`, AFTER lifecycle and
  re-balance maintenance) — once a dead shard's ``recover_after`` dwell
  elapses, its resident tensors are rebuilt from the SURVIVORS'
  subgraphs via :func:`~repro.query.rebalance.merge_subgraph_rows`
  with the unhealthy set excluded (rows resident only on dead shards
  are patched from the index), a fresh ``plan_shards`` partition is
  derived, and :meth:`ShardedDescent.adopt_plan` blue/green-swaps it in
  between compiled programs — beams remapped, result cache flushed via
  ``note_replan`` exactly like a re-balance swap.
* **Isolation** — while any shard is unhealthy the re-balancer defers
  (``Rebalancer.check`` sees ``sd.dead``) and lifecycle maintenance is
  skipped by the engine: neither may bake degraded descent results or a
  dead shard's stale tensors into the graph.

Single-device placements have no shards to fail: the manager stays
inert (``active`` False) and every hook is a no-op.
"""
from __future__ import annotations

import numpy as np

from repro.faults.health import FleetHealth, HealthConfig
from repro.query.rebalance import merge_subgraph_rows
from repro.query.sharded import plan_shards


class FailoverManager:
    """Owns fleet health + the recovery rebuild for one DescentPlan."""

    def __init__(self, plan, injector, cfg: HealthConfig | None = None):
        self.plan = plan
        self.injector = injector
        cfg = cfg or getattr(injector, "health", None) or HealthConfig()
        self.cfg = cfg
        self.health = (FleetHealth(plan.spec.placement, cfg)
                       if plan.spec.placement > 1 else None)
        self.n_failovers = 0
        self.recovery_steps: list[int] = []
        self.last_merge_stats: dict = {}

    @property
    def active(self) -> bool:
        return self.health is not None

    @property
    def degraded(self) -> bool:
        """True while any shard is masked out of serving."""
        return self.active and bool(self.health.serving_mask().any())

    # -- before the plan step ---------------------------------------------

    def observe(self):
        """Probe the injector, advance health, mask unhealthy shards."""
        if not self.active:
            return
        h = self.health
        down = np.array([self.injector.shard_down(s)
                         for s in range(h.n_shards)], dtype=bool)
        h.observe(down)
        mask = h.serving_mask()
        sd = self.plan.sharded_state()
        if not np.array_equal(mask, sd.dead):
            newly = mask & ~sd.dead
            sd.set_dead(mask)
            if newly.any():
                # Wipe the downed shards' in-flight beams NOW — their
                # candidates came from tensors we no longer trust.
                self.plan.mask_shard_slots(newly)

    # -- after lifecycle / rebalance maintenance --------------------------

    def maintain(self):
        """Rebuild + swap for shards whose recovery dwell elapsed."""
        if not self.active:
            return None
        h = self.health
        ready = h.ready_for_recovery()
        if not ready:
            return None
        for s in ready:
            h.mark_recovering(s)
        sd = self.plan.sharded_state()
        spec = self.plan.spec
        # Rebuild reads SURVIVORS only: every non-healthy shard (the
        # recovering ones included — their tensors are the stale state
        # we are replacing) is excluded from the merge.
        exclude = np.flatnonzero(h.serving_mask())
        src, self.last_merge_stats = merge_subgraph_rows(
            sd, exclude=exclude)
        new_plan = plan_shards(sd.index, spec.placement,
                               resident_configs=spec.resident_configs)
        sd.adopt_plan(new_plan, src=src)   # resets sd.dead to all-False
        self.plan.note_replan()            # placement changed: flush cache
        for s in ready:
            self.injector.clear_shard(s)
            self.recovery_steps.append(int(h.step - h.dead_since[s]))
            h.mark_healthy(s)
        self.n_failovers += 1
        # Shards STILL unhealthy after this swap (e.g. a second failure
        # overlapping the first's recovery) must stay masked in the new
        # generation.
        mask = h.serving_mask()
        if mask.any():
            sd.set_dead(mask)
            self.plan.mask_shard_slots(mask)
        return self.last_merge_stats

    def stats(self) -> dict:
        out = {
            "active": self.active,
            "failovers": self.n_failovers,
            "recovery_steps": list(self.recovery_steps),
        }
        if self.active:
            out.update(self.health.stats())
        if self.last_merge_stats:
            out["merge"] = dict(self.last_merge_stats)
        return out
