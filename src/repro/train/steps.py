"""train_step / loss: cross-entropy LM training with microbatch gradient
accumulation, remat, and the MoE aux loss."""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx
from repro.models.model import forward
from repro.train.optimizer import OptConfig, apply_updates

AUX_WEIGHT = 0.01


def _ce_from_logits(logits, labels):
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return -(ll * mask).sum(), mask.sum()


def loss_fn(params, batch, cfg: ModelConfig, ctx: ShardCtx,
            remat: bool = True, loss_chunk: int = 0):
    """Cross-entropy; ``loss_chunk`` > 0 scans the unembedding + softmax
    over sequence chunks so the f32 [B, S, V] logits tensor is never
    materialized (§Perf: at vocab 163840 that tensor alone is 43 GB/device
    on the kimi train cell)."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeddings")
    labels = batch["labels"]
    if not loss_chunk:
        logits, _, aux = forward(params, cfg, ctx, tokens=tokens,
                                 input_embeds=embeds, remat=remat)
        ce_sum, n = _ce_from_logits(logits, labels)
        ce = ce_sum / jnp.maximum(n, 1.0)
        return ce + AUX_WEIGHT * aux, ce

    # Chunked path: run the trunk without the head, then scan the head.
    from repro.models import layers as L
    from repro.models.model import forward_trunk

    x, aux = forward_trunk(params, cfg, ctx, tokens=tokens,
                           input_embeds=embeds, remat=remat)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    head = head.astype(jnp.dtype(cfg.dtype))
    B, S, D = x.shape
    nc = max(S // loss_chunk, 1)
    xc = jnp.moveaxis(x.reshape(B, nc, S // nc, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, S // nc), 1, 0)

    def chunk(carry, inp):
        ce_sum, n = carry
        xb, lb = inp
        logits = jnp.einsum("bsd,dv->bsv", xb, head,
                            preferred_element_type=jnp.float32)
        s, m = _ce_from_logits(logits, lb)
        return (ce_sum + s, n + m), None

    (ce_sum, n), _ = jax.lax.scan(
        chunk, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    ce = ce_sum / jnp.maximum(n, 1.0)
    return ce + AUX_WEIGHT * aux, ce


def train_step(params, opt_state, batch, cfg: ModelConfig, ctx: ShardCtx,
               oc: OptConfig, *, n_microbatches: int = 1,
               remat: bool = True, loss_chunk: int = 0,
               grad_shardings=None):
    """One optimizer step; optionally accumulates over microbatches
    (splits the batch on the leading dim, scans, averages gradients —
    the standard memory/throughput knob at large global batch)."""

    def grads_of(mb):
        (loss, ce), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb, cfg, ctx, remat, loss_chunk)
        return g, loss, ce

    if n_microbatches <= 1:
        grads, loss, ce = grads_of(batch)
    else:
        def split(x):
            b = x.shape[0]
            assert b % n_microbatches == 0, (b, n_microbatches)
            return x.reshape((n_microbatches, b // n_microbatches)
                             + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def acc_fn(carry, mb):
            g_acc, l_acc, c_acc = carry
            g, loss, ce = grads_of(mb)
            return (jax.tree.map(jnp.add, g_acc, g),
                    l_acc + loss, c_acc + ce), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (g_sum, l_sum, c_sum), _ = jax.lax.scan(
            acc_fn, (zeros, jnp.zeros(()), jnp.zeros(())), mbs)
        grads = jax.tree.map(lambda g: g / n_microbatches, g_sum)
        loss, ce = l_sum / n_microbatches, c_sum / n_microbatches

    if grad_shardings is not None:
        # FSDP: pin gradients to the parameter shardings *before* the
        # global-norm clip reads them — GSPMD then lowers the cross-batch
        # gradient psum as reduce-scatter instead of a full all-reduce
        # (§Perf kimi iteration 2: 1.2 TB/device → scattered shards).
        grads = jax.lax.with_sharding_constraint(grads, grad_shardings)

    new_params, new_state = apply_updates(params, grads, opt_state, oc)
    metrics = {"loss": loss, "ce": ce, "step": new_state["step"]}
    return new_params, new_state, metrics


def make_train_step(cfg: ModelConfig, ctx: ShardCtx, oc: OptConfig,
                    n_microbatches: int = 1, remat: bool = True):
    return functools.partial(train_step, cfg=cfg, ctx=ctx, oc=oc,
                             n_microbatches=n_microbatches, remat=remat)
