"""MoE router load-balance lens (DESIGN.md §4).

MoE capacity overflow is the same size-cap-then-redistribute problem as
the paper's recursive splitting (§II-D): experts play clusters, the
capacity factor plays N. This module reports the router histogram the
way benchmarks/fig7_8 reports cluster sizes.
"""
from __future__ import annotations

import numpy as np

from repro.models.config import ModelConfig


def router_stats(gate_e: np.ndarray, cfg: ModelConfig,
                 capacity: int | None = None) -> dict:
    """gate_e int32[T, k] — per-token expert choices from one MoE layer.

    Returns load histogram, imbalance (max/mean — the paper's straggler
    metric for clusters), and the drop fraction at the given capacity.
    """
    E = cfg.n_experts
    loads = np.bincount(np.asarray(gate_e).reshape(-1), minlength=E)
    mean = loads.mean() if E else 0.0
    if capacity is None:
        T = gate_e.shape[0]
        capacity = int(np.ceil(T * cfg.experts_per_token
                               * cfg.capacity_factor / max(E, 1)))
    dropped = np.maximum(loads - capacity, 0).sum()
    return {
        "loads": loads,
        "imbalance": float(loads.max() / mean) if mean else 0.0,
        "capacity": capacity,
        "drop_fraction": float(dropped / max(loads.sum(), 1)),
        "top8_loads": np.sort(loads)[::-1][:8].tolist(),
    }
