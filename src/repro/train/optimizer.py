"""AdamW (hand-rolled, pytree-native) + optional gradient compression.

Distributed-optimization tricks exposed here:
* ``grad_compress="int8"`` — int8-quantized gradient all-reduce with
  per-leaf scale and error-feedback residual (the quantization error is
  added back into the next step's gradient), cutting cross-pod gradient
  traffic 4× at equal convergence in practice.
* ``state_dtype="bfloat16"`` — bf16 first/second moments (halves optimizer
  HBM; used by the kimi-k2 memory hillclimb in EXPERIMENTS §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    grad_compress: Optional[str] = None  # None | "int8"


def init_opt_state(params: Any, oc: OptConfig) -> Any:
    sd = jnp.dtype(oc.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sd)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if oc.grad_compress == "int8":
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    return state


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def quantize_int8(g, err):
    """Error-feedback int8 quantization of one gradient leaf."""
    g = g.astype(jnp.float32) + err.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, (g - deq).astype(jnp.bfloat16)


def apply_updates(params: Any, grads: Any, state: Any, oc: OptConfig):
    """One AdamW step. Returns (new_params, new_state)."""
    new_state = dict(state)
    if oc.grad_compress == "int8":
        pairs = jax.tree.map(quantize_int8, grads, state["err"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state["err"] = jax.tree.map(
            lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    bc1 = 1.0 - oc.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - oc.b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(oc.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * g
        v32 = oc.b2 * v.astype(jnp.float32) + (1 - oc.b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(
            jnp.float32)
        newp = p.astype(jnp.float32) - oc.lr * delta
        return newp.astype(p.dtype), m32.astype(sd), v32.astype(sd)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_state["m"] = jax.tree.map(lambda t: t[1], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_state["v"] = jax.tree.map(lambda t: t[2], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_state["step"] = step
    return new_params, new_state
