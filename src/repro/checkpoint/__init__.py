from repro.checkpoint.checkpoint import (  # noqa: F401
    latest_step, restore, restore_sharded, save)
