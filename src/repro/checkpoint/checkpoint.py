"""Checkpoint/restart with atomic commit and reshard-on-load (elastic).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
``manifest.json`` (tree structure, shapes, dtypes). Writes go to a
``.tmp`` directory and are committed with an atomic rename — a run killed
mid-save never corrupts the latest checkpoint (fault-tolerance contract).

Elasticity: leaves are stored *unsharded* (host arrays), so a restore may
target any mesh/device count — ``restore_sharded`` re-device_puts every
leaf under the new mesh's NamedSharding. On a real multi-host pod each
host would write its addressable shards (tensorstore-style); the manifest
format is deliberately shard-agnostic so that swap is local to this file.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [f"leaf_{i:05d}" for i in range(len(leaves))]
    return leaves, paths, treedef


def save(ckpt_dir: str | os.PathLike, tree: Any, step: int) -> Path:
    """Atomically write one checkpoint. Returns the committed path."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, paths, treedef = _flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "leaves": []}
    for leaf, name in zip(leaves, paths):
        arr = np.asarray(leaf)
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | os.PathLike, like: Any, step: int | None = None):
    """Restore into the structure of ``like`` (values ignored)."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves_like) == manifest["n_leaves"], (
        f"checkpoint has {manifest['n_leaves']} leaves, "
        f"target structure has {len(leaves_like)} — incompatible trees")
    leaves = [np.load(d / f"leaf_{i:05d}.npy")
              for i in range(manifest["n_leaves"])]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_sharded(ckpt_dir, like: Any, shardings: Any,
                    step: int | None = None):
    """Elastic restore: place every leaf under the *current* mesh's
    shardings (device count may differ from the run that saved)."""
    tree, step = restore(ckpt_dir, like, step)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
    return placed, step
