"""Batched serving engine: request queue → prefill → batched decode, in
*wave* mode (the original static batching) or *continuous* mode
(slot-based streaming admission, ``ServeConfig.continuous``).

Wave mode groups up to ``max_batch`` left-padded prompts, runs one jitted
prefill + one jitted decode step per shape, and streams tokens until
EOS/max_new — but a slot that hits EOS early sits idle (padding to wave
end) until the whole wave closes, so one long decode stalls every
request behind it.

Continuous mode shares the slot scheduler with the query engine
(``repro/sched/``): a fixed array of ``slots`` decode rows advances one
token per tick through ONE compiled decode program (per-row cache
positions — ``models/layers.apply_attn``'s vector ``cur_index`` path);
the moment a row emits EOS or exhausts its budget, the scheduler
releases the slot, a queued request is prefilled (batch-1 program),
its cache rows are scattered into the shared decode cache, and the slot
rejoins the next tick mid-flight. The PR 1 per-row EOS early-exit thus
actually *recycles* capacity into new decodes instead of padding.
Straggler cost stays bounded by max_new (the same capped-cost argument
as the paper's N).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx
from repro.models.model import init_cache
from repro.sched import SlotScheduler, trace
from repro.serve.steps import decode_step, prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # int32[prompt_len]
    max_new: int = 32
    eos_id: int = -1            # -1 → never stops early
    # Filled by the engine:
    output: Optional[np.ndarray] = None
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency(self) -> Optional[float]:
        """Serve latency in seconds, or None until the request has both
        been submitted and completed (the raw difference of unset
        timestamps would read as a large negative number)."""
        if self.t_done == 0.0 or self.t_submit == 0.0:
            return None
        return self.t_done - self.t_submit


def _adopt_cache(cache, fresh, slot):
    """Scatter a batch-1 prefill cache into row ``slot`` of the shared
    continuous decode cache.

    Leaves: [n_groups, slots, ...] ← [n_groups, 1, ...]; the attention
    ``pos`` leaf has no batch axis in the prefill cache ([n_groups,
    alloc]) and gains one here. ``slot`` is a traced scalar so one
    compiled program serves every slot.
    """
    from jax.tree_util import DictKey, tree_map_with_path

    def upd(path, big, small):
        if isinstance(path[-1], DictKey) and path[-1].key == "pos":
            small = small[:, None, :]
        return jax.lax.dynamic_update_slice_in_dim(big, small, slot, axis=1)

    return tree_map_with_path(upd, cache, fresh)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_prompt: int = 128
    max_new: int = 64
    pad_id: int = 0
    continuous: bool = False   # slot-based streaming admission (sched/)
    slots: int = 0             # decode slots in continuous mode (0→max_batch)


class Engine:
    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig,
                 ctx: ShardCtx | None = None):
        self.params = params
        self.cfg = cfg
        self.sc = sc
        self.ctx = ctx or ShardCtx()
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self.n_decode_steps = 0   # decode program invocations (all modes)
        self.n_prefills = 0       # prefill program invocations
        self._prefill = jax.jit(
            lambda p, t: prefill_step(
                p, t, self.cfg, self.ctx,
                s_alloc=sc.max_prompt + sc.max_new))
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(p, c, t, i, self.cfg, self.ctx))
        n_slots = sc.slots or sc.max_batch

        def _cont_decode(p, c, t, i):
            trace.bump(("lm_cont_decode", n_slots))
            return decode_step(p, c, t, i, self.cfg, self.ctx)

        self._decode_cont = jax.jit(_cont_decode)
        self._adopt = jax.jit(_adopt_cache, donate_argnums=(0,))

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        assert len(req.prompt) <= self.sc.max_prompt, "prompt too long"
        self.queue.append(req)

    def _next_wave(self) -> list[Request]:
        wave = []
        while self.queue and len(wave) < self.sc.max_batch:
            wave.append(self.queue.popleft())
        return wave

    def _run_wave(self, wave: list[Request]):
        sc = self.sc
        B = len(wave)
        S = sc.max_prompt
        toks = np.full((B, S), sc.pad_id, dtype=np.int32)
        for j, r in enumerate(wave):  # left-pad so last position is real
            toks[j, S - len(r.prompt):] = r.prompt
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        self.n_prefills += 1
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        max_new = min(sc.max_new, max(r.max_new for r in wave))
        outs = [np.asarray(tok)[:, 0]]
        # Per-row completion on host: a row is done once it has emitted its
        # eos_id or its own max_new tokens; when every row is done the wave
        # stops decoding instead of running out the full max_new budget.
        eos_ids = np.array([r.eos_id for r in wave], dtype=np.int64)
        max_per_row = np.array([r.max_new for r in wave], dtype=np.int64)
        row_done = ((outs[0] == eos_ids) & (eos_ids >= 0)) | (max_per_row <= 1)
        for i in range(max_new - 1):
            if row_done.all():
                break
            logits, cache = self._decode(self.params, cache, tok, S + i)
            self.n_decode_steps += 1
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            outs.append(np.asarray(tok)[:, 0])
            row_done |= (outs[-1] == eos_ids) & (eos_ids >= 0)
            row_done |= max_per_row <= len(outs)
        gen = np.stack(outs, axis=1)  # [B, n_emitted]
        now = time.perf_counter()
        n_real = 0
        for j, r in enumerate(wave):
            seq = gen[j, : r.max_new]
            if r.eos_id >= 0:
                hits = np.flatnonzero(seq == r.eos_id)
                if len(hits):
                    seq = seq[: hits[0] + 1]
            r.output = seq
            r.t_done = now
            self.done.append(r)
            n_real += len(seq)
        # Count delivered tokens, not decode-grid cells: rows already done
        # keep decoding as padding until the wave closes, and counting
        # that padding would inflate wave tokens_per_s against the
        # continuous mode (which never decodes padding).
        return n_real

    # -- continuous (slot) serving -----------------------------------------

    def _continuous_cache(self, slots: int):
        """A shared decode cache with PER-ROW positions: attention ``pos``
        leaves widen from [n_groups, alloc] to [n_groups, slots, alloc] so
        every slot carries its own timeline (vector ``cur_index`` path in
        ``apply_attn``)."""
        cache = init_cache(self.cfg, slots,
                           self.sc.max_prompt + self.sc.max_new)
        out = {}
        for name, sub in cache.items():
            if isinstance(sub, dict) and "pos" in sub:
                sub = dict(sub)
                G, alloc = sub["pos"].shape
                sub["pos"] = jnp.broadcast_to(
                    sub["pos"][:, None, :], (G, slots, alloc)).copy()
            out[name] = sub
        return out

    def _run_continuous(self) -> tuple[int, int]:
        """Slot-scheduled serving loop; returns (tokens, ticks).

        One decode tick advances every occupied slot by one token. A slot
        that finishes (EOS / max_new) is released and immediately
        refilled from the queue: the new request is prefilled through the
        batch-1 program and its cache rows scattered into the shared
        decode cache (``_adopt_cache``) — admission never waits for the
        other slots.
        """
        sc = self.sc
        slots = sc.slots or sc.max_batch
        sched = SlotScheduler(slots)
        cache = self._continuous_cache(slots)
        tok = np.zeros((slots, 1), np.int32)
        pos = np.zeros(slots, np.int32)       # next decode index per slot
        outs: list[list[int]] = [[] for _ in range(slots)]
        n_tokens = 0
        n_ticks = 0

        def emit(slot: int, token: int) -> bool:
            """Append one token; True when the slot's request is done."""
            r = sched.occupant(slot)
            outs[slot].append(token)
            budget = min(r.max_new, sc.max_new)
            return ((r.eos_id >= 0 and token == r.eos_id)
                    or len(outs[slot]) >= budget)

        def finish(slot: int):
            nonlocal n_tokens
            r = sched.release(slot)
            budget = max(0, min(r.max_new, sc.max_new))
            r.output = np.array(outs[slot][:budget], dtype=np.int32)
            r.t_done = time.perf_counter()
            n_tokens += len(outs[slot])
            outs[slot] = []
            self.done.append(r)

        while self.queue or sched.has_work():
            while self.queue:
                sched.submit(self.queue.popleft())
            # Admit until slots are full or the queue drains; a request
            # whose first (prefill) token already completes it frees its
            # slot for the next admission in the same tick.
            while True:
                admitted = sched.admit()
                if not admitted:
                    break
                for slot, r in admitted:
                    toks = np.full((1, sc.max_prompt), sc.pad_id, np.int32)
                    toks[0, sc.max_prompt - len(r.prompt):] = r.prompt
                    logits, c1 = self._prefill(self.params,
                                               jnp.asarray(toks))
                    self.n_prefills += 1
                    cache = self._adopt(cache, c1, slot)
                    first = int(np.asarray(
                        jnp.argmax(logits[0, -1])).astype(np.int32))
                    tok[slot, 0] = first
                    pos[slot] = sc.max_prompt
                    if emit(slot, first):
                        finish(slot)
            active = sched.active_mask()
            if not active.any():
                continue
            logits, cache = self._decode_cont(
                self.params, cache, jnp.asarray(tok), jnp.asarray(pos))
            self.n_decode_steps += 1
            n_ticks += 1
            nxt = np.asarray(
                jnp.argmax(logits[:, -1, :], axis=-1)).astype(np.int32)
            tok = nxt[:, None].copy()
            for slot in np.flatnonzero(active):
                pos[slot] += 1
                if emit(int(slot), int(nxt[slot])):
                    finish(int(slot))
        return n_tokens, n_ticks

    def run(self) -> dict:
        """Drain the queue; returns aggregate stats."""
        t0 = time.perf_counter()
        n_done0 = len(self.done)
        n_tokens = 0
        n_waves = 0
        if self.sc.continuous:
            n_tokens, n_waves = self._run_continuous()
        else:
            while self.queue:
                wave = self._next_wave()
                n_tokens += self._run_wave(wave)
                n_waves += 1
        dt = max(time.perf_counter() - t0, 1e-9)
        lats = [r.latency for r in self.done if r.latency is not None]
        return {
            "requests": len(self.done),
            "mode": "continuous" if self.sc.continuous else "wave",
            "waves": n_waves,
            "completed": len(self.done) - n_done0,
            "tokens": int(n_tokens),
            "tokens_per_s": n_tokens / dt,
            "decode_steps": self.n_decode_steps,
            "prefills": self.n_prefills,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "p95_latency_s": float(np.percentile(lats, 95)) if lats else 0.0,
        }
