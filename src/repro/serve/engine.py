"""Batched serving engine: request queue → prefill waves → batched decode.

A deliberately production-shaped (if compact) serving layer over
serve/steps.py: requests arrive in a queue, are grouped into waves of up
to ``max_batch`` equal-position sequences (left-padded prompts), prefetch
one jitted prefill + one jitted decode step per (batch, alloc) shape, and
stream tokens until EOS/max_new. Per-request latency and aggregate
throughput are reported.

Design notes (honest scope): this is *static* (wave) batching — slots
join only between waves. Continuous batching needs per-slot decode
positions (cache ``pos`` per batch row); the cache schema supports the
extension but the validated dry-run cells pin the current layout, so it
is left as the documented next step. Straggler behavior inside a wave is
bounded by max_new (the same capped-cost argument as the paper's N).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx
from repro.serve.steps import decode_step, prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # int32[prompt_len]
    max_new: int = 32
    eos_id: int = -1            # -1 → never stops early
    # Filled by the engine:
    output: Optional[np.ndarray] = None
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_prompt: int = 128
    max_new: int = 64
    pad_id: int = 0


class Engine:
    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig,
                 ctx: ShardCtx | None = None):
        self.params = params
        self.cfg = cfg
        self.sc = sc
        self.ctx = ctx or ShardCtx()
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self._prefill = jax.jit(
            lambda p, t: prefill_step(
                p, t, self.cfg, self.ctx,
                s_alloc=sc.max_prompt + sc.max_new))
        self._decode = jax.jit(
            lambda p, c, t, i: decode_step(p, c, t, i, self.cfg, self.ctx))

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        assert len(req.prompt) <= self.sc.max_prompt, "prompt too long"
        self.queue.append(req)

    def _next_wave(self) -> list[Request]:
        wave = []
        while self.queue and len(wave) < self.sc.max_batch:
            wave.append(self.queue.popleft())
        return wave

    def _run_wave(self, wave: list[Request]):
        sc = self.sc
        B = len(wave)
        S = sc.max_prompt
        toks = np.full((B, S), sc.pad_id, dtype=np.int32)
        for j, r in enumerate(wave):  # left-pad so last position is real
            toks[j, S - len(r.prompt):] = r.prompt
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        max_new = min(sc.max_new, max(r.max_new for r in wave))
        outs = [np.asarray(tok)[:, 0]]
        # Per-row completion on host: a row is done once it has emitted its
        # eos_id or its own max_new tokens; when every row is done the wave
        # stops decoding instead of running out the full max_new budget.
        eos_ids = np.array([r.eos_id for r in wave], dtype=np.int64)
        max_per_row = np.array([r.max_new for r in wave], dtype=np.int64)
        row_done = ((outs[0] == eos_ids) & (eos_ids >= 0)) | (max_per_row <= 1)
        for i in range(max_new - 1):
            if row_done.all():
                break
            logits, cache = self._decode(self.params, cache, tok, S + i)
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            outs.append(np.asarray(tok)[:, 0])
            row_done |= (outs[-1] == eos_ids) & (eos_ids >= 0)
            row_done |= max_per_row <= len(outs)
        gen = np.stack(outs, axis=1)  # [B, n_emitted]
        now = time.perf_counter()
        for j, r in enumerate(wave):
            seq = gen[j, : r.max_new]
            if r.eos_id >= 0:
                hits = np.flatnonzero(seq == r.eos_id)
                if len(hits):
                    seq = seq[: hits[0] + 1]
            r.output = seq
            r.t_done = now
            self.done.append(r)
        return gen.size

    def run(self) -> dict:
        """Drain the queue; returns aggregate stats."""
        t0 = time.perf_counter()
        n_tokens = 0
        n_waves = 0
        while self.queue:
            wave = self._next_wave()
            n_tokens += self._run_wave(wave)
            n_waves += 1
        dt = max(time.perf_counter() - t0, 1e-9)
        lats = [r.latency for r in self.done]
        return {
            "requests": len(self.done),
            "waves": n_waves,
            "tokens": int(n_tokens),
            "tokens_per_s": n_tokens / dt,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "p95_latency_s": float(np.percentile(lats, 95)) if lats else 0.0,
        }
