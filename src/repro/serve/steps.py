"""Serving steps: prefill (context → cache + first logits) and decode
(one token against the cache). ``decode_*`` / ``long_*`` dry-run shapes
lower ``decode_step``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx
from repro.models.model import forward


def prefill_step(params, tokens_or_embeds, cfg: ModelConfig, ctx: ShardCtx,
                 *, s_alloc: int = 0, is_embeds: bool = False):
    """Process the full prompt; returns (logits[B,S,V], cache)."""
    kw = ({"input_embeds": tokens_or_embeds} if is_embeds
          else {"tokens": tokens_or_embeds})
    S = tokens_or_embeds.shape[1]
    logits, cache, _ = forward(params, cfg, ctx, want_cache=True,
                               s_alloc=s_alloc or S, **kw)
    return logits, cache


def decode_step(params, cache, tokens, cur_index, cfg: ModelConfig,
                ctx: ShardCtx):
    """One decode step: tokens [B,1] + cache at position cur_index.

    Returns (logits [B,1,V], new_cache). Sub-quadratic archs (RG-LRU,
    xLSTM) carry O(1) state; attention archs carry the KV cache (ring
    buffer for sliding-window layers)."""
    logits, new_cache, _ = forward(
        params, cfg, ctx, tokens=tokens, cache=cache,
        cur_index=jnp.asarray(cur_index, jnp.int32))
    return logits, new_cache


def greedy_generate(params, prompt, cfg: ModelConfig, ctx: ShardCtx,
                    max_new: int, s_alloc: int = 0):
    """Host-driven greedy decoding (examples/serving demo)."""
    B, S = prompt.shape
    alloc = s_alloc or (S + max_new)
    logits, cache = prefill_step(params, prompt, cfg, ctx, s_alloc=alloc)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [tok]
    step = jax.jit(decode_step, static_argnames=("cfg", "ctx"))
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, S + i, cfg=cfg, ctx=ctx)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
