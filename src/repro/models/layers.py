"""Composable decoder blocks: GQA/MQA attention (+RoPE, sliding window),
gated MLPs, sort-based MoE, RG-LRU (RecurrentGemma), mLSTM/sLSTM (xLSTM).

Pure-function style: ``init_*`` builds param dicts, ``apply_*`` consumes
them. Everything is written to (a) run a real reduced-config step on CPU,
and (b) lower cleanly under pjit on the production mesh with the specs in
models/sharding.py. Compute dtype is cfg.dtype (bf16 by default); softmax,
recurrence gates and losses run in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import ad_checkpoint

from repro.models.config import ModelConfig

Params = Any  # nested dicts of arrays


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Optional mesh context; None mesh → single-device pure JAX."""

    mesh: Any = None
    batch_axes: tuple = ("data",)
    model_axis: str = "model"

    def csp(self, x, *spec):
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- norms

def init_rmsnorm(cfg) -> Params:
    return {"scale": jnp.ones((cfg.d_model,), _pdtype(cfg))}


def apply_rmsnorm(p, x):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + 1e-6)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- RoPE

def rope(x, positions, theta: float):
    """x [B, S, H, hd], positions int32[B, S] → rotated x (split-half)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[:, :, None, None].astype(jnp.float32) * freqs  # [B,S,1,half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention

def init_attn(key, cfg) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pd = _pdtype(cfg)
    return {
        "wq": _dense_init(k1, (D, H, hd), D, pd),
        "wk": _dense_init(k2, (D, KV, hd), D, pd),
        "wv": _dense_init(k3, (D, KV, hd), D, pd),
        "wo": _dense_init(k4, (H, hd, D), H * hd, pd),
    }


def _online_softmax_attn(q, k, v, qpos, kpos, window: int,
                         chunk_q: int, chunk_kv: int):
    """Chunked causal attention with online softmax (flash-style, pure JAX).

    q, k, v [B,S,H,hd] (kv heads already broadcast to H — a *local slice* of
    a replicated array under tensor parallelism, so GSPMD shards every
    einsum on the flat head axis with no resharding); qpos [B,S];
    kpos [B,Skv] (−1 = empty slot). Never materializes the full score
    matrix: peak intermediate is [B, cq, H, ck].
    """
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    cq = min(chunk_q, S)
    ck = min(chunk_kv, Skv)
    nq, nk = S // cq, Skv // ck
    assert S % cq == 0 and Skv % ck == 0
    scale = 1.0 / np.sqrt(hd)

    qc = jnp.moveaxis(q.reshape(B, nq, cq, H, hd), 1, 0)
    qp = jnp.moveaxis(qpos.reshape(B, nq, cq), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, ck, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, H, hd), 1, 0)
    kp = jnp.moveaxis(kpos.reshape(B, nk, ck), 1, 0)

    def q_block(_, q_in):
        qb, qpb = q_in  # [B,cq,H,hd], [B,cq]

        def kv_block(carry, kv_in):
            m, l, acc = carry
            kb, vb, kpb = kv_in
            s = jnp.einsum("bqhd,bkhd->bqhk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = (kpb[:, None, :] <= qpb[:, :, None]) & (kpb[:, None, :] >= 0)
            if window:
                mask &= kpb[:, None, :] > qpb[:, :, None] - window
            s = jnp.where(mask[:, :, None, :], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhk,bkhd->bqhd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, cq, H), -1e30, jnp.float32)
        l0 = jnp.zeros((B, cq, H), jnp.float32)
        a0 = jnp.zeros((B, cq, H, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (kc, vc, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_block, None, (qc, qp))  # [nq,B,cq,H,hd]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
    return out


def _build_cache(k, v, positions, alloc: int):
    """Pack prefill k/v into a (ring) cache of ``alloc`` slots.

    Slot assignment is pos % alloc so subsequent decode steps extend it
    seamlessly (full cache: identity; sliding window: ring buffer)."""
    B, S, KV, hd = k.shape
    take = min(S, alloc)
    kt, vt = k[:, -take:], v[:, -take:]
    pt = positions[0, -take:].astype(jnp.int32)
    slots = pt % alloc
    ck = jnp.zeros((B, alloc, KV, hd), k.dtype).at[:, slots].set(kt)
    cv = jnp.zeros((B, alloc, KV, hd), v.dtype).at[:, slots].set(vt)
    cpos = jnp.full((alloc,), -1, jnp.int32).at[slots].set(pt)
    return {"k": ck, "v": cv, "pos": cpos}


def apply_attn(p, x, cfg: ModelConfig, ctx: ShardCtx, *,
               window: int = 0,
               cache: Optional[Params] = None,
               cur_index=None,
               positions=None,
               want_cache: bool = False,
               s_alloc: int = 0,
               chunk_q: int = 512, chunk_kv: int = 1024):
    """GQA attention. Train/prefill when cache is None; one-token decode
    otherwise (cache: {"k","v","pos"}; pos int32[S_alloc], −1 = empty).
    ``want_cache`` (prefill) additionally returns a cache of ``s_alloc``
    slots (ring-buffered to ``window`` for local attention)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    G = H // KV
    dt = _dtype(cfg)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = ctx.csp(q, ctx.batch_axes, None, ctx.model_axis, None)
    k = ctx.csp(k, ctx.batch_axes, None, None, None)

    if cache is None:
        # Broadcast kv heads to the flat H axis via an index-take (NOT a
        # 5D repeat+reshape, which GSPMD cannot re-tile without a full
        # remat): each model shard gathers its head slice from the
        # replicated kv — no collective, and every attention einsum then
        # shards cleanly on H.
        if G > 1:
            head_to_kv = jnp.arange(H, dtype=jnp.int32) // G
            k_rep = jnp.take(k, head_to_kv, axis=2)
            v_rep = jnp.take(v, head_to_kv, axis=2)
        else:
            k_rep, v_rep = k, v
        k_rep = ctx.csp(k_rep, ctx.batch_axes, None, ctx.model_axis, None)
        v_rep = ctx.csp(v_rep, ctx.batch_axes, None, ctx.model_axis, None)
        out = _online_softmax_attn(q, k_rep, v_rep, positions, positions,
                                   window, chunk_q, chunk_kv)
        new_cache = None
        if want_cache:
            alloc = min(s_alloc or S, window) if window else (s_alloc or S)
            new_cache = _build_cache(k, v, positions, alloc)
    else:
        # Decode: S == 1. Write into the (ring) buffer at cur_index.
        S_alloc = cache["k"].shape[1]
        if cache["pos"].ndim == 2:
            # Per-row decode positions (continuous batching): pos is
            # [B, S_alloc] and cur_index is [B] — every slot writes its
            # own ring position and masks by its own timeline, so one
            # batch row can be at token 3 while another is at token 97.
            ci = cur_index.astype(jnp.int32)
            slot = ci % S_alloc
            rows = jnp.arange(B)
            ck_ = cache["k"].at[rows, slot].set(k[:, 0])
            cv_ = cache["v"].at[rows, slot].set(v[:, 0])
            cpos = cache["pos"].at[rows, slot].set(ci)
            kp = cpos[:, None, :]
        else:
            slot = (cur_index % S_alloc).astype(jnp.int32)
            ck_ = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k, slot, axis=1)
            cv_ = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v, slot, axis=1)
            cpos = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], cur_index[None].astype(jnp.int32), slot, axis=0)
            kp = cpos[None, None, :]
        new_cache = {"k": ck_, "v": cv_, "pos": cpos}
        qg = q.reshape(B, 1, KV, G, hd)
        scale = 1.0 / np.sqrt(hd)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, ck_,
                       preferred_element_type=jnp.float32) * scale
        qp = positions[:, :, None]
        mask = (kp <= qp) & (kp >= 0)
        if window:
            mask = mask & (kp > qp - window)
        s = jnp.where(mask[:, :, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bqhgk,bkhd->bqhgd", w.astype(dt), cv_,
                         preferred_element_type=jnp.float32)

    out = out.reshape(B, -1, H, hd).astype(dt)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    y = ctx.csp(y, ctx.batch_axes, None, None)
    # Name the post-all-reduce tensor so the remat policy can keep it
    # (§Perf kimi iteration: don't recompute TP collectives in backward).
    y = ad_checkpoint.checkpoint_name(y, "tp_out")
    return y, new_cache


def init_attn_cache(cfg, batch: int, s_alloc: int, window: int) -> Params:
    KV, hd = cfg.n_kv_heads, cfg.head_dim_
    alloc = min(s_alloc, window) if window else s_alloc
    dt = _dtype(cfg)
    return {
        "k": jnp.zeros((batch, alloc, KV, hd), dt),
        "v": jnp.zeros((batch, alloc, KV, hd), dt),
        "pos": jnp.full((alloc,), -1, jnp.int32),
    }


# ---------------------------------------------------------------- MLP

def init_mlp(key, cfg) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    pd = _pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(k1, (D, F), D, pd),
            "w_up": _dense_init(k2, (D, F), D, pd),
            "w_down": _dense_init(k3, (F, D), F, pd),
        }
    return {
        "w_up": _dense_init(k1, (D, F), D, pd),
        "w_down": _dense_init(k2, (F, D), F, pd),
    }


def apply_mlp(p, x, cfg, ctx: ShardCtx):
    dt = _dtype(cfg)
    up = x @ p["w_up"].astype(dt)
    up = ctx.csp(up, ctx.batch_axes, None, ctx.model_axis)
    if cfg.mlp_type == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"].astype(dt))
        h = g * up
    elif cfg.mlp_type == "geglu":
        g = jax.nn.gelu(x @ p["w_gate"].astype(dt))
        h = g * up
    else:
        h = jax.nn.gelu(up)
    y = h @ p["w_down"].astype(dt)
    y = ctx.csp(y, ctx.batch_axes, None, None)
    return ad_checkpoint.checkpoint_name(y, "tp_out")


# ---------------------------------------------------------------- MoE

def init_moe(key, cfg) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    pd = _pdtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _dense_init(k1, (D, E), D, pd),
        "w_gate": _dense_init(k2, (E, D, F), D, pd),
        "w_up": _dense_init(k3, (E, D, F), D, pd),
        "w_down": _dense_init(k4, (E, F, D), F, pd),
    }


def _moe_capacity(n_tokens: int, cfg) -> int:
    c = int(np.ceil(n_tokens * cfg.experts_per_token
                    * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _moe_bucketed(xt, gate_w, gate_e, wg, wu, wd, capacity: int, e0: int,
                  dt):
    """Sort-based capacity-bucketed expert dispatch for experts
    [e0, e0+E_loc). xt f32/bf16[T, D]; gate_w f32[T, k]; gate_e int32[T, k].

    Returns the (partial) output [T, D]: sum over this expert range.
    Tokens overflowing an expert's capacity are dropped (standard cf-drop).
    """
    T, k = gate_e.shape
    E_loc = wg.shape[0]
    flat_e = gate_e.reshape(-1)
    order = jnp.argsort(flat_e)                       # [T·k]
    se = flat_e[order]
    run_start = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - run_start.astype(jnp.int32)
    local_e = se - e0
    valid = (local_e >= 0) & (local_e < E_loc) & (pos < capacity)
    slot = jnp.where(valid, local_e * capacity + pos, E_loc * capacity)
    tok = (order // k).astype(jnp.int32)
    gw = gate_w.reshape(-1)[order]

    # Slot tables (last slot = trash for overflow/foreign experts).
    n_slots = E_loc * capacity + 1
    slot_tok = jnp.zeros((n_slots,), jnp.int32).at[slot].set(tok)
    slot_gw = jnp.zeros((n_slots,), gw.dtype).at[slot].set(
        jnp.where(valid, gw, 0.0))
    slot_live = jnp.zeros((n_slots,), bool).at[slot].set(valid)

    xin = xt[slot_tok[:-1]] * slot_live[:-1, None].astype(xt.dtype)
    xin = xin.reshape(E_loc, capacity, -1)            # [E, C, D]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wg.astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", xin, wu.astype(dt))
    y = jnp.einsum("ecf,efd->ecd", g * u, wd.astype(dt))
    y_flat = y.reshape(E_loc * capacity, -1) * slot_gw[:-1, None].astype(y.dtype)

    out = jnp.zeros_like(xt).at[slot_tok[:-1]].add(
        jnp.where(slot_live[:-1, None], y_flat, 0.0).astype(xt.dtype))
    return out


def apply_moe(p, x, cfg, ctx: ShardCtx):
    """Top-k MoE with expert parallelism over the model axis.

    Activations are sharded on the batch axes and replicated across the
    model axis, so each model shard already holds its tokens: it computes
    buckets for its local experts only, and a single psum over the model
    axis combines per-token partial sums (the same all-reduce tensor
    parallelism needs anyway — no all-to-all required; DESIGN.md §5).
    """
    B, S, D = x.shape
    dt = _dtype(cfg)
    xt = x.reshape(B * S, D)
    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    if ctx.mesh is None or cfg.n_experts % ctx.model_size != 0:
        cap = _moe_capacity(B * S, cfg)
        out = _moe_bucketed(xt, gate_w, gate_e, p["w_gate"], p["w_up"],
                            p["w_down"], cap, 0, dt)
    else:
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        n_model = ctx.model_size
        n_batch = int(np.prod([ctx.mesh.shape[a] for a in ctx.batch_axes]))
        t_local = (B * S) // n_batch
        cap = _moe_capacity(t_local, cfg)
        e_loc = cfg.n_experts // n_model

        def local(xt_l, gw_l, ge_l, wg_l, wu_l, wd_l, eidx):
            e0 = eidx[0] * e_loc
            out = _moe_bucketed(xt_l, gw_l, ge_l, wg_l, wu_l, wd_l,
                                cap, e0, dt)
            return jax.lax.psum(out, ctx.model_axis)

        eidx = jnp.arange(n_model, dtype=jnp.int32)
        ba = ctx.batch_axes
        out = shard_map(
            local, mesh=ctx.mesh,
            in_specs=(P(ba, None), P(ba, None), P(ba, None),
                      P(ctx.model_axis, None, None),
                      P(ctx.model_axis, None, None),
                      P(ctx.model_axis, None, None),
                      P(ctx.model_axis)),
            out_specs=P(ba, None),
            check_rep=False,
        )(xt, gate_w, gate_e, p["w_gate"].astype(dt),
          p["w_up"].astype(dt), p["w_down"].astype(dt), eidx)
        out = ad_checkpoint.checkpoint_name(out, "tp_out")
    return out.reshape(B, S, D), (logits, gate_e)


# ---------------------------------------------------------------- RG-LRU

def init_rglru(key, cfg) -> Params:
    D = cfg.d_model
    w = cfg.rglru_width or D
    cw = cfg.conv_width
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_x": _dense_init(ks[0], (D, w), D, pd),
        "w_gate": _dense_init(ks[1], (D, w), D, pd),
        "conv_w": _dense_init(ks[2], (cw, w), cw, pd),
        "w_rec_gate": _dense_init(ks[3], (w, w), w, pd),
        "w_in_gate": _dense_init(ks[4], (w, w), w, pd),
        "lam": jax.random.uniform(ks[5], (w,), jnp.float32, 1.0, 4.0),
        "w_out": _dense_init(ks[0], (w, D), w, pd),
    }


def _rglru_scan(xb, r, i, lam, h0):
    """Linear recurrence h_t = a_t h_{t−1} + sqrt(1−a²)·(i⊙x) via an
    associative scan (O(log S) depth on TPU instead of O(S))."""
    c = 8.0
    log_a = -c * jax.nn.softplus(lam)[None, None, :] * r  # [B,S,w]
    a = jnp.exp(log_a)
    gated = (i * xb).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_s * h0[:, None, :] + b_s
    return h, a, b


def apply_rglru(p, x, cfg, ctx: ShardCtx, *, cache=None, cur_index=None,
                want_cache: bool = False):
    """Griffin recurrent block: conv1d → RG-LRU, GeGLU-style gating."""
    B, S, D = x.shape
    dt = _dtype(cfg)
    w = cfg.rglru_width or D
    cw = cfg.conv_width
    xb = x @ p["w_x"].astype(dt)                      # [B,S,w]
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    xb = ctx.csp(xb, ctx.batch_axes, None, ctx.model_axis)

    # Causal depthwise conv (width cw).
    if cache is None:
        pad = jnp.zeros((B, cw - 1, w), xb.dtype)
        xc = jnp.concatenate([pad, xb], axis=1)
        conv = sum(xc[:, j:j + S, :] * p["conv_w"][j].astype(dt)
                   for j in range(cw))
        new_conv_state = xc[:, -(cw - 1):, :] if cw > 1 else None
    else:
        hist = jnp.concatenate([cache["conv"].astype(dt), xb], axis=1)
        conv = sum(hist[:, j:j + 1, :] * p["conv_w"][j].astype(dt)
                   for j in range(cw))
        new_conv_state = hist[:, 1:, :]

    r = jax.nn.sigmoid(
        (conv @ p["w_rec_gate"].astype(dt)).astype(jnp.float32))
    i = jax.nn.sigmoid(
        (conv @ p["w_in_gate"].astype(dt)).astype(jnp.float32))

    if cache is None:
        h0 = jnp.zeros((B, w), jnp.float32)
        h, _, _ = _rglru_scan(conv.astype(jnp.float32), r, i, p["lam"], h0)
        new_cache = None
        if want_cache and new_conv_state is not None:
            new_cache = {"h": h[:, -1, :], "conv": new_conv_state.astype(dt)}
    else:
        c = 8.0
        a = jnp.exp(-c * jax.nn.softplus(p["lam"])[None, None, :] * r)
        b = jnp.sqrt(jnp.maximum(1 - a * a, 1e-9)) * (
            i * conv.astype(jnp.float32))
        h = a * cache["h"][:, None, :] + b
        new_cache = {"h": h[:, -1, :], "conv": new_conv_state.astype(dt)}

    y = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    return ctx.csp(y, ctx.batch_axes, None, None), new_cache


def init_rglru_cache(cfg, batch: int) -> Params:
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), _dtype(cfg)),
    }


# ---------------------------------------------------------------- xLSTM

def _lstm_dims(cfg):
    w = 2 * cfg.d_model           # up-projection width
    H = max(cfg.n_heads, 1)
    return w, H, w // H


def init_mlstm(key, cfg) -> Params:
    D = cfg.d_model
    w, H, hd = _lstm_dims(cfg)
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": _dense_init(ks[0], (D, w), D, pd),
        "w_q": _dense_init(ks[1], (w, w), w, pd),
        "w_k": _dense_init(ks[2], (w, w), w, pd),
        "w_v": _dense_init(ks[3], (w, w), w, pd),
        "w_i": _dense_init(ks[4], (w, H), w, pd),
        "w_f": _dense_init(ks[5], (w, H), w, pd),
        "w_o": _dense_init(ks[6], (w, w), w, pd),
        "w_down": _dense_init(ks[7], (w, D), w, pd),
    }


def _mlstm_chunkwise(q, k, v, i_g, f_g, C0, n0, chunk: int):
    """Chunkwise-parallel mLSTM (§Perf xlstm hillclimb).

    Within a chunk of L steps the recurrence unrolls to a decay-masked
    attention: with F_t = Π_{s≤t} f_s,

        num_t = F_t·(C0 q_t) + Σ_{s≤t} (F_t/F_s)·i_s·(k_s·q_t)·v_s
        den_t = F_t·(n0·q_t) + Σ_{s≤t} (F_t/F_s)·i_s·(k_s·q_t)
        C_L   = F_L·C0 + Σ_s (F_L/F_s)·i_s·v_s k_sᵀ   (and n_L alike)

    — three matmuls per chunk instead of L sequential rank-1 updates, and
    the [hd,hd] state hits HBM once per chunk instead of once per step.
    Mathematically identical to the sequential scan (decays F_t/F_s ≤ 1,
    computed in log space); tests assert allclose against it.
    """
    B, S, H, hd = q.shape
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    def resh(x):
        return jnp.moveaxis(x.reshape(B, nc, L, *x.shape[2:]), 1, 0)

    qc, kc, vc = resh(q), resh(k), resh(v)
    ic, fc = resh(i_g), resh(f_g)

    def chunk_fn(carry, inp):
        C, n = carry                      # [B,H,hd,hd], [B,H,hd]
        qb, kb, vb, ib, fb = inp          # [B,L,H,*]
        logf = jnp.log(jnp.clip(fb.astype(jnp.float32), 1e-9, 1.0))
        cum = jnp.cumsum(logf, axis=1)    # [B,L,H] — log F_t
        Ft = jnp.exp(cum)
        # D[t,s] = exp(cum_t − cum_s)·i_s for s ≤ t.
        diff = cum[:, :, None, :] - cum[:, None, :, :]    # [B,L,L,H]
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri[None, :, :, None],
                      jnp.exp(diff) * ib[:, None, :, :], 0.0)
        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * D  # [B,L,L,H]
        num = (jnp.einsum("btsh,bshd->bthd", scores, vf)
               + Ft[..., None] * jnp.einsum("bhvk,bthk->bthv", C, qf))
        # den: Σ_s scores[t,s] (the k_s·q_t factor is inside scores).
        den = (jnp.sum(scores, axis=2)
               + Ft * jnp.einsum("bhk,bthk->bth", n, qf))
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # State carry to the next chunk.
        FL = Ft[:, -1]                                     # [B,H]
        decay_s = jnp.exp(cum[:, -1:, :] - cum) * ib       # [B,L,H]
        C = (FL[:, :, None, None] * C
             + jnp.einsum("bsh,bshv,bshk->bhvk", decay_s, vf, kf))
        n = FL[..., None] * n + jnp.einsum("bsh,bshk->bhk", decay_s, kf)
        return (C, n), h

    (C, n), hs = jax.lax.scan(chunk_fn, (C0, n0), (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)
    return h, C, n


def apply_mlstm(p, x, cfg, ctx: ShardCtx, *, cache=None, cur_index=None,
                want_cache: bool = False):
    """mLSTM block (xLSTM): matrix memory C_t = f C_{t−1} + i v kᵀ per head."""
    B, S, D = x.shape
    dt = _dtype(cfg)
    w, H, hd = _lstm_dims(cfg)
    up = x @ p["w_up"].astype(dt)                     # [B,S,w]
    q = (up @ p["w_q"].astype(dt)).reshape(B, S, H, hd)
    k = (up @ p["w_k"].astype(dt)).reshape(B, S, H, hd) / np.sqrt(hd)
    v = (up @ p["w_v"].astype(dt)).reshape(B, S, H, hd)
    i_g = jax.nn.sigmoid((up @ p["w_i"].astype(dt)).astype(jnp.float32))
    f_g = jax.nn.sigmoid((up @ p["w_f"].astype(dt)).astype(jnp.float32))

    C0 = (cache["C"] if cache is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))
    n0 = (cache["n"] if cache is not None
          else jnp.zeros((B, H, hd), jnp.float32))

    if cache is None and cfg.mlstm_chunk and S >= cfg.mlstm_chunk:
        hmat, C, n = _mlstm_chunkwise(q, k, v, i_g, f_g, C0, n0,
                                      cfg.mlstm_chunk)
        h = hmat.reshape(B, S, w).astype(dt)
        o = jax.nn.sigmoid(up @ p["w_o"].astype(dt))
        y = (o * h) @ p["w_down"].astype(dt)
        new_cache = {"C": C, "n": n} if want_cache else None
        return ctx.csp(y, ctx.batch_axes, None, None), new_cache

    def step(carry, inputs):
        C, n = carry
        qt, kt, vt, it, ft = inputs  # [B,H,hd] ×3, [B,H] ×2
        C = ft[..., None, None] * C + it[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])      # [B,H,hd,hd]
        n = ft[..., None] * n + it[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt.astype(jnp.float32))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt.astype(jnp.float32))),
            1.0)
        return (C, n), (num / den[..., None])

    seq = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
           jnp.moveaxis(v, 1, 0), jnp.moveaxis(i_g, 1, 0),
           jnp.moveaxis(f_g, 1, 0))
    (C, n), hs = jax.lax.scan(step, (C0, n0), seq)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, w).astype(dt)
    o = jax.nn.sigmoid(up @ p["w_o"].astype(dt))
    y = (o * h) @ p["w_down"].astype(dt)
    new_cache = ({"C": C, "n": n}
                 if (cache is not None or want_cache) else None)
    return ctx.csp(y, ctx.batch_axes, None, None), new_cache


def init_mlstm_cache(cfg, batch: int) -> Params:
    _, H, hd = _lstm_dims(cfg)
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32)}


def init_slstm(key, cfg) -> Params:
    D = cfg.d_model
    w, H, hd = _lstm_dims(cfg)
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": _dense_init(ks[0], (D, w), D, pd),
        "w_z": _dense_init(ks[1], (w, w), w, pd),
        "w_i": _dense_init(ks[2], (w, w), w, pd),
        "w_f": _dense_init(ks[3], (w, w), w, pd),
        "w_o": _dense_init(ks[4], (w, w), w, pd),
        "r_z": _dense_init(ks[5], (H, hd, hd), hd, pd),  # recurrent, per head
        "w_down": _dense_init(ks[6], (w, D), w, pd),
    }


def apply_slstm(p, x, cfg, ctx: ShardCtx, *, cache=None, cur_index=None,
                want_cache: bool = False):
    """sLSTM block (xLSTM): scalar memory with head-wise recurrent mixing."""
    B, S, D = x.shape
    dt = _dtype(cfg)
    w, H, hd = _lstm_dims(cfg)
    up = x @ p["w_up"].astype(dt)
    z_in = up @ p["w_z"].astype(dt)
    i_in = (up @ p["w_i"].astype(dt)).astype(jnp.float32)
    f_in = (up @ p["w_f"].astype(dt)).astype(jnp.float32)
    o_g = jax.nn.sigmoid(up @ p["w_o"].astype(dt))

    c0 = cache["c"] if cache is not None else jnp.zeros((B, w), jnp.float32)
    n0 = cache["n"] if cache is not None else jnp.zeros((B, w), jnp.float32)
    h0 = cache["h"] if cache is not None else jnp.zeros((B, w), jnp.float32)

    def step(carry, inputs):
        c, n, h = carry
        zt, it, ft = inputs
        hr = h.reshape(B, H, hd)
        mix = jnp.einsum("bhk,hkj->bhj", hr, p["r_z"].astype(jnp.float32))
        z = jnp.tanh(zt.astype(jnp.float32) + mix.reshape(B, w))
        i = jax.nn.sigmoid(it)
        f = jax.nn.sigmoid(ft)
        c = f * c + i * z
        n = f * n + i
        h = c / jnp.maximum(n, 1.0)
        return (c, n, h), h

    seq = (jnp.moveaxis(z_in, 1, 0), jnp.moveaxis(i_in, 1, 0),
           jnp.moveaxis(f_in, 1, 0))
    (c, n, h), hs = jax.lax.scan(step, (c0, n0, h0), seq)
    hseq = jnp.moveaxis(hs, 0, 1).astype(dt)
    y = (o_g * hseq) @ p["w_down"].astype(dt)
    new_cache = ({"c": c, "n": n, "h": h}
                 if (cache is not None or want_cache) else None)
    return ctx.csp(y, ctx.batch_axes, None, None), new_cache


def init_slstm_cache(cfg, batch: int) -> Params:
    w, _, _ = _lstm_dims(cfg)
    z = jnp.zeros((batch, w), jnp.float32)
    return {"c": z, "n": z, "h": z}
