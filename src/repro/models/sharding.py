"""Sharding rules: FSDP on the data axis × tensor parallel on the model
axis, with the pod axis (multi-pod mesh) as pure data parallelism.

Rules are divisibility-guarded: a dimension is only sharded if the mesh
axis divides it (e.g. MQA kv=1 heads replicate; gemma's 8 q-heads fall
back from a 16-way model axis to replication). Parameters carry a leading
n_groups (scan) dim which is never sharded.

Param FSDP lives on "data" only — all-gathers for layer compute stay
intra-pod; only gradient all-reduces cross the pod axis (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx


def batch_axes_of(mesh: Mesh) -> tuple:
    """Data-parallel axes: ("pod","data") on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_ctx(mesh: Mesh | None) -> ShardCtx:
    if mesh is None:
        return ShardCtx(mesh=None)
    return ShardCtx(mesh=mesh, batch_axes=batch_axes_of(mesh),
                    model_axis="model")


def _div(mesh: Mesh, axis: str, dim: int):
    """axis name if it divides dim, else None (replicate)."""
    return axis if (axis in mesh.axis_names and dim % mesh.shape[axis] == 0) else None


def param_pspecs(cfg: ModelConfig, params_tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree mirroring the params (works on abstract trees)."""
    dp = "data"
    tp = "model"

    def rule(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        shape = leaf.shape
        name = keys[-1]
        if keys[0] == "embed":
            return P(_div(mesh, tp, shape[0]), _div(mesh, dp, shape[1]))
        if keys[0] == "lm_head":
            return P(_div(mesh, dp, shape[0]), _div(mesh, tp, shape[1]))
        if name == "scale":  # norms
            return P(*([None] * len(shape)))
        # Block params: leading n_groups scan dim → None first.
        s = shape[1:] if keys[0] == "groups" else shape
        lead = (None,) if keys[0] == "groups" else ()

        def spec(*rest):
            return P(*(lead + rest))

        if name == "wq":
            return spec(_div(mesh, dp, s[0]), _div(mesh, tp, s[1]), None)
        if name in ("wk", "wv"):
            return spec(_div(mesh, dp, s[0]), _div(mesh, tp, s[1]),
                        None if _div(mesh, tp, s[1]) else _div(mesh, tp, s[2]))
        if name == "wo":
            return spec(_div(mesh, tp, s[0]), None, _div(mesh, dp, s[2]))
        if name in ("w_gate", "w_up"):
            if len(s) == 3:  # MoE experts [E, D, F]
                return spec(_div(mesh, tp, s[0]), _div(mesh, dp, s[1]), None)
            return spec(_div(mesh, dp, s[0]), _div(mesh, tp, s[1]))
        if name == "w_down":
            if len(s) == 3:  # MoE [E, F, D]
                return spec(_div(mesh, tp, s[0]), None, _div(mesh, dp, s[2]))
            return spec(_div(mesh, tp, s[0]), _div(mesh, dp, s[1]))
        if name == "router":
            return spec(_div(mesh, dp, s[0]), None)
        if name in ("w_x", "w_z", "w_i", "w_f", "w_o", "w_q", "w_k", "w_v",
                    "w_rec_gate", "w_in_gate", "w_up"):
            if len(s) == 2:
                return spec(_div(mesh, dp, s[0]), _div(mesh, tp, s[1]))
            return spec(*([None] * len(s)))
        if name == "conv_w":
            return spec(None, _div(mesh, tp, s[1]))
        if name == "lam":
            return spec(_div(mesh, tp, s[0]))
        if name == "w_out":
            return spec(_div(mesh, tp, s[0]), _div(mesh, dp, s[1]))
        if name == "r_z":
            return spec(*([None] * len(s)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def cache_pspecs(cfg: ModelConfig, cache_tree: Any, mesh: Mesh) -> Any:
    """Decode-cache specs: batch on the data axes; heads on model when
    divisible (MQA kv=1 replicates across model — batch carries it)."""
    ba = batch_axes_of(mesh)
    n_batch = int(np.prod([mesh.shape[a] for a in ba]))

    def rule(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        shape = leaf.shape
        bax = ba if shape[1] % n_batch == 0 else None
        if name in ("k", "v"):      # [G, B, alloc, KV, hd]
            return P(None, bax, None, _div(mesh, "model", shape[3]), None)
        if name == "pos":           # [G, alloc]
            return P(None, None)
        if name == "conv":          # [G, B, cw−1, w]
            return P(None, bax, None, _div(mesh, "model", shape[3]))
        if name == "C":             # [G, B, H, hd, hd]
            return P(None, bax, _div(mesh, "model", shape[2]), None, None)
        if name in ("n",):          # [G, B, H, hd] or [G, B, w]
            if len(shape) == 4:
                return P(None, bax, _div(mesh, "model", shape[2]), None)
            return P(None, bax, _div(mesh, "model", shape[2]))
        if name in ("h", "c"):      # [G, B, w]
            return P(None, bax, _div(mesh, "model", shape[2]))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def to_shardings(tree_of_pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh: Mesh, rank: int) -> P:
    """Token batches: batch dim on the data axes, rest replicated."""
    ba = batch_axes_of(mesh)
    return P(ba, *([None] * (rank - 1)))
