"""Decoder-only LM assembly: init / forward / prefill / decode.

The layer stack is scanned over *pattern groups* (stacked params with a
leading n_groups dim) so an 88-layer model lowers to one compact
``lax.scan`` body — essential for keeping 512-device SPMD compiles fast.
A remainder (n_layers % pattern period) is applied unrolled.

Every block application is pre-norm + residual; MoE blocks additionally
accumulate a load-balancing aux loss through the scan carry.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx

BLOCK_INIT = {
    "attn": L.init_attn,
    "local_attn": L.init_attn,
    "mlp": L.init_mlp,
    "moe": L.init_moe,
    "rglru": L.init_rglru,
    "mlstm": L.init_mlstm,
    "slstm": L.init_slstm,
}

_STATEFUL = ("attn", "local_attn", "rglru", "mlstm", "slstm")


def _flat_pattern(cfg: ModelConfig):
    """[(key, kind), ...] across one pattern period; key is unique."""
    out = []
    for li, grp in enumerate(cfg.block_pattern):
        for bi, kind in enumerate(grp):
            out.append((f"l{li}b{bi}_{kind}", kind))
    return out


# ------------------------------------------------------------------ init

def init_params(key, cfg: ModelConfig) -> Any:
    """Materialize parameters (use under jax.eval_shape for the dry-run)."""
    D, V = cfg.d_model, cfg.vocab_size
    pd = jnp.dtype(cfg.param_dtype)
    k_embed, k_head, k_groups, k_rem = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (V, D), jnp.float32)
                  * 0.02).astype(pd),
        "final_norm": L.init_rmsnorm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(k_head, (D, V), D, pd)

    entries = _flat_pattern(cfg)

    def init_group(gkey):
        sub = {}
        ks = jax.random.split(gkey, len(entries))
        for (name, kind), kk in zip(entries, ks):
            sub[name] = {"norm": L.init_rmsnorm(cfg),
                         "block": BLOCK_INIT[kind](kk, cfg)}
        return sub

    n_groups = cfg.n_groups
    params["groups"] = jax.vmap(init_group)(
        jax.random.split(k_groups, n_groups))
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.key(0))


# ------------------------------------------------------------------ cache

def init_cache(cfg: ModelConfig, batch: int, s_alloc: int) -> Any:
    """Decode cache pytree, leaves stacked over groups: [n_groups, ...]."""
    def one_group():
        sub = {}
        for name, kind in _flat_pattern(cfg):
            if kind in ("attn", "local_attn"):
                window = cfg.window if kind == "local_attn" else 0
                sub[name] = L.init_attn_cache(cfg, batch, s_alloc, window)
            elif kind == "rglru":
                sub[name] = L.init_rglru_cache(cfg, batch)
            elif kind == "mlstm":
                sub[name] = L.init_mlstm_cache(cfg, batch)
            elif kind == "slstm":
                sub[name] = L.init_slstm_cache(cfg, batch)
        return sub

    one = one_group()
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape).copy(), one)


def abstract_cache(cfg: ModelConfig, batch: int, s_alloc: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, s_alloc))


# ------------------------------------------------------------------ forward

def _apply_block(kind, bp, x, cfg, ctx, *, cache, cur_index, positions,
                 want_cache, s_alloc):
    """Pre-norm + residual around one block; returns (x, cache, aux)."""
    h = L.apply_rmsnorm(bp["norm"], x)
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        y, new_cache = L.apply_attn(
            bp["block"], h, cfg, ctx, window=window, cache=cache,
            cur_index=cur_index, positions=positions,
            want_cache=want_cache, s_alloc=s_alloc)
    elif kind == "mlp":
        y = L.apply_mlp(bp["block"], h, cfg, ctx)
    elif kind == "moe":
        y, (logits, gate_e) = L.apply_moe(bp["block"], h, cfg, ctx)
        # Switch-style load-balance loss: E · Σ_e f_e·P_e.
        E = cfg.n_experts
        probs = jax.nn.softmax(logits, axis=-1)
        P_e = probs.mean(axis=0)
        f_e = jnp.zeros((E,), jnp.float32).at[gate_e.reshape(-1)].add(
            1.0 / gate_e.size)
        aux = E * jnp.sum(f_e * P_e)
    elif kind == "rglru":
        y, new_cache = L.apply_rglru(bp["block"], h, cfg, ctx, cache=cache,
                                     cur_index=cur_index,
                                     want_cache=want_cache)
    elif kind == "mlstm":
        y, new_cache = L.apply_mlstm(bp["block"], h, cfg, ctx, cache=cache,
                                     cur_index=cur_index,
                                     want_cache=want_cache)
    elif kind == "slstm":
        y, new_cache = L.apply_slstm(bp["block"], h, cfg, ctx, cache=cache,
                                     cur_index=cur_index,
                                     want_cache=want_cache)
    else:  # pragma: no cover
        raise ValueError(kind)
    return x + y, new_cache, aux


def remat_policy(name):
    """Named activation-checkpoint policies (§Perf knob).

    ``full``     — recompute everything (baseline);
    ``save_tp``  — keep post-all-reduce block outputs so the backward pass
                   never re-runs TP collectives (cuts the collective term
                   ~1/3 at the cost of one bf16 [B,S,D] per block).
    """
    if isinstance(name, str) and name == "save_tp":
        return jax.checkpoint_policies.save_only_these_names("tp_out")
    return None


def forward(params, cfg: ModelConfig, ctx: ShardCtx, *,
            tokens=None, input_embeds=None, positions=None,
            cache=None, cur_index=None,
            want_cache: bool = False, s_alloc: int = 0,
            remat: bool = False):
    """Returns (logits, new_cache, aux_loss).

    Train: tokens [B,S] (or input_embeds [B,S,D] for stub frontends),
    cache=None. Prefill: want_cache=True, s_alloc = cache allocation.
    Decode: cache pytree + cur_index scalar; tokens [B,1].
    """
    dt = jnp.dtype(cfg.dtype)
    if input_embeds is not None:
        x = input_embeds.astype(dt)
    else:
        x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    B, S, _ = x.shape
    x = ctx.csp(x, ctx.batch_axes, None, None)
    if positions is None:
        if cur_index is not None:
            ci = cur_index.astype(jnp.int32)
            if ci.ndim == 1:  # per-row decode positions (continuous batching)
                positions = jnp.broadcast_to(ci[:, None], (B, S))
            else:
                positions = jnp.broadcast_to(ci, (B, S))
        else:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, S))

    entries = _flat_pattern(cfg)

    def group_fn(carry, xs):
        x, aux = carry
        gparams, gcache = xs
        new_gcache = {}
        for name, kind in entries:
            bc = None if gcache is None else gcache.get(name)
            x, nc, a = _apply_block(
                kind, gparams[name], x, cfg, ctx,
                cache=bc, cur_index=cur_index, positions=positions,
                want_cache=want_cache, s_alloc=s_alloc)
            if nc is not None:
                new_gcache[name] = nc
            aux = aux + a
        return (x, aux), (new_gcache if new_gcache else None)

    body = (jax.checkpoint(group_fn, policy=remat_policy(remat))
            if remat else group_fn)
    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, aux0), (params["groups"], cache))

    x = L.apply_rmsnorm(params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt),
                        preferred_element_type=jnp.float32)
    logits = ctx.csp(logits, ctx.batch_axes, None, ctx.model_axis)
    return logits, new_cache, aux


def forward_trunk(params, cfg: ModelConfig, ctx: ShardCtx, *,
                  tokens=None, input_embeds=None, remat: bool = False):
    """Forward without the unembedding head: returns (x_normed, aux).
    Used by the chunked-loss path (§Perf) to avoid materializing the full
    f32 logits tensor."""
    dt = jnp.dtype(cfg.dtype)
    if input_embeds is not None:
        x = input_embeds.astype(dt)
    else:
        x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    B, S, _ = x.shape
    x = ctx.csp(x, ctx.batch_axes, None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    entries = _flat_pattern(cfg)

    def group_fn(carry, xs):
        x, aux = carry
        gparams, _ = xs
        for name, kind in entries:
            x, _, a = _apply_block(
                kind, gparams[name], x, cfg, ctx,
                cache=None, cur_index=None, positions=positions,
                want_cache=False, s_alloc=0)
            aux = aux + a
        return (x, aux), None

    body = (jax.checkpoint(group_fn, policy=remat_policy(remat))
            if remat else group_fn)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["groups"], None))
    return L.apply_rmsnorm(params["final_norm"], x), aux
