"""Model configuration for the LM-family architecture pool.

One frozen dataclass describes every assigned architecture; per-arch files
in repro/configs/ instantiate it with the exact published numbers. Layers
follow a cycled ``block_pattern`` (e.g. Griffin's recurrent/recurrent/
local-attention 2:1 pattern); the stack is scanned over pattern *groups*
so heterogeneous models still lower to one compact scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

BLOCK_KINDS = ("attn", "local_attn", "mlp", "moe", "rglru", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 → d_model // n_heads
    # Per-layer block pattern, cycled across layers. Each entry is a tuple
    # of blocks applied in sequence within that layer position.
    block_pattern: tuple[tuple[str, ...], ...] = (("attn", "mlp"),)
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # MLP/activation
    mlp_type: str = "swiglu"    # swiglu | geglu | gelu
    # Attention
    window: int = 0             # sliding window for local_attn blocks
    rope_theta: float = 10_000.0
    # Recurrent blocks
    rglru_width: int = 0        # 0 → d_model
    conv_width: int = 4
    mlstm_chunk: int = 0        # 0 = sequential scan; >0 = chunkwise (§Perf)
    # Embedding
    tie_embeddings: bool = False
    scale_embed: bool = False   # gemma-style sqrt(d) embedding scale
    frontend: Optional[str] = None  # None | "audio" | "vision"
    # Numerics
    dtype: str = "bfloat16"     # activation/compute dtype
    param_dtype: str = "float32"
    # Notes for DESIGN/EXPERIMENTS (e.g. long_500k applicability)
    subquadratic: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern period {len(self.block_pattern)}")
        for grp in self.block_pattern:
            for kind in grp:
                assert kind in BLOCK_KINDS, kind

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        """Scan length: number of pattern repetitions."""
        return self.n_layers // len(self.block_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        D, F, V, H = self.d_model, self.d_ff, self.vocab_size, self.n_heads
        hd, kv = self.head_dim_, self.n_kv_heads
        total = V * D if self.tie_embeddings else 2 * V * D
        per_pattern = 0
        for grp in self.block_pattern:
            for kind in grp:
                if kind in ("attn", "local_attn"):
                    per_pattern += D * H * hd + 2 * D * kv * hd + H * hd * D
                elif kind == "mlp":
                    n_in = 2 if self.mlp_type in ("swiglu", "geglu") else 1
                    per_pattern += (n_in * D * F) + F * D
                elif kind == "moe":
                    per_pattern += D * self.n_experts  # router
                    per_pattern += self.n_experts * 3 * D * F
                elif kind == "rglru":
                    w = self.rglru_width or D
                    per_pattern += 2 * D * w + w * self.conv_width + 2 * w + w * D
                elif kind in ("mlstm", "slstm"):
                    w = 2 * D  # up-projection width
                    per_pattern += 2 * D * w + w * D + 4 * w * (w // max(self.n_heads, 1))
            per_pattern += 2 * D  # norms
        total += per_pattern * self.n_groups
        total += D  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        dead = (self.n_experts - self.experts_per_token) * 3 * D * F
        n_moe = sum(grp.count("moe") for grp in self.block_pattern) * self.n_groups
        return self.param_count() - dead * n_moe


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    period = len(cfg.block_pattern)
    base = dict(
        n_layers=2 * period if period <= 3 else period,
        d_model=64,
        n_heads=max(2, min(4, cfg.n_heads)),
        n_kv_heads=1 if cfg.n_kv_heads == 1 else 2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.n_experts else 0,
        window=min(cfg.window, 16) if cfg.window else 0,
        rglru_width=64 if cfg.rglru_width else 0,
        name=cfg.name + "-smoke",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
