"""Greedy incremental KNN baselines: Hyrec [3] and NNDescent [11,12].

Both start from a random k-degree graph and refine it by exploring
neighbors-of-neighbors (paper §IV-B2):

* **Hyrec**: compares each user u against u's neighbors' neighbors.
* **NNDescent**: compares all pairs (uᵢ, uⱼ) among u's neighbors and
  updates *their* neighborhoods — realized here through the standard
  reverse-neighborhood formulation: the candidate set of x is the union of
  the neighborhoods of every u that lists x (co-neighbors), which is
  exactly the set of pairs NNDescent generates.

Termination matches §IV-C: stop when the per-iteration update count drops
below δ·k·n (δ=0.001) or after ``max_iters`` (30). Iterations are jitted
device steps; the δ check runs on host between steps (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.knn.topk import merge_topk
from repro.sketch.goldfinger import GoldFinger, jaccard_pairwise
from repro.types import NEG_INF, PAD_ID, KNNGraph


@dataclasses.dataclass
class GreedyStats:
    iters: int
    updates: list[int]
    n_sims: int
    t_total: float


def random_graph(n: int, k: int, seed: int) -> np.ndarray:
    """Initial random k-degree graph (no self edges)."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n - 1, size=(n, k), dtype=np.int32)
    rows = np.arange(n, dtype=np.int32)[:, None]
    ids = np.where(ids >= rows, ids + 1, ids)  # skip self
    return ids


@functools.partial(jax.jit, static_argnames=("k",), donate_argnums=(0, 1))
def _refine_block(ids, sims, cand_ids, words, card, k: int):
    """One refinement pass: merge candidate lists into the current graph.

    ids/sims: [n, k] current graph; cand_ids: [n, c] proposals (PAD_ID ok).
    Returns new (ids, sims, n_changed).
    """
    n = ids.shape[0]
    safe = jnp.where(cand_ids == PAD_ID, 0, cand_ids)
    cw = words[safe]                     # [n, c, W]
    cc = jnp.where(cand_ids == PAD_ID, 0, card[safe])

    def row_sims(w_u, c_u, w_c, c_c):
        return jaccard_pairwise(w_u[None], c_u[None], w_c, c_c)[0]

    cand_sims = jax.vmap(row_sims)(words, card, cw, cc)  # [n, c]
    cand_sims = jnp.where(cand_ids == PAD_ID, NEG_INF, cand_sims)

    all_ids = jnp.concatenate([ids, cand_ids], axis=1)
    all_sims = jnp.concatenate([sims, cand_sims], axis=1)
    self_ids = jnp.arange(n, dtype=jnp.int32)
    new_ids, new_sims = merge_topk(all_ids, all_sims, k, self_ids)
    # A slot counts as updated if its id changed (paper's update counter).
    changed = jnp.sum(jnp.any(new_ids != ids, axis=1).astype(jnp.int32))
    return new_ids, new_sims, changed


def _initial_sims(ids, words, card):
    safe = jnp.where(ids == PAD_ID, 0, ids)
    cw = words[safe]
    cc = jnp.where(ids == PAD_ID, 0, card[safe])

    def row(w_u, c_u, w_c, c_c):
        return jaccard_pairwise(w_u[None], c_u[None], w_c, c_c)[0]

    s = jax.vmap(row)(words, card, cw, cc)
    return jnp.where(ids == PAD_ID, NEG_INF, s)


@jax.jit
def _hyrec_candidates(ids):
    """Neighbors-of-neighbors: [n, k·k]."""
    n, k = ids.shape
    safe = jnp.where(ids == PAD_ID, 0, ids)
    non = ids[safe].reshape(n, k * k)  # neighbors of neighbors
    return jnp.where((ids == PAD_ID).repeat(k, axis=1), PAD_ID, non)


@functools.partial(jax.jit, static_argnames=("r_max",))
def _reverse_neighbors(ids, r_max: int):
    """Reverse adjacency R[x] = up to r_max users u with x ∈ N(u)."""
    n, k = ids.shape
    rev = jnp.full((n, r_max), PAD_ID, dtype=jnp.int32)
    counts = jnp.zeros((n,), dtype=jnp.int32)
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    dst = ids.reshape(-1)

    def body(i, state):
        rev, counts = state
        d = dst[i]
        slot = jnp.minimum(counts[d], r_max - 1)
        ok = d != PAD_ID
        rev = jax.lax.cond(
            ok, lambda r: r.at[d, slot].set(src[i]), lambda r: r, rev)
        counts = jax.lax.cond(
            ok, lambda c: c.at[d].add(1), lambda c: c, counts)
        return rev, counts

    rev, _ = jax.lax.fori_loop(0, n * k, body, (rev, counts))
    return rev


def _reverse_neighbors_np(ids: np.ndarray, r_max: int) -> np.ndarray:
    """Host scatter version (faster than fori_loop on CPU backend)."""
    n, k = ids.shape
    rev = np.full((n, r_max), PAD_ID, dtype=np.int32)
    counts = np.zeros(n, dtype=np.int64)
    src = np.repeat(np.arange(n, dtype=np.int32), k)
    dst = ids.reshape(-1)
    order = np.random.default_rng(0).permutation(n * k)  # unbiased truncation
    for e in order:
        d = dst[e]
        if d == PAD_ID:
            continue
        c = counts[d]
        if c < r_max:
            rev[d, c] = src[e]
            counts[d] = c + 1
    return rev


reverse_neighbors_np = _reverse_neighbors_np  # public alias (query index)


def hyrec(gf: GoldFinger, k: int, max_iters: int = 30, delta: float = 0.001,
          seed: int = 0, ids0: np.ndarray | None = None):
    """Hyrec KNN graph construction."""
    n = gf.n
    words, card = jnp.asarray(gf.words), jnp.asarray(gf.card)
    t0 = time.perf_counter()
    ids = jnp.asarray(ids0 if ids0 is not None else random_graph(n, k, seed))
    sims = _initial_sims(ids, words, card)
    updates, n_sims = [], n * k
    it = 0
    for it in range(1, max_iters + 1):
        cands = _hyrec_candidates(ids)
        ids, sims, changed = _refine_block(ids, sims, cands, words, card, k)
        n_sims += n * k * k
        changed = int(changed)
        updates.append(changed)
        if changed < delta * k * n:
            break
    stats = GreedyStats(iters=it, updates=updates, n_sims=n_sims,
                        t_total=time.perf_counter() - t0)
    return KNNGraph(ids=np.asarray(ids), sims=np.asarray(sims)), stats


def nndescent(gf: GoldFinger, k: int, max_iters: int = 30,
              delta: float = 0.001, seed: int = 0,
              ids0: np.ndarray | None = None):
    """NNDescent KNN graph construction (reverse-join formulation)."""
    n = gf.n
    words, card = jnp.asarray(gf.words), jnp.asarray(gf.card)
    t0 = time.perf_counter()
    ids = jnp.asarray(ids0 if ids0 is not None else random_graph(n, k, seed + 1))
    sims = _initial_sims(ids, words, card)
    updates, n_sims = [], n * k
    r_max = k  # sampled reverse degree, as in NNDescent's ρ-sampling
    it = 0
    for it in range(1, max_iters + 1):
        ids_h = np.asarray(ids)
        rev = jnp.asarray(_reverse_neighbors_np(ids_h, r_max))
        # Co-neighbor join: neighbors of (forward ∪ reverse) neighbors.
        both = jnp.concatenate([ids, rev], axis=1)  # [n, 2k]
        safe = jnp.where(both == PAD_ID, 0, both)
        cands = ids[safe].reshape(n, -1)            # [n, 2k·k]
        cands = jnp.where(
            (both == PAD_ID).repeat(k, axis=1), PAD_ID, cands)
        cands = jnp.concatenate([cands, rev], axis=1)
        ids, sims, changed = _refine_block(ids, sims, cands, words, card, k)
        n_sims += n * (2 * k * k + r_max)
        changed = int(changed)
        updates.append(changed)
        if changed < delta * k * n:
            break
    stats = GreedyStats(iters=it, updates=updates, n_sims=n_sims,
                        t_total=time.perf_counter() - t0)
    return KNNGraph(ids=np.asarray(ids), sims=np.asarray(sims)), stats
