"""Shared top-k neighbor utilities (the TPU replacement for bounded heaps).

The paper maintains per-user bounded heaps (Alg. 3). On TPU we instead
concatenate candidate lists and run one wide ``lax.top_k`` after masking
duplicates and self-edges — a single vectorized op instead of pointer
chasing (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.types import NEG_INF, PAD_ID, KNNGraph


def dedup_mask(ids: jax.Array) -> jax.Array:
    """bool[n, c]: True for the first occurrence of each id in its row.

    Sorts ids per row, marks repeats, then scatters the mask back through
    the inverse permutation — O(c log c) per row, fully vectorized.
    """
    order = jnp.argsort(ids, axis=-1)
    sorted_ids = jnp.take_along_axis(ids, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones_like(sorted_ids[..., :1], dtype=bool),
         sorted_ids[..., 1:] != sorted_ids[..., :-1]],
        axis=-1,
    )
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(first, inv, axis=-1)


def merge_topk(ids: jax.Array, sims: jax.Array, k: int,
               self_ids: jax.Array | None = None):
    """Per-row top-k with dedup / self-edge / PAD masking.

    ids:  int32[n, c] candidate neighbor ids (PAD_ID = absent)
    sims: float32[n, c] candidate similarities
    Returns (ids int32[n, k], sims float32[n, k]) sorted by sim desc.
    """
    if ids.shape[1] < k:  # fewer candidates than requested neighbors
        pad = k - ids.shape[1]
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=PAD_ID)
        sims = jnp.pad(sims, ((0, 0), (0, pad)), constant_values=NEG_INF)
    valid = ids != PAD_ID
    if self_ids is not None:
        valid &= ids != self_ids[:, None]
    valid &= dedup_mask(ids)
    masked = jnp.where(valid, sims, NEG_INF)
    top_sims, pos = jax.lax.top_k(masked, k)
    top_ids = jnp.take_along_axis(ids, pos, axis=-1)
    top_ids = jnp.where(top_sims == NEG_INF, PAD_ID, top_ids)
    return top_ids, top_sims


def graph_from_device(ids, sims) -> KNNGraph:
    return KNNGraph(ids=np.asarray(ids), sims=np.asarray(sims))


def union_graphs(a: KNNGraph, b: KNNGraph, k: int | None = None) -> KNNGraph:
    """Merge two KNN graphs per user (host API over the device top-k)."""
    k = k or a.k
    ids = jnp.concatenate([jnp.asarray(a.ids), jnp.asarray(b.ids)], axis=1)
    sims = jnp.concatenate([jnp.asarray(a.sims), jnp.asarray(b.sims)], axis=1)
    self_ids = jnp.arange(a.n, dtype=ids.dtype)
    out_ids, out_sims = merge_topk(ids, sims, k, self_ids)
    return graph_from_device(out_ids, out_sims)
