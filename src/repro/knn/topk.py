"""Shared top-k neighbor utilities (the TPU replacement for bounded heaps).

The paper maintains per-user bounded heaps (Alg. 3). On TPU we instead
concatenate candidate lists and run one wide ``lax.top_k`` after masking
duplicates and self-edges — a single vectorized op instead of pointer
chasing (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.types import NEG_INF, PAD_ID, KNNGraph


def dedup_mask(ids: jax.Array) -> jax.Array:
    """bool[n, c]: True for the first occurrence of each id in its row.

    Sorts ids per row, marks repeats, then scatters the mask back through
    the inverse permutation — O(c log c) per row, fully vectorized.
    """
    order = jnp.argsort(ids, axis=-1)
    sorted_ids = jnp.take_along_axis(ids, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones_like(sorted_ids[..., :1], dtype=bool),
         sorted_ids[..., 1:] != sorted_ids[..., :-1]],
        axis=-1,
    )
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(first, inv, axis=-1)


def select_topk(cand_sims: jax.Array, cand_ids: jax.Array, k: int,
                *, dedup_ids: bool = False):
    """In-register top-k: k rounds of (max, first-occurrence) selection.

    cand_sims f32[n, c], cand_ids i32[n, c] → (f32[n, k], i32[n, k]).
    Ties resolve to the lowest column index, matching ``lax.top_k``. With
    ``dedup_ids`` every column carrying a round's winning id retires with
    the winner, so an id is selected at most once — because duplicate
    columns of an id always carry the same sim, this reproduces the
    ``dedup_mask`` + ``lax.top_k`` semantics of :func:`merge_topk`
    exactly (the winning column is the id's first occurrence).

    No gathers, no sort — everything lowers to plain VPU reduce/eltwise
    ops, so this is safe inside Pallas kernel bodies (the goldfinger_knn
    streaming merge and the descent_score beam merge both use it).
    """
    n, c = cand_sims.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (n, c), 1)
    sel_sims = []
    sel_ids = []
    for _ in range(k):
        m = jnp.max(cand_sims, axis=1)                      # [n]
        hit = cand_sims == m[:, None]
        first_col = jnp.min(jnp.where(hit, col, c), axis=1)  # [n]
        first = col == first_col[:, None]
        win = jnp.sum(jnp.where(first, cand_ids, 0), axis=1)
        sel_sims.append(m)
        sel_ids.append(win)
        kill = first
        if dedup_ids:
            kill = kill | (cand_ids == win[:, None])
        cand_sims = jnp.where(kill, NEG_INF, cand_sims)
    return (jnp.stack(sel_sims, axis=1),
            jnp.stack(sel_ids, axis=1).astype(jnp.int32))


def merge_topk(ids: jax.Array, sims: jax.Array, k: int,
               self_ids: jax.Array | None = None):
    """Per-row top-k with dedup / self-edge / PAD masking.

    ids:  int32[n, c] candidate neighbor ids (PAD_ID = absent)
    sims: float32[n, c] candidate similarities
    Returns (ids int32[n, k], sims float32[n, k]) sorted by sim desc.
    """
    if ids.shape[1] < k:  # fewer candidates than requested neighbors
        pad = k - ids.shape[1]
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=PAD_ID)
        sims = jnp.pad(sims, ((0, 0), (0, pad)), constant_values=NEG_INF)
    valid = ids != PAD_ID
    if self_ids is not None:
        valid &= ids != self_ids[:, None]
    valid &= dedup_mask(ids)
    masked = jnp.where(valid, sims, NEG_INF)
    top_sims, pos = jax.lax.top_k(masked, k)
    top_ids = jnp.take_along_axis(ids, pos, axis=-1)
    top_ids = jnp.where(top_sims == NEG_INF, PAD_ID, top_ids)
    return top_ids, top_sims


def graph_from_device(ids, sims) -> KNNGraph:
    return KNNGraph(ids=np.asarray(ids), sims=np.asarray(sims))


def union_graphs(a: KNNGraph, b: KNNGraph, k: int | None = None) -> KNNGraph:
    """Merge two KNN graphs per user (host API over the device top-k)."""
    k = k or a.k
    ids = jnp.concatenate([jnp.asarray(a.ids), jnp.asarray(b.ids)], axis=1)
    sims = jnp.concatenate([jnp.asarray(a.sims), jnp.asarray(b.sims)], axis=1)
    self_ids = jnp.arange(a.n, dtype=ids.dtype)
    out_ids, out_sims = merge_topk(ids, sims, k, self_ids)
    return graph_from_device(out_ids, out_sims)
