"""Brute-force KNN graph (paper §IV-B1) — the exact reference.

Computes all n·(n−1)/2 similarities, blocked over rows so the similarity
matrix never fully materializes. Used (a) as the exact-graph reference for
the quality metric, and (b) inside C² for clusters below the ρk² switch,
where it runs through the fused Pallas kernel instead (core/local_knn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.knn.topk import graph_from_device
from repro.sketch.goldfinger import GoldFinger, jaccard_pairwise
from repro.types import NEG_INF, PAD_ID, KNNGraph


@functools.partial(jax.jit, static_argnames=("k",))
def _block_knn(words_blk, card_blk, row_ids, words_all, card_all, k: int):
    sims = jaccard_pairwise(words_blk, card_blk, words_all, card_all)
    n_all = words_all.shape[0]
    cols = jnp.arange(n_all, dtype=jnp.int32)
    sims = jnp.where(cols[None, :] == row_ids[:, None], NEG_INF, sims)
    top_sims, top_ids = jax.lax.top_k(sims, k)
    top_ids = jnp.where(top_sims == NEG_INF, PAD_ID, top_ids.astype(jnp.int32))
    return top_ids, top_sims


def brute_force_knn(gf: GoldFinger, k: int, block: int = 512) -> KNNGraph:
    """Exact (under the GoldFinger estimator) KNN graph, row-blocked."""
    n = gf.n
    words = jnp.asarray(gf.words)
    card = jnp.asarray(gf.card)
    ids_out = np.full((n, k), PAD_ID, dtype=np.int32)
    sims_out = np.full((n, k), NEG_INF, dtype=np.float32)
    for start in range(0, n, block):
        stop = min(start + block, n)
        rows = jnp.arange(start, stop, dtype=jnp.int32)
        ids, sims = _block_knn(words[start:stop], card[start:stop], rows,
                               words, card, k)
        ids_out[start:stop] = np.asarray(ids)
        sims_out[start:stop] = np.asarray(sims)
    return KNNGraph(ids=ids_out, sims=sims_out)


def n_similarities(n: int) -> int:
    """Similarity-computation count of brute force (paper: n(n−1)/2)."""
    return n * (n - 1) // 2
