"""LSH/MinHash baseline (paper §IV-B3).

Each of t hash functions is a min-wise permutation of the item universe
(implemented as a random hash over item ids, the standard MinHash
approximation); a user's signature is the minimum permuted value over her
profile, and each function's buckets are formed by signature value —
"each hash function creates its own buckets", exactly as the paper
implements LSH for fairness. Neighbors are then searched within buckets and
merged, reusing C²'s local-KNN and merge machinery (the differences vs C²
are precisely the paper's point: unbounded hash space = |I| buckets, no
recursive splitting).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import hashing
from repro.core.clustering import ClusterPlan
from repro.core.local_knn import local_knn
from repro.core.merge import merge_partial
from repro.core.params import C2Params
from repro.sketch.goldfinger import GoldFinger
from repro.types import Dataset, KNNGraph


def lsh_plan(ds: Dataset, t: int, seed: int = 0) -> ClusterPlan:
    """Bucket users by MinHash signature under t permutations."""
    seeds = np.arange(t, dtype=np.int32) + np.int32(seed * 7919 + 13)
    # Hash space = the item universe (MinHash permutation image).
    item_h = hashing.item_hashes(ds.items, seeds, max(ds.n_items, 2))
    sig = hashing.user_min_hash_np(item_h, ds.offsets)  # [t, n]
    members: list[np.ndarray] = []
    config_of: list[int] = []
    for i in range(t):
        s = sig[i]
        valid = s != hashing.NO_HASH
        users = np.arange(ds.n_users, dtype=np.int64)[valid]
        order = np.argsort(s[valid], kind="stable")
        su, sh = users[order], s[valid][order]
        bounds = np.flatnonzero(np.diff(sh, prepend=-1) != 0)
        for b0, b1 in zip(bounds, np.append(bounds[1:], len(su))):
            if b1 - b0 >= 2:
                members.append(su[b0:b1])
                config_of.append(i)
    return ClusterPlan(members=members,
                       config_of=np.array(config_of, dtype=np.int32),
                       n_users=ds.n_users, t=t)


def lsh_knn(ds: Dataset, gf: GoldFinger, k: int, t: int = 10, seed: int = 0):
    t0 = time.perf_counter()
    plan = lsh_plan(ds, t, seed)
    ids, sims = local_knn(plan, gf, C2Params(k=k, t=t))
    graph = merge_partial(ids, sims, k)
    elapsed = time.perf_counter() - t0
    return graph, {
        "t_total": elapsed,
        "n_buckets": plan.n_clusters,
        "n_sims": plan.brute_force_sims(),
        "max_bucket": int(plan.sizes.max()) if plan.n_clusters else 0,
    }
