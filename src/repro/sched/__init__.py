"""Continuous-batching scheduler shared by the query and LM engines."""
from repro.sched.scheduler import (ADMISSION_POLICIES, Cadence,  # noqa: F401
                                   ManualClock, SlotScheduler,
                                   shed_and_select)
from repro.sched import trace  # noqa: F401
