"""Continuous-batching scheduler shared by the query and LM engines."""
from repro.sched.scheduler import Cadence, SlotScheduler  # noqa: F401
from repro.sched import trace  # noqa: F401
