"""Jit-trace counters for compile-count regressions.

A continuous-batching engine must compile its step program ONCE per
static configuration and then reuse it for every tick, no matter how
requests stream in — a silent retrace per admission would turn the
latency win into a compile storm. The counter exploits that a jitted
function's *Python body* runs only while JAX traces it: the engine calls
:func:`bump` inside the traced body, so the count equals the number of
traces (= compiles, modulo cache eviction) for that key.

``tests/test_continuous.py`` asserts the count stays at 1 across
arbitrary admission interleavings.
"""
from __future__ import annotations

from collections import Counter
from typing import Hashable

_TRACES: Counter = Counter()


def bump(key: Hashable):
    """Record one trace of the program identified by ``key``.

    Call ONLY from inside a jit-traced function body.
    """
    _TRACES[key] += 1


def count(key: Hashable) -> int:
    """Traces recorded for ``key`` since process start (or last reset)."""
    return _TRACES[key]


def compile_count(plan_key: Hashable) -> int:
    """Total traces of every program tagged with ``plan_key``.

    Plan-owned programs (``query/plan.py`` via ``query/search.py`` /
    ``query/sharded.py``) embed the plan's identity tuple
    (:attr:`~repro.query.plan.PlanSpec.key`) in their bump keys; this
    sums the trace counts of every key carrying that tag, whatever the
    program or shape. ``tests/test_plan.py`` / ``tests/test_continuous``
    assert the total goes flat after warmup — compile-once per plan
    across admission interleavings AND delta reshards.
    """
    return sum(v for k, v in _TRACES.items()
               if isinstance(k, tuple) and any(e == plan_key for e in k))


def counts(prefix: str | None = None) -> dict:
    """Snapshot of all counters, optionally filtered by key[0] == prefix."""
    if prefix is None:
        return dict(_TRACES)
    return {k: v for k, v in _TRACES.items()
            if isinstance(k, tuple) and k and k[0] == prefix}


# -- host-side launch counters ---------------------------------------------
#
# ``bump`` counts TRACES (compiles) because it runs inside a jitted body;
# ``launch`` counts host-side program DISPATCHES — it is called from
# ordinary Python right where the engine launches (or would launch) a
# compiled program. The zero-hop-burst regression in ``query/plan.py``
# uses it: a tick's worth of completions must cost ONE slot-result
# snapshot, however many admission chunks fed the tick. Kept in a
# separate store so launch keys can carry plan-key tuples without
# polluting :func:`compile_count`'s tag search.

_LAUNCHES: Counter = Counter()


def launch(key: Hashable):
    """Record one host-side dispatch of the program identified by ``key``."""
    _LAUNCHES[key] += 1


def launch_count(key: Hashable) -> int:
    """Dispatches recorded for ``key`` since process start (or reset)."""
    return _LAUNCHES[key]


def reset():
    """Clear all counters (test isolation)."""
    _TRACES.clear()
    _LAUNCHES.clear()
