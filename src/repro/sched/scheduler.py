"""Slot-based continuous-batching scheduler shared by both serving engines.

Wave batching (the pre-PR-3 discipline of ``query/engine.py`` and
``serve/engine.py``) closes a batch before admitting new requests: one
slow descent or one long decode stalls everything queued behind it. The
fix mirrors what C² does at build time by pre-clustering — bound the
cost any single straggler can impose. Here the bound comes from *slots*:
the compiled program always runs at fixed capacity ``n_slots``, each
slot carries one in-flight request, and a slot frees the moment its
request completes (beam converged / hop budget exhausted on the query
side; EOS / max_new on the LM side). Freed slots are refilled from the
FIFO queue *mid-flight* — admission never waits for the rest of the
batch.

The scheduler itself is engine-agnostic host bookkeeping: it owns the
pending FIFO, the slot → request assignment, and the active mask, and it
enforces the invariants the property suite locks down
(``tests/test_sched_properties.py``):

* a slot is never double-assigned (``admit`` only hands out free slots);
* admission is FIFO — requests enter slots in submission order;
* every submitted request is admitted exactly once and released exactly
  once (``n_submitted == n_completed`` when the scheduler drains);
* the active mask equals the set of occupied slots at every step.

Freed slots are reused lowest-index-first so admission is deterministic
given the submit/complete interleaving — which is what makes the
continuous-vs-wave equivalence tests exact rather than statistical.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Any, Optional

import numpy as np


class Cadence:
    """Deterministic periodic trigger for between-tick maintenance.

    Serving loops call :meth:`tick` once per scheduler step; it returns
    True every ``every``-th call. The lifecycle subsystem hangs its
    repair passes off one of these so maintenance lands BETWEEN compiled
    steps — in-flight continuous slots never observe a half-applied
    mutation — and so the fire pattern is a pure function of the step
    count (reproducible under the property suite's interleavings).
    ``every <= 0`` disables the trigger entirely.
    """

    def __init__(self, every: int):
        self.every = every
        self._count = 0
        self.n_fired = 0

    def tick(self) -> bool:
        """Advance one step; True when this step is a fire boundary."""
        if self.every <= 0:
            return False
        self._count += 1
        if self._count < self.every:
            return False
        self._count = 0
        self.n_fired += 1
        return True


class SlotScheduler:
    """FIFO admission queue + fixed-capacity slot assignment."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.pending: deque[Any] = deque()
        self._occupant: list[Optional[Any]] = [None] * n_slots
        self._free: list[int] = list(range(n_slots))  # min-heap
        heapq.heapify(self._free)
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_completed = 0

    # -- queue -------------------------------------------------------------

    def submit(self, item: Any):
        """Enqueue a request; it enters a slot at a later ``admit``."""
        self.pending.append(item)
        self.n_submitted += 1

    def admit(self) -> list[tuple[int, Any]]:
        """Move queued requests into free slots (FIFO, lowest slot first).

        Returns the ``(slot, item)`` pairs admitted this call — the
        engine initializes per-slot device state for exactly these rows.
        """
        admitted: list[tuple[int, Any]] = []
        while self.pending and self._free:
            slot = heapq.heappop(self._free)
            assert self._occupant[slot] is None, \
                f"slot {slot} double-assignment"
            item = self.pending.popleft()
            self._occupant[slot] = item
            self.n_admitted += 1
            admitted.append((slot, item))
        return admitted

    def release(self, slot: int) -> Any:
        """Free a slot whose request completed; returns the occupant."""
        item = self._occupant[slot]
        assert item is not None, f"release of free slot {slot}"
        self._occupant[slot] = None
        heapq.heappush(self._free, slot)
        self.n_completed += 1
        return item

    def release_many(self, slots) -> list[Any]:
        """Free several completed slots; returns their occupants in the
        given slot order (one completion batch of a continuous tick)."""
        return [self.release(int(s)) for s in slots]

    # -- introspection -----------------------------------------------------

    def occupant(self, slot: int) -> Optional[Any]:
        return self._occupant[slot]

    @property
    def active_slots(self) -> list[int]:
        return [s for s, it in enumerate(self._occupant) if it is not None]

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def active_mask(self) -> np.ndarray:
        """bool[n_slots]: True where a request is in flight."""
        return np.array([it is not None for it in self._occupant], dtype=bool)

    def has_work(self) -> bool:
        """True while anything is queued or in flight."""
        return bool(self.pending) or self.n_active > 0

    def check_invariants(self):
        """Structural consistency (exercised by the property suite)."""
        occupied = set(self.active_slots)
        free = set(self._free)
        assert occupied.isdisjoint(free), occupied & free
        assert occupied | free == set(range(self.n_slots))
        assert len(self._free) == len(free), "free-heap duplicate"
        assert self.n_admitted == self.n_completed + self.n_active
        assert self.n_submitted == self.n_admitted + len(self.pending)
