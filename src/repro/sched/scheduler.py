"""Slot-based continuous-batching scheduler shared by both serving engines.

Wave batching (the pre-PR-3 discipline of ``query/engine.py`` and
``serve/engine.py``) closes a batch before admitting new requests: one
slow descent or one long decode stalls everything queued behind it. The
fix mirrors what C² does at build time by pre-clustering — bound the
cost any single straggler can impose. Here the bound comes from *slots*:
the compiled program always runs at fixed capacity ``n_slots``, each
slot carries one in-flight request, and a slot frees the moment its
request completes (beam converged / hop budget exhausted on the query
side; EOS / max_new on the LM side). Freed slots are refilled from the
FIFO queue *mid-flight* — admission never waits for the rest of the
batch.

The scheduler itself is engine-agnostic host bookkeeping: it owns the
pending FIFO, the slot → request assignment, and the active mask, and it
enforces the invariants the property suite locks down
(``tests/test_sched_properties.py``):

* a slot is never double-assigned (``admit`` only hands out free slots);
* admission is FIFO — requests enter slots in submission order;
* every submitted request is admitted exactly once and released exactly
  once (``n_submitted == n_completed`` when the scheduler drains);
* the active mask equals the set of occupied slots at every step.

Freed slots are reused lowest-index-first so admission is deterministic
given the submit/complete interleaving — which is what makes the
continuous-vs-wave equivalence tests exact rather than statistical.

SLO-aware admission (``policy="slo"``) layers priority classes and
deadlines on the same slot machinery: items may carry ``priority`` (int,
0 = highest class) and ``deadline`` (absolute clock time, None = never
expires) attributes; :meth:`SlotScheduler.admit` then picks by class,
then earliest deadline, then submission order — and a bounded pending
queue (``max_pending``) sheds expired and worst-ranked overflow requests
EXPLICITLY into :attr:`SlotScheduler.shed` instead of letting the deque
grow without bound under overload. Shed requests are handed back to the
engine, which completes them with a ``rejected`` marker — they never
silently vanish, and the exactly-once accounting extends to them
(``n_submitted == n_admitted + len(pending) + n_shed``). The default
``policy="fifo"`` path is byte-identical to the pre-SLO scheduler, which
is what keeps the continuous-vs-wave bitwise-equivalence tests exact.
"""
from __future__ import annotations

import heapq
import math
import time
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

ADMISSION_POLICIES = ("fifo", "slo")


def _priority_of(item: Any) -> int:
    """SLO class of a request (0 = highest); items without the attribute
    (e.g. the LM engine's GenRequest) are all top-class, which degrades
    the slo policy to deadline-then-FIFO."""
    p = getattr(item, "priority", 0)
    return 0 if p is None else int(p)


def _deadline_of(item: Any) -> float:
    """Absolute expiry time of a request; None (or absent) = +inf."""
    d = getattr(item, "deadline", None)
    return math.inf if d is None else float(d)


def shed_and_select(pending, n: int, now: float,
                    max_pending: int = 0) -> tuple[list, list]:
    """SLO admission over a pending queue: pick ``n``, shed the hopeless.

    ``pending`` (a deque/list in submission order, mutated in place)
    is split three ways:

    * **expired** — deadline already behind ``now``: shed (serving them
      would burn a slot on a result the caller stopped waiting for);
    * **selected** — the best ``n`` survivors by (priority class,
      earliest deadline, submission order);
    * **overflow** — with ``max_pending > 0``, the worst-ranked
      survivors beyond that bound: shed, so the queue stays bounded
      under sustained overload instead of collapsing.

    Returns ``(selected, shed)``; what remains in ``pending`` keeps
    submission order (so FIFO tie-breaks stay deterministic across
    repeated calls). Both engines' admission paths (wave closing and
    the slot scheduler) route through this one function.
    """
    shed: list = []
    keep: list[tuple[int, Any]] = []
    for seq, item in enumerate(pending):
        if _deadline_of(item) < now:
            shed.append(item)
        else:
            keep.append((seq, item))
    keep.sort(key=lambda si: (_priority_of(si[1]), _deadline_of(si[1]),
                              si[0]))
    selected = [item for _, item in keep[:n]]
    rest = keep[n:]
    if max_pending > 0 and len(rest) > max_pending:
        shed.extend(item for _, item in rest[max_pending:])
        rest = rest[:max_pending]
    rest.sort(key=lambda si: si[0])
    pending.clear()
    pending.extend(item for _, item in rest)
    return selected, shed


class ManualClock:
    """Injectable deterministic clock for engines, schedulers and fault
    tests.

    Everywhere the serving stack reads wall time (``QueryEngine``,
    ``DescentPlan``, :class:`SlotScheduler` deadlines, the fault
    injector's slow-shard latency) it goes through an injectable
    ``clock()`` callable defaulting to ``time.perf_counter``. A
    ``ManualClock`` only moves when :meth:`advance` is called, so
    latency stats, deadline shedding and backoff windows become pure
    functions of the test script — no ``time.sleep``, no flaky timing.

    ``sleep(dt)`` is an alias for ``advance(dt)`` so code written
    against ``time.sleep`` (open-loop pacing, injected slow-shard
    latency) can take the same object.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a clock backwards ({dt})")
        self.now += float(dt)
        return self.now

    def sleep(self, dt: float) -> None:
        self.advance(dt)


class Cadence:
    """Deterministic periodic trigger for between-tick maintenance.

    Serving loops call :meth:`tick` once per scheduler step; it returns
    True every ``every``-th call. The lifecycle subsystem hangs its
    repair passes off one of these so maintenance lands BETWEEN compiled
    steps — in-flight continuous slots never observe a half-applied
    mutation — and so the fire pattern is a pure function of the step
    count (reproducible under the property suite's interleavings).
    ``every <= 0`` disables the trigger entirely.
    """

    def __init__(self, every: int):
        self.every = every
        self._count = 0
        self.n_fired = 0

    def tick(self) -> bool:
        """Advance one step; True when this step is a fire boundary."""
        if self.every <= 0:
            return False
        self._count += 1
        if self._count < self.every:
            return False
        self._count = 0
        self.n_fired += 1
        return True


class SlotScheduler:
    """Admission queue + fixed-capacity slot assignment.

    ``policy="fifo"`` (default) admits in submission order with an
    unbounded queue — the exact pre-SLO behavior. ``policy="slo"``
    admits by (priority class, earliest deadline, submission order),
    sheds expired requests, and — with ``max_pending > 0`` — bounds the
    pending queue by shedding the worst-ranked overflow. Shed items land
    in :attr:`shed` for the engine to drain (:meth:`drain_shed`) and
    complete with a rejected marker. ``clock`` is injectable so deadline
    behavior is deterministic under test.
    """

    def __init__(self, n_slots: int, *, policy: str = "fifo",
                 max_pending: int = 0,
                 clock: Optional[Callable[[], float]] = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"supported: {ADMISSION_POLICIES}")
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        self.n_slots = n_slots
        self.policy = policy
        self.max_pending = max_pending
        self.clock = clock or time.perf_counter
        self.pending: deque[Any] = deque()
        self.shed: list[Any] = []  # engine drains these (drain_shed)
        self._occupant: list[Optional[Any]] = [None] * n_slots
        self._free: list[int] = list(range(n_slots))  # min-heap
        heapq.heapify(self._free)
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_completed = 0
        self.n_shed = 0

    # -- queue -------------------------------------------------------------

    def submit(self, item: Any):
        """Enqueue a request; it enters a slot at a later ``admit``."""
        self.pending.append(item)
        self.n_submitted += 1

    def admit(self) -> list[tuple[int, Any]]:
        """Move queued requests into free slots (lowest slot first).

        FIFO policy: requests enter slots in submission order. SLO
        policy: requests enter by (priority class, earliest deadline,
        submission order), expired requests and worst-ranked overflow
        beyond ``max_pending`` are shed into :attr:`shed` instead of
        admitted. Returns the ``(slot, item)`` pairs admitted this call
        — the engine initializes per-slot device state for exactly
        these rows.
        """
        admitted: list[tuple[int, Any]] = []
        if self.policy == "slo":
            selected, shed = shed_and_select(
                self.pending, len(self._free), self.clock(),
                self.max_pending)
            self.n_shed += len(shed)
            self.shed.extend(shed)
            for item in selected:
                slot = heapq.heappop(self._free)
                assert self._occupant[slot] is None, \
                    f"slot {slot} double-assignment"
                self._occupant[slot] = item
                self.n_admitted += 1
                admitted.append((slot, item))
            return admitted
        while self.pending and self._free:
            slot = heapq.heappop(self._free)
            assert self._occupant[slot] is None, \
                f"slot {slot} double-assignment"
            item = self.pending.popleft()
            self._occupant[slot] = item
            self.n_admitted += 1
            admitted.append((slot, item))
        return admitted

    def drain_shed(self) -> list[Any]:
        """Hand the engine every request shed since the last drain (the
        engine completes them with a rejected marker — shed requests
        never silently vanish)."""
        out, self.shed = self.shed, []
        return out

    def release(self, slot: int) -> Any:
        """Free a slot whose request completed; returns the occupant."""
        item = self._occupant[slot]
        assert item is not None, f"release of free slot {slot}"
        self._occupant[slot] = None
        heapq.heappush(self._free, slot)
        self.n_completed += 1
        return item

    def release_many(self, slots) -> list[Any]:
        """Free several completed slots; returns their occupants in the
        given slot order (one completion batch of a continuous tick)."""
        return [self.release(int(s)) for s in slots]

    # -- introspection -----------------------------------------------------

    def occupant(self, slot: int) -> Optional[Any]:
        return self._occupant[slot]

    @property
    def active_slots(self) -> list[int]:
        return [s for s, it in enumerate(self._occupant) if it is not None]

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def active_mask(self) -> np.ndarray:
        """bool[n_slots]: True where a request is in flight."""
        return np.array([it is not None for it in self._occupant], dtype=bool)

    def has_work(self) -> bool:
        """True while anything is queued or in flight."""
        return bool(self.pending) or self.n_active > 0

    def check_invariants(self):
        """Structural consistency (exercised by the property suite)."""
        occupied = set(self.active_slots)
        free = set(self._free)
        assert occupied.isdisjoint(free), occupied & free
        assert occupied | free == set(range(self.n_slots))
        assert len(self._free) == len(free), "free-heap duplicate"
        assert self.n_admitted == self.n_completed + self.n_active
        assert self.n_submitted == (self.n_admitted + len(self.pending)
                                    + self.n_shed)
        if self.max_pending > 0:
            # The bound is enforced at every admit; submits between
            # admits may transiently exceed it, but an admit always
            # restores it — callers check AFTER stepping.
            assert self.n_shed >= 0
