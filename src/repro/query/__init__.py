"""Online KNN query serving over built C² graphs.

``index``  — frozen, servable :class:`KNNIndex` artifact (graph +
GoldFinger fingerprints + FRH routing tables + reverse adjacency).
``router`` — FastRandomHash placement of unseen profiles into the
clusters of each hash configuration (seed candidates).
``search`` — jitted, batched beam descent over the index graph.
``sharded`` — LPT cluster shards: per-shard descent + cross-shard merge.
``engine`` — queue → wave :class:`QueryEngine` with online insertion.
"""
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import KNNIndex, build_index
from repro.query.router import route
from repro.query.search import batched_descent, exact_knn
from repro.query.sharded import ShardedDescent, ShardPlan, plan_shards

__all__ = [
    "KNNIndex", "build_index", "route", "batched_descent", "exact_knn",
    "QueryConfig", "QueryEngine", "QueryRequest",
    "ShardedDescent", "ShardPlan", "plan_shards",
]
