"""FRH routing: place unseen profiles into the build-time clusters.

A query profile is hashed with the *same* ``fmix32`` min-hash machinery
(and the same per-configuration seeds) the build used, yielding its
ascending distinct-hash sequence per configuration — exactly the values
that drove recursive splitting (core/splitting.py). A cluster's identity
is its split path (η₁..η_d) = the shared distinct-hash *prefix* of its
members, so routing is a longest-prefix match of the query's sequence
against the index's path table. Seed candidates are gathered from the
deepest matching cluster first, then its ancestors ("stayers" remain in
parent clusters per §II-D), up to a per-configuration cap.
"""
from __future__ import annotations

import numpy as np

from repro.core import hashing
from repro.query.index import KNNIndex
from repro.sketch.goldfinger import GoldFinger, fingerprint_dataset
from repro.types import PAD_ID, Dataset


def profiles_to_csr(profiles) -> tuple[np.ndarray, np.ndarray]:
    """List of item-id iterables → (items int32[nnz], offsets int64[q+1])."""
    rows = [np.unique(np.asarray(list(p), dtype=np.int32)) for p in profiles]
    sizes = np.array([len(r) for r in rows], dtype=np.int64)
    offsets = np.zeros(len(rows) + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    items = (np.concatenate(rows) if rows
             else np.zeros((0,), np.int32)).astype(np.int32)
    return items, offsets


def fingerprint_profiles(items: np.ndarray, offsets: np.ndarray,
                         n_bits: int, seed: int) -> GoldFinger:
    """GoldFinger fingerprints for query profiles (same hash as the build)."""
    n_items = int(items.max()) + 1 if len(items) else 1
    ds = Dataset(name="queries", n_users=len(offsets) - 1, n_items=n_items,
                 items=items, offsets=offsets)
    return fingerprint_dataset(ds, n_bits=n_bits, seed=seed)


def routed_queries(index: KNNIndex, profiles,
                   seeds_per_config: int = 16):
    """Marshal raw profiles into a routed wave.

    Returns host arrays (q_words uint32[q, W], q_card int32[q],
    seeds int32[q, t·seeds_per_config]) — the unpadded inputs
    ``descent_init``/``descent_step`` take. The engine layers its own
    capacity padding on top; benchmarks drive the descent with these
    directly.
    """
    items, offsets = profiles_to_csr(profiles)
    qgf = fingerprint_profiles(items, offsets, index.n_bits, index.fp_seed)
    seeds = route(index, items, offsets, seeds_per_config)
    return np.asarray(qgf.words), np.asarray(qgf.card), seeds


def query_hash_tables(index: KNNIndex, items: np.ndarray,
                      offsets: np.ndarray) -> np.ndarray:
    """Ascending distinct FRH values per (config, query): int32[t, q, depth]."""
    item_h = hashing.item_hashes(items, index.hash_seeds, index.b)
    return hashing.user_distinct_hashes_np(item_h, offsets, index.split_depth)


def _matches_for(lut: dict, cfg: int, cands_row: np.ndarray) -> list[int]:
    """Cluster indices matching a query's hash prefix, deepest-first."""
    found: list[int] = []
    path: tuple[int, ...] = ()
    for h in cands_row:
        if h == hashing.NO_HASH:
            break
        path = path + (int(h),)
        ci = lut.get((cfg, path))
        if ci is not None:
            found.append(ci)
    found.reverse()
    return found


def placements(index: KNNIndex, items: np.ndarray,
               offsets: np.ndarray) -> list[list[list[int]]]:
    """Per query, per config: matched cluster indices (deepest-first)."""
    cands = query_hash_tables(index, items, offsets)  # [t, q, depth]
    lut = index.path_lut()
    q = len(offsets) - 1
    return [[_matches_for(lut, cfg, cands[cfg, qi])
             for cfg in range(index.t)] for qi in range(q)]


def route(index: KNNIndex, items: np.ndarray, offsets: np.ndarray,
          seeds_per_config: int = 16,
          placed: list[list[list[int]]] | None = None) -> np.ndarray:
    """Seed candidate ids per query: int32[q, t · seeds_per_config].

    Unmatched (config, query) slots are PAD_ID-padded; a query that no
    configuration can place (all its item hashes unseen at depth 1)
    falls back to an id-strided sample of the indexed users so descent
    always has a non-empty frontier. Pass ``placed`` (from
    :func:`placements`) to reuse already-computed hash placements.

    Tombstoned users never seed: cluster membership is append-only (the
    sharded placement's residency monotonicity depends on it), so
    "router deregistration" of a removed user happens here — dead
    members are filtered out of every candidate list, and the
    routing-miss fallback samples live rows only.
    """
    cap = seeds_per_config
    q = len(offsets) - 1
    tomb = index.tombstone
    out = np.full((q, index.t * cap), PAD_ID, dtype=np.int32)
    if placed is None:
        placed = placements(index, items, offsets)
    alive = None
    for qi, per_cfg in enumerate(placed):
        for cfg, matched in enumerate(per_cfg):
            col = cfg * cap
            room = cap
            for ci in matched:
                if room <= 0:
                    break
                mem = index.cluster_users(ci)
                mem = mem[~tomb[mem]][:room]
                out[qi, col:col + len(mem)] = mem
                col += len(mem)
                room -= len(mem)
        if (out[qi] == PAD_ID).all():  # total routing miss
            if alive is None:
                alive = index.alive_ids()
            take = np.linspace(0, len(alive) - 1,
                               num=min(cap, len(alive)), dtype=np.int64)
            fill = alive[take].astype(np.int32)
            out[qi, : len(fill)] = fill
    return out
