"""The servable KNN index artifact (build output → query input).

A :class:`KNNIndex` bundles everything the online query path needs:

* the merged C² :class:`~repro.types.KNNGraph` (forward adjacency),
* the GoldFinger fingerprints of every indexed user (similarity scoring),
* the FastRandomHash routing tables — per-configuration hash seeds plus
  the split-path → cluster-members mapping of the build-time
  :class:`~repro.core.clustering.ClusterPlan` — so an unseen profile can
  be placed in *its* cluster per configuration without touching the
  dataset (repro/query/router.py),
* the reverse adjacency (KNN graphs are directed; descent that follows
  forward edges only can strand a query in a sink region — cf. the
  friend-of-a-friend principle of NNDescent/Hyrec).

The artifact is a single ``.npz``: ``launch/knn_build --index-out`` emits
it, ``launch/knn_serve --index`` loads it. Online insertion
(:meth:`KNNIndex.append_user`) mutates the host arrays and bumps
``version`` so engines know to refresh device copies.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from repro.core.clustering import ClusterPlan, build_plan, frh_seeds
from repro.core.hashing import NO_HASH
from repro.core.local_knn import local_knn
from repro.core.merge import merge_partial
from repro.core.params import C2Params
from repro.knn.greedy import reverse_neighbors_np
from repro.sketch.goldfinger import GoldFinger, fingerprint_dataset
from repro.types import NEG_INF, PAD_ID, Dataset, KNNGraph

_META = ("b", "n_bits", "fp_seed", "split_depth", "version")


@dataclasses.dataclass
class KNNIndex:
    """A built C² graph packaged for online query serving."""

    # Graph + similarity state.
    graph_ids: np.ndarray        # int32[n, k]   forward neighbors
    graph_sims: np.ndarray       # float32[n, k] estimated Jaccard sims
    words: np.ndarray            # uint32[n, W]  GoldFinger fingerprints
    card: np.ndarray             # int32[n]      fingerprint popcounts
    rev_ids: np.ndarray          # int32[n, r]   reverse neighbors (capped)
    # FRH routing tables.
    hash_seeds: np.ndarray       # int32[t]      per-configuration seeds
    cluster_paths: np.ndarray    # int32[c, depth] split paths, NO_HASH pad
    cluster_config: np.ndarray   # int32[c]      hash configuration index
    cluster_members: np.ndarray  # int32[Σ|C|]   member CSR values
    cluster_offsets: np.ndarray  # int64[c + 1]  member CSR offsets
    # Hashing metadata (must match the build).
    b: int                       # FRH range
    n_bits: int                  # GoldFinger width
    fp_seed: int                 # fingerprint seed
    split_depth: int             # distinct-hash depth of the split tables
    version: int = 0             # bumped on mutation (engine cache key)

    def __post_init__(self):
        self._lut: dict | None = None
        # Members appended online, per cluster index (consolidated into
        # the CSR on save).
        self._extra_members: dict[int, list[int]] = {}

    # -- shape accessors ---------------------------------------------------

    @property
    def n(self) -> int:
        return self.graph_ids.shape[0]

    @property
    def k(self) -> int:
        return self.graph_ids.shape[1]

    @property
    def t(self) -> int:
        return len(self.hash_seeds)

    @property
    def n_clusters(self) -> int:
        return len(self.cluster_config)

    @property
    def gf(self) -> GoldFinger:
        return GoldFinger(words=self.words, card=self.card)

    @property
    def graph(self) -> KNNGraph:
        return KNNGraph(ids=self.graph_ids, sims=self.graph_sims)

    # -- routing tables ----------------------------------------------------

    def path_lut(self) -> dict:
        """(config, split-path tuple) → cluster index."""
        if self._lut is None:
            lut = {}
            for ci in range(self.n_clusters):
                path = tuple(int(h) for h in self.cluster_paths[ci]
                             if h != NO_HASH)
                lut[(int(self.cluster_config[ci]), path)] = ci
            self._lut = lut
        return self._lut

    def cluster_users(self, ci: int) -> np.ndarray:
        """Members of cluster ``ci``, including users inserted online."""
        base = self.cluster_members[
            self.cluster_offsets[ci]:self.cluster_offsets[ci + 1]]
        extra = self._extra_members.get(ci)
        if not extra:
            return base
        return np.concatenate([base, np.asarray(extra, dtype=np.int32)])

    def add_cluster_member(self, ci: int, user: int):
        self._extra_members.setdefault(ci, []).append(int(user))

    # -- online insertion --------------------------------------------------

    def append_user(self, words_row: np.ndarray, card_row: int,
                    nbr_ids: np.ndarray, nbr_sims: np.ndarray) -> int:
        """Append one user and link it into the graph.

        ``nbr_ids``/``nbr_sims`` are the user's search result (its forward
        edges, ≤ k entries, PAD_ID allowed). The reverse patch applies the
        paper's bounded-heap semantics to each neighbor: the new user
        displaces the neighbor's worst edge iff it is closer (or the
        neighborhood has a free slot). Arrays are reallocated per insert —
        fine at demo scale; amortized growth is a serving-scale follow-up.
        """
        u = self.n
        k, r = self.k, self.rev_ids.shape[1]
        row_ids = np.full(k, PAD_ID, dtype=np.int32)
        row_sims = np.full(k, NEG_INF, dtype=np.float32)
        valid = np.flatnonzero(np.asarray(nbr_ids) != PAD_ID)[:k]
        order = valid[np.argsort(-np.asarray(nbr_sims, dtype=np.float32)[valid],
                                 kind="stable")]
        row_ids[: len(order)] = np.asarray(nbr_ids)[order]
        row_sims[: len(order)] = np.asarray(nbr_sims)[order]

        self.words = np.concatenate(
            [self.words, np.asarray(words_row, np.uint32)[None]])
        self.card = np.concatenate(
            [self.card, np.asarray([card_row], np.int32)])
        self.graph_ids = np.concatenate([self.graph_ids, row_ids[None]])
        self.graph_sims = np.concatenate([self.graph_sims, row_sims[None]])

        rev_row = np.full(r, PAD_ID, dtype=np.int32)
        n_rev = 0
        for v, s in zip(row_ids, row_sims):
            if v == PAD_ID:
                break
            v = int(v)
            # u → v exists, so u joins rev(v) (replace the tail if full).
            free = np.flatnonzero(self.rev_ids[v] == PAD_ID)
            self.rev_ids[v, free[0] if len(free) else r - 1] = u
            # Bounded-heap insert of u into v's forward neighborhood.
            eff = np.where(self.graph_ids[v] == PAD_ID, NEG_INF,
                           self.graph_sims[v])
            j = int(np.argmin(eff))
            if s > eff[j]:
                self.graph_ids[v, j] = u
                self.graph_sims[v, j] = s
                o = np.argsort(-self.graph_sims[v], kind="stable")
                self.graph_ids[v] = self.graph_ids[v, o]
                self.graph_sims[v] = self.graph_sims[v, o]
                if n_rev < r:  # v → u now exists, so v joins rev(u)
                    rev_row[n_rev] = v
                    n_rev += 1
        self.rev_ids = np.concatenate([self.rev_ids, rev_row[None]])
        self.version += 1
        return u

    # -- persistence -------------------------------------------------------

    def consolidate(self):
        """Fold online-inserted members into the cluster CSR."""
        if not self._extra_members:
            return
        members = [self.cluster_users(ci) for ci in range(self.n_clusters)]
        self.cluster_members = (
            np.concatenate(members) if members
            else np.zeros((0,), np.int32)).astype(np.int32)
        sizes = np.array([len(m) for m in members], dtype=np.int64)
        self.cluster_offsets = np.zeros(self.n_clusters + 1, dtype=np.int64)
        np.cumsum(sizes, out=self.cluster_offsets[1:])
        self._extra_members = {}

    def save(self, path: str | Path):
        self.consolidate()
        arrays = {f.name: getattr(self, f.name)
                  for f in dataclasses.fields(self) if f.name not in _META}
        meta = {name: np.int64(getattr(self, name)) for name in _META}
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez(path, **arrays, **meta)

    @classmethod
    def load(cls, path: str | Path) -> "KNNIndex":
        z = np.load(path)
        kw = {name: z[name] for name in z.files if name not in _META}
        kw.update({name: int(z[name]) for name in _META})
        return cls(**kw)


def build_index(ds: Dataset, params: C2Params | None = None, *,
                gf: GoldFinger | None = None,
                plan: ClusterPlan | None = None,
                graph: KNNGraph | None = None) -> KNNIndex:
    """Package a built C² graph (or build one) into a servable index.

    Pass ``graph``/``plan``/``gf`` from an existing build (e.g.
    ``launch/knn_build.build``) to avoid recomputation; whatever is
    missing is computed here with ``params``.
    """
    params = params or C2Params()
    if gf is None:
        gf = fingerprint_dataset(ds, n_bits=params.n_bits, seed=params.seed)
    if plan is None:
        plan = build_plan(ds, params)
    assert plan.paths is not None, "plan must retain split paths for routing"
    if graph is None:
        ids, sims = local_knn(plan, gf, params)
        graph = merge_partial(ids, sims, params.k)

    depth = params.split_depth
    paths = np.full((plan.n_clusters, depth), NO_HASH, dtype=np.int32)
    for ci, p in enumerate(plan.paths):
        paths[ci, : len(p)] = p[:depth]
    sizes = plan.sizes
    offsets = np.zeros(plan.n_clusters + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    members = (np.concatenate(plan.members) if plan.members
               else np.zeros((0,), np.int32)).astype(np.int32)

    return KNNIndex(
        graph_ids=np.ascontiguousarray(graph.ids, dtype=np.int32),
        graph_sims=np.ascontiguousarray(graph.sims, dtype=np.float32),
        words=np.asarray(gf.words, dtype=np.uint32),
        card=np.asarray(gf.card, dtype=np.int32),
        rev_ids=reverse_neighbors_np(np.asarray(graph.ids), r_max=graph.k),
        hash_seeds=frh_seeds(params),
        cluster_paths=paths,
        cluster_config=plan.config_of.astype(np.int32),
        cluster_members=members,
        cluster_offsets=offsets,
        b=params.b,
        n_bits=gf.n_bits,
        fp_seed=params.seed,
        split_depth=depth,
    )
