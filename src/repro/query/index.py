"""The servable KNN index artifact (build output → query input).

A :class:`KNNIndex` bundles everything the online query path needs:

* the merged C² :class:`~repro.types.KNNGraph` (forward adjacency),
* the GoldFinger fingerprints of every indexed user (similarity scoring),
* the FastRandomHash routing tables — per-configuration hash seeds plus
  the split-path → cluster-members mapping of the build-time
  :class:`~repro.core.clustering.ClusterPlan` — so an unseen profile can
  be placed in *its* cluster per configuration without touching the
  dataset (repro/query/router.py),
* the reverse adjacency (KNN graphs are directed; descent that follows
  forward edges only can strand a query in a sink region — cf. the
  friend-of-a-friend principle of NNDescent/Hyrec).

The artifact is a single ``.npz``: ``launch/knn_build --index-out`` emits
it, ``launch/knn_serve --index`` loads it.

Online growth: per-row state lives in capacity buffers with spare rows
(geometric doubling, à la Debatty et al.'s online graph building), so
:meth:`KNNIndex.append_user` is O(degree) — it writes one row and patches
the neighbors' rows in place; the only reallocation is the doubling
itself, amortized O(1) per insert. The public array attributes
(``graph_ids`` …) are views of the first ``n`` rows, so readers never see
the spare capacity. :meth:`refresh_cohort` re-runs C² clustering
(recursive FRH splitting) on an inserted cohort to register new routable
clusters once enough users accumulated online.

Lifecycle (repro/lifecycle/): beyond append, rows can be *removed*
(:meth:`remove_user` — tombstone + best-effort edge patching; the
tombstone mask, threaded through descent, is what guarantees a dead id
never reaches a result) and *updated* (:meth:`swap_profile` re-sketches
the fingerprint and re-scores incident edges; :meth:`relink_user`
replaces the forward row from a fresh localized search). Removed rows
join a free list and are reused by later appends, so a churning index
does not grow without bound. Cluster membership stays append-only even
through deletes — the sharded placement's residency monotonicity
depends on it — so "deregistration" happens at seed time: the router
filters tombstoned members out of every candidate list. Deletions get
their own journal (mirroring the row/membership journals) so sharded
device state reshards incrementally through deletes, and all three
journals *compact* (merge old entries into a superset entry stamped at
the drop boundary) rather than truncate, so long-running engines keep
delta-syncing instead of periodically rematerializing shard tensors.
"""
from __future__ import annotations

import heapq
from pathlib import Path

import numpy as np

from repro.core import hashing
from repro.core.clustering import ClusterPlan, build_plan, frh_seeds
from repro.core.hashing import NO_HASH
from repro.core.local_knn import local_knn
from repro.core.merge import merge_partial
from repro.core.params import C2Params
from repro.core.splitting import split_config
from repro.knn.greedy import reverse_neighbors_np
from repro.sketch.goldfinger import (GoldFinger, fingerprint_dataset,
                                     popcount_rows)
from repro.types import NEG_INF, PAD_ID, Dataset, KNNGraph

_ROWS = ("graph_ids", "graph_sims", "words", "card", "rev_ids",
         "tombstone", "last_touch")
_TABLES = ("hash_seeds", "cluster_paths", "cluster_config",
           "cluster_members", "cluster_offsets")
_META = ("b", "n_bits", "fp_seed", "split_depth", "version")

_ROW_DTYPES = {"graph_ids": np.int32, "graph_sims": np.float32,
               "words": np.uint32, "card": np.int32, "rev_ids": np.int32,
               "tombstone": np.bool_, "last_touch": np.int64}
_ROW_FILL = {"graph_ids": PAD_ID, "graph_sims": NEG_INF, "words": 0,
             "card": 0, "rev_ids": PAD_ID, "tombstone": False,
             "last_touch": 0}


class KNNIndex:
    """A built C² graph packaged for online query serving.

    Row-indexed arrays (one row per user) are stored in over-allocated
    buffers; ``index.graph_ids`` etc. are length-``n`` views.
    """

    # Journal bounds. When a journal overflows its cap, the oldest half is
    # *compacted* — merged into one superset entry stamped at the drop
    # boundary's version — so the journal keeps reaching back to its
    # original base (readers synced anywhere above it replay a superset of
    # what they missed; every consumer scatters/unions current values, so
    # superset replay is idempotent). Only when the merged entry itself
    # would exceed _LOG_MERGE_MAX rows does the trim fall back to dropping
    # and advancing the base (readers below it must fully resync).
    _ROW_LOG_CAP = 2048
    _MEMBER_LOG_CAP = 8192
    _TOMB_LOG_CAP = 2048
    _LOG_MERGE_MAX = 4096

    def __init__(self, *, graph_ids, graph_sims, words, card, rev_ids,
                 hash_seeds, cluster_paths, cluster_config, cluster_members,
                 cluster_offsets, b, n_bits, fp_seed, split_depth,
                 version: int = 0, tombstone=None, last_touch=None):
        self._n = int(np.asarray(graph_ids).shape[0])
        self._bufs: dict[str, np.ndarray] = {}
        row_args = {"graph_ids": graph_ids, "graph_sims": graph_sims,
                    "words": words, "card": card, "rev_ids": rev_ids,
                    "tombstone": tombstone, "last_touch": last_touch}
        for name in _ROWS:
            arr = row_args[name]
            if arr is None:  # pre-lifecycle artifact: all rows live/untouched
                arr = np.full((self._n,), _ROW_FILL[name],
                              dtype=_ROW_DTYPES[name])
            buf = np.ascontiguousarray(arr, _ROW_DTYPES[name])
            if not buf.flags.writeable:  # jax-derived arrays alias read-only
                buf = buf.copy()
            self._bufs[name] = buf
        # FRH routing tables.
        self.hash_seeds = np.asarray(hash_seeds, dtype=np.int32)
        self.cluster_paths = np.asarray(cluster_paths, dtype=np.int32)
        self.cluster_config = np.asarray(cluster_config, dtype=np.int32)
        self.cluster_members = np.asarray(cluster_members, dtype=np.int32)
        self.cluster_offsets = np.asarray(cluster_offsets, dtype=np.int64)
        # Hashing metadata (must match the build).
        self.b = int(b)
        self.n_bits = int(n_bits)
        self.fp_seed = int(fp_seed)
        self.split_depth = int(split_depth)
        self.version = int(version)  # bumped on mutation (engine cache key)
        self._lut: dict | None = None
        # Optional write-ahead log (repro/faults/wal.py). When attached,
        # every public mutator records (op, args) BEFORE applying, so a
        # crash between scheduler steps can replay the suffix onto the
        # last snapshot and land bitwise where the live index was.
        self._wal = None
        # Members appended online, per cluster index (consolidated into
        # the CSR on save / refresh_cohort).
        self._extra_members: dict[int, list[int]] = {}
        # Journal of row mutations: (version, touched rows) per append,
        # so engines can update device copies incrementally instead of
        # re-uploading the whole index per insert.
        self._row_log: list[tuple[int, tuple[int, ...]]] = []
        self._row_log_base = self.version
        # Journal of cluster-membership additions: (version, cluster, uid)
        # per registration — the membership counterpart of the row journal,
        # consumed by the sharded placement's delta reshard
        # (query/sharded.py) to grow per-shard resident sets without
        # re-deriving the whole plan. Membership is append-only, so the
        # journal fully determines residency growth.
        self._member_log: list[tuple[int, int, int]] = []
        # Readers replay entries >= their synced version (see
        # members_added_since), so the reachability floor sits one BELOW
        # the current version — unlike the row journal, whose replay is
        # strictly >. After a trim the floor is the last dropped entry's
        # version itself: entries logged AT that version may be split
        # across the drop boundary, so readers synced there must resync.
        self._member_log_base = self.version - 1
        # Deletion journal: (version, rows whose liveness flipped) — a
        # remove_user tombstones a row, a free-list reuse resurrects it.
        # Consumers scatter the row's *current* tombstone value, so
        # replaying a superset (after compaction) is idempotent.
        self._tomb_log: list[tuple[int, tuple[int, ...]]] = []
        self._tomb_log_base = self.version
        # Free list of tombstoned rows, reused lowest-id-first by
        # append_user. Rebuilt from the tombstone column on load. A reused
        # row keeps its old cluster memberships (membership is append-only)
        # — stale residency only adds seed candidates, it cannot surface a
        # wrong result; refresh_cohort registers the new profile properly.
        self._free_rows: list[int] = [
            int(i) for i in np.flatnonzero(self._bufs["tombstone"][:self._n])]
        heapq.heapify(self._free_rows)

    # -- row buffers (views over spare capacity) ---------------------------

    def __getattr__(self, name):
        bufs = self.__dict__.get("_bufs")
        if bufs is not None and name in bufs:
            return bufs[name][: self.__dict__["_n"]]
        raise AttributeError(name)

    @property
    def capacity(self) -> int:
        """Allocated user rows (≥ n; grows by doubling, never per insert)."""
        return self._bufs["graph_ids"].shape[0]

    def _ensure_capacity(self, n_needed: int):
        cap = self.capacity
        if n_needed <= cap:
            return
        new_cap = max(cap, 64)
        while new_cap < n_needed:
            new_cap *= 2
        for name, buf in self._bufs.items():
            grown = np.full((new_cap,) + buf.shape[1:], _ROW_FILL[name],
                            dtype=buf.dtype)
            grown[: self._n] = buf[: self._n]
            self._bufs[name] = grown

    # -- shape accessors ---------------------------------------------------

    @property
    def n(self) -> int:
        return self._n

    @property
    def n_live(self) -> int:
        """Rows that are not tombstoned (n counts dead rows too)."""
        return self._n - int(self._bufs["tombstone"][: self._n].sum())

    def alive_ids(self) -> np.ndarray:
        """int64 ids of live rows, ascending."""
        return np.flatnonzero(~self.tombstone)

    @property
    def k(self) -> int:
        return self._bufs["graph_ids"].shape[1]

    @property
    def t(self) -> int:
        return len(self.hash_seeds)

    @property
    def n_clusters(self) -> int:
        return len(self.cluster_config)

    @property
    def row_bytes(self) -> int:
        """Serving bytes one resident row costs a shard: adjacency +
        reverse adjacency + fingerprint words (all int32/uint32) + card
        + local→global id + tombstone flag. Tiered residency
        (``plan_shards(resident_configs=)``) and the bench's residency
        sweep price per-shard memory with this."""
        kg = self._bufs["graph_ids"].shape[1]
        kr = self._bufs["rev_ids"].shape[1]
        w = self._bufs["words"].shape[1]
        return 4 * (kg + kr + w) + 4 + 4 + 1

    @property
    def gf(self) -> GoldFinger:
        return GoldFinger(words=self.words, card=self.card)

    @property
    def graph(self) -> KNNGraph:
        return KNNGraph(ids=self.graph_ids, sims=self.graph_sims)

    # -- routing tables ----------------------------------------------------

    def path_lut(self) -> dict:
        """(config, split-path tuple) → cluster index."""
        if self._lut is None:
            lut = {}
            for ci in range(self.n_clusters):
                path = tuple(int(h) for h in self.cluster_paths[ci]
                             if h != NO_HASH)
                lut[(int(self.cluster_config[ci]), path)] = ci
            self._lut = lut
        return self._lut

    def cluster_users(self, ci: int) -> np.ndarray:
        """Members of cluster ``ci``, including users inserted online."""
        base = self.cluster_members[
            self.cluster_offsets[ci]:self.cluster_offsets[ci + 1]]
        extra = self._extra_members.get(ci)
        if not extra:
            return base
        return np.concatenate([base, np.asarray(extra, dtype=np.int32)])

    def cluster_sizes(self) -> np.ndarray:
        """int64[n_clusters] member counts, online extras included."""
        sizes = np.diff(self.cluster_offsets)
        for ci, extra in self._extra_members.items():
            sizes[ci] += len(extra)
        return sizes

    def attach_wal(self, wal) -> None:
        """Start write-ahead logging every mutation into ``wal`` (an
        object with ``record(op, **args)`` — see repro/faults/wal.py)."""
        self._wal = wal

    def detach_wal(self):
        """Stop logging; returns the detached WAL (or None)."""
        wal, self._wal = self._wal, None
        return wal

    def add_cluster_member(self, ci: int, user: int):
        if self._wal is not None:
            self._wal.record("add_cluster_member", ci=int(ci),
                             user=int(user))
        self._extra_members.setdefault(ci, []).append(int(user))
        self._log_member(ci, user)

    def _log_member(self, ci: int, user: int):
        self._member_log.append((self.version, int(ci), int(user)))
        if len(self._member_log) > self._MEMBER_LOG_CAP:
            half = self._MEMBER_LOG_CAP // 2
            drop, keep = self._member_log[:half], self._member_log[half:]
            boundary = drop[-1][0]
            # Compact: re-stamp the dropped registrations at the boundary
            # version, deduplicated but order-preserving — readers synced
            # below the boundary replay them as a superset in the original
            # order (union is idempotent; order fixes residency layout).
            seen: set[tuple[int, int]] = set()
            merged: list[tuple[int, int, int]] = []
            for _, mci, mu in drop:
                if (mci, mu) not in seen:
                    seen.add((mci, mu))
                    merged.append((boundary, mci, mu))
            if len(merged) <= self._LOG_MERGE_MAX:
                self._member_log = merged + keep
            else:  # merged entry too big: drop and advance the floor
                self._member_log = keep
                self._member_log_base = boundary

    def members_added_since(self, version: int
                            ) -> list[tuple[int, int]] | None:
        """(cluster, uid) registrations after ``version`` in order, or
        None when the membership journal no longer reaches back that far
        (caller must re-derive residency from the full cluster tables).

        Entries logged at exactly ``version`` are included: membership
        registration does not bump :attr:`version` by itself (the row
        append or cohort refresh around it does), so a reader synced to
        version v has seen the rows of v but not members logged *at* v
        afterwards. Registrations always precede or accompany a version
        bump, so replaying ``> version - 1`` never misses one and the
        (idempotent) union absorbs any replayed duplicates. The trimmed
        floor is accordingly inclusive: a trim can split the entries of
        its boundary version, so readers synced at (or below) it resync.
        """
        if version <= self._member_log_base:
            return None
        return [(ci, u) for v, ci, u in self._member_log if v >= version]

    # -- online insertion --------------------------------------------------

    def append_user(self, words_row: np.ndarray, card_row: int,
                    nbr_ids: np.ndarray, nbr_sims: np.ndarray) -> int:
        """Append one user and link it into the graph.

        ``nbr_ids``/``nbr_sims`` are the user's search result (its forward
        edges, ≤ k entries, PAD_ID allowed). The reverse patch applies the
        paper's bounded-heap semantics to each neighbor: the new user
        displaces the neighbor's worst edge iff it is closer (or the
        neighborhood has a free slot). O(degree): one row write plus one
        in-place patch per neighbor — the backing buffers only reallocate
        on geometric-doubling boundaries.

        Tombstoned rows are recycled lowest-id-first: the returned id may
        be a previously removed user's row (its liveness flip rides the
        deletion journal so synced device masks follow).
        """
        if self._wal is not None:
            self._wal.record("append_user", words_row=words_row,
                             card_row=card_row, nbr_ids=nbr_ids,
                             nbr_sims=nbr_sims)
        reused = bool(self._free_rows)
        if reused:
            u = heapq.heappop(self._free_rows)
        else:
            u = self._n
            self._ensure_capacity(u + 1)
        bufs = self._bufs
        k, r = self.k, bufs["rev_ids"].shape[1]
        row_ids = np.full(k, PAD_ID, dtype=np.int32)
        row_sims = np.full(k, NEG_INF, dtype=np.float32)
        valid = np.flatnonzero(np.asarray(nbr_ids) != PAD_ID)[:k]
        order = valid[np.argsort(-np.asarray(nbr_sims, dtype=np.float32)[valid],
                                 kind="stable")]
        row_ids[: len(order)] = np.asarray(nbr_ids)[order]
        row_sims[: len(order)] = np.asarray(nbr_sims)[order]

        bufs["words"][u] = np.asarray(words_row, np.uint32)
        bufs["card"][u] = card_row
        bufs["graph_ids"][u] = row_ids
        bufs["graph_sims"][u] = row_sims

        graph_ids, graph_sims = bufs["graph_ids"], bufs["graph_sims"]
        rev_ids = bufs["rev_ids"]
        rev_row = np.full(r, PAD_ID, dtype=np.int32)
        n_rev = 0
        for v, s in zip(row_ids, row_sims):
            if v == PAD_ID:
                break
            v = int(v)
            # u → v exists, so u joins rev(v) (replace the tail if full).
            free = np.flatnonzero(rev_ids[v] == PAD_ID)
            rev_ids[v, free[0] if len(free) else r - 1] = u
            # Bounded-heap insert of u into v's forward neighborhood.
            eff = np.where(graph_ids[v] == PAD_ID, NEG_INF, graph_sims[v])
            j = int(np.argmin(eff))
            if s > eff[j]:
                graph_ids[v, j] = u
                graph_sims[v, j] = s
                o = np.argsort(-graph_sims[v], kind="stable")
                graph_ids[v] = graph_ids[v, o]
                graph_sims[v] = graph_sims[v, o]
                if n_rev < r:  # v → u now exists, so v joins rev(u)
                    rev_row[n_rev] = v
                    n_rev += 1
        rev_ids[u] = rev_row
        bufs["tombstone"][u] = False
        bufs["last_touch"][u] = 0
        if not reused:
            self._n = u + 1
        self.version += 1
        touched = (u,) + tuple(int(v) for v in row_ids if v != PAD_ID)
        self._journal_rows(touched)
        if reused:
            self._journal_tomb((u,))
        return u

    def _journal_rows(self, touched: tuple[int, ...]):
        self._row_log.append((self.version, tuple(touched)))
        if len(self._row_log) > self._ROW_LOG_CAP:
            self._row_log, self._row_log_base = self._compact_touched_log(
                self._row_log, self._ROW_LOG_CAP // 2, self._row_log_base)

    def _journal_tomb(self, rows: tuple[int, ...]):
        self._tomb_log.append((self.version, tuple(rows)))
        if len(self._tomb_log) > self._TOMB_LOG_CAP:
            self._tomb_log, self._tomb_log_base = self._compact_touched_log(
                self._tomb_log, self._TOMB_LOG_CAP // 2, self._tomb_log_base)

    def _compact_touched_log(self, log, half, base):
        """Shared trim for the (version, rows) journals: merge the oldest
        half into one superset entry stamped at the drop boundary, keeping
        the base (see class docstring on journal bounds); fall back to a
        base-advancing drop when the merged entry would be oversized."""
        drop, keep = log[:half], log[half:]
        boundary = drop[-1][0]
        merged: set[int] = set()
        for _, rows in drop:
            merged.update(rows)
        if len(merged) <= self._LOG_MERGE_MAX:
            return [(boundary, tuple(sorted(merged)))] + keep, base
        return keep, boundary

    def rows_changed_since(self, version: int) -> set[int] | None:
        """Row indices mutated after ``version``, or None when the
        journal no longer reaches back that far (caller must resync)."""
        if version < self._row_log_base:
            return None
        rows: set[int] = set()
        for v, touched in reversed(self._row_log):
            if v <= version:
                break
            rows.update(touched)
        return rows

    def tombstones_since(self, version: int) -> set[int] | None:
        """Rows whose liveness flipped after ``version`` (removal or
        free-row reuse), or None when the deletion journal no longer
        reaches back (caller re-derives the mask from :attr:`tombstone`).
        Consumers scatter each row's *current* tombstone value, so the
        superset replay a compacted journal produces is idempotent."""
        if version < self._tomb_log_base:
            return None
        rows: set[int] = set()
        for v, rs in reversed(self._tomb_log):
            if v <= version:
                break
            rows.update(rs)
        return rows

    # -- lifecycle mutations (repro/lifecycle drives these) ----------------

    def _check_live(self, u: int) -> int:
        u = int(u)
        if not 0 <= u < self._n:
            raise IndexError(f"user {u} out of range [0, {self._n})")
        if self._bufs["tombstone"][u]:
            raise ValueError(f"user {u} is tombstoned")
        return u

    def _pair_sim(self, a: int, b: int) -> np.float32:
        """Host GoldFinger Jaccard estimate, same f32 epilogue as the
        device scorers (goldfinger.jaccard_pairwise) so host-written edge
        sims are bit-compatible with descent-produced ones."""
        bufs = self._bufs
        inter = np.float32(int(popcount_rows(
            (bufs["words"][a] & bufs["words"][b])[None, :])[0]))
        union = np.float32(bufs["card"][a]) + np.float32(bufs["card"][b]) \
            - inter
        if not union > 0:
            return np.float32(0.0)
        return np.float32(inter / max(union, np.float32(1.0)))

    def _resort_row(self, u: int):
        """Restore row ``u``'s by-similarity order after an in-place lane
        edit (stable, so equal-sim lanes keep their relative order — the
        same discipline as append_user's bounded-heap patch)."""
        bufs = self._bufs
        o = np.argsort(-bufs["graph_sims"][u], kind="stable")
        bufs["graph_ids"][u] = bufs["graph_ids"][u][o]
        bufs["graph_sims"][u] = bufs["graph_sims"][u][o]

    def _drop_from_rev(self, v: int, u: int) -> bool:
        """Remove ``u`` from rev(v), shift-compacting so free lanes stay
        at the tail (where append_user's patch expects them)."""
        rev = self._bufs["rev_ids"]
        keep = rev[v] != u
        if keep.all():
            return False
        row = rev[v][keep]
        rev[v] = PAD_ID
        rev[v, : len(row)] = row
        return True

    def remove_user(self, u: int):
        """Tombstone ``u`` and patch its known incident edges out.

        The reverse table is bounded (tail-replacement drops entries), so
        the patch is best-effort repair, not the correctness mechanism:
        the tombstone mask — threaded through routing and descent — is
        what guarantees a dead id is never seeded, scored, or returned,
        even while stale references linger in unpatched rows. Cluster
        memberships are intentionally kept (residency must stay
        append-only for delta resharding); the router filters dead
        members at seed time. The freed row joins the reuse list.
        """
        if self._wal is not None:
            self._wal.record("remove_user", u=int(u))
        u = self._check_live(u)
        bufs = self._bufs
        graph_ids, graph_sims = bufs["graph_ids"], bufs["graph_sims"]
        touched = {u}
        for w in bufs["rev_ids"][u]:  # u leaves in-neighbors' forward rows
            if w == PAD_ID:
                continue
            w = int(w)
            lanes = graph_ids[w] == u
            if lanes.any():
                graph_ids[w][lanes] = PAD_ID
                graph_sims[w][lanes] = NEG_INF
                self._resort_row(w)
                touched.add(w)
        for v in graph_ids[u]:  # u leaves out-neighbors' reverse rows
            if v == PAD_ID:
                continue
            if self._drop_from_rev(int(v), u):
                touched.add(int(v))
        graph_ids[u] = PAD_ID
        graph_sims[u] = NEG_INF
        bufs["rev_ids"][u] = PAD_ID
        bufs["words"][u] = 0
        bufs["card"][u] = 0
        bufs["tombstone"][u] = True
        bufs["last_touch"][u] = 0
        heapq.heappush(self._free_rows, u)
        self.version += 1
        self._journal_rows(tuple(sorted(touched)))
        self._journal_tomb((u,))

    def swap_profile(self, u: int, words_row: np.ndarray, card_row: int):
        """Replace ``u``'s fingerprint and re-score every edge incident
        to it, keeping stored sims consistent with the sketches. The
        graph *topology* is untouched — pair with :meth:`relink_user`
        (fed by a localized neighbors-of-neighbors descent) to move
        ``u``'s forward edges to its new neighborhood.
        """
        if self._wal is not None:
            self._wal.record("swap_profile", u=int(u), words_row=words_row,
                             card_row=card_row)
        u = self._check_live(u)
        bufs = self._bufs
        bufs["words"][u] = np.asarray(words_row, np.uint32)
        bufs["card"][u] = card_row
        graph_ids, graph_sims = bufs["graph_ids"], bufs["graph_sims"]
        touched = {u}
        for j, v in enumerate(graph_ids[u]):
            if v != PAD_ID:
                graph_sims[u, j] = self._pair_sim(u, int(v))
        self._resort_row(u)
        for w in bufs["rev_ids"][u]:  # in-neighbors' lanes pointing at u
            if w == PAD_ID:
                continue
            w = int(w)
            lanes = graph_ids[w] == u
            if lanes.any():
                graph_sims[w][lanes] = self._pair_sim(w, u)
                self._resort_row(w)
                touched.add(w)
        self.version += 1
        self._journal_rows(tuple(sorted(touched)))

    def relink_user(self, u: int, nbr_ids: np.ndarray,
                    nbr_sims: np.ndarray):
        """Replace ``u``'s forward row with a fresh search result and
        restore mutuality — the update counterpart of append_user's
        reverse patch. ``nbr_ids``/``nbr_sims`` come from a localized
        descent over ``u``'s (new) fingerprint; ``u`` itself and
        tombstoned ids are dropped defensively.
        """
        if self._wal is not None:
            self._wal.record("relink_user", u=int(u), nbr_ids=nbr_ids,
                             nbr_sims=nbr_sims)
        u = self._check_live(u)
        bufs = self._bufs
        graph_ids, graph_sims = bufs["graph_ids"], bufs["graph_sims"]
        rev_ids = bufs["rev_ids"]
        k, r = self.k, rev_ids.shape[1]
        nbr_ids = np.asarray(nbr_ids)
        nbr_sims = np.asarray(nbr_sims, dtype=np.float32)
        ok = (nbr_ids != PAD_ID) & (nbr_ids != u) \
            & ~bufs["tombstone"][np.clip(nbr_ids, 0, self._n - 1)]
        valid = np.flatnonzero(ok)[:k]
        order = valid[np.argsort(-nbr_sims[valid], kind="stable")]
        row_ids = np.full(k, PAD_ID, dtype=np.int32)
        row_sims = np.full(k, NEG_INF, dtype=np.float32)
        row_ids[: len(order)] = nbr_ids[order]
        row_sims[: len(order)] = nbr_sims[order]

        touched = {u}
        new_set = set(int(v) for v in row_ids if v != PAD_ID)
        for v in graph_ids[u]:  # detach from dropped out-neighbors
            if v == PAD_ID or int(v) in new_set:
                continue
            if self._drop_from_rev(int(v), u):
                touched.add(int(v))
        graph_ids[u] = row_ids
        graph_sims[u] = row_sims
        for v, s in zip(row_ids, row_sims):
            if v == PAD_ID:
                break
            v = int(v)
            touched.add(v)
            if u not in rev_ids[v]:  # u → v now exists
                free = np.flatnonzero(rev_ids[v] == PAD_ID)
                rev_ids[v, free[0] if len(free) else r - 1] = u
            # Mutual bounded-heap insert of u into v's forward row (or a
            # sim refresh when the edge already exists).
            lanes = graph_ids[v] == u
            if lanes.any():
                graph_sims[v][lanes] = s
                self._resort_row(v)
                continue
            eff = np.where(graph_ids[v] == PAD_ID, NEG_INF, graph_sims[v])
            j = int(np.argmin(eff))
            if s > eff[j]:
                graph_ids[v, j] = u
                graph_sims[v, j] = s
                self._resort_row(v)
                if v not in rev_ids[u]:  # v → u now exists
                    free = np.flatnonzero(rev_ids[u] == PAD_ID)
                    rev_ids[u, free[0] if len(free) else r - 1] = v
        self.version += 1
        self._journal_rows(tuple(sorted(touched)))

    def touch_row(self, u: int, clock: int):
        """Stamp ``u``'s TTL clock (host-only state: never shipped to
        device, so no journal entry and no version bump — but it IS
        write-ahead logged, because TTL expiry decisions after recovery
        must match the never-crashed engine's)."""
        if self._wal is not None:
            self._wal.record("touch_row", u=int(u), clock=int(clock))
        self._bufs["last_touch"][self._check_live(u)] = clock

    # -- cohort refresh (amortized re-clustering) --------------------------

    def refresh_cohort(self, items: np.ndarray, offsets: np.ndarray,
                       user_ids: np.ndarray,
                       max_cluster: int | None = None) -> int:
        """Re-run C² clustering on an inserted cohort; returns the number
        of *new* routable clusters registered.

        ``items``/``offsets`` are the cohort profiles in CSR form (one row
        per inserted user, same order as ``user_ids``). The cohort is
        re-hashed with the index's FRH seeds and recursively split exactly
        like the build (core/splitting.py); every resulting cohort cluster
        whose split path already names a build-time cluster folds its
        members into it, and paths unseen at build time become new
        clusters in the routing table — so a drifting insert stream grows
        fresh routable entry points instead of piling onto stale ones.
        """
        user_ids = np.asarray(user_ids, dtype=np.int32)
        if len(user_ids) == 0:
            return 0
        if max_cluster is None:
            base_sizes = np.diff(self.cluster_offsets)
            max_cluster = int(base_sizes.max()) if len(base_sizes) else 64
        # WAL records the *resolved* max_cluster (the default depends on
        # consolidation state, which a snapshot normalizes) and suspends
        # itself for the body: the nested add_cluster_member calls are
        # deterministic consequences of this one record.
        if self._wal is not None:
            self._wal.record("refresh_cohort", items=items, offsets=offsets,
                             user_ids=user_ids, max_cluster=int(max_cluster))
        wal, self._wal = self._wal, None
        try:
            return self._refresh_cohort(items, offsets, user_ids,
                                        max_cluster)
        finally:
            self._wal = wal

    def _refresh_cohort(self, items, offsets, user_ids: np.ndarray,
                        max_cluster: int) -> int:
        item_h = hashing.item_hashes(np.asarray(items, np.int32),
                                     self.hash_seeds, self.b)
        cands = hashing.user_distinct_hashes_np(
            item_h, np.asarray(offsets, np.int64), self.split_depth)
        lut = self.path_lut()
        new_paths: list[tuple[int, tuple[int, ...]]] = []
        new_members: list[np.ndarray] = []
        for cfg in range(self.t):
            res = split_config(cands[cfg], max_cluster)
            for mem, path in zip(res.members, res.paths):
                users = user_ids[mem]
                ci = lut.get((cfg, path))
                if ci is not None:
                    known = set(self.cluster_users(ci).tolist())
                    for u in users:
                        if int(u) not in known:
                            self.add_cluster_member(ci, int(u))
                elif len(users) >= 2:  # singletons yield no routing value
                    new_paths.append((cfg, path))
                    new_members.append(users)
        if new_members:
            base_ci = self.n_clusters
            for i, mem in enumerate(new_members):  # journal new clusters
                for u in mem:
                    self._log_member(base_ci + i, int(u))
            depth = self.cluster_paths.shape[1] if self.n_clusters else \
                self.split_depth
            add_paths = np.full((len(new_paths), depth), NO_HASH,
                                dtype=np.int32)
            for i, (_, p) in enumerate(new_paths):
                add_paths[i, : min(len(p), depth)] = p[:depth]
            self.cluster_paths = (
                np.concatenate([self.cluster_paths, add_paths])
                if self.n_clusters else add_paths)
            self.cluster_config = np.concatenate(
                [self.cluster_config,
                 np.array([c for c, _ in new_paths], dtype=np.int32)])
            self.cluster_members = np.concatenate(
                [self.cluster_members] + new_members).astype(np.int32)
            sizes = np.array([len(m) for m in new_members], dtype=np.int64)
            self.cluster_offsets = np.concatenate(
                [self.cluster_offsets,
                 self.cluster_offsets[-1] + np.cumsum(sizes)])
        self._lut = None
        self.version += 1
        return len(new_members)

    # -- persistence -------------------------------------------------------

    def consolidate(self):
        """Fold online-inserted members into the cluster CSR."""
        if not self._extra_members:
            return
        members = [self.cluster_users(ci) for ci in range(self.n_clusters)]
        self.cluster_members = (
            np.concatenate(members) if members
            else np.zeros((0,), np.int32)).astype(np.int32)
        sizes = np.array([len(m) for m in members], dtype=np.int64)
        self.cluster_offsets = np.zeros(self.n_clusters + 1, dtype=np.int64)
        np.cumsum(sizes, out=self.cluster_offsets[1:])
        self._extra_members = {}
        self._lut = None

    @staticmethod
    def _pack_touched_log(log):
        """(version, rows) journal → (versions, flat rows, offsets)."""
        versions = np.array([v for v, _ in log], dtype=np.int64)
        lengths = np.array([len(rows) for _, rows in log], dtype=np.int64)
        offsets = np.zeros(len(log) + 1, dtype=np.int64)
        np.cumsum(lengths, out=offsets[1:])
        flat = np.array([r for _, rows in log for r in rows],
                        dtype=np.int64)
        return versions, flat, offsets

    def _journal_arrays(self) -> dict:
        """Journal state as savez-able arrays. Persisting the journals
        matters: without them a loaded index starts with empty logs whose
        bases sit at the load-time version, so the first post-load delta
        ``sync()`` silently falls back to full shard rematerialization."""
        rv, rf, ro = self._pack_touched_log(self._row_log)
        tv, tf, to = self._pack_touched_log(self._tomb_log)
        mem = (np.array(self._member_log, dtype=np.int64).reshape(-1, 3)
               if self._member_log else np.zeros((0, 3), dtype=np.int64))
        return {
            "jrn_row_versions": rv, "jrn_row_rows": rf,
            "jrn_row_offsets": ro,
            "jrn_row_base": np.int64(self._row_log_base),
            "jrn_tomb_versions": tv, "jrn_tomb_rows": tf,
            "jrn_tomb_offsets": to,
            "jrn_tomb_base": np.int64(self._tomb_log_base),
            "jrn_members": mem,
            "jrn_member_base": np.int64(self._member_log_base),
        }

    def _restore_journals(self, z) -> None:
        def unpack(versions, flat, offsets):
            return [(int(v), tuple(int(r) for r in flat[offsets[i]:
                                                        offsets[i + 1]]))
                    for i, v in enumerate(versions)]
        self._row_log = unpack(z["jrn_row_versions"], z["jrn_row_rows"],
                               z["jrn_row_offsets"])
        self._row_log_base = int(z["jrn_row_base"])
        self._tomb_log = unpack(z["jrn_tomb_versions"], z["jrn_tomb_rows"],
                                z["jrn_tomb_offsets"])
        self._tomb_log_base = int(z["jrn_tomb_base"])
        self._member_log = [(int(v), int(ci), int(u))
                            for v, ci, u in z["jrn_members"]]
        self._member_log_base = int(z["jrn_member_base"])

    def save(self, path: str | Path):
        self.consolidate()
        arrays = {name: getattr(self, name) for name in _ROWS + _TABLES}
        meta = {name: np.int64(getattr(self, name)) for name in _META}
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez(path, **arrays, **meta, **self._journal_arrays())

    @classmethod
    def load(cls, path: str | Path) -> "KNNIndex":
        z = np.load(path)
        kw = {name: z[name] for name in z.files
              if name not in _META and not name.startswith("jrn_")}
        kw.update({name: int(z[name]) for name in _META})
        ix = cls(**kw)
        if "jrn_row_base" in z.files:  # pre-journal artifacts load fine
            ix._restore_journals(z)
        return ix


def build_index(ds: Dataset, params: C2Params | None = None, *,
                gf: GoldFinger | None = None,
                plan: ClusterPlan | None = None,
                graph: KNNGraph | None = None) -> KNNIndex:
    """Package a built C² graph (or build one) into a servable index.

    Pass ``graph``/``plan``/``gf`` from an existing build (e.g.
    ``launch/knn_build.build``) to avoid recomputation; whatever is
    missing is computed here with ``params``.
    """
    params = params or C2Params()
    if gf is None:
        gf = fingerprint_dataset(ds, n_bits=params.n_bits, seed=params.seed)
    if plan is None:
        plan = build_plan(ds, params)
    assert plan.paths is not None, "plan must retain split paths for routing"
    if graph is None:
        ids, sims = local_knn(plan, gf, params)
        graph = merge_partial(ids, sims, params.k)

    depth = params.split_depth
    paths = np.full((plan.n_clusters, depth), NO_HASH, dtype=np.int32)
    for ci, p in enumerate(plan.paths):
        paths[ci, : len(p)] = p[:depth]
    sizes = plan.sizes
    offsets = np.zeros(plan.n_clusters + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    members = (np.concatenate(plan.members) if plan.members
               else np.zeros((0,), np.int32)).astype(np.int32)

    return KNNIndex(
        graph_ids=np.ascontiguousarray(graph.ids, dtype=np.int32),
        graph_sims=np.ascontiguousarray(graph.sims, dtype=np.float32),
        words=np.asarray(gf.words, dtype=np.uint32),
        card=np.asarray(gf.card, dtype=np.int32),
        rev_ids=reverse_neighbors_np(np.asarray(graph.ids), r_max=graph.k),
        hash_seeds=frh_seeds(params),
        cluster_paths=paths,
        cluster_config=plan.config_of.astype(np.int32),
        cluster_members=members,
        cluster_offsets=offsets,
        b=params.b,
        n_bits=gf.n_bits,
        fp_seed=params.seed,
        split_depth=depth,
    )
