"""The servable KNN index artifact (build output → query input).

A :class:`KNNIndex` bundles everything the online query path needs:

* the merged C² :class:`~repro.types.KNNGraph` (forward adjacency),
* the GoldFinger fingerprints of every indexed user (similarity scoring),
* the FastRandomHash routing tables — per-configuration hash seeds plus
  the split-path → cluster-members mapping of the build-time
  :class:`~repro.core.clustering.ClusterPlan` — so an unseen profile can
  be placed in *its* cluster per configuration without touching the
  dataset (repro/query/router.py),
* the reverse adjacency (KNN graphs are directed; descent that follows
  forward edges only can strand a query in a sink region — cf. the
  friend-of-a-friend principle of NNDescent/Hyrec).

The artifact is a single ``.npz``: ``launch/knn_build --index-out`` emits
it, ``launch/knn_serve --index`` loads it.

Online growth: per-row state lives in capacity buffers with spare rows
(geometric doubling, à la Debatty et al.'s online graph building), so
:meth:`KNNIndex.append_user` is O(degree) — it writes one row and patches
the neighbors' rows in place; the only reallocation is the doubling
itself, amortized O(1) per insert. The public array attributes
(``graph_ids`` …) are views of the first ``n`` rows, so readers never see
the spare capacity. :meth:`refresh_cohort` re-runs C² clustering
(recursive FRH splitting) on an inserted cohort to register new routable
clusters once enough users accumulated online.
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.core import hashing
from repro.core.clustering import ClusterPlan, build_plan, frh_seeds
from repro.core.hashing import NO_HASH
from repro.core.local_knn import local_knn
from repro.core.merge import merge_partial
from repro.core.params import C2Params
from repro.core.splitting import split_config
from repro.knn.greedy import reverse_neighbors_np
from repro.sketch.goldfinger import GoldFinger, fingerprint_dataset
from repro.types import NEG_INF, PAD_ID, Dataset, KNNGraph

_ROWS = ("graph_ids", "graph_sims", "words", "card", "rev_ids")
_TABLES = ("hash_seeds", "cluster_paths", "cluster_config",
           "cluster_members", "cluster_offsets")
_META = ("b", "n_bits", "fp_seed", "split_depth", "version")

_ROW_DTYPES = {"graph_ids": np.int32, "graph_sims": np.float32,
               "words": np.uint32, "card": np.int32, "rev_ids": np.int32}
_ROW_FILL = {"graph_ids": PAD_ID, "graph_sims": NEG_INF, "words": 0,
             "card": 0, "rev_ids": PAD_ID}


class KNNIndex:
    """A built C² graph packaged for online query serving.

    Row-indexed arrays (one row per user) are stored in over-allocated
    buffers; ``index.graph_ids`` etc. are length-``n`` views.
    """

    def __init__(self, *, graph_ids, graph_sims, words, card, rev_ids,
                 hash_seeds, cluster_paths, cluster_config, cluster_members,
                 cluster_offsets, b, n_bits, fp_seed, split_depth,
                 version: int = 0):
        self._n = int(np.asarray(graph_ids).shape[0])
        self._bufs: dict[str, np.ndarray] = {}
        for name, arr in (("graph_ids", graph_ids), ("graph_sims", graph_sims),
                          ("words", words), ("card", card),
                          ("rev_ids", rev_ids)):
            self._bufs[name] = np.ascontiguousarray(arr, _ROW_DTYPES[name])
        # FRH routing tables.
        self.hash_seeds = np.asarray(hash_seeds, dtype=np.int32)
        self.cluster_paths = np.asarray(cluster_paths, dtype=np.int32)
        self.cluster_config = np.asarray(cluster_config, dtype=np.int32)
        self.cluster_members = np.asarray(cluster_members, dtype=np.int32)
        self.cluster_offsets = np.asarray(cluster_offsets, dtype=np.int64)
        # Hashing metadata (must match the build).
        self.b = int(b)
        self.n_bits = int(n_bits)
        self.fp_seed = int(fp_seed)
        self.split_depth = int(split_depth)
        self.version = int(version)  # bumped on mutation (engine cache key)
        self._lut: dict | None = None
        # Members appended online, per cluster index (consolidated into
        # the CSR on save / refresh_cohort).
        self._extra_members: dict[int, list[int]] = {}
        # Journal of row mutations: (version, touched rows) per append,
        # so engines can update device copies incrementally instead of
        # re-uploading the whole index per insert.
        self._row_log: list[tuple[int, tuple[int, ...]]] = []
        self._row_log_base = self.version
        # Journal of cluster-membership additions: (version, cluster, uid)
        # per registration — the membership counterpart of the row journal,
        # consumed by the sharded placement's delta reshard
        # (query/sharded.py) to grow per-shard resident sets without
        # re-deriving the whole plan. Membership is append-only, so the
        # journal fully determines residency growth.
        self._member_log: list[tuple[int, int, int]] = []
        # Readers replay entries >= their synced version (see
        # members_added_since), so the reachability floor sits one BELOW
        # the current version — unlike the row journal, whose replay is
        # strictly >. After a trim the floor is the last dropped entry's
        # version itself: entries logged AT that version may be split
        # across the drop boundary, so readers synced there must resync.
        self._member_log_base = self.version - 1

    # -- row buffers (views over spare capacity) ---------------------------

    def __getattr__(self, name):
        bufs = self.__dict__.get("_bufs")
        if bufs is not None and name in bufs:
            return bufs[name][: self.__dict__["_n"]]
        raise AttributeError(name)

    @property
    def capacity(self) -> int:
        """Allocated user rows (≥ n; grows by doubling, never per insert)."""
        return self._bufs["graph_ids"].shape[0]

    def _ensure_capacity(self, n_needed: int):
        cap = self.capacity
        if n_needed <= cap:
            return
        new_cap = max(cap, 64)
        while new_cap < n_needed:
            new_cap *= 2
        for name, buf in self._bufs.items():
            grown = np.full((new_cap,) + buf.shape[1:], _ROW_FILL[name],
                            dtype=buf.dtype)
            grown[: self._n] = buf[: self._n]
            self._bufs[name] = grown

    # -- shape accessors ---------------------------------------------------

    @property
    def n(self) -> int:
        return self._n

    @property
    def k(self) -> int:
        return self._bufs["graph_ids"].shape[1]

    @property
    def t(self) -> int:
        return len(self.hash_seeds)

    @property
    def n_clusters(self) -> int:
        return len(self.cluster_config)

    @property
    def gf(self) -> GoldFinger:
        return GoldFinger(words=self.words, card=self.card)

    @property
    def graph(self) -> KNNGraph:
        return KNNGraph(ids=self.graph_ids, sims=self.graph_sims)

    # -- routing tables ----------------------------------------------------

    def path_lut(self) -> dict:
        """(config, split-path tuple) → cluster index."""
        if self._lut is None:
            lut = {}
            for ci in range(self.n_clusters):
                path = tuple(int(h) for h in self.cluster_paths[ci]
                             if h != NO_HASH)
                lut[(int(self.cluster_config[ci]), path)] = ci
            self._lut = lut
        return self._lut

    def cluster_users(self, ci: int) -> np.ndarray:
        """Members of cluster ``ci``, including users inserted online."""
        base = self.cluster_members[
            self.cluster_offsets[ci]:self.cluster_offsets[ci + 1]]
        extra = self._extra_members.get(ci)
        if not extra:
            return base
        return np.concatenate([base, np.asarray(extra, dtype=np.int32)])

    def cluster_sizes(self) -> np.ndarray:
        """int64[n_clusters] member counts, online extras included."""
        sizes = np.diff(self.cluster_offsets)
        for ci, extra in self._extra_members.items():
            sizes[ci] += len(extra)
        return sizes

    def add_cluster_member(self, ci: int, user: int):
        self._extra_members.setdefault(ci, []).append(int(user))
        self._log_member(ci, user)

    def _log_member(self, ci: int, user: int):
        self._member_log.append((self.version, int(ci), int(user)))
        if len(self._member_log) > 8192:  # bounded, like the row journal
            drop = self._member_log[:4096]
            self._member_log = self._member_log[4096:]
            self._member_log_base = drop[-1][0]

    def members_added_since(self, version: int
                            ) -> list[tuple[int, int]] | None:
        """(cluster, uid) registrations after ``version`` in order, or
        None when the membership journal no longer reaches back that far
        (caller must re-derive residency from the full cluster tables).

        Entries logged at exactly ``version`` are included: membership
        registration does not bump :attr:`version` by itself (the row
        append or cohort refresh around it does), so a reader synced to
        version v has seen the rows of v but not members logged *at* v
        afterwards. Registrations always precede or accompany a version
        bump, so replaying ``> version - 1`` never misses one and the
        (idempotent) union absorbs any replayed duplicates. The trimmed
        floor is accordingly inclusive: a trim can split the entries of
        its boundary version, so readers synced at (or below) it resync.
        """
        if version <= self._member_log_base:
            return None
        return [(ci, u) for v, ci, u in self._member_log if v >= version]

    # -- online insertion --------------------------------------------------

    def append_user(self, words_row: np.ndarray, card_row: int,
                    nbr_ids: np.ndarray, nbr_sims: np.ndarray) -> int:
        """Append one user and link it into the graph.

        ``nbr_ids``/``nbr_sims`` are the user's search result (its forward
        edges, ≤ k entries, PAD_ID allowed). The reverse patch applies the
        paper's bounded-heap semantics to each neighbor: the new user
        displaces the neighbor's worst edge iff it is closer (or the
        neighborhood has a free slot). O(degree): one row write plus one
        in-place patch per neighbor — the backing buffers only reallocate
        on geometric-doubling boundaries.
        """
        u = self._n
        self._ensure_capacity(u + 1)
        bufs = self._bufs
        k, r = self.k, bufs["rev_ids"].shape[1]
        row_ids = np.full(k, PAD_ID, dtype=np.int32)
        row_sims = np.full(k, NEG_INF, dtype=np.float32)
        valid = np.flatnonzero(np.asarray(nbr_ids) != PAD_ID)[:k]
        order = valid[np.argsort(-np.asarray(nbr_sims, dtype=np.float32)[valid],
                                 kind="stable")]
        row_ids[: len(order)] = np.asarray(nbr_ids)[order]
        row_sims[: len(order)] = np.asarray(nbr_sims)[order]

        bufs["words"][u] = np.asarray(words_row, np.uint32)
        bufs["card"][u] = card_row
        bufs["graph_ids"][u] = row_ids
        bufs["graph_sims"][u] = row_sims

        graph_ids, graph_sims = bufs["graph_ids"], bufs["graph_sims"]
        rev_ids = bufs["rev_ids"]
        rev_row = np.full(r, PAD_ID, dtype=np.int32)
        n_rev = 0
        for v, s in zip(row_ids, row_sims):
            if v == PAD_ID:
                break
            v = int(v)
            # u → v exists, so u joins rev(v) (replace the tail if full).
            free = np.flatnonzero(rev_ids[v] == PAD_ID)
            rev_ids[v, free[0] if len(free) else r - 1] = u
            # Bounded-heap insert of u into v's forward neighborhood.
            eff = np.where(graph_ids[v] == PAD_ID, NEG_INF, graph_sims[v])
            j = int(np.argmin(eff))
            if s > eff[j]:
                graph_ids[v, j] = u
                graph_sims[v, j] = s
                o = np.argsort(-graph_sims[v], kind="stable")
                graph_ids[v] = graph_ids[v, o]
                graph_sims[v] = graph_sims[v, o]
                if n_rev < r:  # v → u now exists, so v joins rev(u)
                    rev_row[n_rev] = v
                    n_rev += 1
        rev_ids[u] = rev_row
        self._n = u + 1
        self.version += 1
        touched = (u,) + tuple(int(v) for v in row_ids if v != PAD_ID)
        self._row_log.append((self.version, touched))
        if len(self._row_log) > 2048:  # bounded journal; old entries
            drop = self._row_log[:1024]  # force a full resync instead
            self._row_log = self._row_log[1024:]
            self._row_log_base = drop[-1][0]
        return u

    def rows_changed_since(self, version: int) -> set[int] | None:
        """Row indices mutated after ``version``, or None when the
        journal no longer reaches back that far (caller must resync)."""
        if version < self._row_log_base:
            return None
        rows: set[int] = set()
        for v, touched in reversed(self._row_log):
            if v <= version:
                break
            rows.update(touched)
        return rows

    # -- cohort refresh (amortized re-clustering) --------------------------

    def refresh_cohort(self, items: np.ndarray, offsets: np.ndarray,
                       user_ids: np.ndarray,
                       max_cluster: int | None = None) -> int:
        """Re-run C² clustering on an inserted cohort; returns the number
        of *new* routable clusters registered.

        ``items``/``offsets`` are the cohort profiles in CSR form (one row
        per inserted user, same order as ``user_ids``). The cohort is
        re-hashed with the index's FRH seeds and recursively split exactly
        like the build (core/splitting.py); every resulting cohort cluster
        whose split path already names a build-time cluster folds its
        members into it, and paths unseen at build time become new
        clusters in the routing table — so a drifting insert stream grows
        fresh routable entry points instead of piling onto stale ones.
        """
        user_ids = np.asarray(user_ids, dtype=np.int32)
        if len(user_ids) == 0:
            return 0
        if max_cluster is None:
            base_sizes = np.diff(self.cluster_offsets)
            max_cluster = int(base_sizes.max()) if len(base_sizes) else 64
        item_h = hashing.item_hashes(np.asarray(items, np.int32),
                                     self.hash_seeds, self.b)
        cands = hashing.user_distinct_hashes_np(
            item_h, np.asarray(offsets, np.int64), self.split_depth)
        lut = self.path_lut()
        new_paths: list[tuple[int, tuple[int, ...]]] = []
        new_members: list[np.ndarray] = []
        for cfg in range(self.t):
            res = split_config(cands[cfg], max_cluster)
            for mem, path in zip(res.members, res.paths):
                users = user_ids[mem]
                ci = lut.get((cfg, path))
                if ci is not None:
                    known = set(self.cluster_users(ci).tolist())
                    for u in users:
                        if int(u) not in known:
                            self.add_cluster_member(ci, int(u))
                elif len(users) >= 2:  # singletons yield no routing value
                    new_paths.append((cfg, path))
                    new_members.append(users)
        if new_members:
            base_ci = self.n_clusters
            for i, mem in enumerate(new_members):  # journal new clusters
                for u in mem:
                    self._log_member(base_ci + i, int(u))
            depth = self.cluster_paths.shape[1] if self.n_clusters else \
                self.split_depth
            add_paths = np.full((len(new_paths), depth), NO_HASH,
                                dtype=np.int32)
            for i, (_, p) in enumerate(new_paths):
                add_paths[i, : min(len(p), depth)] = p[:depth]
            self.cluster_paths = (
                np.concatenate([self.cluster_paths, add_paths])
                if self.n_clusters else add_paths)
            self.cluster_config = np.concatenate(
                [self.cluster_config,
                 np.array([c for c, _ in new_paths], dtype=np.int32)])
            self.cluster_members = np.concatenate(
                [self.cluster_members] + new_members).astype(np.int32)
            sizes = np.array([len(m) for m in new_members], dtype=np.int64)
            self.cluster_offsets = np.concatenate(
                [self.cluster_offsets,
                 self.cluster_offsets[-1] + np.cumsum(sizes)])
        self._lut = None
        self.version += 1
        return len(new_members)

    # -- persistence -------------------------------------------------------

    def consolidate(self):
        """Fold online-inserted members into the cluster CSR."""
        if not self._extra_members:
            return
        members = [self.cluster_users(ci) for ci in range(self.n_clusters)]
        self.cluster_members = (
            np.concatenate(members) if members
            else np.zeros((0,), np.int32)).astype(np.int32)
        sizes = np.array([len(m) for m in members], dtype=np.int64)
        self.cluster_offsets = np.zeros(self.n_clusters + 1, dtype=np.int64)
        np.cumsum(sizes, out=self.cluster_offsets[1:])
        self._extra_members = {}
        self._lut = None

    def save(self, path: str | Path):
        self.consolidate()
        arrays = {name: getattr(self, name) for name in _ROWS + _TABLES}
        meta = {name: np.int64(getattr(self, name)) for name in _META}
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez(path, **arrays, **meta)

    @classmethod
    def load(cls, path: str | Path) -> "KNNIndex":
        z = np.load(path)
        kw = {name: z[name] for name in z.files if name not in _META}
        kw.update({name: int(z[name]) for name in _META})
        return cls(**kw)


def build_index(ds: Dataset, params: C2Params | None = None, *,
                gf: GoldFinger | None = None,
                plan: ClusterPlan | None = None,
                graph: KNNGraph | None = None) -> KNNIndex:
    """Package a built C² graph (or build one) into a servable index.

    Pass ``graph``/``plan``/``gf`` from an existing build (e.g.
    ``launch/knn_build.build``) to avoid recomputation; whatever is
    missing is computed here with ``params``.
    """
    params = params or C2Params()
    if gf is None:
        gf = fingerprint_dataset(ds, n_bits=params.n_bits, seed=params.seed)
    if plan is None:
        plan = build_plan(ds, params)
    assert plan.paths is not None, "plan must retain split paths for routing"
    if graph is None:
        ids, sims = local_knn(plan, gf, params)
        graph = merge_partial(ids, sims, params.k)

    depth = params.split_depth
    paths = np.full((plan.n_clusters, depth), NO_HASH, dtype=np.int32)
    for ci, p in enumerate(plan.paths):
        paths[ci, : len(p)] = p[:depth]
    sizes = plan.sizes
    offsets = np.zeros(plan.n_clusters + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    members = (np.concatenate(plan.members) if plan.members
               else np.zeros((0,), np.int32)).astype(np.int32)

    return KNNIndex(
        graph_ids=np.ascontiguousarray(graph.ids, dtype=np.int32),
        graph_sims=np.ascontiguousarray(graph.sims, dtype=np.float32),
        words=np.asarray(gf.words, dtype=np.uint32),
        card=np.asarray(gf.card, dtype=np.int32),
        rev_ids=reverse_neighbors_np(np.asarray(graph.ids), r_max=graph.k),
        hash_seeds=frh_seeds(params),
        cluster_paths=paths,
        cluster_config=plan.config_of.astype(np.int32),
        cluster_members=members,
        cluster_offsets=offsets,
        b=params.b,
        n_bits=gf.n_bits,
        fp_seed=params.seed,
        split_depth=depth,
    )
