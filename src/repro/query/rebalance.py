"""Background shard re-balance: blue/green plan swap with merge-based
subgraph rebuild.

The frozen-base shard plan never re-balances: ``extend_plan`` sends new
clusters round-robin and new users to ``u % S``, so the measured
``imbalance`` (max/mean resident cluster mass per shard) drifts without
bound under sustained inserts — the placement-layer version of the
"laborious spurious work" C² exists to avoid. This module closes that
gap without ever taking the index offline:

* **Trigger** — a :class:`repro.sched.Cadence` fires every
  ``RebalanceConfig.every`` scheduler steps (between compiled programs,
  exactly like lifecycle maintenance); each firing re-measures imbalance
  from CURRENT cluster sizes (the delta sync deliberately leaves
  ``ShardPlan.imbalance`` stale — that would be O(members) per insert).
* **Re-derive** — when the measurement exceeds
  ``RebalanceConfig.threshold``, a fresh :func:`plan_shards` is derived
  from the current index (same LPT packing a cold start would get,
  tiered residency included).
* **Merge-based rebuild** — the new per-shard resident tensors are
  constructed by *symmetric merge* of the OLD shard subgraphs' rows
  ("On the Merge of k-NN Graph", Zhao et al.): every shard's local row
  is the global adjacency row with non-resident lanes dropped to PAD,
  so uniting the copies across all shards hosting a user reconstructs
  the global row lane-by-lane. The delta :meth:`ShardedDescent.sync`
  runs first (consuming the row / membership / tombstone journals —
  journal compaction keeps that bounded), so the old device tensors are
  current and the merge reads THEM, not the global index. Lanes no
  surviving co-resident copy retains (an edge whose endpoints never
  shared a shard) are patched from the index and counted —
  ``merge_stats`` reports the recovered fraction — which keeps the
  rebuilt tensors bitwise-equal to a from-scratch ``plan_shards``
  re-scatter (the property the hypothesis battery locks down).
* **Blue/green swap** — :meth:`ShardedDescent.adopt_plan` installs the
  plan + tensors + old→new local-id beam remap in one host-side call
  between scheduler steps: in-flight continuous slots keep descending
  (rows evicted from their shard drop to PAD with sims masked), and no
  request ever observes a half-swapped generation. The plan's
  :class:`~repro.query.cache.ResultCache` is invalidated explicitly —
  a swap changes no index content, so no journal proves anything, but
  placement is the one axis that changes results and pre-swap entries
  must never be served.
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace

import numpy as np

from repro.core.distributed import lpt_loads
from repro.query.sharded import ShardedDescent, ShardPlan, plan_shards
from repro.sched import Cadence, trace
from repro.types import PAD_ID


@dataclasses.dataclass(frozen=True)
class RebalanceConfig:
    """Knobs for the background re-balancer (engine flag-pile mapped)."""

    every: int = 0          # check cadence in scheduler steps (0 = off)
    threshold: float = 1.25  # measured imbalance that triggers a swap
    merge: bool = True      # symmetric-merge rebuild (False: re-scatter
                            # from the index — same tensors, used as the
                            # property-test baseline)


def measured_imbalance(index, plan: ShardPlan) -> float:
    """Max/mean resident cluster mass per shard at CURRENT sizes.

    The delta sync keeps ``plan.imbalance`` frozen at derivation time;
    this is the live measurement the re-balance trigger compares against
    its threshold. Non-resident configurations under tiered residency
    carry no rows and therefore no load.
    """
    sizes = index.cluster_sizes().astype(np.float64)
    if plan.resident_configs:
        sizes = np.where(
            np.asarray(index.cluster_config) < plan.resident_configs,
            sizes, 0.0)
    nc = min(len(sizes), len(plan.cluster_shard))
    loads = lpt_loads(sizes[:nc], plan.cluster_shard[:nc], plan.n_shards)
    return float(loads.max() / max(loads.mean(), 1e-9))


def merge_subgraph_rows(sd: ShardedDescent, exclude=()):
    """Reconstruct global row content by symmetric merge of the (synced)
    shard subgraphs; returns ``(src, stats)``.

    ``src`` quacks like the index (``graph_ids / rev_ids / words / card
    / tombstone``) and feeds :meth:`ShardedDescent._materialize` — the
    new shards' rows come from the old shards' device state instead of
    a global re-scatter. Per lane, every hosting shard's copy is either
    PAD (target not co-resident there) or the global id, so the union
    across hosting shards recovers the row; fingerprints / card /
    tombstone are identical on every copy and come from the first host.

    A lane stays unrecoverable only when NO old shard hosted both
    endpoints. Those are patched from the index and counted in
    ``stats`` — the audit that makes the merged rebuild bitwise-equal
    to a from-scratch ``plan_shards`` build rather than approximately
    so.

    ``exclude`` names shards whose device tensors must NOT be read —
    the failover path (repro/faults/failover.py) passes the unhealthy
    set, so a dead shard's rows rebuild from survivors + the index
    only. Rows resident nowhere else are patched wholesale from the
    index (counted as ``rows_unseen``); with an empty ``exclude`` full
    residency coverage is asserted as before.
    """
    ix = sd.index
    n = ix.n
    plan = sd.plan
    exclude = frozenset(int(s) for s in exclude)
    l_graph, l_rev, l_words, l_card, _, l_tomb = \
        (np.asarray(a) for a in sd._dev)
    kg, kr = l_graph.shape[2], l_rev.shape[2]
    graph = np.full((n, kg), PAD_ID, dtype=np.int32)
    rev = np.full((n, kr), PAD_ID, dtype=np.int32)
    words = np.zeros((n, l_words.shape[2]), dtype=l_words.dtype)
    card = np.zeros(n, dtype=l_card.dtype)
    tomb = np.zeros(n, dtype=bool)
    seen = np.zeros(n, dtype=bool)
    for s in range(plan.n_shards):
        if s in exclude:
            continue
        res = plan.residents[s]
        loc = sd._g2l[s, res]
        l2g = np.asarray(sd._dev[4])[s]
        g = _to_global(l2g, l_graph[s][loc])
        r = _to_global(l2g, l_rev[s][loc])
        # Symmetric merge: a lane already recovered elsewhere agrees
        # bitwise (every copy remaps the same global row), so first
        # non-PAD wins.
        graph[res] = np.where(graph[res] == PAD_ID, g, graph[res])
        rev[res] = np.where(rev[res] == PAD_ID, r, rev[res])
        first = ~seen[res]
        words[res[first]] = l_words[s][loc[first]]
        card[res[first]] = l_card[s][loc[first]]
        tomb[res[first]] = l_tomb[s][loc[first]]
        seen[res] = True
    rows_unseen = 0
    if exclude:
        missing = np.flatnonzero(~seen)
        rows_unseen = len(missing)
        if rows_unseen:  # resident only on excluded shards: index-patch
            words[missing] = ix.words[missing]
            card[missing] = ix.card[missing]
            tomb[missing] = ix.tombstone[missing]
    else:
        assert seen.all(), "shard residency no longer covers every user"
    # Audit pass: lanes whose endpoints never shared a shard cannot be
    # recovered from subgraph copies — patch them from the index so the
    # rebuild stays bitwise-equal to a from-scratch scatter.
    lost_g = (graph == PAD_ID) & (ix.graph_ids != PAD_ID)
    lost_r = (rev == PAD_ID) & (ix.rev_ids != PAD_ID)
    graph = np.where(lost_g, ix.graph_ids, graph)
    rev = np.where(lost_r, ix.rev_ids, rev)
    total = int((ix.graph_ids != PAD_ID).sum() + (ix.rev_ids != PAD_ID).sum())
    patched = int(lost_g.sum() + lost_r.sum())
    stats = {
        "rows": int(n),
        "lanes": total,
        "lanes_patched": patched,
        "merge_coverage": round(1.0 - patched / max(total, 1), 4),
    }
    if exclude:
        stats["excluded"] = sorted(exclude)
        stats["rows_unseen"] = rows_unseen
    src = SimpleNamespace(graph_ids=graph, rev_ids=rev, words=words,
                          card=card, tombstone=tomb)
    return src, stats


def _to_global(l2g: np.ndarray, local_ids: np.ndarray) -> np.ndarray:
    safe = np.where(local_ids == PAD_ID, 0, local_ids)
    return np.where(local_ids == PAD_ID, PAD_ID, l2g[safe])


class Rebalancer:
    """Cadence-gated background re-balancer owned by a QueryEngine.

    ``maintain()`` runs after every scheduler step (after lifecycle
    maintenance, so TTL expiry / repair mutations of the SAME step are
    already journaled and measured). It is a no-op for single-device
    placements and while the cadence is cold; a firing measures
    imbalance and swaps only past the threshold. ``swap()`` is also
    callable directly (benchmarks force swaps to isolate the mechanism).
    """

    def __init__(self, plan, cfg: RebalanceConfig):
        self.plan = plan        # the DescentPlan (owns sharded state)
        self.cfg = cfg
        self.cadence = Cadence(cfg.every)
        self.n_checks = 0
        self.n_swaps = 0
        self.n_deferred = 0  # checks skipped while the fleet is degraded
        self.last_imbalance: float | None = None
        self.merge_stats: dict = {}

    @property
    def active(self) -> bool:
        return self.cfg.every > 0 and self.plan.spec.placement > 1

    def maintain(self) -> float | None:
        """One between-steps tick; returns the post-swap imbalance when
        a swap fired, else None."""
        if not self.active or not self.cadence.tick():
            return None
        return self.check()

    def check(self, force: bool = False) -> float | None:
        """Measure imbalance; swap when past threshold (or ``force``)."""
        sd = self.plan.sharded_state()  # delta sync: journals consumed
        if sd.dead.any():
            # Degraded fleet: a re-balance swap would read the dead
            # shard's tensors into the merge and reset its mask. The
            # failover manager owns recovery; re-balancing resumes once
            # every shard is healthy again.
            self.n_deferred += 1
            return None
        imb = measured_imbalance(sd.index, sd.plan)
        self.n_checks += 1
        self.last_imbalance = imb
        sd.plan.imbalance = imb  # refresh the delta-path-stale metric
        if not force and imb <= self.cfg.threshold:
            return None
        return self.swap(sd)

    def swap(self, sd: ShardedDescent | None = None) -> float:
        """Blue/green swap to a fresh ``plan_shards`` partition; returns
        the new plan's imbalance."""
        spec = self.plan.spec
        if sd is None:
            sd = self.plan.sharded_state()
        new_plan = plan_shards(sd.index, spec.placement,
                               resident_configs=spec.resident_configs)
        src = None
        if self.cfg.merge:
            src, self.merge_stats = merge_subgraph_rows(sd)
        sd.adopt_plan(new_plan, src=src)
        self.plan.note_replan()  # placement changed: flush cached results
        self.n_swaps += 1
        self.last_imbalance = new_plan.imbalance
        trace.launch(("rebalance_swap", self.plan.key))
        return new_plan.imbalance

    def stats(self) -> dict:
        out = {
            "every": self.cfg.every,
            "threshold": self.cfg.threshold,
            "checks": self.n_checks,
            "swaps": self.n_swaps,
            "deferred": self.n_deferred,
            "imbalance": (round(self.last_imbalance, 4)
                          if self.last_imbalance is not None else None),
        }
        if self.merge_stats:
            out["merge"] = dict(self.merge_stats)
        return out
