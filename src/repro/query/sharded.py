"""Sharded query serving: partition a :class:`KNNIndex` across devices.

The build already scales Step 2 across the mesh by LPT bin-packing FRH
clusters onto devices (``core/distributed.py``). Serving reuses exactly
that partition axis: clusters are LPT-assigned to shards by member count,
each shard owns the *residents* of its clusters (the union of their
members, plus an id-strided share of unclustered users so every indexed
row lives somewhere), and each shard materializes a self-contained local
subgraph — adjacency rows of its residents with neighbor ids remapped to
shard-local indices (cross-shard edges drop to PAD), its residents'
fingerprints, and a local→global id map.

A query is routed once (global FRH placement); each routed seed is then
handed to exactly ONE shard — the shard that *owns* the seed user (users
are claimed by their largest cluster in LPT order, so ownership follows
the cluster partition). This matters: residents overlap across shards
(every user sits in up to t clusters), so broadcasting identical seeds
everywhere would make the per-shard descents redundant copies of each
other; ownership partitions the search basins instead. Beam descent runs
*per shard* over the shard-local subgraph — under ``shard_map`` when the
mesh has a device per shard (SPMD, no collectives inside, like
``distributed_local_knn``), or vmapped over the shard axis on a single
device (identical numerics; this is the CPU/CI path). Per-shard top-k
results return in global ids and are merged with ``knn/topk.merge_topk``
— the partition-then-merge strategy of "On the Merge of k-NN Graph"
(Zhao et al.).

Each shard's beam defaults to ``oversample · beam / n_shards`` (floored
at k): the fleet's total frontier stays ~``oversample ×`` the
single-device configuration, but every ``top_k`` row is ``n_shards ×``
narrower — which is what makes the vmapped CPU path competitive and the
mesh path a near-linear scale-out.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import lpt_assign, lpt_loads
from repro.core.local_knn import capacity_of
from repro.knn.topk import merge_topk
from repro.query.index import KNNIndex
from repro.query.search import descent_kernel
from repro.types import PAD_ID


@dataclasses.dataclass
class ShardPlan:
    """Static cluster → shard partition of an index."""

    n_shards: int
    cluster_shard: np.ndarray     # int64[n_clusters]
    residents: list[np.ndarray]   # sorted unique global user ids per shard
    owner: np.ndarray             # int64[n] — the one shard seeding each user
    imbalance: float              # max/mean assigned cluster-size load


def plan_shards(index: KNNIndex, n_shards: int) -> ShardPlan:
    """LPT bin-packing of FRH clusters onto ``n_shards`` serving shards.

    Serving cost is linear in resident rows (descent gathers + scoring),
    so clusters are weighed by member count — unlike the build, whose
    brute-force cost is quadratic. Besides the (overlapping) resident
    sets, the plan fixes a disjoint *ownership*: every user belongs to
    exactly one shard — the shard of the largest cluster claiming it —
    which is where routed seeds naming that user are explored.
    """
    sizes = index.cluster_sizes().astype(np.float64)
    assign = lpt_assign(sizes, n_shards)
    residents: list[np.ndarray] = []
    covered = np.zeros(index.n, dtype=bool)
    for s in range(n_shards):
        mems = [index.cluster_users(ci)
                for ci in np.flatnonzero(assign == s)]
        res = (np.unique(np.concatenate(mems)).astype(np.int64)
               if mems else np.zeros(0, np.int64))
        res = res[(res >= 0) & (res < index.n)]
        residents.append(res)
        covered[res] = True
    owner = np.full(index.n, -1, dtype=np.int64)
    for ci in np.argsort(-sizes, kind="stable"):  # big clusters claim first
        mem = index.cluster_users(int(ci))
        mem = mem[(mem >= 0) & (mem < index.n)]
        free = mem[owner[mem] < 0]
        owner[free] = assign[ci]
    # Unclustered users (singleton clusters are dropped at build; fresh
    # inserts may not be registered yet) still need a home shard.
    leftovers = np.flatnonzero(~covered)
    if len(leftovers):
        residents = [np.union1d(res, leftovers[s::n_shards])
                     for s, res in enumerate(residents)]
    unowned = np.flatnonzero(owner < 0)
    for s in range(n_shards):
        owner[unowned[s::n_shards]] = s
    # Balance metric: assigned cluster-size mass per shard (residency
    # alone under-reports skew — clusters overlap across configurations).
    loads = lpt_loads(sizes, assign, n_shards)
    imbalance = float(loads.max() / max(loads.mean(), 1e-9))
    return ShardPlan(n_shards=n_shards, cluster_shard=assign,
                     residents=residents, owner=owner, imbalance=imbalance)


class ShardedDescent:
    """Per-shard local subgraphs + the descent/merge program over them.

    Rebuilt when the index version changes (the engine caches one per
    (version, n_shards), so an insert burst costs one rebuild at the next
    query wave, not one per insert).
    """

    def __init__(self, index: KNNIndex, n_shards: int,
                 plan: ShardPlan | None = None, use_mesh: bool | None = None,
                 oversample: float = 1.5):
        assert n_shards >= 1
        self.index = index
        self.oversample = oversample
        self.plan = plan or plan_shards(index, n_shards)
        S = self.plan.n_shards
        n = index.n
        cap = max(capacity_of(len(r), minimum=64)
                  for r in self.plan.residents)
        kg, kr = index.k, index.rev_ids.shape[1]
        W = index.words.shape[1]

        l2g = np.full((S, cap), PAD_ID, dtype=np.int32)
        g2l = np.full((S, n), PAD_ID, dtype=np.int32)
        l_graph = np.full((S, cap, kg), PAD_ID, dtype=np.int32)
        l_rev = np.full((S, cap, kr), PAD_ID, dtype=np.int32)
        l_words = np.zeros((S, cap, W), dtype=np.uint32)
        l_card = np.zeros((S, cap), dtype=np.int32)
        for s, res in enumerate(self.plan.residents):
            m = len(res)
            l2g[s, :m] = res
            g2l[s, res] = np.arange(m, dtype=np.int32)
            l_graph[s, :m] = self._remap(g2l[s], index.graph_ids[res])
            l_rev[s, :m] = self._remap(g2l[s], index.rev_ids[res])
            l_words[s, :m] = index.words[res]
            l_card[s, :m] = index.card[res]
        self._g2l = g2l
        self.version = index.version
        if use_mesh is None:  # auto: one device per shard when available
            use_mesh = S > 1 and jax.device_count() >= S
        self.mesh = None
        arrays = (l_graph, l_rev, l_words, l_card, l2g)
        if use_mesh:
            from jax.sharding import NamedSharding, PartitionSpec as P

            self.mesh = jax.sharding.Mesh(
                np.asarray(jax.devices()[:S]), ("shards",))
            # Pin each shard's subgraph to its device ONCE — per-call
            # resharding would move the whole index every wave.
            self._dev = tuple(
                jax.device_put(a, NamedSharding(
                    self.mesh, P("shards", *([None] * (a.ndim - 1)))))
                for a in arrays)
        else:
            self._dev = tuple(jnp.asarray(a) for a in arrays)

    @staticmethod
    def _remap(g2l_row: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Global → shard-local ids; non-resident targets become PAD."""
        safe = np.where(ids == PAD_ID, 0, ids)
        return np.where(ids == PAD_ID, PAD_ID, g2l_row[safe])

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    def shard_seeds(self, seeds: np.ndarray) -> np.ndarray:
        """Partition routed global seeds by ownership and remap to local.

        Returns int32[S, q, S_cols]: seed ids in shard-local coordinates;
        a seed appears on exactly the shard owning that user (PAD
        elsewhere), so the fleet explores disjoint basins.
        """
        S = self.n_shards
        safe = np.where(seeds == PAD_ID, 0, seeds)
        owned = ((self.plan.owner[safe][None]
                  == np.arange(S)[:, None, None])
                 & (seeds[None] != PAD_ID))              # [S, q, cols]
        local = self._g2l[:, safe]
        return np.where(owned, local, PAD_ID)

    def descend(self, q_words, q_card, seeds: np.ndarray, *,
                k: int, beam: int, hops: int, kernel: bool = False):
        """Route-seeded descent on every shard + cross-shard top-k merge.

        ``seeds`` are global ids (router output, PAD padded); ``beam`` is
        the single-device frontier width, divided among shards (with
        ``self.oversample`` slack, floored at k). ``kernel`` selects the
        fused Pallas hop (bitwise-identical results). Returns
        (ids int32[q, k], sims float32[q, k]) in global ids.
        """
        l_seeds = jnp.asarray(self.shard_seeds(seeds))
        shard_beam = max(
            k, int(np.ceil(self.oversample * beam / self.n_shards)))
        args = (*self._dev, jnp.asarray(q_words), jnp.asarray(q_card),
                l_seeds)
        if self.mesh is not None:
            program = _mesh_program(self.mesh, k=k, beam=shard_beam,
                                    hops=hops, kernel=kernel)
            ids, sims = program(*args)
        else:
            ids, sims = _vmapped_descent(*args, k=k, beam=shard_beam,
                                         hops=hops, kernel=kernel)
        return _merge_shard_topk(ids, sims, k)


def _per_shard(graph, rev, words, card, l2g, q_words, q_card, seeds,
               *, k, beam, hops, kernel=False):
    """One shard's descent; results mapped back to global ids."""
    ids, sims = descent_kernel(graph, rev, words, card,
                               q_words, q_card, seeds,
                               k=k, beam=beam, hops=hops, kernel=kernel)
    safe = jnp.where(ids == PAD_ID, 0, ids)
    return jnp.where(ids == PAD_ID, PAD_ID, l2g[safe]), sims


@functools.partial(jax.jit, static_argnames=("k", "beam", "hops", "kernel"))
def _vmapped_descent(l_graph, l_rev, l_words, l_card, l2g,
                     q_words, q_card, l_seeds, *, k, beam, hops,
                     kernel=False):
    """Single-device fallback: the shard axis is a vmap axis (the fused
    Pallas hop batches through its pallas_call batching rule)."""
    return jax.vmap(
        lambda g, r, w, c, m, s: _per_shard(
            g, r, w, c, m, q_words, q_card, s, k=k, beam=beam, hops=hops,
            kernel=kernel)
    )(l_graph, l_rev, l_words, l_card, l2g, l_seeds)


@functools.lru_cache(maxsize=64)
def _mesh_program(mesh, *, k, beam, hops, kernel=False):
    """SPMD path: one shard per device, no collectives inside (the merge
    happens after the shard-parallel top-k, mirroring
    distributed_local_knn's reduce phase). Returns a jitted callable.

    Cached at module level (jax.sharding.Mesh hashes by devices + axis
    names), so resharding after an insert burst reuses the compiled
    program as long as shapes and (k, beam, hops) are unchanged —
    symmetric with the module-level jitted ``_vmapped_descent``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def device_fn(g, r, w, c, m, qw, qc, s):
        ids, sims = _per_shard(g[0], r[0], w[0], c[0], m[0], qw, qc, s[0],
                               k=k, beam=beam, hops=hops, kernel=kernel)
        return ids[None], sims[None]

    in_specs = (P("shards", None, None), P("shards", None, None),
                P("shards", None, None), P("shards", None),
                P("shards", None), P(), P(), P("shards", None, None))
    out_specs = (P("shards", None, None), P("shards", None, None))
    return jax.jit(shard_map(device_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_shard_topk(ids, sims, k: int):
    """[S, q, k'] per-shard results → global top-k per query."""
    S, q, kk = ids.shape
    flat_ids = jnp.swapaxes(ids, 0, 1).reshape(q, S * kk)
    flat_sims = jnp.swapaxes(sims, 0, 1).reshape(q, S * kk)
    return merge_topk(flat_ids, flat_sims, k)
