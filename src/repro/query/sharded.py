"""Sharded query serving: partition a :class:`KNNIndex` across devices.

The build already scales Step 2 across the mesh by LPT bin-packing FRH
clusters onto devices (``core/distributed.py``). Serving reuses exactly
that partition axis: clusters are LPT-assigned to shards by member count,
each shard owns the *residents* of its clusters (the union of their
members, plus an id-strided share of unclustered users so every indexed
row lives somewhere), and each shard materializes a self-contained local
subgraph — adjacency rows of its residents with neighbor ids remapped to
shard-local indices (cross-shard edges drop to PAD), its residents'
fingerprints, and a local→global id map.

A query is routed once (global FRH placement); each routed seed is then
handed to exactly ONE shard — the shard that *owns* the seed user (users
are claimed by their largest cluster in LPT order, so ownership follows
the cluster partition). This matters: residents overlap across shards
(every user sits in up to t clusters), so broadcasting identical seeds
everywhere would make the per-shard descents redundant copies of each
other; ownership partitions the search basins instead. Beam descent runs
*per shard* over the shard-local subgraph — under ``shard_map`` when the
mesh has a device per shard (SPMD, no collectives inside, like
``distributed_local_knn``), or vmapped over the shard axis on a single
device (identical numerics; this is the CPU/CI path). Per-shard top-k
results return in global ids and are merged with ``knn/topk.merge_topk``
— the partition-then-merge strategy of "On the Merge of k-NN Graph"
(Zhao et al.).

Each shard's beam defaults to ``oversample · beam / n_shards`` (floored
at k): the fleet's total frontier stays ~``oversample ×`` the
single-device configuration, but every ``top_k`` row is ``n_shards ×``
narrower — which is what makes the vmapped CPU path competitive and the
mesh path a near-linear scale-out.

Incremental resharding (:meth:`ShardedDescent.sync`): the partition is
FROZEN at construction and *extended* — never re-balanced — as the index
mutates, mirroring the online-update discipline of Debatty et al.'s
incremental graph building. New clusters go round-robin to shards, new
users to their home shard ``u % S`` plus wherever their clusters live,
and both rules are pure functions of (base plan, current index), so a
delta-maintained state is bitwise-equal to a from-scratch
rematerialization under :func:`extend_plan` (property-tested in
``tests/test_plan.py``). An insert burst therefore costs one O(degree)
row scatter per shard — consuming the same row journal the single-device
sync uses (:meth:`KNNIndex.rows_changed_since`) plus the membership
journal (:meth:`KNNIndex.members_added_since`) — instead of a
full-tensor rebuild, and the serving programs keep their compiled shapes
(capacity rows double geometrically, like the index's own buffers). Full
per-shard rematerialization happens only when a *pre-existing* user
gains residency (cohort refresh registering it in a new cluster — its
in-edges must be remapped, and bounded reverse adjacency cannot name
them all), when capacity crosses a doubling boundary, or when a journal
no longer reaches back to the synced version.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import lpt_assign, lpt_loads
from repro.core.local_knn import capacity_of
from repro.knn.topk import merge_topk
from repro.query.index import KNNIndex
from repro.query.search import descent_kernel
from repro.sched import trace
from repro.types import NEG_INF, PAD_ID


@dataclasses.dataclass
class ShardPlan:
    """Static cluster → shard partition of an index."""

    n_shards: int
    cluster_shard: np.ndarray     # int64[n_clusters]
    residents: list[np.ndarray]   # sorted unique global user ids per shard
    owner: np.ndarray             # int64[n] — the one shard seeding each user
    imbalance: float              # max/mean assigned cluster-size load
    version: int = -1             # index.version at derivation (journal
                                  # floor for extend_plan's scoped scans)
    resident_configs: int = 0     # tiered residency: only clusters of hash
                                  # configurations < this contribute
                                  # residents (0 = all t configurations)

    @property
    def base_n(self) -> int:
        """Users covered by this plan (== index.n when it was derived)."""
        return len(self.owner)

    def validate(self) -> "ShardPlan":
        """Assert the ``owner ∈ residents`` invariant.

        Every user's routed seeds are explored ONLY on the shard owning
        it (:meth:`ShardedDescent.shard_seeds`); if that shard does not
        host the user's rows, ``_g2l`` maps the seed to PAD and the
        whole basin silently vanishes. Derivation paths call this once
        per plan (the per-insert delta sync keeps the invariant by
        construction and skips the O(n log n) check).
        """
        for s, res in enumerate(self.residents):
            owned = np.flatnonzero(self.owner == s)
            hosted = np.isin(owned, res, assume_unique=False)
            if not hosted.all():
                bad = owned[~hosted][:8]
                raise AssertionError(
                    f"shard {s} owns users it does not host "
                    f"(e.g. {bad.tolist()}): their owner-partitioned "
                    f"seeds would be silently dropped")
        return self


def plan_shards(index: KNNIndex, n_shards: int, *,
                resident_configs: int = 0) -> ShardPlan:
    """LPT bin-packing of FRH clusters onto ``n_shards`` serving shards.

    Serving cost is linear in resident rows (descent gathers + scoring),
    so clusters are weighed by member count — unlike the build, whose
    brute-force cost is quadratic. Besides the (overlapping) resident
    sets, the plan fixes a disjoint *ownership*: every user belongs to
    exactly one shard — the shard of the largest cluster claiming it —
    which is where routed seeds naming that user are explored.

    ``resident_configs`` = m > 0 restricts residency (and ownership
    claims) to clusters of the first m hash configurations — tiered
    residency. With t configurations every user is resident on up to t
    shards; a subset trades a little recall (fewer local rows → more
    cross-shard edges dropped) for ~t/m per-shard memory. Users in no
    selected cluster ride the leftover stride, so coverage stays total;
    routing is untouched (seeds from any configuration descend on their
    owner shard).
    """
    rc = resident_configs if 0 < resident_configs < index.t else 0
    sizes = index.cluster_sizes().astype(np.float64)
    res_cluster = (np.asarray(index.cluster_config) < rc if rc
                   else np.ones(index.n_clusters, dtype=bool))
    eff = np.where(res_cluster, sizes, 0.0)
    assign = lpt_assign(eff, n_shards)
    residents: list[np.ndarray] = []
    covered = np.zeros(index.n, dtype=bool)
    for s in range(n_shards):
        mems = [index.cluster_users(ci)
                for ci in np.flatnonzero((assign == s) & res_cluster)]
        res = (np.unique(np.concatenate(mems)).astype(np.int64)
               if mems else np.zeros(0, np.int64))
        res = res[(res >= 0) & (res < index.n)]
        residents.append(res)
        covered[res] = True
    owner = np.full(index.n, -1, dtype=np.int64)
    for ci in np.argsort(-eff, kind="stable"):  # big clusters claim first
        if not res_cluster[ci]:
            continue  # non-resident configurations cannot claim owners
        mem = index.cluster_users(int(ci))
        mem = mem[(mem >= 0) & (mem < index.n)]
        free = mem[owner[mem] < 0]
        owner[free] = assign[ci]
    # Unclustered users (singleton clusters are dropped at build; fresh
    # inserts may not be registered yet; non-resident configurations
    # under tiered residency) still need a home shard. The same stride
    # assigns residency AND ownership, so ``owner ∈ residents`` holds by
    # construction — ownership is never handed to a shard that does not
    # host the user's rows (that would silently drop its seeds).
    leftovers = np.flatnonzero(~covered)
    if len(leftovers):
        residents = [np.union1d(res, leftovers[s::n_shards])
                     for s, res in enumerate(residents)]
        for s in range(n_shards):
            owner[leftovers[s::n_shards]] = s
    # Balance metric: assigned resident cluster-size mass per shard
    # (residency alone under-reports skew — clusters overlap across
    # configurations; non-resident configurations carry no rows).
    loads = lpt_loads(eff, assign, n_shards)
    imbalance = float(loads.max() / max(loads.mean(), 1e-9))
    return ShardPlan(n_shards=n_shards, cluster_shard=assign,
                     residents=residents, owner=owner, imbalance=imbalance,
                     version=index.version,
                     resident_configs=rc).validate()


def extend_plan(base: ShardPlan, index: KNNIndex) -> ShardPlan:
    """Extend a frozen partition to the index's current state.

    The base assignment never re-balances (that would reshuffle resident
    tensors wholesale); growth follows deterministic rules that are pure
    functions of (base, current index) — so incremental journal-driven
    extension and this one-shot re-derivation agree exactly:

    * clusters unseen by ``base`` go round-robin: shard ``ci % S``;
    * users unseen by ``base`` live on (and are owned by) their home
      shard ``u % S``, plus every shard whose clusters register them;
    * membership is append-only, so resident sets only grow — a user
      never migrates off a shard until a fresh :func:`plan_shards`
      (the background re-balancer's blue/green swap,
      ``query/rebalance.py``, is that one exception).

    Membership scans are scoped by the journal: only clusters born or
    membership-touched since ``base`` was derived can contribute
    residents beyond ``base.residents`` (an untouched base cluster's
    members are already in it), so the one-shot re-derivation costs
    O(journal + new clusters) scans instead of O(S·C). When the
    membership journal no longer reaches back to ``base.version`` the
    full scan runs instead — same result, never a wrong one.
    """
    S = base.n_shards
    base_nc = len(base.cluster_shard)
    n = index.n
    rc = base.resident_configs
    cluster_shard = np.concatenate([
        base.cluster_shard,
        np.arange(base_nc, index.n_clusters, dtype=np.int64) % S])
    res_cluster = (np.asarray(index.cluster_config) < rc if rc
                   else np.ones(index.n_clusters, dtype=bool))
    owner = np.concatenate([
        base.owner, np.arange(base.base_n, n, dtype=np.int64) % S])
    home = np.arange(base.base_n, n, dtype=np.int64)
    mems = (index.members_added_since(base.version)
            if base.version >= 0 else None)
    if mems is None:  # journal expired (or a pre-journal plan): full scan
        scan = [np.flatnonzero((cluster_shard == s) & res_cluster)
                for s in range(S)]
    else:
        touched = ({int(ci) for ci, _ in mems}
                   | set(range(base_nc, index.n_clusters)))
        scan = [sorted(ci for ci in touched
                       if cluster_shard[ci] == s and res_cluster[ci])
                for s in range(S)]
    residents = []
    for s in range(S):
        parts = [base.residents[s], home[home % S == s]]
        for ci in scan[s]:
            mem = index.cluster_users(int(ci)).astype(np.int64)
            parts.append(mem[(mem >= 0) & (mem < n)])
        residents.append(np.unique(np.concatenate(parts)))
    sizes = index.cluster_sizes().astype(np.float64)
    loads = lpt_loads(np.where(res_cluster, sizes, 0.0), cluster_shard, S)
    imbalance = float(loads.max() / max(loads.mean(), 1e-9))
    return ShardPlan(n_shards=S, cluster_shard=cluster_shard,
                     residents=residents, owner=owner, imbalance=imbalance,
                     version=base.version, resident_configs=rc).validate()


class ShardedDescent:
    """Per-shard local subgraphs + the descent/merge program over them.

    Owned by a :class:`~repro.query.plan.DescentPlan`'s sharded
    placement; :meth:`sync` repairs the resident tensors incrementally
    after index mutations (see the module docstring) so an insert burst
    costs row scatters, not a rebuild — and a sharded engine never holds
    a full-index device copy.
    """

    def __init__(self, index: KNNIndex, n_shards: int,
                 plan: ShardPlan | None = None, use_mesh: bool | None = None,
                 oversample: float = 1.5, resident_configs: int = 0):
        assert n_shards >= 1
        self.index = index
        self.oversample = oversample
        self.base_plan = plan or plan_shards(
            index, n_shards, resident_configs=resident_configs)
        self.plan = self.base_plan
        # Bumped by every blue/green swap (query/rebalance.py): all
        # device tensors + plan + pending beam remap move together
        # between scheduler steps, so a generation is never observed
        # half-swapped.
        self.generation = 0
        # Degraded-serving mask (repro/faults): True shards are down —
        # their owned seeds drop at shard_seeds and their merge lanes
        # are neutralized, so survivors keep answering (bounded recall
        # loss) until the failover rebuild swaps the shard back in.
        self.dead = np.zeros(self.plan.n_shards, dtype=bool)
        S = self.plan.n_shards
        if use_mesh is None:  # auto: one device per shard when available
            use_mesh = S > 1 and jax.device_count() >= S
        self.mesh = None
        self._sharding = None
        if use_mesh:
            from jax.sharding import NamedSharding, PartitionSpec as P

            self.mesh = jax.sharding.Mesh(
                np.asarray(jax.devices()[:S]), ("shards",))
            self._sharding = lambda ndim: NamedSharding(
                self.mesh, P("shards", *([None] * (ndim - 1))))
        # Pending old-local → new-local id remap for in-flight slot
        # beams ([S, cap-at-snapshot] or None); see take_beam_remap().
        self._beam_remap: np.ndarray | None = None
        self._materialize()

    # -- tensor materialization / repair -----------------------------------

    @staticmethod
    def _remap(g2l_row: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Global → shard-local ids; non-resident targets become PAD."""
        safe = np.where(ids == PAD_ID, 0, ids)
        return np.where(ids == PAD_ID, PAD_ID, g2l_row[safe])

    def _shard_block(self, s: int, cap: int, src=None):
        """Host tensors of shard ``s`` at ``cap`` rows (rebuild unit).

        ``src`` overrides WHERE row content is read from: anything with
        ``graph_ids / rev_ids / words / card / tombstone`` [n]-row
        arrays — by default the index itself, during a re-balance swap
        the symmetric-merge reconstruction of the old shard subgraphs
        (:func:`repro.query.rebalance.merge_subgraph_rows`). Shapes and
        the g2l width always come from the index.
        """
        ix = self.index
        if src is None:
            src = ix
        res = self.plan.residents[s]
        m = len(res)
        kg, kr = ix.k, ix.rev_ids.shape[1]
        W = ix.words.shape[1]
        l2g = np.full(cap, PAD_ID, dtype=np.int32)
        l2g[:m] = res
        # Capacity-width (not n-width): the map then grows only on the
        # index's own doubling boundaries, so per-insert delta syncs
        # never re-copy the whole [S, n] table.
        g2l = np.full(ix.capacity, PAD_ID, dtype=np.int32)
        g2l[res] = np.arange(m, dtype=np.int32)
        graph = np.full((cap, kg), PAD_ID, dtype=np.int32)
        rev = np.full((cap, kr), PAD_ID, dtype=np.int32)
        words = np.zeros((cap, W), dtype=np.uint32)
        card = np.zeros(cap, dtype=np.int32)
        tomb = np.zeros(cap, dtype=bool)
        graph[:m] = self._remap(g2l, src.graph_ids[res])
        rev[:m] = self._remap(g2l, src.rev_ids[res])
        words[:m] = src.words[res]
        card[:m] = src.card[res]
        tomb[:m] = src.tombstone[res]
        return l2g, g2l, graph, rev, words, card, tomb

    def _materialize(self, src=None):
        """Full (re)build of every shard's resident tensors.

        First use, capacity crossings, and journal-expiry fall back here;
        steady-state mutations go through :meth:`sync`'s delta path. Each
        shard's subgraph is pinned to its device once when a mesh is
        active — per-call resharding would move the whole index every
        wave.
        """
        ix = self.index
        S = self.plan.n_shards
        cap = max(capacity_of(len(r), minimum=64)
                  for r in self.plan.residents)
        self.cap = cap
        blocks = [self._shard_block(s, cap, src=src) for s in range(S)]
        self._g2l = np.stack([b[1] for b in blocks])
        arrays = (
            np.stack([b[2] for b in blocks]),   # l_graph
            np.stack([b[3] for b in blocks]),   # l_rev
            np.stack([b[4] for b in blocks]),   # l_words
            np.stack([b[5] for b in blocks]),   # l_card
            np.stack([b[0] for b in blocks]),   # l2g
            np.stack([b[6] for b in blocks]),   # l_tomb
        )
        self._dev = tuple(self._pin(a) for a in arrays)
        self.version = ix.version
        self._n_seen = ix.n

    def _pin(self, a):
        if self._sharding is not None:
            return jax.device_put(a, self._sharding(np.ndim(a)))
        return jnp.asarray(a)

    def sync(self) -> str:
        """Repair device state to the index's current version.

        Returns "noop" | "delta" | "rebuild". The delta path consumes
        the index's row + membership journals and scatters only touched
        rows into affected shards; see the module docstring for when a
        rebuild (full or per-shard) is forced instead.
        """
        ix = self.index
        if self.version == ix.version:
            return "noop"
        # Snapshot the local→global map before any mutation: if local
        # ids shift (per-shard rematerialization), in-flight slot beams
        # hold stale locals and need the old→new remap this produces.
        old_l2g = np.asarray(self._dev[4])
        rows = ix.rows_changed_since(self.version)
        mems = ix.members_added_since(self.version)
        tombs = ix.tombstones_since(self.version)
        if rows is None or mems is None or tombs is None:  # journal expired
            self.plan = extend_plan(self.base_plan, ix)
            self._materialize()
            self._record_remap(old_l2g)
            return "rebuild"
        # Liveness flips always ride the row journal too (remove_user and
        # free-row reuse journal the flipped row), so rows ⊇ tombs when
        # both journals reach back — the union is defensive.
        rows = rows | tombs
        old_n = self._n_seen
        S = self.plan.n_shards
        # Incremental plan extension (== extend_plan(base_plan, ix);
        # the bitwise-vs-rebuild property test locks this equality down).
        cluster_shard = np.concatenate([
            self.plan.cluster_shard,
            np.arange(len(self.plan.cluster_shard), ix.n_clusters,
                      dtype=np.int64) % S])
        owner = np.concatenate([
            self.plan.owner, np.arange(old_n, ix.n, dtype=np.int64) % S])
        g2l = self._g2l
        if g2l.shape[1] < ix.n:  # index crossed a doubling boundary
            g2l = np.pad(g2l, ((0, 0), (0, ix.capacity - g2l.shape[1])),
                         constant_values=PAD_ID)
        rc = self.plan.resident_configs
        adds: list[set[int]] = [set() for _ in range(S)]
        for u in range(old_n, ix.n):
            adds[u % S].add(u)
        for ci, u in mems:
            if rc and int(ix.cluster_config[ci]) >= rc:
                continue  # tiered residency: configuration not resident
            s = int(cluster_shard[ci])
            if g2l[s, u] == PAD_ID:
                adds[s].add(u)
        residents = []
        stale: list[int] = []  # shards whose old rows need a remap pass
        for s in range(S):
            new = np.array(sorted(a for a in adds[s]
                                  if g2l[s, a] == PAD_ID), dtype=np.int64)
            if len(new) and new[0] < old_n:
                # A pre-existing user gained residency here (cohort
                # refresh): its in-edges on this shard predate the row
                # journal window, so the whole shard remaps.
                stale.append(s)
                residents.append(np.unique(
                    np.concatenate([self.plan.residents[s], new])))
            elif len(new):
                residents.append(
                    np.concatenate([self.plan.residents[s], new]))
            else:
                residents.append(self.plan.residents[s])
        # Imbalance stays stale on the delta path (cluster_sizes +
        # lpt_loads are O(members) host work per sync — per INSERT under
        # a sharded engine); rebuilds and extend_plan refresh it.
        self.plan = ShardPlan(
            n_shards=S, cluster_shard=cluster_shard, residents=residents,
            owner=owner, imbalance=self.plan.imbalance,
            version=self.plan.version, resident_configs=rc)
        cap = max(capacity_of(len(r), minimum=64) for r in residents)
        if cap != self.cap:  # doubling boundary: shapes change anyway
            self._materialize()
            self._record_remap(old_l2g)
            return "rebuild"
        self._g2l = g2l
        dev = list(self._dev)
        for s in range(S):
            if s in stale:
                l2g_b, g2l_b, graph, rev, words, card, tomb = \
                    self._shard_block(s, cap)
                self._g2l[s] = g2l_b
                updates = (graph, rev, words, card, l2g_b, tomb)
                dev = [a.at[s].set(jnp.asarray(u))
                       for a, u in zip(dev, updates)]
                continue
            res = residents[s]
            # Delta adds are all fresh rows (ids >= old_n) here, so the
            # sorted resident array grew by pure appends — existing
            # local indices are untouched.
            new = res[np.searchsorted(res, old_n):]
            m_old = len(res) - len(new)
            if len(new):
                self._g2l[s, new] = np.arange(m_old, len(res),
                                              dtype=np.int32)
            # Touched rows resident here: journaled mutations + the new
            # rows themselves (their adjacency may also reference other
            # fresh residents, so remap with the UPDATED g2l).
            touch = np.array(sorted({int(r) for r in rows
                                     if g2l_local(self._g2l[s], r)}
                                    | set(int(u) for u in new)),
                             dtype=np.int64)
            if not len(touch):
                continue
            loc = self._g2l[s, touch]
            li = jnp.asarray(loc.astype(np.int32))
            gr = self._remap(self._g2l[s], ix.graph_ids[touch])
            rv = self._remap(self._g2l[s], ix.rev_ids[touch])
            dev[0] = dev[0].at[s, li].set(jnp.asarray(gr))
            dev[1] = dev[1].at[s, li].set(jnp.asarray(rv))
            dev[2] = dev[2].at[s, li].set(jnp.asarray(ix.words[touch]))
            dev[3] = dev[3].at[s, li].set(jnp.asarray(ix.card[touch]))
            dev[4] = dev[4].at[s, li].set(
                jnp.asarray(touch.astype(np.int32)))
            dev[5] = dev[5].at[s, li].set(jnp.asarray(ix.tombstone[touch]))
        if self._sharding is not None:  # keep the per-device pinning
            dev = [a if a.sharding == self._sharding(a.ndim)
                   else jax.device_put(a, self._sharding(a.ndim))
                   for a in dev]
        self._dev = tuple(dev)
        self.version = ix.version
        self._n_seen = ix.n
        if stale:  # locals shifted on the rematerialized shards
            self._record_remap(old_l2g)
        return "delta"

    def adopt_plan(self, plan: ShardPlan, src=None) -> None:
        """Blue/green swap: install a freshly derived partition and
        rebuild every resident tensor in one shot.

        The re-balancer (``query/rebalance.py``) calls this BETWEEN
        scheduler steps with a fresh :func:`plan_shards` — the one
        reshard where residency is NOT monotone (rows migrate off
        shards). ``src`` supplies row content reconstructed by symmetric
        merge of the old shard subgraphs; None re-scatters from the
        index (bitwise the same tensors — the merge is audited against
        the index, see ``merge_subgraph_rows``). In-flight slot beams
        survive through the recorded old→new local map: rows still
        resident keep descending under new labels, evicted rows drop to
        PAD (their sims are masked to NEG_INF when the continuous plan
        applies the map). The plan, tensors, g2l, and pending remap all
        move in this one host-side call, so no request ever observes a
        half-swapped generation.
        """
        old_l2g = np.asarray(self._dev[4])
        self.base_plan = plan
        self.plan = plan
        self._materialize(src=src)
        self._record_remap(old_l2g)
        self.generation += 1
        # The swap installs freshly rebuilt tensors for every shard; the
        # failover manager re-masks any shard that is still unhealthy.
        self.dead = np.zeros(self.plan.n_shards, dtype=bool)

    def _record_remap(self, old_l2g: np.ndarray):
        """Accumulate an old-local → new-local id map after a reshard
        that may have shifted local ids. Under the frozen-base extension
        residency is monotone, so every previously-resident row still
        has a local id — the map is total on live lanes (PAD stays
        PAD). After a re-balance swap (:meth:`adopt_plan`) rows may have
        left their shard: those lanes map to PAD, and the continuous
        plan masks their sims out of the beam."""
        S = old_l2g.shape[0]
        rows = np.arange(S)[:, None]
        safe = np.where(old_l2g == PAD_ID, 0, old_l2g)
        mp = np.where(old_l2g == PAD_ID, PAD_ID, self._g2l[rows, safe])
        if self._beam_remap is not None:  # compose with an unconsumed map
            prev = self._beam_remap
            psafe = np.where(prev == PAD_ID, 0, prev)
            mp = np.where(prev == PAD_ID, PAD_ID, mp[rows, psafe])
        self._beam_remap = mp.astype(np.int32)

    def take_beam_remap(self) -> np.ndarray | None:
        """Consume the pending old→new local-id map (int32[S, old_cap]),
        or None when local ids were stable since the last take. The
        continuous plan applies it to in-flight per-shard slot beams
        before the next hop — beam *contents* (global identity + sims)
        are unchanged, only their local labels move, so results stay
        bitwise wave-identical across mid-stream reshards. Lanes the map
        sends to PAD (rows evicted by a re-balance swap) must also have
        their sims masked to NEG_INF by the consumer."""
        mp, self._beam_remap = self._beam_remap, None
        return mp

    # -- serving -----------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    def set_dead(self, mask) -> None:
        """Install the degraded-serving mask (bool[n_shards]); dead
        shards stop receiving seeds and stop contributing to merges
        from the next descent on."""
        mask = np.asarray(mask, dtype=bool)
        assert mask.shape == (self.plan.n_shards,), mask.shape
        self.dead = mask.copy()

    def shard_seeds(self, seeds: np.ndarray) -> np.ndarray:
        """Partition routed global seeds by ownership and remap to local.

        Returns int32[S, q, S_cols]: seed ids in shard-local coordinates;
        a seed appears on exactly the shard owning that user (PAD
        elsewhere), so the fleet explores disjoint basins. Seeds owned
        by a dead shard are dropped entirely — their basins are the
        degraded-mode recall loss — rather than re-homed: survivors do
        not host those rows (tiered residency may not host them at
        all), and a deterministic drop is what the masked-seed parity
        test pins against a shard-excluded rebuild.
        """
        S = self.n_shards
        safe = np.where(seeds == PAD_ID, 0, seeds)
        owned = ((self.plan.owner[safe][None]
                  == np.arange(S)[:, None, None])
                 & (seeds[None] != PAD_ID))              # [S, q, cols]
        if self.dead.any():
            owned &= ~self.dead[:, None, None]
        local = self._g2l[:, safe]
        return np.where(owned, local, PAD_ID)

    def descend(self, q_words, q_card, seeds: np.ndarray, *,
                k: int, beam: int, hops: int, kernel: bool = False,
                dma: bool = False, tag=None):
        """Route-seeded descent on every shard + cross-shard top-k merge.

        ``seeds`` are global ids (router output, PAD padded); ``beam`` is
        the single-device frontier width, divided among shards (with
        ``self.oversample`` slack, floored at k). ``kernel`` selects the
        fused Pallas hop, ``dma`` its HBM-resident placement
        (bitwise-identical results either way). ``tag`` (a
        hashable plan key) lands in the jit-trace counter so
        ``sched.trace.compile_count`` can assert compile-once per plan.
        Returns (ids int32[q, k], sims float32[q, k]) in global ids.
        As a side effect, ``self.last_hop_stats`` holds this call's
        per-query ``(n_scored, dma_bytes, bytes_saved)`` i32[q, 3],
        summed over ALIVE shards (the plan reads it right after the
        call to feed serving stats).
        """
        l_seeds = jnp.asarray(self.shard_seeds(seeds))
        shard_beam = self.shard_beam(beam, k)
        args = (*self._dev, jnp.asarray(q_words), jnp.asarray(q_card),
                l_seeds)
        if self.mesh is not None:
            program = _mesh_program(self.mesh, k=k, beam=shard_beam,
                                    hops=hops, kernel=kernel, dma=dma,
                                    tag=tag)
            ids, sims, stats = program(*args)
        else:
            ids, sims, stats = _vmapped_descent(
                *args, k=k, beam=shard_beam, hops=hops, kernel=kernel,
                dma=dma, tag=tag)
        if self.dead.any():
            # Belt and braces on top of the seed drop: a dead shard
            # contributes nothing to the merge even if a stale seed
            # slipped in (e.g. a continuous slot admitted pre-failure).
            alive = jnp.asarray(~self.dead)[:, None, None]
            ids = jnp.where(alive, ids, PAD_ID)
            sims = jnp.where(alive, sims, NEG_INF)
            stats = jnp.where(alive, stats, 0)
        self.last_hop_stats = np.asarray(jnp.sum(stats, axis=0))
        return _merge_shard_topk(ids, sims, k)

    def shard_beam(self, beam: int, k: int) -> int:
        """Per-shard frontier width for a fleet-level ``beam``."""
        return max(k, int(np.ceil(self.oversample * beam / self.n_shards)))

    def resident_bytes(self) -> list[int]:
        """Per-shard bytes of RESIDENT rows (adjacency + reverse +
        fingerprint words + card + l2g + tombstone) — the quantity
        tiered residency trades recall against (padding to ``cap``
        excluded: it is shared dead weight, not per-row cost)."""
        per_row = self.index.row_bytes
        return [len(r) * per_row for r in self.plan.residents]


def g2l_local(g2l_row: np.ndarray, r: int) -> bool:
    """True when global row ``r`` is resident in this shard's map."""
    return r < len(g2l_row) and g2l_row[r] != PAD_ID


def _per_shard(graph, rev, words, card, l2g, tomb, q_words, q_card, seeds,
               *, k, beam, hops, kernel=False, dma=False):
    """One shard's descent; results mapped back to global ids."""
    ids, sims, stats = descent_kernel(graph, rev, words, card,
                                      q_words, q_card, seeds,
                                      k=k, beam=beam, hops=hops,
                                      kernel=kernel, dma=dma, tomb=tomb)
    safe = jnp.where(ids == PAD_ID, 0, ids)
    return jnp.where(ids == PAD_ID, PAD_ID, l2g[safe]), sims, stats


@functools.partial(jax.jit,
                   static_argnames=("k", "beam", "hops", "kernel", "dma",
                                    "tag"))
def _vmapped_descent(l_graph, l_rev, l_words, l_card, l2g, l_tomb,
                     q_words, q_card, l_seeds, *, k, beam, hops,
                     kernel=False, dma=False, tag=None):
    """Single-device fallback: the shard axis is a vmap axis (the fused
    Pallas hop batches through its pallas_call batching rule)."""
    trace.bump(("query_wave_sharded", tag, l_graph.shape[0],
                q_words.shape[0], k, beam, hops, kernel, dma))
    return jax.vmap(
        lambda g, r, w, c, m, t, s: _per_shard(
            g, r, w, c, m, t, q_words, q_card, s, k=k, beam=beam,
            hops=hops, kernel=kernel, dma=dma)
    )(l_graph, l_rev, l_words, l_card, l2g, l_tomb, l_seeds)


@functools.lru_cache(maxsize=64)
def _mesh_program(mesh, *, k, beam, hops, kernel=False, dma=False,
                  tag=None):
    """SPMD path: one shard per device, no collectives inside (the merge
    happens after the shard-parallel top-k, mirroring
    distributed_local_knn's reduce phase). Returns a jitted callable.

    Cached at module level (jax.sharding.Mesh hashes by devices + axis
    names), so resharding after an insert burst reuses the compiled
    program as long as shapes and (k, beam, hops) are unchanged —
    symmetric with the module-level jitted ``_vmapped_descent``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def device_fn(g, r, w, c, m, t, qw, qc, s):
        trace.bump(("query_wave_sharded", tag, len(mesh.devices),
                    qw.shape[0], k, beam, hops, kernel, dma))
        ids, sims, stats = _per_shard(g[0], r[0], w[0], c[0], m[0], t[0],
                                      qw, qc, s[0],
                                      k=k, beam=beam, hops=hops,
                                      kernel=kernel, dma=dma)
        return ids[None], sims[None], stats[None]

    in_specs = (P("shards", None, None), P("shards", None, None),
                P("shards", None, None), P("shards", None),
                P("shards", None), P("shards", None),
                P(), P(), P("shards", None, None))
    out_specs = (P("shards", None, None), P("shards", None, None),
                 P("shards", None, None))
    return jax.jit(shard_map(device_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_shard_topk(ids, sims, k: int):
    """[S, q, k'] per-shard results → global top-k per query."""
    S, q, kk = ids.shape
    flat_ids = jnp.swapaxes(ids, 0, 1).reshape(q, S * kk)
    flat_sims = jnp.swapaxes(sims, 0, 1).reshape(q, S * kk)
    return merge_topk(flat_ids, flat_sims, k)
