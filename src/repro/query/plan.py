"""Composable descent plans: placement × batching × scorer.

A :class:`DescentPlan` is the one serving abstraction behind
:class:`~repro.query.engine.QueryEngine`. Where the engine used to
enumerate hand-rolled paths (single-device wave, continuous slots,
sharded wave) a plan is the CROSS-PRODUCT of three independent axes:

* **placement** — ``1`` (single device) or ``N`` LPT cluster shards
  (``query/sharded.py``: owner-partitioned seeds, per-shard local
  subgraphs, cross-shard top-k merge);
* **batching** — ``"wave"`` (closed batches, one jitted program per
  wave capacity) or ``"continuous"`` (slot scheduler from ``sched/``,
  streaming admission, per-slot hop budgets);
* **scorer** — ``"jnp"`` (unfused reference hop), ``"pallas"`` (the
  fused ``kernels/descent_score`` hop, tables staged through blocked
  VMEM), or ``"pallas_dma"`` (same fused hop with HBM-resident tables
  and per-chunk candidate-row DMA); all three bitwise-identical.

Any combination is a valid plan; every axis composes with every other
because the hop itself is row-independent (``query/search.py``) — the
shard axis vmaps over it, the slot axis scatters into it, and the
scorer swaps inside it. Each plan compiles one program per (plan,
shape) — tagged with :attr:`PlanSpec.key` in the ``sched.trace``
counters so ``trace.compile_count(plan.key)`` can assert compile-once
across admissions and reshards — and OWNS its device state:

* single placement: journal-repaired padded index copies (the former
  ``QueryEngine._sync``);
* sharded placement: a delta-reshardable
  :class:`~repro.query.sharded.ShardedDescent` — no full-index device
  copy exists in sharded mode (which halves sharded serving's index
  memory vs the pre-plan engine).

Result invariants (locked down by ``tests/test_plan.py``): for a fixed
placement, batching and scorer NEVER change a result — continuous ==
wave and pallas == jnp, bitwise on (ids, sims). Placement is the one
axis that trades results for scale (disjoint seed basins + dropped
cross-shard edges), and it does so identically under every batching ×
scorer combination.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_knn import capacity_of
from repro.query.cache import ResultCache
from repro.query.index import KNNIndex
from repro.query.router import (fingerprint_profiles, placements,
                                profiles_to_csr, route)
from repro.query.search import (batched_descent, shard_slot_admit,
                                shard_slot_hop, shard_slot_topk,
                                slot_admit, slot_hop, slot_prefix_stable)
from repro.sched import ADMISSION_POLICIES, SlotScheduler, shed_and_select
from repro.sched import trace
from repro.types import NEG_INF, PAD_ID

BATCHINGS = ("wave", "continuous")
SCORERS = ("jnp", "pallas", "pallas_dma")


def _csr_subset(items: np.ndarray, offsets: np.ndarray,
                idxs) -> tuple[np.ndarray, np.ndarray]:
    """CSR rows ``idxs`` of a (items, offsets) profile batch."""
    rows = [items[offsets[i]:offsets[i + 1]] for i in idxs]
    sizes = np.array([len(r) for r in rows], dtype=np.int64)
    out_offsets = np.zeros(len(rows) + 1, dtype=np.int64)
    np.cumsum(sizes, out=out_offsets[1:])
    out_items = (np.concatenate(rows) if rows
                 else np.zeros((0,), np.int32)).astype(np.int32)
    return out_items, out_offsets


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """Static description of a descent plan (hashable, validated).

    ``QueryConfig.spec()`` maps the engine's flag pile onto one of
    these; benchmarks and tests can also build them directly.
    """

    placement: int = 1          # shards (1 = single device)
    batching: str = "wave"      # "wave" | "continuous"
    scorer: str = "jnp"         # "jnp" | "pallas" | "pallas_dma"
    k: int = 10
    beam: int = 32
    hops: int = 3
    max_wave: int = 256         # wave batching: queries per program
    slots: int = 32             # continuous batching: in-flight capacity
    seeds_per_config: int = 16
    shard_oversample: float = 1.5
    admission: str = "fifo"     # "fifo" | "slo" (priority + deadline
                                # admission with explicit shedding)
    max_pending: int = 0        # slo: pending-queue bound (0 = unbounded)
    adaptive: int = 0           # continuous: free a slot once its top-k
                                # prefix held this many hops (0 = off)
    cache: int = 0              # fingerprint result-cache capacity (0=off)
    resident_configs: int = 0   # tiered residency: clusters of the first
                                # m hash configurations contribute shard
                                # residents (0 = all t; sharded only)

    def __post_init__(self):
        if self.placement < 1:
            raise ValueError(
                f"plan placement must be >= 1 shard, got {self.placement}")
        if self.batching not in BATCHINGS:
            raise ValueError(
                f"unknown batching {self.batching!r}; supported: "
                f"{BATCHINGS} (every batching composes with every "
                f"placement and scorer)")
        if self.scorer not in SCORERS:
            raise ValueError(
                f"unknown scorer {self.scorer!r}; supported: {SCORERS}")
        if self.batching == "continuous" and self.slots < 1:
            raise ValueError(f"continuous plans need slots >= 1, "
                             f"got {self.slots}")
        if self.batching == "wave" and self.max_wave < 1:
            raise ValueError(f"wave plans need max_wave >= 1, "
                             f"got {self.max_wave}")
        if self.k < 1 or self.hops < 0:
            raise ValueError(f"invalid k={self.k} / hops={self.hops}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission {self.admission!r}; supported: "
                f"{ADMISSION_POLICIES}")
        if self.max_pending < 0:
            raise ValueError(
                f"max_pending must be >= 0, got {self.max_pending}")
        if self.max_pending > 0 and self.admission != "slo":
            raise ValueError(
                "max_pending bounds the slo admission queue; pure FIFO "
                "never sheds (set admission='slo' to bound the queue)")
        if self.adaptive < 0:
            raise ValueError(f"adaptive patience must be >= 0, "
                             f"got {self.adaptive}")
        if self.adaptive > 0 and self.batching != "continuous":
            raise ValueError(
                "adaptive hop budgets free continuous slots on top-k "
                "prefix stability; wave batching has no per-request "
                "termination (use batching='continuous')")
        if self.cache < 0:
            raise ValueError(f"cache capacity must be >= 0, "
                             f"got {self.cache}")
        if self.resident_configs < 0:
            raise ValueError(f"resident_configs must be >= 0, "
                             f"got {self.resident_configs}")
        if self.resident_configs > 0 and self.placement == 1:
            raise ValueError(
                "resident_configs restricts SHARD residency to a subset "
                "of hash configurations; a single-device placement hosts "
                "every row (use placement > 1)")

    @property
    def kernel(self) -> bool:
        return self.scorer in ("pallas", "pallas_dma")

    @property
    def dma(self) -> bool:
        """HBM-resident table placement with per-chunk candidate DMA
        (``kernels/descent_score/ops.descent_hop(dma=True)``)."""
        return self.scorer == "pallas_dma"

    @property
    def key(self) -> tuple:
        """The plan's identity on the serving axes — the jit-trace tag
        (``sched.trace.compile_count``) and the bench row key."""
        return (self.placement, self.batching, self.scorer)

    def describe(self) -> str:
        place = ("single" if self.placement == 1
                 else f"sharded({self.placement})")
        batch = ("wave" if self.batching == "wave"
                 else f"continuous(slots={self.slots})")
        base = f"{place} x {batch} x {self.scorer}"
        extras = []
        if self.admission != "fifo":
            extras.append(f"slo(max_pending={self.max_pending})")
        if self.adaptive:
            extras.append(f"adaptive({self.adaptive})")
        if self.cache:
            extras.append(f"cache({self.cache})")
        if self.resident_configs:
            extras.append(f"resident_configs({self.resident_configs})")
        return base + (" + " + ", ".join(extras) if extras else "")


class _SlotState:
    """Device-resident per-slot state for a continuous plan.

    Mirrors PR 3's single-device slot arrays, with one twist: under a
    sharded placement the beams carry a leading shard axis
    (``[S, n_slots, shard_beam]``) — every shard advances its own beam
    per slot, and the cross-shard merge happens at release time. Query
    fingerprints, hop counters, and the scheduler stay shard-agnostic.
    """

    def __init__(self, index: KNNIndex, spec: PlanSpec, beam: int,
                 pin=None, clock=None):
        n_slots = spec.slots
        self.beam = beam
        self.admit_cap = int(np.clip(n_slots // 4, 8, 32))
        self.seed_cols = index.t * spec.seeds_per_config
        self.sched = SlotScheduler(n_slots, policy=spec.admission,
                                   max_pending=spec.max_pending,
                                   clock=clock)
        self.q_words = jnp.zeros((n_slots, index.words.shape[1]),
                                 jnp.uint32)
        self.q_card = jnp.zeros(n_slots, jnp.int32)
        if spec.placement > 1:
            shape = (spec.placement, n_slots, beam)
        else:
            shape = (n_slots, beam)
        beam_ids = np.full(shape, PAD_ID, np.int32)
        beam_sims = np.full(shape, NEG_INF, np.float32)
        # On a mesh, per-shard beams live on their shard's device.
        self.beam_ids = pin(beam_ids) if pin else jnp.asarray(beam_ids)
        self.beam_sims = pin(beam_sims) if pin else jnp.asarray(beam_sims)
        self.hops_done = np.zeros(n_slots, np.int64)
        self.budget = np.full(n_slots, spec.hops, np.int64)
        # Adaptive-budget bookkeeping (allocated only when the policy is
        # on): per-slot count of consecutive hops whose top-k prefix was
        # unchanged, the device-resident previous prefix it compares
        # against, and a freshness flag so a re-admitted slot never
        # inherits its previous occupant's prefix (identical repeated
        # queries would otherwise look "stable" at hop one).
        self.streak = np.zeros(n_slots, np.int64)
        self.fresh = np.ones(n_slots, bool)
        self.prefix_ids = None
        if spec.adaptive > 0:
            pshape = ((spec.placement, n_slots, spec.k)
                      if spec.placement > 1 else (n_slots, spec.k))
            prefix = np.full(pshape, PAD_ID, np.int32)
            self.prefix_ids = pin(prefix) if pin else jnp.asarray(prefix)


class DescentPlan:
    """One placement × batching × scorer combination, compiled once per
    shape, owning its device state and serving loop.

    The engine's whole serving surface is ``submit → plan.step(queue,
    done) → collect``; ``search``/``query_batch`` expose the raw wave
    program (used for insert searches and benchmarks under any plan).
    """

    def __init__(self, index: KNNIndex, spec: PlanSpec, clock=None):
        self.index = index
        self.spec = spec
        self.key = spec.key
        self.beam = max(spec.beam, spec.k)
        # Injectable clock (defaults to wall time): every completion /
        # shed / deadline stamp in the serving loop reads it, so fault
        # and SLO tests drive latency deterministically (sched.ManualClock).
        self.clock = clock or time.perf_counter
        self._single = None     # (version, cap, device arrays)
        self._sharded = None    # ShardedDescent (delta-synced)
        self._slots: Optional[_SlotState] = None
        self.n_ticks = 0
        # Memory-hierarchy accounting for kernel scorers, accumulated
        # over every hop this plan ran (real query rows only — pad rows
        # and inactive slots are masked out before they land here).
        # ``scored_lanes`` counts candidate lanes that survived
        # suppression; for the DMA scorer ``dma_bytes`` is the
        # fingerprint traffic actually moved HBM→VMEM and
        # ``bytes_saved`` the traffic the suppressed-lane skip avoided.
        # The jnp scorer contributes zeros (it moves no explicit DMA).
        self.descent_stats = {"scored_lanes": 0, "dma_bytes": 0,
                              "bytes_saved": 0, "hop_queries": 0}
        # Fingerprint-keyed result cache (query/cache.py), flushed on
        # journal-visible index mutations — exact hits serve without a
        # descent, bitwise-identically to one.
        self.cache = ResultCache(index, spec.cache) if spec.cache else None

    def describe(self) -> str:
        return self.spec.describe()

    def _note_stats(self, stats) -> None:
        """Fold one program's hop accounting (i32[rows, 3] of
        ``(n_scored, dma_bytes, bytes_saved)``, already masked to real
        rows) into :attr:`descent_stats`."""
        s = np.asarray(stats, dtype=np.int64)
        if s.size == 0:
            return
        self.descent_stats["scored_lanes"] += int(s[:, 0].sum())
        self.descent_stats["dma_bytes"] += int(s[:, 1].sum())
        self.descent_stats["bytes_saved"] += int(s[:, 2].sum())
        self.descent_stats["hop_queries"] += int(s.shape[0])

    # -- device state ------------------------------------------------------

    def sync(self):
        """Repair this plan's device state to the index's version.

        Single placement: journal-driven row scatter into the padded
        full-index copy. Sharded placement: delta reshard
        (:meth:`ShardedDescent.sync`) — the plan never materializes a
        full-index device copy in sharded mode.
        """
        if self.spec.placement > 1:
            return self._sync_sharded()
        return self._sync_single()

    def _sync_single(self):
        """Device copies of the index, padded to a power-of-two row count.

        Stale copies are repaired incrementally when possible: an insert
        touches only the new row plus its patched neighbors (the index
        journals them — :meth:`KNNIndex.rows_changed_since`), so those
        rows are scattered into the resident device arrays instead of
        re-padding and re-uploading all n rows per version bump. The full
        upload happens only on first use, capacity crossings, or after
        enough mutations that the journal no longer helps."""
        ix = self.index
        if self._single is not None and self._single[0] == ix.version:
            return self._single[2]
        n, cap = ix.n, capacity_of(ix.n, minimum=64)
        if self._single is not None and self._single[1] == cap:
            changed = ix.rows_changed_since(self._single[0])
            if changed is not None and len(changed) <= max(64, n // 8):
                arrays = self._single[2]
                if changed:
                    rows = np.fromiter(sorted(changed), dtype=np.int64,
                                       count=len(changed))
                    idx = jnp.asarray(rows)
                    g, r, w, c, t = arrays
                    arrays = (
                        g.at[idx].set(jnp.asarray(ix.graph_ids[rows])),
                        r.at[idx].set(jnp.asarray(ix.rev_ids[rows])),
                        w.at[idx].set(jnp.asarray(ix.words[rows])),
                        c.at[idx].set(jnp.asarray(ix.card[rows])),
                        t.at[idx].set(jnp.asarray(ix.tombstone[rows])),
                    )
                self._single = (ix.version, cap, arrays)
                return arrays
        pad = cap - n
        arrays = (
            jnp.asarray(np.pad(ix.graph_ids, ((0, pad), (0, 0)),
                               constant_values=PAD_ID)),
            jnp.asarray(np.pad(ix.rev_ids, ((0, pad), (0, 0)),
                               constant_values=PAD_ID)),
            jnp.asarray(np.pad(ix.words, ((0, pad), (0, 0)))),
            jnp.asarray(np.pad(ix.card, (0, pad))),
            jnp.asarray(np.pad(ix.tombstone, (0, pad))),
        )
        self._single = (ix.version, cap, arrays)
        return arrays

    def _sync_sharded(self):
        from repro.query.sharded import ShardedDescent

        if (self._sharded is None
                or self._sharded.n_shards != self.spec.placement):
            self._sharded = ShardedDescent(
                self.index, self.spec.placement,
                oversample=self.spec.shard_oversample,
                resident_configs=self.spec.resident_configs)
        else:
            self._sharded.sync()
        return self._sharded

    def sharded_state(self):
        """The delta-synced ShardedDescent, or None for single-device
        placements. Public accessor for diagnostics."""
        return self._sync_sharded() if self.spec.placement > 1 else None

    def _degraded(self) -> bool:
        """True while any shard is masked out of serving (fault layer).
        Completions stamped in a degraded window carry
        ``req.degraded = True`` and are never cached."""
        sd = self._sharded
        return sd is not None and bool(sd.dead.any())

    def mask_shard_slots(self, down) -> None:
        """Wipe the in-flight per-shard slot beams of newly-downed
        shards (bool[S] mask): their lanes drop to PAD/NEG_INF so a
        dead shard's pre-failure beam content cannot win a release-time
        merge. Survivor shards' beams are untouched — in-flight
        requests keep descending on the healthy fleet. No-op for wave
        plans (no slot state) and single placements."""
        if self._slots is None or self.spec.placement <= 1:
            return
        down = np.asarray(down, dtype=bool)
        if not down.any():
            return
        st = self._slots
        d = jnp.asarray(down)[:, None, None]
        st.beam_ids = jnp.where(d, PAD_ID, st.beam_ids)
        st.beam_sims = jnp.where(d, NEG_INF, st.beam_sims)
        if self.spec.adaptive > 0:
            # Prefixes were computed against the full fleet — restart
            # every stability streak rather than free a slot on a
            # pre-failure comparison.
            st.streak[:] = 0
            st.fresh[:] = True

    def note_replan(self):
        """A blue/green re-balance swapped the sharded partition
        (``query/rebalance.py``). No index content changed — every
        journal would PROVE a no-op — but placement is the one axis
        that legitimately changes results, so cached pre-swap entries
        must never be served: flush explicitly. The flush counter bump
        also stops in-flight continuous requests (admitted pre-swap,
        completing post-swap) from populating the cache with straddled
        results."""
        if self.cache is not None:
            self.cache.invalidate()

    # -- raw wave-program search (any plan; insert + benchmarks use it) ----

    def search(self, items, offsets, qgf, k: int, *,
               hops: int | None = None, placed=None):
        """Route + beam-descend already-fingerprinted query profiles
        through this plan's placement (one closed wave, whatever the
        plan's batching — the raw batch API).

        With a result cache configured, exact-fingerprint hits are
        served from it (bitwise what the descent would return — the
        cache flushes on any journal-visible index mutation) and only
        the misses route + descend.
        """
        hops = self.spec.hops if hops is None else hops
        if self.cache is None:
            seeds = route(self.index, items, offsets,
                          self.spec.seeds_per_config, placed=placed)
            return self.descend_rows(qgf.words, qgf.card, seeds, k,
                                     hops=hops)
        self.cache.sync()
        qw, qc = np.asarray(qgf.words), np.asarray(qgf.card)
        qn = qw.shape[0]
        keys = [self.cache.key(qw[i], qc[i], k, hops) for i in range(qn)]
        out_ids = np.empty((qn, k), np.int32)
        out_sims = np.empty((qn, k), np.float32)
        miss = []
        for i, cache_key in enumerate(keys):
            hit = self.cache.get(cache_key)
            if hit is None:
                miss.append(i)
            else:
                out_ids[i], out_sims[i] = hit
        if miss:
            m_items, m_offsets = _csr_subset(items, offsets, miss)
            m_placed = ([placed[i] for i in miss]
                        if placed is not None else None)
            seeds = route(self.index, m_items, m_offsets,
                          self.spec.seeds_per_config, placed=m_placed)
            m_ids, m_sims = self.descend_rows(qw[miss], qc[miss], seeds,
                                              k, hops=hops)
            degraded = self._degraded()
            for j, i in enumerate(miss):
                out_ids[i], out_sims[i] = m_ids[j], m_sims[j]
                if degraded:
                    self.cache.degraded_skips += 1
                else:
                    self.cache.put(keys[i], m_ids[j], m_sims[j])
        return out_ids, out_sims

    def descend_rows(self, q_words, q_card, seeds, k: int, *,
                     hops: int | None = None, beam: int | None = None):
        """Beam-descend from EXPLICIT seed rows — no FRH routing.

        The lifecycle subsystem's localized re-linking runs through this:
        an updated (or repair-pass) user seeds descent from its current
        graph neighborhood instead of hash placement, so the search cost
        stays bounded by the neighborhood, not the index. Same compiled
        programs as :meth:`search` (the seed width — and the optional
        ``beam`` override — are the only new shape axes, and callers
        keep them static)."""
        spec = self.spec
        beam = max(self.beam if beam is None else beam, k)
        hops = spec.hops if hops is None else hops
        q_words = np.asarray(q_words)
        q_card = np.asarray(q_card)
        seeds = np.asarray(seeds)
        qn = q_words.shape[0]
        qcap = capacity_of(qn, minimum=8)
        qw = np.zeros((qcap, q_words.shape[1]), dtype=np.uint32)
        qw[:qn] = q_words
        qcard = np.zeros(qcap, dtype=np.int32)
        qcard[:qn] = q_card
        qseeds = np.full((qcap, seeds.shape[1]), PAD_ID, dtype=np.int32)
        qseeds[:qn] = seeds
        if spec.placement > 1:
            sd = self._sync_sharded()
            ids, sims = sd.descend(
                qw, qcard, qseeds, k=k, beam=beam, hops=hops,
                kernel=spec.kernel, dma=spec.dma, tag=self.key)
            self._note_stats(sd.last_hop_stats[:qn])
        else:
            graph_ids, rev_ids, words, card, tomb = self._sync_single()
            ids, sims, stats = batched_descent(
                graph_ids, rev_ids, words, card,
                jnp.asarray(qw), jnp.asarray(qcard), jnp.asarray(qseeds),
                k=k, beam=beam, hops=hops, kernel=spec.kernel,
                dma=spec.dma, tag=self.key, tomb=tomb)
            self._note_stats(np.asarray(stats)[:qn])
        return np.asarray(ids)[:qn], np.asarray(sims)[:qn]

    def query_batch(self, profiles, k: int | None = None,
                    hops: int | None = None):
        """Answer raw profiles: (ids int32[q, k], sims float32[q, k])."""
        items, offsets = profiles_to_csr(profiles)
        qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                   self.index.fp_seed)
        return self.search(items, offsets, qgf, k or self.spec.k,
                           hops=hops)

    # -- the serving loop --------------------------------------------------

    @property
    def scheduler(self) -> Optional[SlotScheduler]:
        """The continuous slot scheduler (None for wave plans)."""
        return self._slots.sched if self._slots is not None else None

    def busy(self) -> bool:
        """True while this plan holds in-flight work (continuous slots)."""
        return self._slots is not None and self._slots.sched.has_work()

    def step(self, queue, done) -> int:
        """Serve one scheduler step — one wave, or one continuous tick.

        Drains/admits from ``queue`` (a deque of QueryRequest-likes),
        appends completed requests to ``done`` with results + ``t_done``
        stamped, and returns how many completed. This is the ONLY
        serving path: every placement × batching × scorer combination
        goes through it.
        """
        if self.spec.batching == "continuous":
            return self._step_continuous(queue, done)
        return self._step_wave(queue, done)

    # -- wave batching -----------------------------------------------------

    def _reject(self, shed, done) -> int:
        """Complete shed requests with the ``rejected`` marker — they
        enter ``done`` (counted, latency-excluded) rather than vanish."""
        if not shed:
            return 0
        now = self.clock()
        for r in shed:
            r.status = "rejected"
            r.t_done = now
            done.append(r)
        return len(shed)

    def _step_wave(self, queue, done) -> int:
        """Close one wave from the queue; returns requests completed.

        A wave runs to the MAX hop budget of its members (the compiled
        program has one static hop count) — one deep request convoys
        every shallow request behind it. Continuous batching's per-slot
        hop budgets are the fix. Under slo admission the wave closes
        over the best (class, deadline) requests and expired/overflow
        requests are shed with a rejected marker; the default FIFO path
        is byte-identical to the pre-SLO wave.
        """
        spec = self.spec
        n_done = 0
        if spec.admission == "slo":
            wave, shed = shed_and_select(queue, spec.max_wave,
                                         self.clock(),
                                         spec.max_pending)
            n_done = self._reject(shed, done)
        else:
            wave = []
            while queue and len(wave) < spec.max_wave:
                wave.append(queue.popleft())
        if not wave:
            return n_done
        hops = max(r.hops if r.hops is not None else spec.hops
                   for r in wave)
        ids, sims = self.query_batch([r.profile for r in wave], hops=hops)
        now = self.clock()
        degraded = self._degraded()
        for j, r in enumerate(wave):
            r.ids, r.sims = ids[j], sims[j]
            r.t_done = now
            r.status = "done"
            r.degraded = degraded
            done.append(r)
        return len(wave) + n_done

    # -- continuous batching -----------------------------------------------

    def _slot_state(self) -> _SlotState:
        if self._slots is None:
            beam = self.beam
            pin = None
            if self.spec.placement > 1:
                sd = self._sync_sharded()
                beam = sd.shard_beam(self.beam, self.spec.k)
                if sd.mesh is not None:
                    pin = sd._pin
            self._slots = _SlotState(self.index, self.spec, beam, pin=pin,
                                     clock=self.clock)
        return self._slots

    def _slot_results(self, st: _SlotState):
        """(ids int32[n_slots, k], sims f32[n_slots, k]) host snapshots.

        Single placement: the beam is canonical, so top-k is its prefix.
        Sharded placement: per-shard prefixes merged cross-shard in
        global ids (:func:`~repro.query.search.shard_slot_topk`) —
        byte-identical to the wave path's closing merges either way.

        Every call is one host-side snapshot dispatch —
        ``trace.launch_count(("slot_results", plan.key))`` lets tests
        assert a tick costs ONE snapshot however many admission chunks
        (including zero-hop bursts) fed it.
        """
        trace.launch(("slot_results", self.key))
        k = self.spec.k
        if self.spec.placement > 1:
            ids, sims = shard_slot_topk(self._sharded._dev[4], st.beam_ids,
                                        st.beam_sims, k=k, tag=self.key)
            return np.asarray(ids), np.asarray(sims)
        return (np.asarray(st.beam_ids)[:, :k],
                np.asarray(st.beam_sims)[:, :k])

    def _admit(self, st: _SlotState, admitted, done) -> int:
        """Scatter an admission generation into the slot arrays,
        bucketed to ``admit_cap`` rows so one program compiles per
        bucket shape no matter how requests stream in.

        With a result cache, each admitted request is first looked up by
        exact fingerprint: hits complete immediately (slot released
        without ever entering the scatter — their rows keep the
        ``n_slots`` drop sentinel) and only misses are routed and
        scattered. Returns the number of cache-served completions so the
        tick loop can re-admit into the freed slots.
        """
        spec = self.spec
        items, offsets = profiles_to_csr([r.profile for _, r in admitted])
        qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                   self.index.fp_seed)
        qw, qc = np.asarray(qgf.words), np.asarray(qgf.card)
        n_hit = 0
        if self.cache is None:
            rows = [(j, slot, req)
                    for j, (slot, req) in enumerate(admitted)]
            m_items, m_offsets = items, offsets
        else:
            rows = []
            now = self.clock()
            for j, (slot, req) in enumerate(admitted):
                budget = req.hops if req.hops is not None else spec.hops
                ck = self.cache.key(qw[j], qc[j], spec.k, budget)
                hit = self.cache.get(ck)
                if hit is not None:
                    st.sched.release(slot)
                    req.ids, req.sims = hit
                    req.t_done = now
                    req.status = "done"
                    done.append(req)
                    n_hit += 1
                else:
                    # Completion caches this result only if the cache
                    # was never flushed while the request was in flight
                    # (flush count unchanged == every intervening
                    # version bump was provably a no-op).
                    req._cache_key = ck
                    req._cache_flushes = self.cache.flushes
                    rows.append((j, slot, req))
            if not rows:
                return n_hit
            m_items, m_offsets = _csr_subset(items, offsets,
                                             [j for j, _, _ in rows])
        seeds = route(self.index, m_items, m_offsets,
                      spec.seeds_per_config)
        A = st.admit_cap
        sharded = spec.placement > 1
        for lo in range(0, len(rows), A):
            chunk = rows[lo:lo + A]
            new_w = np.zeros((A, st.q_words.shape[1]), np.uint32)
            new_c = np.zeros(A, np.int32)
            new_s = np.full((A, st.seed_cols), PAD_ID, np.int32)
            # n_slots = one-past-the-end sentinel; the admit scatter
            # drops those rows (mode="drop").
            idx = np.full(A, st.sched.n_slots, np.int32)
            for p, (j, slot, req) in enumerate(chunk):
                new_w[p] = qw[j]
                new_c[p] = int(qc[j])
                new_s[p] = seeds[lo + p]
                idx[p] = slot
                st.hops_done[slot] = 0
                st.budget[slot] = (req.hops if req.hops is not None
                                   else spec.hops)
                st.streak[slot] = 0
                st.fresh[slot] = True
            if sharded:
                l_seeds = self._sharded.shard_seeds(new_s)  # [S, A, cols]
                st.q_words, st.q_card, st.beam_ids, st.beam_sims = \
                    shard_slot_admit(
                        self._sharded._dev[2], self._sharded._dev[3],
                        jnp.asarray(new_w), jnp.asarray(new_c),
                        jnp.asarray(l_seeds), jnp.asarray(idx),
                        st.q_words, st.q_card, st.beam_ids, st.beam_sims,
                        beam=st.beam, tag=self.key,
                        l_tomb=self._sharded._dev[5])
            else:
                words, card, tomb = self._sync_single()[2:5]
                st.q_words, st.q_card, st.beam_ids, st.beam_sims = \
                    slot_admit(words, card, jnp.asarray(new_w),
                               jnp.asarray(new_c), jnp.asarray(new_s),
                               jnp.asarray(idx), st.q_words, st.q_card,
                               st.beam_ids, st.beam_sims, beam=st.beam,
                               tag=self.key, tomb=tomb)
        return n_hit

    def _step_continuous(self, queue, done) -> int:
        """One continuous tick: admit into free slots, advance every
        in-flight beam one hop, complete converged/exhausted slots.

        Returns the number of requests completed this tick (cache hits,
        rejections, and descents alike). Admission is mid-flight: rows
        freed by a previous tick take fresh requests while the remaining
        rows keep descending — no wave barrier. Zero-hop admissions stay
        resident through the tick (excluded from the hop, finished by
        ``hops_done >= budget``) so a tick's completions cost ONE
        slot-result snapshot however many admission chunks fed it.
        """
        spec = self.spec
        self.sync()  # placement state must be current before any program
        had_state = self._slots is not None
        st = self._slot_state()
        if spec.placement > 1:
            # A reshard since the last tick may have relabeled shard-
            # local ids (per-shard rematerialization after a cohort
            # refresh); in-flight beams hold locals, so relabel them too.
            remap = self._sharded.take_beam_remap()
            if remap is not None and had_state:
                mp = jnp.asarray(remap)
                safe = jnp.where(st.beam_ids == PAD_ID, 0, st.beam_ids)
                st.beam_ids = jnp.where(
                    st.beam_ids == PAD_ID, PAD_ID,
                    jax.vmap(lambda m, b: m[b])(mp, safe))
                # A re-balance swap may have EVICTED beam rows from
                # their shard (the map sends them to PAD): mask their
                # sims so dead lanes cannot win a merge. Under the
                # monotone frozen-base extension no live lane maps to
                # PAD, so this is the identity there.
                st.beam_sims = jnp.where(st.beam_ids == PAD_ID, NEG_INF,
                                         st.beam_sims)
                if spec.adaptive > 0:
                    # Stored prefixes are in pre-reshard local labels —
                    # restart every stability streak rather than risk a
                    # stale comparison.
                    st.streak[:] = 0
                    st.fresh[:] = True
        sched = st.sched
        while queue:
            sched.submit(queue.popleft())
        if self.cache is not None:
            self.cache.sync()
        n_done = 0
        admitted = sched.admit()
        while admitted:
            freed = self._admit(st, admitted, done)
            n_done += freed
            if not freed:
                break
            # Cache hits released their slots mid-admission; keep
            # draining the pending queue into them.
            admitted = sched.admit()
        n_done += self._reject(sched.drain_shed(), done)
        active = sched.active_mask()
        if not active.any():
            return n_done
        # Zero-budget slots never enter the hop (wave parity: a hops=0
        # wave runs a length-0 scan) — they ride to the snapshot below.
        hop_active = active & (st.hops_done < st.budget)
        changed = np.zeros(active.shape[0], bool)
        if hop_active.any():
            if spec.placement > 1:
                sd = self._sharded
                st.beam_ids, st.beam_sims, changed, hop_stats = \
                    shard_slot_hop(
                        *sd._dev[:4], st.q_words, st.q_card,
                        st.beam_ids, st.beam_sims,
                        jnp.asarray(hop_active), kernel=spec.kernel,
                        dma=spec.dma, tag=self.key, l_tomb=sd._dev[5])
            else:
                graph_ids, rev_ids, words, card, tomb = \
                    self._sync_single()
                st.beam_ids, st.beam_sims, changed, hop_stats = slot_hop(
                    graph_ids, rev_ids, words, card, st.q_words,
                    st.q_card, st.beam_ids, st.beam_sims,
                    jnp.asarray(hop_active), kernel=spec.kernel,
                    dma=spec.dma, tag=self.key, tomb=tomb)
            changed = np.asarray(changed)
            # The compiled tick hops EVERY slot row (static shapes);
            # only count the rows the host actually considers active.
            self._note_stats(np.asarray(hop_stats)[hop_active])
            st.hops_done[hop_active] += 1
            self.n_ticks += 1
            if spec.adaptive > 0:
                stable, st.prefix_ids = slot_prefix_stable(
                    st.beam_ids, st.prefix_ids, k=spec.k, tag=self.key)
                stable = np.asarray(stable)
                # A slot's FIRST hop compares against its previous
                # occupant's prefix — `fresh` keeps it out of the streak.
                gained = hop_active & stable & ~st.fresh
                st.streak[gained] += 1
                st.streak[hop_active & ~gained] = 0
                st.fresh[hop_active] = False
        # Exact completions: budget exhausted, or the full beam hit its
        # fixed point this hop (no further hop can change it — the
        # result IS the full-budget result, hence cacheable). Adaptive
        # frees on top-k-prefix stability are approximate: served, but
        # never cached.
        exact = (st.hops_done >= st.budget) | (hop_active & ~changed)
        finished = active & exact
        if spec.adaptive > 0:
            finished = finished | (hop_active
                                   & (st.streak >= spec.adaptive))
        if not finished.any():
            return n_done
        ids, sims = self._slot_results(st)
        now = self.clock()
        degraded = self._degraded()
        slots = np.flatnonzero(finished)
        for slot, req in zip(slots, sched.release_many(slots)):
            req.ids = ids[slot].copy()
            req.sims = sims[slot].copy()
            req.t_done = now
            req.status = "done"
            req.degraded = degraded
            done.append(req)
            n_done += 1
            if (self.cache is not None and exact[slot]
                    and getattr(req, "_cache_flushes", -1)
                    == self.cache.flushes):
                if degraded:
                    # A masked-fleet answer is NOT what a healthy
                    # descent would return — serving it later as a
                    # cache hit would outlive the failure window.
                    self.cache.degraded_skips += 1
                else:
                    self.cache.put(req._cache_key, req.ids, req.sims)
        return n_done
