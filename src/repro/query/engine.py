"""Queue → wave query engine over a :class:`~repro.query.index.KNNIndex`.

Modeled on ``serve/engine.py``: requests queue up, are drained in waves
of up to ``max_wave``, and each wave runs one jitted
:func:`~repro.query.search.batched_descent`. Wave row-counts and the
index row-count are padded to power-of-two capacities so each (capacity,
beam, hops, k) shape compiles once and is reused across waves — the same
padded-capacity-group discipline as ``core/local_knn.py``.

Online insertion: :meth:`QueryEngine.insert` searches for the new
profile's neighbors, appends its fingerprint + forward edges to the
index (O(degree) — the index grows into spare capacity), patches reverse
edges (bounded-heap displacement), and registers the user in its FRH
clusters so subsequent queries route to it. Inserted profiles accumulate
in a *cohort*; once it exceeds ``QueryConfig.refresh_every`` the engine
re-runs C² clustering on the cohort (:meth:`KNNIndex.refresh_cohort`) so
drifting insert streams grow fresh routable clusters.

Sharded serving (``QueryConfig.shards > 1``): descent runs per LPT
cluster shard with a cross-shard top-k merge (repro/query/sharded.py) —
``shard_map`` over the mesh when a device per shard exists, vmapped on
one device otherwise.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.local_knn import capacity_of
from repro.eval.metrics import knn_recall
from repro.query.index import KNNIndex
from repro.query.router import (fingerprint_profiles, placements,
                                profiles_to_csr, route)
from repro.query.search import (batched_descent, exact_knn, slot_admit,
                                slot_hop)
from repro.sched import SlotScheduler
from repro.types import NEG_INF, PAD_ID


@dataclasses.dataclass
class QueryRequest:
    rid: int
    profile: np.ndarray                  # int32[|P|] item ids
    hops: Optional[int] = None           # per-request hop budget
                                         # (None → QueryConfig.hops)
    # Filled by the engine:
    ids: Optional[np.ndarray] = None     # int32[k] neighbor ids
    sims: Optional[np.ndarray] = None    # float32[k] similarities
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class QueryConfig:
    k: int = 10                # neighbors returned per query
    beam: int = 32             # descent frontier width
    hops: int = 3              # descent depth (fixed, compiled in)
    max_wave: int = 256        # queries per jitted wave
    seeds_per_config: int = 16 # routed seed candidates per hash config
    shards: int = 1            # >1: LPT cluster shards + cross-shard merge
    shard_oversample: float = 1.5  # fleet frontier vs single-device beam
    refresh_every: int = 64    # cohort size triggering re-clustering
    continuous: bool = False   # slot-based streaming admission (sched/)
    slots: int = 32            # in-flight capacity in continuous mode
    kernel: bool = False       # fused Pallas descent-scoring hop
                               # (kernels/descent_score; bitwise-identical
                               # results, interpret mode off-TPU)


class _ContinuousState:
    """Per-slot state for the continuous-batching path.

    Beam state and query fingerprints are DEVICE-resident at the fixed
    capacity ``QueryConfig.slots`` — admissions scatter into them
    (:func:`~repro.query.search.slot_admit`, bucketed to ``admit_cap``
    rows) and :func:`~repro.query.search.slot_hop` advances them in
    place, so a steady-state tick moves no per-slot query state across
    the host boundary. Hop counters and the scheduler stay on host.
    """

    def __init__(self, index: KNNIndex, qc: QueryConfig):
        n_slots, beam = qc.slots, max(qc.beam, qc.k)
        self.beam = beam
        self.admit_cap = int(np.clip(n_slots // 4, 8, 32))
        self.seed_cols = index.t * qc.seeds_per_config
        self.sched = SlotScheduler(n_slots)
        self.q_words = jnp.zeros((n_slots, index.words.shape[1]),
                                 jnp.uint32)
        self.q_card = jnp.zeros(n_slots, jnp.int32)
        self.beam_ids = jnp.full((n_slots, beam), PAD_ID, jnp.int32)
        self.beam_sims = jnp.full((n_slots, beam), NEG_INF, jnp.float32)
        self.hops_done = np.zeros(n_slots, np.int64)
        self.budget = np.full(n_slots, qc.hops, np.int64)  # per-slot hops


class QueryEngine:
    def __init__(self, index: KNNIndex, qc: QueryConfig | None = None):
        self.index = index
        self.qc = qc or QueryConfig()
        if self.qc.continuous and self.qc.shards > 1:
            raise ValueError(
                "continuous mode streams through the single-device slot "
                "program; sharded continuous serving is a ROADMAP item")
        self.queue: deque[QueryRequest] = deque()
        self.done: list[QueryRequest] = []
        self.n_inserted = 0
        self.n_refreshes = 0
        self.n_ticks = 0          # continuous slot_step invocations
        self._dev = None          # (version, n_cap, device arrays)
        self._sharded = None      # cached ShardedDescent (version keyed)
        self._cont: _ContinuousState | None = None
        self._cohort: list[tuple[int, np.ndarray]] = []  # (uid, profile)

    # -- device state ------------------------------------------------------

    def _sync(self):
        """Device copies of the index, padded to a power-of-two row count.

        Stale copies are repaired incrementally when possible: an insert
        touches only the new row plus its patched neighbors (the index
        journals them — :meth:`KNNIndex.rows_changed_since`), so those
        rows are scattered into the resident device arrays instead of
        re-padding and re-uploading all n rows per version bump. The full
        upload happens only on first use, capacity crossings, or after
        enough mutations that the journal no longer helps."""
        ix = self.index
        if self._dev is not None and self._dev[0] == ix.version:
            return self._dev[2]
        n, cap = ix.n, capacity_of(ix.n, minimum=64)
        if self._dev is not None and self._dev[1] == cap:
            changed = ix.rows_changed_since(self._dev[0])
            if changed is not None and len(changed) <= max(64, n // 8):
                arrays = self._dev[2]
                if changed:
                    rows = np.fromiter(sorted(changed), dtype=np.int64,
                                       count=len(changed))
                    idx = jnp.asarray(rows)
                    g, r, w, c = arrays
                    arrays = (
                        g.at[idx].set(jnp.asarray(ix.graph_ids[rows])),
                        r.at[idx].set(jnp.asarray(ix.rev_ids[rows])),
                        w.at[idx].set(jnp.asarray(ix.words[rows])),
                        c.at[idx].set(jnp.asarray(ix.card[rows])),
                    )
                self._dev = (ix.version, cap, arrays)
                return arrays
        pad = cap - n
        arrays = (
            jnp.asarray(np.pad(ix.graph_ids, ((0, pad), (0, 0)),
                               constant_values=PAD_ID)),
            jnp.asarray(np.pad(ix.rev_ids, ((0, pad), (0, 0)),
                               constant_values=PAD_ID)),
            jnp.asarray(np.pad(ix.words, ((0, pad), (0, 0)))),
            jnp.asarray(np.pad(ix.card, (0, pad))),
        )
        self._dev = (ix.version, cap, arrays)
        return arrays

    def _sync_sharded(self):
        """Cached per-shard subgraphs; rebuilt lazily after mutations, so
        an insert burst costs one reshard at the next query wave."""
        from repro.query.sharded import ShardedDescent

        ix = self.index
        if (self._sharded is None
                or self._sharded.version != ix.version
                or self._sharded.n_shards != self.qc.shards):
            self._sharded = ShardedDescent(
                ix, self.qc.shards, oversample=self.qc.shard_oversample)
        return self._sharded

    def sharded_state(self):
        """The current ShardedDescent (built on demand), or None when the
        engine serves single-device. Public accessor for diagnostics."""
        return self._sync_sharded() if self.qc.shards > 1 else None

    # -- core batched path -------------------------------------------------

    def query_batch(self, profiles, k: int | None = None,
                    hops: int | None = None):
        """Answer a batch of raw profiles: (ids int32[q, k], sims f32[q, k])."""
        items, offsets = profiles_to_csr(profiles)
        qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                   self.index.fp_seed)
        return self._descend(items, offsets, qgf, k or self.qc.k, hops=hops)

    def _descend(self, items, offsets, qgf, k: int, placed=None,
                 single: bool = False, hops: int | None = None):
        """Route + beam-descend already-fingerprinted query profiles.

        ``single=True`` forces the single-device path even when the
        engine serves sharded — used by :meth:`insert`, whose neighbor
        search must not trigger a full reshard per version bump.
        """
        qc = self.qc
        beam = max(qc.beam, k)
        hops = qc.hops if hops is None else hops
        seeds = route(self.index, items, offsets, qc.seeds_per_config,
                      placed=placed)
        qn = len(offsets) - 1
        qcap = capacity_of(qn, minimum=8)
        qw = np.zeros((qcap, qgf.words.shape[1]), dtype=np.uint32)
        qw[:qn] = qgf.words
        qcard = np.zeros(qcap, dtype=np.int32)
        qcard[:qn] = qgf.card
        qseeds = np.full((qcap, seeds.shape[1]), PAD_ID, dtype=np.int32)
        qseeds[:qn] = seeds
        if qc.shards > 1 and not single:
            ids, sims = self._sync_sharded().descend(
                qw, qcard, qseeds, k=k, beam=beam, hops=hops,
                kernel=qc.kernel)
        else:
            graph_ids, rev_ids, words, card = self._sync()
            ids, sims = batched_descent(
                graph_ids, rev_ids, words, card,
                jnp.asarray(qw), jnp.asarray(qcard), jnp.asarray(qseeds),
                k=k, beam=beam, hops=hops, kernel=qc.kernel)
        return np.asarray(ids)[:qn], np.asarray(sims)[:qn]

    # -- queue / wave serving ----------------------------------------------

    def submit(self, req: QueryRequest):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _next_wave(self) -> list[QueryRequest]:
        wave = []
        while self.queue and len(wave) < self.qc.max_wave:
            wave.append(self.queue.popleft())
        return wave

    def _serve_wave(self) -> int:
        """Close one wave from the queue; returns requests completed.

        A wave runs to the MAX hop budget of its members (the compiled
        program has one static hop count) — one deep request convoys
        every shallow request behind it. Continuous mode per-slot hop
        budgets are the fix.
        """
        wave = self._next_wave()
        if not wave:
            return 0
        hops = max(r.hops if r.hops is not None else self.qc.hops
                   for r in wave)
        ids, sims = self.query_batch([r.profile for r in wave], hops=hops)
        now = time.perf_counter()
        for j, r in enumerate(wave):
            r.ids, r.sims = ids[j], sims[j]
            r.t_done = now
            self.done.append(r)
        return len(wave)

    def busy(self) -> bool:
        """True while requests are queued or (continuous) in flight."""
        if self.queue:
            return True
        return self._cont is not None and self._cont.sched.has_work()

    def step(self) -> int:
        """Serve one scheduler step — one wave, or one continuous tick.

        The open-loop benchmark drives this directly so arrivals can be
        interleaved with service; :meth:`run` loops it until drained.
        """
        return self.tick() if self.qc.continuous else self._serve_wave()

    # -- continuous (slot) serving -----------------------------------------

    def _cont_state(self) -> _ContinuousState:
        if self._cont is None:
            self._cont = _ContinuousState(self.index, self.qc)
        return self._cont

    def tick(self) -> int:
        """One continuous tick: admit into free slots, advance every
        in-flight beam one hop, complete converged/exhausted slots.

        Returns the number of requests completed this tick. Admission is
        mid-flight: rows freed by a previous tick take fresh requests
        while the remaining rows keep descending — no wave barrier.
        """
        qc = self.qc
        st = self._cont_state()
        sched = st.sched
        while self.queue:
            sched.submit(self.queue.popleft())
        graph_ids, rev_ids, words, card = self._sync()
        n_done = 0
        admitted = sched.admit()
        while admitted:
            items, offsets = profiles_to_csr([r.profile for _, r in admitted])
            qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                       self.index.fp_seed)
            seeds = route(self.index, items, offsets, qc.seeds_per_config)
            A = st.admit_cap
            for lo in range(0, len(admitted), A):
                chunk = admitted[lo:lo + A]
                new_w = np.zeros((A, st.q_words.shape[1]), np.uint32)
                new_c = np.zeros(A, np.int32)
                new_s = np.full((A, st.seed_cols), PAD_ID, np.int32)
                # n_slots = one-past-the-end sentinel; the admit scatter
                # drops those rows (mode="drop").
                idx = np.full(A, sched.n_slots, np.int32)
                for j, (slot, req) in enumerate(chunk):
                    new_w[j] = qgf.words[lo + j]
                    new_c[j] = int(qgf.card[lo + j])
                    new_s[j] = seeds[lo + j]
                    idx[j] = slot
                    st.hops_done[slot] = 0
                    st.budget[slot] = (req.hops if req.hops is not None
                                       else qc.hops)
                st.q_words, st.q_card, st.beam_ids, st.beam_sims = \
                    slot_admit(words, card, jnp.asarray(new_w),
                               jnp.asarray(new_c), jnp.asarray(new_s),
                               jnp.asarray(idx), st.q_words, st.q_card,
                               st.beam_ids, st.beam_sims, beam=st.beam)
            # A zero-hop budget completes on its seed-initialized beam
            # without entering the hop (wave parity: a hops=0 wave runs a
            # length-0 scan). The freed slots may admit further queued
            # requests, hence the loop.
            zero = [(s, r) for s, r in admitted if st.budget[s] <= 0]
            if not zero:
                break
            bids = np.asarray(st.beam_ids)
            bsims = np.asarray(st.beam_sims)
            now = time.perf_counter()
            for slot, req in zero:
                sched.release(slot)
                req.ids = bids[slot, : qc.k].copy()
                req.sims = bsims[slot, : qc.k].copy()
                req.t_done = now
                self.done.append(req)
                n_done += 1
            admitted = sched.admit()
        active = sched.active_mask()
        if not active.any():
            return n_done
        st.beam_ids, st.beam_sims, changed = slot_hop(
            graph_ids, rev_ids, words, card, st.q_words, st.q_card,
            st.beam_ids, st.beam_sims, jnp.asarray(active),
            kernel=qc.kernel)
        st.hops_done[active] += 1
        self.n_ticks += 1
        finished = active & (
            (st.hops_done >= st.budget) | ~np.asarray(changed))
        if not finished.any():
            return n_done
        # The beam is sim-descending, deduped, and PAD-masked (merge_topk
        # output), so the final top-k is its prefix — byte-identical to
        # the wave kernel's closing merge_topk(beam, k).
        bids = np.asarray(st.beam_ids)
        bsims = np.asarray(st.beam_sims)
        now = time.perf_counter()
        for slot in np.flatnonzero(finished):
            req = sched.release(int(slot))
            req.ids = bids[slot, : qc.k].copy()
            req.sims = bsims[slot, : qc.k].copy()
            req.t_done = now
            self.done.append(req)
            n_done += 1
        return n_done

    def run(self, on_tick=None) -> dict:
        """Drain the queue (waves, or continuous ticks when
        ``QueryConfig.continuous``); returns aggregate serving stats.

        ``on_tick`` (continuous only): host callback ``f(engine, tick)``
        invoked between scheduler steps — the hook the interleaved
        insert-under-load tests (and any mid-stream mutation) use.
        """
        t0 = time.perf_counter()
        n_steps = 0
        n_new_done = 0
        if self.qc.continuous:
            while self.busy():
                if on_tick is not None:
                    on_tick(self, n_steps)
                n_new_done += self.tick()
                n_steps += 1
        else:
            while self.queue:
                n_new_done += self._serve_wave()
                n_steps += 1
        dt = max(time.perf_counter() - t0, 1e-9)
        lats = [r.latency for r in self.done[-n_new_done:]] if n_new_done else []
        return {
            "requests": n_new_done,
            "mode": "continuous" if self.qc.continuous else "wave",
            "waves": n_steps,
            "qps": n_new_done / dt,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "p50_latency_s": float(np.percentile(lats, 50)) if lats else 0.0,
            "p95_latency_s": float(np.percentile(lats, 95)) if lats else 0.0,
            "inserted": self.n_inserted,
            "shards": self.qc.shards,
            "refreshes": self.n_refreshes,
        }

    # -- online insertion --------------------------------------------------

    def insert(self, profile) -> int:
        """Add a new user online; returns its id in the index.

        Links the user via its own search result (graph-degree k), then
        registers it with the FRH router so later queries seed from it.
        """
        ix = self.index
        items, offsets = profiles_to_csr([profile])
        qgf = fingerprint_profiles(items, offsets, ix.n_bits, ix.fp_seed)
        placed = placements(ix, items, offsets)
        # Single-device search: each insert bumps the index version, and
        # searching through the sharded path would rebuild the whole
        # shard state per insert. The reshard happens once, lazily, at
        # the next sharded query wave. Cost of this choice: a sharded
        # engine that inserts holds BOTH the full device copy (repaired
        # incrementally per insert) and the per-shard subgraphs — ~2x
        # index memory; see the resharding follow-up in ROADMAP.md.
        ids, sims = self._descend(items, offsets, qgf, ix.k, placed=placed,
                                  single=True)
        u = ix.append_user(np.asarray(qgf.words)[0], int(qgf.card[0]),
                           ids[0], sims[0])
        for matched in placed[0]:
            if matched:  # deepest matching cluster of this configuration
                ix.add_cluster_member(matched[0], u)
        self.n_inserted += 1
        # Keep the materialized CSR row, not the caller's object — a
        # one-shot iterable profile is already exhausted by now.
        self._cohort.append((u, items[offsets[0]:offsets[1]].copy()))
        if len(self._cohort) >= self.qc.refresh_every:
            self.flush_cohort()
        return u

    def flush_cohort(self) -> int:
        """Re-run C² clustering on the accumulated insert cohort (see
        :meth:`KNNIndex.refresh_cohort`); returns new clusters registered."""
        if not self._cohort:
            return 0
        uids = np.array([u for u, _ in self._cohort], dtype=np.int32)
        items, offsets = profiles_to_csr([p for _, p in self._cohort])
        n_new = self.index.refresh_cohort(items, offsets, uids)
        self._cohort = []  # drained only after the refresh succeeded
        self.n_refreshes += 1
        return n_new

    # -- quality -----------------------------------------------------------

    def recall_vs_brute_force(self, requests: list[QueryRequest] | None = None,
                              ) -> float:
        """Mean recall@k of served results vs brute force over the index."""
        reqs = requests if requests is not None else self.done
        reqs = [r for r in reqs if r.ids is not None]
        if not reqs:
            return 0.0
        items, offsets = profiles_to_csr([r.profile for r in reqs])
        qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                   self.index.fp_seed)
        k = len(reqs[0].ids)
        exact_ids, _ = exact_knn(self.index.words, self.index.card,
                                 np.asarray(qgf.words),
                                 np.asarray(qgf.card), k)
        return knn_recall(np.stack([r.ids for r in reqs]), exact_ids)
