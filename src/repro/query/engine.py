"""Queue → wave query engine over a :class:`~repro.query.index.KNNIndex`.

Modeled on ``serve/engine.py``: requests queue up, are drained in waves
of up to ``max_wave``, and each wave runs one jitted
:func:`~repro.query.search.batched_descent`. Wave row-counts and the
index row-count are padded to power-of-two capacities so each (capacity,
beam, hops, k) shape compiles once and is reused across waves — the same
padded-capacity-group discipline as ``core/local_knn.py``.

Online insertion: :meth:`QueryEngine.insert` searches for the new
profile's neighbors, appends its fingerprint + forward edges to the
index (O(degree) — the index grows into spare capacity), patches reverse
edges (bounded-heap displacement), and registers the user in its FRH
clusters so subsequent queries route to it. Inserted profiles accumulate
in a *cohort*; once it exceeds ``QueryConfig.refresh_every`` the engine
re-runs C² clustering on the cohort (:meth:`KNNIndex.refresh_cohort`) so
drifting insert streams grow fresh routable clusters.

Sharded serving (``QueryConfig.shards > 1``): descent runs per LPT
cluster shard with a cross-shard top-k merge (repro/query/sharded.py) —
``shard_map`` over the mesh when a device per shard exists, vmapped on
one device otherwise.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.local_knn import capacity_of
from repro.eval.metrics import knn_recall
from repro.query.index import KNNIndex
from repro.query.router import (fingerprint_profiles, placements,
                                profiles_to_csr, route)
from repro.query.search import batched_descent, exact_knn
from repro.types import PAD_ID


@dataclasses.dataclass
class QueryRequest:
    rid: int
    profile: np.ndarray                  # int32[|P|] item ids
    # Filled by the engine:
    ids: Optional[np.ndarray] = None     # int32[k] neighbor ids
    sims: Optional[np.ndarray] = None    # float32[k] similarities
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class QueryConfig:
    k: int = 10                # neighbors returned per query
    beam: int = 32             # descent frontier width
    hops: int = 3              # descent depth (fixed, compiled in)
    max_wave: int = 256        # queries per jitted wave
    seeds_per_config: int = 16 # routed seed candidates per hash config
    shards: int = 1            # >1: LPT cluster shards + cross-shard merge
    shard_oversample: float = 1.5  # fleet frontier vs single-device beam
    refresh_every: int = 64    # cohort size triggering re-clustering


class QueryEngine:
    def __init__(self, index: KNNIndex, qc: QueryConfig | None = None):
        self.index = index
        self.qc = qc or QueryConfig()
        self.queue: deque[QueryRequest] = deque()
        self.done: list[QueryRequest] = []
        self.n_inserted = 0
        self.n_refreshes = 0
        self._dev = None          # (version, n_cap, device arrays)
        self._sharded = None      # cached ShardedDescent (version keyed)
        self._cohort: list[tuple[int, np.ndarray]] = []  # (uid, profile)

    # -- device state ------------------------------------------------------

    def _sync(self):
        """Device copies of the index, padded to a power-of-two row count.

        Stale copies are repaired incrementally when possible: an insert
        touches only the new row plus its patched neighbors (the index
        journals them — :meth:`KNNIndex.rows_changed_since`), so those
        rows are scattered into the resident device arrays instead of
        re-padding and re-uploading all n rows per version bump. The full
        upload happens only on first use, capacity crossings, or after
        enough mutations that the journal no longer helps."""
        ix = self.index
        if self._dev is not None and self._dev[0] == ix.version:
            return self._dev[2]
        n, cap = ix.n, capacity_of(ix.n, minimum=64)
        if self._dev is not None and self._dev[1] == cap:
            changed = ix.rows_changed_since(self._dev[0])
            if changed is not None and len(changed) <= max(64, n // 8):
                arrays = self._dev[2]
                if changed:
                    rows = np.fromiter(sorted(changed), dtype=np.int64,
                                       count=len(changed))
                    idx = jnp.asarray(rows)
                    g, r, w, c = arrays
                    arrays = (
                        g.at[idx].set(jnp.asarray(ix.graph_ids[rows])),
                        r.at[idx].set(jnp.asarray(ix.rev_ids[rows])),
                        w.at[idx].set(jnp.asarray(ix.words[rows])),
                        c.at[idx].set(jnp.asarray(ix.card[rows])),
                    )
                self._dev = (ix.version, cap, arrays)
                return arrays
        pad = cap - n
        arrays = (
            jnp.asarray(np.pad(ix.graph_ids, ((0, pad), (0, 0)),
                               constant_values=PAD_ID)),
            jnp.asarray(np.pad(ix.rev_ids, ((0, pad), (0, 0)),
                               constant_values=PAD_ID)),
            jnp.asarray(np.pad(ix.words, ((0, pad), (0, 0)))),
            jnp.asarray(np.pad(ix.card, (0, pad))),
        )
        self._dev = (ix.version, cap, arrays)
        return arrays

    def _sync_sharded(self):
        """Cached per-shard subgraphs; rebuilt lazily after mutations, so
        an insert burst costs one reshard at the next query wave."""
        from repro.query.sharded import ShardedDescent

        ix = self.index
        if (self._sharded is None
                or self._sharded.version != ix.version
                or self._sharded.n_shards != self.qc.shards):
            self._sharded = ShardedDescent(
                ix, self.qc.shards, oversample=self.qc.shard_oversample)
        return self._sharded

    def sharded_state(self):
        """The current ShardedDescent (built on demand), or None when the
        engine serves single-device. Public accessor for diagnostics."""
        return self._sync_sharded() if self.qc.shards > 1 else None

    # -- core batched path -------------------------------------------------

    def query_batch(self, profiles, k: int | None = None):
        """Answer a batch of raw profiles: (ids int32[q, k], sims f32[q, k])."""
        items, offsets = profiles_to_csr(profiles)
        qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                   self.index.fp_seed)
        return self._descend(items, offsets, qgf, k or self.qc.k)

    def _descend(self, items, offsets, qgf, k: int, placed=None,
                 single: bool = False):
        """Route + beam-descend already-fingerprinted query profiles.

        ``single=True`` forces the single-device path even when the
        engine serves sharded — used by :meth:`insert`, whose neighbor
        search must not trigger a full reshard per version bump.
        """
        qc = self.qc
        beam = max(qc.beam, k)
        seeds = route(self.index, items, offsets, qc.seeds_per_config,
                      placed=placed)
        qn = len(offsets) - 1
        qcap = capacity_of(qn, minimum=8)
        qw = np.zeros((qcap, qgf.words.shape[1]), dtype=np.uint32)
        qw[:qn] = qgf.words
        qcard = np.zeros(qcap, dtype=np.int32)
        qcard[:qn] = qgf.card
        qseeds = np.full((qcap, seeds.shape[1]), PAD_ID, dtype=np.int32)
        qseeds[:qn] = seeds
        if qc.shards > 1 and not single:
            ids, sims = self._sync_sharded().descend(
                qw, qcard, qseeds, k=k, beam=beam, hops=qc.hops)
        else:
            graph_ids, rev_ids, words, card = self._sync()
            ids, sims = batched_descent(
                graph_ids, rev_ids, words, card,
                jnp.asarray(qw), jnp.asarray(qcard), jnp.asarray(qseeds),
                k=k, beam=beam, hops=qc.hops)
        return np.asarray(ids)[:qn], np.asarray(sims)[:qn]

    # -- queue / wave serving ----------------------------------------------

    def submit(self, req: QueryRequest):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _next_wave(self) -> list[QueryRequest]:
        wave = []
        while self.queue and len(wave) < self.qc.max_wave:
            wave.append(self.queue.popleft())
        return wave

    def run(self) -> dict:
        """Drain the queue in waves; returns aggregate serving stats."""
        t0 = time.perf_counter()
        n_waves = 0
        n_new_done = 0
        while self.queue:
            wave = self._next_wave()
            ids, sims = self.query_batch([r.profile for r in wave])
            now = time.perf_counter()
            for j, r in enumerate(wave):
                r.ids, r.sims = ids[j], sims[j]
                r.t_done = now
                self.done.append(r)
            n_waves += 1
            n_new_done += len(wave)
        dt = max(time.perf_counter() - t0, 1e-9)
        lats = [r.latency for r in self.done[-n_new_done:]] if n_new_done else []
        return {
            "requests": n_new_done,
            "waves": n_waves,
            "qps": n_new_done / dt,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "p50_latency_s": float(np.percentile(lats, 50)) if lats else 0.0,
            "p95_latency_s": float(np.percentile(lats, 95)) if lats else 0.0,
            "inserted": self.n_inserted,
            "shards": self.qc.shards,
            "refreshes": self.n_refreshes,
        }

    # -- online insertion --------------------------------------------------

    def insert(self, profile) -> int:
        """Add a new user online; returns its id in the index.

        Links the user via its own search result (graph-degree k), then
        registers it with the FRH router so later queries seed from it.
        """
        ix = self.index
        items, offsets = profiles_to_csr([profile])
        qgf = fingerprint_profiles(items, offsets, ix.n_bits, ix.fp_seed)
        placed = placements(ix, items, offsets)
        # Single-device search: each insert bumps the index version, and
        # searching through the sharded path would rebuild the whole
        # shard state per insert. The reshard happens once, lazily, at
        # the next sharded query wave. Cost of this choice: a sharded
        # engine that inserts holds BOTH the full device copy (repaired
        # incrementally per insert) and the per-shard subgraphs — ~2x
        # index memory; see the resharding follow-up in ROADMAP.md.
        ids, sims = self._descend(items, offsets, qgf, ix.k, placed=placed,
                                  single=True)
        u = ix.append_user(np.asarray(qgf.words)[0], int(qgf.card[0]),
                           ids[0], sims[0])
        for matched in placed[0]:
            if matched:  # deepest matching cluster of this configuration
                ix.add_cluster_member(matched[0], u)
        self.n_inserted += 1
        # Keep the materialized CSR row, not the caller's object — a
        # one-shot iterable profile is already exhausted by now.
        self._cohort.append((u, items[offsets[0]:offsets[1]].copy()))
        if len(self._cohort) >= self.qc.refresh_every:
            self.flush_cohort()
        return u

    def flush_cohort(self) -> int:
        """Re-run C² clustering on the accumulated insert cohort (see
        :meth:`KNNIndex.refresh_cohort`); returns new clusters registered."""
        if not self._cohort:
            return 0
        uids = np.array([u for u, _ in self._cohort], dtype=np.int32)
        items, offsets = profiles_to_csr([p for _, p in self._cohort])
        n_new = self.index.refresh_cohort(items, offsets, uids)
        self._cohort = []  # drained only after the refresh succeeded
        self.n_refreshes += 1
        return n_new

    # -- quality -----------------------------------------------------------

    def recall_vs_brute_force(self, requests: list[QueryRequest] | None = None,
                              ) -> float:
        """Mean recall@k of served results vs brute force over the index."""
        reqs = requests if requests is not None else self.done
        reqs = [r for r in reqs if r.ids is not None]
        if not reqs:
            return 0.0
        items, offsets = profiles_to_csr([r.profile for r in reqs])
        qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                   self.index.fp_seed)
        k = len(reqs[0].ids)
        exact_ids, _ = exact_knn(self.index.words, self.index.card,
                                 np.asarray(qgf.words),
                                 np.asarray(qgf.card), k)
        return knn_recall(np.stack([r.ids for r in reqs]), exact_ids)
