"""Plan-driven query engine over a :class:`~repro.query.index.KNNIndex`.

The engine is host bookkeeping around ONE serving abstraction: a
:class:`~repro.query.plan.DescentPlan` — the cross-product of placement
(single device | N LPT cluster shards), batching (closed waves |
continuous slots), and scorer (jnp | fused Pallas hop). Every request
takes the same path: ``submit → plan.step → collect``. The plan owns
the device state and compiled programs for its combination; this module
owns the queue, completion records, serving stats, and online mutation
(insertion + cohort refresh).

:class:`QueryConfig` is the flag-pile-compatible front door (CLI flags
map straight onto it); :meth:`QueryConfig.spec` maps it onto the
validated :class:`~repro.query.plan.PlanSpec` the plan is built from —
unsupported values fail loudly there instead of silently dropping a
flag.

Online insertion: :meth:`QueryEngine.insert` searches for the new
profile's neighbors *through the engine's own plan* (the sharded
placement repairs its per-shard tensors incrementally per version bump
— ``ShardedDescent.sync`` — so a sharded engine no longer needs the
full-index device copy inserts used to route through), appends the
fingerprint + forward edges to the index (O(degree) into spare
capacity), patches reverse edges, and registers the user with the FRH
router. Inserted profiles accumulate in a *cohort*; once it exceeds
``QueryConfig.refresh_every`` the engine re-runs C² clustering on the
cohort (:meth:`KNNIndex.refresh_cohort`) so drifting insert streams
grow fresh routable clusters.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.eval.metrics import knn_recall
from repro.lifecycle import LifecycleConfig, LifecycleManager
from repro.query.index import KNNIndex
from repro.query.plan import DescentPlan, PlanSpec
from repro.query.rebalance import RebalanceConfig, Rebalancer
from repro.query.router import (fingerprint_profiles, placements,
                                profiles_to_csr)
from repro.query.search import exact_knn


@dataclasses.dataclass
class QueryRequest:
    rid: int
    profile: np.ndarray                  # int32[|P|] item ids
    hops: Optional[int] = None           # per-request hop budget
                                         # (None → QueryConfig.hops)
    priority: int = 0                    # SLO class (0 = highest; higher
                                         # classes are shed first)
    deadline: Optional[float] = None     # absolute perf_counter() expiry
                                         # (None = never; expired pending
                                         # requests are shed, not served)
    # Filled by the engine:
    ids: Optional[np.ndarray] = None     # int32[k] neighbor ids
    sims: Optional[np.ndarray] = None    # float32[k] similarities
    t_submit: float = 0.0
    t_done: float = 0.0
    status: str = "pending"              # pending | done | rejected
    degraded: bool = False               # served while >=1 shard was
                                         # masked out (bounded recall
                                         # loss; never cached)

    @property
    def rejected(self) -> bool:
        """True when admission shed this request (deadline expired or
        bounded-queue overflow) — it completed WITHOUT a result."""
        return self.status == "rejected"

    @property
    def latency(self) -> Optional[float]:
        """Seconds from submit to completion, or None while unserved.

        An unserved request has ``t_done == 0.0``; the old behavior of
        returning ``0.0 - t_submit`` silently poisoned any percentile
        computed over a mixed done/pending list with large negative
        values. None makes that misuse fail loudly instead.
        """
        if self.t_done == 0.0 or self.t_submit == 0.0:
            return None
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class QueryConfig:
    k: int = 10                # neighbors returned per query
    beam: int = 32             # descent frontier width
    hops: int = 3              # descent depth (fixed, compiled in)
    max_wave: int = 256        # queries per jitted wave
    seeds_per_config: int = 16 # routed seed candidates per hash config
    shards: int = 1            # >1: LPT cluster shards + cross-shard merge
    shard_oversample: float = 1.5  # fleet frontier vs single-device beam
    refresh_every: int = 64    # cohort size triggering re-clustering
    continuous: bool = False   # slot-based streaming admission (sched/)
    slots: int = 32            # in-flight capacity in continuous mode
    kernel: bool = False       # fused Pallas descent-scoring hop
                               # (kernels/descent_score; bitwise-identical
                               # results, interpret mode per
                               # kernels/config.py)
    dma: bool = False          # with kernel: HBM-resident tables +
                               # per-chunk candidate-row DMA (the
                               # "pallas_dma" scorer; bitwise-identical,
                               # reports dma_bytes/bytes_saved)
    ttl: int = 0               # lifecycle: ticks before an untouched row
                               # expires (0 = never)
    repair_every: int = 0      # lifecycle: churn-repair cadence in ticks
                               # (0 = off)
    admission: str = "fifo"    # "slo": priority classes + deadline-aware
                               # admission, explicit shedding (sched/)
    max_pending: int = 0       # pending-queue bound under slo admission
                               # (0 = unbounded; overflow is shed)
    adaptive: int = 0          # >0: free continuous slots once the top-k
                               # prefix held for this many hops (patience)
    cache: int = 0             # >0: fingerprint-keyed result-cache
                               # capacity (journal-invalidated)
    resident_configs: int = 0  # tiered residency: only clusters of the
                               # first m hash configurations contribute
                               # shard residents (0 = all t; shards > 1)
    rebalance_every: int = 0   # background re-balance check cadence in
                               # scheduler steps (0 = off; shards > 1)
    rebalance_threshold: float = 1.25  # measured imbalance that triggers
                               # a blue/green plan swap

    def spec(self) -> PlanSpec:
        """Map the flag pile onto a validated plan on the three axes."""
        if self.dma and not self.kernel:
            raise ValueError(
                "dma selects the HBM-resident placement OF the fused "
                "kernel hop; it needs kernel=True")
        scorer = ("pallas_dma" if self.dma
                  else "pallas" if self.kernel else "jnp")
        return PlanSpec(
            placement=self.shards,
            batching="continuous" if self.continuous else "wave",
            scorer=scorer,
            k=self.k, beam=self.beam, hops=self.hops,
            max_wave=self.max_wave, slots=self.slots,
            seeds_per_config=self.seeds_per_config,
            shard_oversample=self.shard_oversample,
            admission=self.admission, max_pending=self.max_pending,
            adaptive=self.adaptive, cache=self.cache,
            resident_configs=self.resident_configs)


class QueryEngine:
    def __init__(self, index: KNNIndex, qc: QueryConfig | None = None, *,
                 clock=None, faults=None, store=None):
        self.index = index
        self.qc = qc or QueryConfig()
        # Injectable clock (same pattern as SlotScheduler): tests drive
        # a sched.ManualClock so latency / deadline / backoff behavior
        # is deterministic without a single time.sleep.
        self.clock = clock or time.perf_counter
        self.plan = DescentPlan(index, self.qc.spec(), clock=self.clock)
        self.queue: deque[QueryRequest] = deque()
        self.done: list[QueryRequest] = []
        self.n_inserted = 0
        self.n_refreshes = 0
        self._cohort: list[tuple[int, np.ndarray]] = []  # (uid, profile)
        self.lifecycle = LifecycleManager(
            self, LifecycleConfig(ttl=self.qc.ttl,
                                  repair_every=self.qc.repair_every))
        if self.qc.rebalance_every > 0 and self.qc.shards <= 1:
            raise ValueError(
                "rebalance_every re-balances the SHARD partition; a "
                "single-device placement has nothing to re-balance "
                "(use shards > 1)")
        self.rebalance = Rebalancer(
            self.plan, RebalanceConfig(
                every=self.qc.rebalance_every,
                threshold=self.qc.rebalance_threshold))
        # Fault pipeline (repro/faults): injector → health/failover →
        # crash store. Deferred imports keep repro.query importable
        # without the faults package in the graph.
        self.faults = faults
        self.failover = None
        if faults is not None:
            from repro.faults.failover import FailoverManager
            self.failover = FailoverManager(self.plan, faults)
        self.store = store
        if store is not None:
            store.attach(self)

    @property
    def n_ticks(self) -> int:
        """Continuous slot-step invocations (0 for wave plans)."""
        return self.plan.n_ticks

    # -- batched search (the plan's raw wave program) ----------------------

    def query_batch(self, profiles, k: int | None = None,
                    hops: int | None = None):
        """Answer a batch of raw profiles: (ids int32[q, k], sims f32[q, k])."""
        return self.plan.query_batch(profiles, k=k, hops=hops)

    def sharded_state(self):
        """The plan's delta-synced ShardedDescent (built on demand), or
        None when it serves single-device. Public accessor for
        diagnostics."""
        return self.plan.sharded_state()

    # -- queue / serving loop ----------------------------------------------

    def submit(self, req: QueryRequest):
        req.t_submit = self.clock()
        self.queue.append(req)

    @property
    def degraded(self) -> bool:
        """True while the fleet serves with >=1 shard masked out."""
        return self.failover is not None and self.failover.degraded

    def busy(self) -> bool:
        """True while requests are queued or (continuous) in flight."""
        return bool(self.queue) or self.plan.busy()

    def step(self) -> int:
        """Serve one scheduler step — one wave, or one continuous tick.

        The open-loop benchmark drives this directly so arrivals can be
        interleaved with service; :meth:`run` loops it until drained.
        Lifecycle maintenance (TTL expiry, churn repair) fires AFTER the
        plan step — between compiled programs — so continuous slots
        in flight never see a half-applied mutation mid-hop. The shard
        re-balancer runs after lifecycle: its imbalance measurement
        (and any blue/green swap) sees the step's lifecycle mutations
        already journaled, and the swap lands before the next compiled
        program.

        The fault pipeline brackets all of it: the injector's
        ``begin_step`` fires FIRST (a ``crash@T`` lands before any work
        of step T — the boundary the WAL guarantees consistency at) and
        the failover probe masks newly-dead shards before the plan step
        serves. Failover recovery and the crash store run LAST, so a
        recovery swap / snapshot sees the step's mutations journaled.
        """
        if self.faults is not None:
            self.faults.begin_step()  # may raise EngineCrash
        if self.failover is not None:
            self.failover.observe()
        n = self.plan.step(self.queue, self.done)
        self.lifecycle.maintain()
        self.rebalance.maintain()
        if self.failover is not None:
            self.failover.maintain()
        if self.store is not None:
            self.store.maintain(self)
        return n

    def tick(self) -> int:
        """One continuous tick (alias of :meth:`step` for slot plans)."""
        if not self.qc.continuous:
            raise ValueError("tick() is the continuous step; this engine "
                             f"serves {self.plan.describe()}")
        return self.step()

    def run(self, on_tick=None) -> dict:
        """Drain the queue through the plan; returns aggregate stats.

        ``on_tick`` (continuous plans only): host callback
        ``f(engine, tick)`` invoked between scheduler steps — the hook
        the interleaved insert-under-load tests (and any mid-stream
        mutation) use.
        """
        t0 = self.clock()
        n_steps = 0
        n_new_done = 0
        continuous = self.qc.continuous
        while self.busy():
            if continuous and on_tick is not None:
                on_tick(self, n_steps)
            n_new_done += self.step()
            n_steps += 1
        dt = max(self.clock() - t0, 1e-9)
        recent = self.done[-n_new_done:] if n_new_done else []
        # Latency percentiles cover SERVED requests only: a rejected
        # (shed) request's submit→shed interval is queueing, not
        # service, and an unserved latency is None by contract.
        lats = [r.latency for r in recent
                if r.status == "done" and r.latency is not None]
        n_shed = sum(1 for r in recent if r.rejected)
        stats = {
            "requests": n_new_done,
            "served": n_new_done - n_shed,
            "shed": n_shed,
            "mode": "continuous" if continuous else "wave",
            "plan": self.plan.describe(),
            "waves": n_steps,
            "qps": (n_new_done - n_shed) / dt,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "p50_latency_s": float(np.percentile(lats, 50)) if lats else 0.0,
            "p95_latency_s": float(np.percentile(lats, 95)) if lats else 0.0,
            "inserted": self.n_inserted,
            "shards": self.qc.shards,
            "refreshes": self.n_refreshes,
        }
        if self.plan.spec.kernel:
            # Memory-hierarchy accounting from the fused hop (cumulative
            # over the plan's lifetime; the DMA scorer fills the byte
            # counters, the VMEM scorer only scored_lanes).
            stats["descent"] = dict(self.plan.descent_stats)
        if self.plan.cache is not None:
            stats["cache"] = self.plan.cache.stats()
        if self.rebalance.active:
            stats["rebalance"] = self.rebalance.stats()
        if self.faults is not None:
            faults = dict(self.faults.stats())
            if self.failover is not None:
                faults.update(self.failover.stats())
            faults["degraded_served"] = sum(
                1 for r in recent if getattr(r, "degraded", False))
            stats["faults"] = faults
        if self.store is not None:
            stats["store"] = self.store.stats()
        return stats

    # -- online insertion --------------------------------------------------

    def insert(self, profile) -> int:
        """Add a new user online; returns its id in the index.

        Links the user via its own search result (graph-degree k), then
        registers it with the FRH router so later queries seed from it.
        The neighbor search runs through the engine's own plan: under a
        sharded placement each insert costs one O(degree) delta reshard
        (row + membership journals), NOT a rebuild — and no full-index
        device copy is ever materialized.
        """
        ix = self.index
        items, offsets = profiles_to_csr([profile])
        qgf = fingerprint_profiles(items, offsets, ix.n_bits, ix.fp_seed)
        placed = placements(ix, items, offsets)
        ids, sims = self.plan.search(items, offsets, qgf, ix.k,
                                     placed=placed)
        u = ix.append_user(np.asarray(qgf.words)[0], int(qgf.card[0]),
                           ids[0], sims[0])
        for matched in placed[0]:
            if matched:  # deepest matching cluster of this configuration
                ix.add_cluster_member(matched[0], u)
        self.n_inserted += 1
        # Keep the materialized CSR row, not the caller's object — a
        # one-shot iterable profile is already exhausted by now.
        self._cohort.append((u, items[offsets[0]:offsets[1]].copy()))
        self.lifecycle.note_insert(u)
        if len(self._cohort) >= self.qc.refresh_every:
            self.flush_cohort()
        return u

    # -- lifecycle (deletes / updates / TTL — src/repro/lifecycle) ---------

    def remove_user(self, u: int):
        """Delete user ``u`` online: tombstone, patch incident edges,
        deregister from routing. Queries in flight and later never see
        it (the tombstone mask is threaded through every plan)."""
        self.lifecycle.remove(u)

    def update_user(self, u: int, profile):
        """Replace ``u``'s profile online: re-sketch, re-score incident
        edges, and re-link via a localized neighborhood descent."""
        return self.lifecycle.update(u, profile)

    def touch(self, u: int):
        """Record activity on ``u`` (resets its TTL window)."""
        self.lifecycle.touch(u)

    def flush_cohort(self) -> int:
        """Re-run C² clustering on the accumulated insert cohort (see
        :meth:`KNNIndex.refresh_cohort`); returns new clusters registered."""
        if not self._cohort:
            return 0
        uids = np.array([u for u, _ in self._cohort], dtype=np.int32)
        items, offsets = profiles_to_csr([p for _, p in self._cohort])
        n_new = self.index.refresh_cohort(items, offsets, uids)
        self._cohort = []  # drained only after the refresh succeeded
        self.n_refreshes += 1
        return n_new

    # -- crash recovery (snapshot + WAL replay — src/repro/faults/wal) -----

    @classmethod
    def recover(cls, path, qc: QueryConfig | None = None, *,
                clock=None, faults=None, store=None) -> "QueryEngine":
        """Rebuild an engine from a :class:`~repro.faults.wal.CrashStore`
        directory: load the last snapshot, replay the WAL suffix, and —
        for sharded configs — restore the frozen base plan from its
        sidecar so the serving partition extends the SAME lineage the
        crashed engine was on (``extend_plan`` composes: extending the
        restored base over the replayed index lands bitwise where the
        live plan was). Passing ``store`` re-attaches persistence: the
        first act of the recovered engine is a fresh snapshot, so a
        second crash replays from there, not from before the first.
        """
        from repro.faults.wal import CrashStore
        index, base_plan, manifest = CrashStore.load(path)
        eng = cls(index, qc, clock=clock, faults=faults)
        eng.lifecycle.clock = int(manifest.get("lifecycle_clock", 0))
        if base_plan is not None and eng.qc.shards == base_plan.n_shards:
            from repro.query.sharded import ShardedDescent, extend_plan
            spec = eng.qc.spec()
            eng.plan._sharded = ShardedDescent(
                index, base_plan.n_shards,
                plan=extend_plan(base_plan, index),
                oversample=spec.shard_oversample,
                resident_configs=spec.resident_configs)
        if store is not None:
            eng.store = store
            store.attach(eng)  # snapshot AFTER the plan restore
        return eng

    # -- quality -----------------------------------------------------------

    def recall_vs_brute_force(self, requests: list[QueryRequest] | None = None,
                              ) -> float:
        """Mean recall@k of served results vs brute force over the index.

        Rejected/unserved requests (``ids is None``) are excluded.
        Request sets may mix per-request k (callers serve through
        engines with different ``k``): results are grouped by their k
        and each group is scored against its own brute-force truth —
        the old ``np.stack`` over ragged id rows raised instead.
        """
        reqs = requests if requests is not None else self.done
        reqs = [r for r in reqs if r.ids is not None]
        if not reqs:
            return 0.0
        by_k: dict[int, list[QueryRequest]] = {}
        for r in reqs:
            by_k.setdefault(len(r.ids), []).append(r)
        total = 0.0
        for k, group in sorted(by_k.items()):
            items, offsets = profiles_to_csr([r.profile for r in group])
            qgf = fingerprint_profiles(items, offsets, self.index.n_bits,
                                       self.index.fp_seed)
            exact_ids, _ = exact_knn(self.index.words, self.index.card,
                                     np.asarray(qgf.words),
                                     np.asarray(qgf.card), k,
                                     tomb=self.index.tombstone)
            total += knn_recall(np.stack([r.ids for r in group]),
                                exact_ids) * len(group)
        return total / len(reqs)
