"""Jitted, batched graph descent for query serving.

One compiled program answers a whole *wave* of queries (mirroring the
padded-capacity-group style of ``core/local_knn.py``): every query keeps
a fixed-width beam of its best candidates so far; each hop gathers the
forward AND reverse neighbors of the beam (neighbors-of-neighbors, the
Hyrec/NNDescent friend-of-a-friend principle), scores them against the
query fingerprint with the GoldFinger Jaccard estimator, and re-selects
the beam with ``merge_topk``. Beam width, hop count, and k are static,
so the engine compiles one program per (wave capacity, beam, hops, k)
and reuses it across waves — no divergence, no per-query control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.knn.topk import merge_topk
from repro.sketch.goldfinger import jaccard_pairwise
from repro.types import NEG_INF, PAD_ID


def _scorer(words, card):
    """Row scorer: sims of one query against a PAD_ID-padded id list."""

    def score_row(qw, qc, cids):
        safe = jnp.where(cids == PAD_ID, 0, cids)
        cw = words[safe]
        cc = jnp.where(cids == PAD_ID, 0, card[safe])
        s = jaccard_pairwise(qw[None], qc[None], cw, cc)[0]
        return jnp.where(cids == PAD_ID, NEG_INF, s)

    return jax.vmap(score_row)


def descent_kernel(graph_ids, rev_ids, words, card,
                   q_words, q_card, seed_ids, *,
                   k: int, beam: int, hops: int):
    """Beam search over the index graph for a wave of queries.

    graph_ids int32[n, kg], rev_ids int32[n, r]: forward/reverse adjacency.
    words uint32[n, W], card int32[n]: index fingerprints.
    q_words uint32[q, W], q_card int32[q]: query fingerprints.
    seed_ids int32[q, S]: routed seed candidates (PAD_ID padded).
    Returns (ids int32[q, k], sims float32[q, k]), sim-descending.

    Unjitted so callers can compose it (``batched_descent`` jits it
    directly; ``query/sharded.py`` vmaps/shard_maps it over shards).
    """
    nq = q_words.shape[0]
    kg, kr = graph_ids.shape[1], rev_ids.shape[1]
    score = _scorer(words, card)

    beam_ids, beam_sims = merge_topk(
        seed_ids, score(q_words, q_card, seed_ids), beam)

    def hop(state, _):
        bids, bsims = state
        safe = jnp.where(bids == PAD_ID, 0, bids)
        fwd = graph_ids[safe].reshape(nq, -1)
        fwd = jnp.where((bids == PAD_ID).repeat(kg, axis=1), PAD_ID, fwd)
        rev = rev_ids[safe].reshape(nq, -1)
        rev = jnp.where((bids == PAD_ID).repeat(kr, axis=1), PAD_ID, rev)
        cand = jnp.concatenate([fwd, rev], axis=1)      # [q, beam·(kg+kr)]
        cand_sims = score(q_words, q_card, cand)
        nids, nsims = merge_topk(
            jnp.concatenate([bids, cand], axis=1),
            jnp.concatenate([bsims, cand_sims], axis=1), beam)
        return (nids, nsims), None

    (beam_ids, beam_sims), _ = jax.lax.scan(
        hop, (beam_ids, beam_sims), None, length=hops)
    return merge_topk(beam_ids, beam_sims, k)


batched_descent = functools.partial(
    jax.jit, static_argnames=("k", "beam", "hops"))(descent_kernel)


@functools.partial(jax.jit, static_argnames=("k",))
def _exact_block(words, card, q_words, q_card, k: int):
    sims = jaccard_pairwise(q_words, q_card, words, card)
    top_sims, top_ids = jax.lax.top_k(sims, k)
    top_ids = jnp.where(top_sims == NEG_INF, PAD_ID, top_ids.astype(jnp.int32))
    return top_ids, top_sims


def exact_knn(words, card, q_words, q_card, k: int, block: int = 256):
    """Brute-force query KNN (ground truth for recall), query-blocked."""
    words, card = jnp.asarray(words), jnp.asarray(card)
    q = q_words.shape[0]
    ids_out = np.full((q, k), PAD_ID, dtype=np.int32)
    sims_out = np.full((q, k), NEG_INF, dtype=np.float32)
    for s in range(0, q, block):
        e = min(s + block, q)
        ids, sims = _exact_block(words, card,
                                 jnp.asarray(q_words[s:e]),
                                 jnp.asarray(q_card[s:e]), k)
        ids_out[s:e] = np.asarray(ids)
        sims_out[s:e] = np.asarray(sims)
    return ids_out, sims_out
