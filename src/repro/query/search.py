"""Jitted, batched graph descent for query serving.

One compiled program answers a whole *wave* of queries (mirroring the
padded-capacity-group style of ``core/local_knn.py``): every query keeps
a fixed-width beam of its best candidates so far; each hop gathers the
forward AND reverse neighbors of the beam (neighbors-of-neighbors, the
Hyrec/NNDescent friend-of-a-friend principle), scores them against the
query fingerprint with the GoldFinger Jaccard estimator, and re-selects
the beam. Beam width, hop count, and k are static, so the engine
compiles one program per (wave capacity, beam, hops, k) and reuses it
across waves — no divergence, no per-query control flow.

The hop itself (:func:`descent_step`) has two implementations with
bitwise-identical results, selected by the static ``kernel`` flag
(``QueryConfig(kernel=)`` threads it through all three serving modes):

* ``kernel=False`` — the unfused jnp reference
  (``kernels/descent_score/ref.py``): gather, score every candidate
  lane, dedup after the fact, wide ``lax.top_k``.
* ``kernel=True`` — the fused Pallas hop
  (``kernels/descent_score/ops.py``): one ``pallas_call`` per hop that
  suppresses duplicate/PAD/already-in-beam lanes *before* the estimator
  runs and merges with an in-register top-k, never materializing the
  ``[q, beam·(kg+kr)]`` candidate tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.descent_score import ops as ds_ops
from repro.kernels.descent_score import ref as ds_ref
from repro.knn.topk import merge_topk
from repro.sched import trace
from repro.sketch.goldfinger import jaccard_pairwise_auto
from repro.types import NEG_INF, PAD_ID


def descent_init(words, card, q_words, q_card, seed_ids, *, beam: int,
                 tomb=None):
    """Score routed seeds and select the initial beam per query.

    Returns (beam_ids int32[q, beam], beam_sims float32[q, beam]),
    sim-descending, PAD_ID padded. ``tomb`` (bool[n] or None) PADs out
    seeds naming tombstoned rows before scoring — a dead user is never
    seeded, even from a stale routing snapshot.
    """
    if tomb is not None:
        seed_ids = ds_ref.mask_dead(tomb, jnp.asarray(seed_ids))
    score = ds_ref.row_scorer(words, card)
    return merge_topk(seed_ids, score(q_words, q_card, seed_ids), beam)


def descent_step(graph_ids, rev_ids, words, card,
                 q_words, q_card, beam_ids, beam_sims, *,
                 kernel: bool = False, dma: bool = False, tomb=None):
    """One descent hop: expand every query's beam by its friends-of-friends.

    Gathers forward + reverse neighbors of the current beam, scores them
    against the query fingerprints, and re-selects the beam. Rows are
    independent — the hop for query i depends only on row i's beam and
    the (shared, read-only) index arrays — which is what lets the
    continuous-batching slot program advance in-flight queries hop by
    hop while fresh admissions re-init other rows (``slot_hop``), with
    results identical to running the whole wave in lockstep.

    ``kernel``/``dma`` are static: kernel=False runs the unfused jnp
    reference, kernel=True the fused Pallas hop, and dma=True on top
    selects the HBM-resident placement with per-chunk candidate-row
    DMA — bitwise-identical (ids and sims) all three ways.
    ``tomb`` (bool[n] or None) suppresses tombstoned beam/candidate
    lanes before scoring, identically in every implementation.

    Returns ``(beam_ids, beam_sims, hop_stats)`` where ``hop_stats`` is
    i32[q, 3] — per-query ``(n_scored, dma_bytes, bytes_saved)`` for
    this hop. The jnp reference always scores every lane and moves no
    DMA, so its stats are identically zero; the VMEM kernel fills only
    ``n_scored``; the DMA kernel fills all three.
    """
    if kernel:
        ids, sims, nsc, dmab, saved = ds_ops.descent_hop(
            graph_ids, rev_ids, words, card, q_words, q_card,
            beam_ids, beam_sims, tomb=tomb, dma=dma, with_counts=True)
        return ids, sims, jnp.stack([nsc, dmab, saved], axis=1)
    ids, sims = ds_ref.descent_hop_ref(graph_ids, rev_ids, words, card,
                                       q_words, q_card, beam_ids,
                                       beam_sims, tomb=tomb)
    return ids, sims, jnp.zeros((beam_ids.shape[0], 3), jnp.int32)


def descent_kernel(graph_ids, rev_ids, words, card,
                   q_words, q_card, seed_ids, *,
                   k: int, beam: int, hops: int, kernel: bool = False,
                   dma: bool = False, tag=None, tomb=None):
    """Beam search over the index graph for a wave of queries.

    graph_ids int32[n, kg], rev_ids int32[n, r]: forward/reverse adjacency.
    words uint32[n, W], card int32[n]: index fingerprints.
    q_words uint32[q, W], q_card int32[q]: query fingerprints.
    seed_ids int32[q, S]: routed seed candidates (PAD_ID padded).
    Returns (ids int32[q, k], sims float32[q, k], stats int32[q, 3]),
    sims sim-descending; ``stats`` accumulates per-hop
    ``(n_scored, dma_bytes, bytes_saved)`` over all ``hops`` (zeros for
    the jnp path — see :func:`descent_step`).

    Composed from :func:`descent_init` + ``hops`` × :func:`descent_step`
    (the continuous path runs the same pieces tick-by-tick). Unjitted so
    callers can compose it (``batched_descent`` jits it directly;
    ``query/sharded.py`` vmaps/shard_maps it over shards). ``tag`` is a
    hashable plan key recorded in the jit-trace counters
    (``sched.trace.compile_count``) when set; composing callers pass
    ``None`` and bump their own outer-program key instead.
    """
    if tag is not None:
        trace.bump(("query_wave", tag, q_words.shape[0],
                    graph_ids.shape[0], k, beam, hops, kernel, dma))
    beam_ids, beam_sims = descent_init(
        words, card, q_words, q_card, seed_ids, beam=beam, tomb=tomb)
    acc = jnp.zeros((beam_ids.shape[0], 3), jnp.int32)

    def hop(state, _):
        bi, bs, acc = state
        nids, nsims, st = descent_step(graph_ids, rev_ids, words, card,
                                       q_words, q_card, bi, bs,
                                       kernel=kernel, dma=dma, tomb=tomb)
        return (nids, nsims, acc + st), None

    (beam_ids, beam_sims, acc), _ = jax.lax.scan(
        hop, (beam_ids, beam_sims, acc), None, length=hops)
    ids, sims = merge_topk(beam_ids, beam_sims, k)
    return ids, sims, acc


batched_descent = functools.partial(
    jax.jit,
    static_argnames=("k", "beam", "hops", "kernel", "dma",
                     "tag"))(descent_kernel)


@functools.partial(jax.jit, static_argnames=("beam", "tag"),
                   donate_argnames=("q_words", "q_card",
                                    "beam_ids", "beam_sims"))
def slot_admit(words, card, new_words, new_card, new_seeds, slot_idx,
               q_words, q_card, beam_ids, beam_sims, *, beam: int,
               tag=None, tomb=None):
    """Admit up to A requests into the persistent slot state.

    ``new_*`` are A-row admission buckets (A is a small fixed capacity,
    so one program compiles per bucket shape no matter how many requests
    stream in); ``slot_idx`` int32[A] names the target slot per row, with
    ``n_slots`` (one past the end) marking unused bucket rows — the
    out-of-bounds scatter drops them (``mode="drop"``). Each admitted
    row's beam is re-initialized from its routed seeds
    (:func:`descent_init`) and its fingerprint is parked in the
    device-resident ``q_words``/``q_card`` so subsequent hops never
    re-upload per-slot query state.
    """
    trace.bump(("query_slot_admit", tag, new_words.shape[0],
                beam_ids.shape[0], beam))
    init_ids, init_sims = descent_init(
        words, card, new_words, new_card, new_seeds, beam=beam, tomb=tomb)
    return (q_words.at[slot_idx].set(new_words, mode="drop"),
            q_card.at[slot_idx].set(new_card, mode="drop"),
            beam_ids.at[slot_idx].set(init_ids, mode="drop"),
            beam_sims.at[slot_idx].set(init_sims, mode="drop"))


@functools.partial(jax.jit, static_argnames=("kernel", "dma", "tag"),
                   donate_argnames=("beam_ids", "beam_sims"))
def slot_hop(graph_ids, rev_ids, words, card,
             q_words, q_card, beam_ids, beam_sims, active, *,
             kernel: bool = False, dma: bool = False, tag=None,
             tomb=None):
    """One continuous-batching tick over the fixed slot array.

    All slot-axis inputs have the static capacity ``n_slots`` so one
    program compiles per (n_slots, beam, index capacity, kernel, dma)
    and is reused for every tick regardless of how requests stream in
    (asserted by the compile-count regression via ``sched.trace``).
    ``active`` rows take one :func:`descent_step` hop (fused Pallas hop
    when ``kernel``, HBM/DMA placement when also ``dma``); inactive
    rows pass through untouched (their state is garbage the host
    ignores).

    Returns (beam_ids, beam_sims, changed, stats) where ``changed[i]``
    is False when row i's beam reached a fixed point this hop — since
    the hop is a deterministic function of the beam, an unchanged beam
    can never change again, so the host may complete the request early
    without affecting its result (exact wave equivalence). ``stats`` is
    the hop's raw i32[n_slots, 3] ``(n_scored, dma_bytes, bytes_saved)``
    — the kernel runs every slot row, so the HOST must mask rows by its
    own active set before accumulating (inactive rows still score).
    """
    trace.bump(("query_slot_hop", tag, beam_ids.shape[0],
                beam_ids.shape[1], graph_ids.shape[0], kernel, dma))
    nids, nsims, stats = descent_step(graph_ids, rev_ids, words, card,
                                      q_words, q_card, beam_ids,
                                      beam_sims, kernel=kernel, dma=dma,
                                      tomb=tomb)
    changed = jnp.any(nids != beam_ids, axis=1) & active
    out_ids = jnp.where(active[:, None], nids, beam_ids)
    out_sims = jnp.where(active[:, None], nsims, beam_sims)
    return out_ids, out_sims, changed, stats


@functools.partial(jax.jit, static_argnames=("k", "tag"),
                   donate_argnames=("prev_prefix",))
def slot_prefix_stable(beam_ids, prev_prefix, *, k: int, tag=None):
    """Per-slot top-k-prefix stability between consecutive hops.

    The adaptive-budget policy (``PlanSpec.adaptive``) frees a slot once
    its RESULT — the k-prefix of the beam, not the whole beam — has
    survived ``patience`` consecutive hops unchanged: the tail of a beam
    keeps churning long after the answer has settled, so full
    fixed-point detection (``slot_hop``'s ``changed``) leaves budget on
    the table. Works on single-placement ``[n_slots, beam]`` and
    sharded ``[S, n_slots, beam]`` beams (a slot is stable only when
    every shard's prefix is — conservative, since the cross-shard merge
    of unchanged prefixes cannot change).

    Returns ``(stable bool[n_slots], prefix)`` where ``prefix`` is the
    current k-prefix to feed back as ``prev_prefix`` next tick.
    """
    trace.bump(("query_slot_prefix", tag) + beam_ids.shape + (k,))
    cur = beam_ids[..., :k]
    axes = (0, 2) if beam_ids.ndim == 3 else (1,)
    return jnp.all(cur == prev_prefix, axis=axes), cur


# -- shard-axis slot programs (sharded × continuous composition) -----------
#
# The single-device slot programs above lift verbatim over a leading
# shard axis: every shard keeps its OWN per-slot beam over its local
# subgraph (beam_ids/beam_sims are [S, n_slots, shard_beam]), while the
# query fingerprints and the host-side scheduler stay shard-agnostic —
# one SlotScheduler drives all S per-shard slot arrays in lockstep. The
# cross-shard merge happens only at slot-release time
# (:func:`shard_slot_topk`), reproducing the wave path's per-shard
# ``merge_topk(beam, k)`` + ``_merge_shard_topk`` byte for byte, so a
# sharded continuous plan returns bitwise-identical results to the
# sharded wave plan. On a mesh the shard axis arrives pre-sharded
# (NamedSharding over "shards") and GSPMD partitions the vmap; on one
# device it is an ordinary batch axis.


@functools.partial(jax.jit, static_argnames=("beam", "tag"),
                   donate_argnames=("q_words", "q_card",
                                    "beam_ids", "beam_sims"))
def shard_slot_admit(l_words, l_card, new_words, new_card, new_seeds,
                     slot_idx, q_words, q_card, beam_ids, beam_sims, *,
                     beam: int, tag=None, l_tomb=None):
    """Admit up to A requests into every shard's persistent slot state.

    ``new_seeds`` int32[S, A, cols] are OWNER-PARTITIONED shard-local
    seeds (:meth:`~repro.query.sharded.ShardedDescent.shard_seeds` of
    the admission bucket): each shard re-initializes its slot rows from
    the seeds it owns, exactly as the sharded wave path seeds its
    per-shard descent. Unused bucket rows carry slot ``n_slots`` and are
    dropped by the scatter, as in :func:`slot_admit`.
    """
    trace.bump(("query_shard_slot_admit", tag, l_words.shape[0],
                new_words.shape[0], beam_ids.shape[1], beam))
    if l_tomb is None:
        l_tomb = jnp.zeros(l_words.shape[:2], bool)

    def per_shard(words, card, seeds, tomb, bids, bsims):
        init_ids, init_sims = descent_init(
            words, card, new_words, new_card, seeds, beam=beam, tomb=tomb)
        return (bids.at[slot_idx].set(init_ids, mode="drop"),
                bsims.at[slot_idx].set(init_sims, mode="drop"))

    beam_ids, beam_sims = jax.vmap(per_shard)(
        l_words, l_card, new_seeds, l_tomb, beam_ids, beam_sims)
    return (q_words.at[slot_idx].set(new_words, mode="drop"),
            q_card.at[slot_idx].set(new_card, mode="drop"),
            beam_ids, beam_sims)


@functools.partial(jax.jit, static_argnames=("kernel", "dma", "tag"),
                   donate_argnames=("beam_ids", "beam_sims"))
def shard_slot_hop(l_graph, l_rev, l_words, l_card, q_words, q_card,
                   beam_ids, beam_sims, active, *,
                   kernel: bool = False, dma: bool = False, tag=None,
                   l_tomb=None):
    """One continuous tick over every shard's fixed slot array.

    The per-shard hop is :func:`descent_step` vmapped over the shard
    axis (the fused Pallas hop batches through its pallas_call batching
    rule, as in the sharded wave path). ``changed[i]`` is False only
    when slot i's beam reached a fixed point on EVERY shard — each
    shard's hop is a deterministic function of its own beam, so a slot
    whose beams are all unchanged can never change again and the host
    may release it early with wave-identical results. ``stats`` is the
    raw per-slot hop accounting summed over shards (i32[n_slots, 3] of
    ``(n_scored, dma_bytes, bytes_saved)``); the host masks rows by its
    own active set before accumulating, as in :func:`slot_hop`.
    """
    trace.bump(("query_shard_slot_hop", tag, l_graph.shape[0],
                beam_ids.shape[1], beam_ids.shape[2], l_graph.shape[1],
                kernel, dma))
    if l_tomb is None:
        l_tomb = jnp.zeros(l_words.shape[:2], bool)

    def per_shard(g, r, w, c, t, bids, bsims):
        nids, nsims, stats = descent_step(g, r, w, c, q_words, q_card,
                                          bids, bsims, kernel=kernel,
                                          dma=dma, tomb=t)
        changed = jnp.any(nids != bids, axis=1)
        return (jnp.where(active[:, None], nids, bids),
                jnp.where(active[:, None], nsims, bsims), changed,
                stats)

    beam_ids, beam_sims, changed, stats = jax.vmap(per_shard)(
        l_graph, l_rev, l_words, l_card, l_tomb, beam_ids, beam_sims)
    return (beam_ids, beam_sims, jnp.any(changed, axis=0) & active,
            jnp.sum(stats, axis=0))


@functools.partial(jax.jit, static_argnames=("k", "tag"))
def shard_slot_topk(l2g, beam_ids, beam_sims, *, k: int, tag=None):
    """Cross-shard top-k of every slot's per-shard beams, in global ids.

    Each shard's beam is canonical (sim-descending, deduped, PAD-masked
    — merge_topk output), so its top-k is its k-prefix — byte-identical
    to the wave path's per-shard closing ``merge_topk(beam, k)``. The
    prefixes are remapped local→global and merged shard-major, exactly
    mirroring ``sharded._merge_shard_topk`` — which is what makes the
    sharded continuous plan bitwise-equal to the sharded wave plan.
    Returns (ids int32[n_slots, k], sims float32[n_slots, k]).
    """
    trace.bump(("query_shard_slot_topk", tag, l2g.shape[0],
                beam_ids.shape[1], k))
    ids_k = beam_ids[:, :, :k]
    sims_k = beam_sims[:, :, :k]
    safe = jnp.where(ids_k == PAD_ID, 0, ids_k)
    gids = jax.vmap(lambda m, ids, s: jnp.where(ids == PAD_ID, PAD_ID,
                                                m[s]))(l2g, ids_k, safe)
    S, n_slots, kk = gids.shape
    flat_ids = jnp.swapaxes(gids, 0, 1).reshape(n_slots, S * kk)
    flat_sims = jnp.swapaxes(sims_k, 0, 1).reshape(n_slots, S * kk)
    return merge_topk(flat_ids, flat_sims, k)


@functools.partial(jax.jit, static_argnames=("k", "dchunk"))
def _exact_block(words, card, tomb, q_words, q_card, k: int,
                 dchunk: int = 512):
    # Database axis is streamed in dchunk-column tiles so the pairwise
    # interaction is bounded at [block, dchunk] instead of the implicit
    # [block, n] the one-shot top_k needed — the same chunked-scoring
    # shape as the kernels. Streaming merge_topk is bitwise-equal to the
    # global top_k: the running set is concatenated first, so equal-sim
    # ties keep resolving to the earliest database id, and filler slots
    # come out PAD either way.
    trace.bump(("exact_block", words.shape[0], q_words.shape[0], k,
                dchunk))
    n = words.shape[0]
    q = q_words.shape[0]
    ids = jnp.full((q, k), PAD_ID, jnp.int32)
    sims = jnp.full((q, k), NEG_INF, jnp.float32)
    for s in range(0, n, dchunk):
        e = min(s + dchunk, n)
        c_sims = jaccard_pairwise_auto(q_words, q_card,
                                       words[s:e], card[s:e])
        c_sims = jnp.where(tomb[s:e][None, :], NEG_INF, c_sims)
        c_ids = jnp.broadcast_to(
            jnp.arange(s, e, dtype=jnp.int32)[None, :], c_sims.shape)
        ids, sims = merge_topk(
            jnp.concatenate([ids, c_ids], axis=1),
            jnp.concatenate([sims, c_sims], axis=1), k)
    return ids, sims


def exact_knn(words, card, q_words, q_card, k: int, block: int = 256,
              tomb=None):
    """Brute-force query KNN (ground truth for recall), query-blocked.

    Every block — including the final partial one and short query sets —
    is padded up to ``block`` rows, so ONE ``_exact_block`` shape
    compiles per (index rows, block, k) no matter how many queries each
    call brings (the same remainder-padding trick ``local_knn`` uses for
    its capacity-group batches). Pad rows are zero-fingerprint and are
    sliced off before returning. ``tomb`` (bool[n] or None) drops
    tombstoned rows to −inf so the ground truth ranks survivors only —
    an all-live mask is synthesized when None to keep one compile shape.
    """
    words, card = jnp.asarray(words), jnp.asarray(card)
    tomb = (jnp.zeros(words.shape[0], bool) if tomb is None
            else jnp.asarray(tomb))
    q = q_words.shape[0]
    ids_out = np.full((q, k), PAD_ID, dtype=np.int32)
    sims_out = np.full((q, k), NEG_INF, dtype=np.float32)
    for s in range(0, q, block):
        e = min(s + block, q)
        qw = np.zeros((block, q_words.shape[1]), dtype=np.uint32)
        qw[: e - s] = np.asarray(q_words[s:e])
        qc = np.zeros(block, dtype=np.int32)
        qc[: e - s] = np.asarray(q_card[s:e])
        ids, sims = _exact_block(words, card, tomb, jnp.asarray(qw),
                                 jnp.asarray(qc), k)
        ids_out[s:e] = np.asarray(ids)[: e - s]
        sims_out[s:e] = np.asarray(sims)[: e - s]
    return ids_out, sims_out
