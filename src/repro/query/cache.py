"""Fingerprint-keyed result cache with journal-driven invalidation.

Repeated and near-duplicate queries are the norm in a recommendation
front door — the same hot profiles descend the same graph over and over.
The cache sits in front of a plan's serving paths
(``DescentPlan.search`` for waves / the raw batch API, the admission
step for continuous slots) and keys on the EXACT query fingerprint plus
the static knobs that determine the computation: ``(words bytes, card,
k, hops)``. Descent is a deterministic function of (index state, query
fingerprint, k, hops), so an exact-fingerprint hit can be served from
cache bitwise-identically to a fresh descent — the invariant the
hypothesis battery in ``tests/test_cache_properties.py`` locks down
(cache-on == cache-off on ids AND sims across any mutation
interleaving).

Invalidation rides on the mutation journals the lifecycle work already
maintains (``KNNIndex.rows_changed_since`` / ``tombstones_since`` /
``members_added_since``): a version bump whose journals prove NOTHING
changed (no row content, no liveness flip, no routable membership) keeps
the cache; any real mutation flushes it wholesale. Flushing everything
— not just entries naming a touched row — is what the bitwise guarantee
requires: a single new edge can reroute a descent whose result set never
contained the touched row, so per-entry invalidation would serve results
a fresh descent no longer produces. Deletes and updates are therefore
never served stale, and as belt and braces :meth:`get` drops any entry
naming a tombstoned id (counted, never served) even though the flush
rule already makes that unreachable.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.types import PAD_ID


class ResultCache:
    """LRU cache of (ids, sims) results keyed by exact query fingerprint.

    ``capacity`` bounds the entry count (LRU eviction). The cache tracks
    the index version it was filled at; :meth:`sync` must run before a
    batch of lookups (the plan does this once per wave / tick).
    """

    def __init__(self, index, capacity: int):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.index = index
        self.capacity = capacity
        self.version = index.version
        self._entries: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.flushes = 0
        self.stale_drops = 0
        # Results computed on a degraded (shard-masked) fleet are
        # served but never stored — a cached degraded answer would
        # outlive the failure window. The plan counts the skips here.
        self.degraded_skips = 0

    @staticmethod
    def key(words_row: np.ndarray, card: int, k: int, hops: int) -> tuple:
        """Cache key: exact fingerprint + the static serving knobs."""
        return (np.asarray(words_row).tobytes(), int(card), int(k),
                int(hops))

    def __len__(self) -> int:
        return len(self._entries)

    # -- invalidation ------------------------------------------------------

    def sync(self):
        """Reconcile with the index's version before a lookup batch.

        Keeps the cache only when the journals PROVE the bump changed
        nothing a descent could observe; flushes wholesale otherwise
        (including when a journal has expired and can no longer answer —
        ``rows_changed_since`` returning None means "don't know", and
        "don't know" must read as "changed").
        """
        ix = self.index
        if ix.version == self.version:
            return
        changed = ix.rows_changed_since(self.version)
        tombs = ix.tombstones_since(self.version)
        members = ix.members_added_since(self.version)
        if changed is not None and not changed \
                and tombs is not None and not tombs \
                and members is not None and not members:
            self.version = ix.version  # provably a no-op bump
            return
        self._entries.clear()
        self.flushes += 1
        self.version = ix.version

    def invalidate(self):
        """Flush unconditionally — for events the journals cannot see.

        A shard re-balance swap (``query/rebalance.py``) mutates no
        index content, so :meth:`sync` would provably keep the cache —
        yet the partition (and therefore every descent result) changed.
        Counts as a flush, so in-flight requests that straddled the
        swap fail the flush-count check at completion and never
        populate the cache with pre-swap results.
        """
        self._entries.clear()
        self.flushes += 1
        self.version = self.index.version

    # -- lookup / fill -----------------------------------------------------

    def get(self, key: tuple):
        """(ids, sims) copies for ``key``, or None. Counts hit/miss.

        An entry naming a tombstoned id is dropped and reported as a
        miss — unreachable under the flush rule (any tombstone flushes
        first), but the no-stale-result guarantee must not depend on
        that reasoning alone.
        """
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        ids, sims = entry
        live = ids[ids != PAD_ID]
        if live.size and self.index.tombstone[live].any():
            del self._entries[key]
            self.stale_drops += 1
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return ids.copy(), sims.copy()

    def put(self, key: tuple, ids: np.ndarray, sims: np.ndarray):
        """Store a freshly computed result (only when it was computed
        entirely at the cache's current index version — the caller
        checks; results that straddled a mutation are not cacheable)."""
        if self.index.version != self.version:
            return  # computed against a state we no longer certify
        self._entries[key] = (np.array(ids, copy=True),
                              np.array(sims, copy=True))
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "capacity": self.capacity,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hits / lookups, 4) if lookups else 0.0,
            "flushes": self.flushes,
            "stale_drops": self.stale_drops,
            "degraded_skips": self.degraded_skips,
        }
