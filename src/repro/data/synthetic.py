"""Statistics-matched synthetic datasets.

The container is offline, so the paper's six datasets (Table I) cannot be
downloaded. We generate synthetic item-based datasets that match each
dataset's published statistics: user count, item-universe size, mean profile
size, and a Zipf item-popularity law fitted so the dataset is "dense"
(MovieLens-like) or "sparse" (Amazon/DBLP/Gowalla-like). A ``scale``
parameter shrinks the user set (keeping mean |P_u| and the item universe)
so brute-force ground truth stays tractable on one CPU core.

Each generator also plants *community structure* (users draw most items from
one of C latent topics) so that KNN graphs are meaningful and clustering
quality is measurable — a pure iid-Zipf dataset has near-constant pairwise
similarity and makes every KNN algorithm look identical.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.types import Dataset


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_users: int
    n_items: int
    mean_profile: float   # paper's |P_u| column
    zipf_a: float         # item popularity exponent
    n_topics: int         # latent communities
    topic_affinity: float  # fraction of a profile drawn from the home topic


# Paper Table I statistics. "synth" is a CI-sized non-paper dataset for
# serving demos and smoke benchmarks (small universe, strong communities).
PAPER_DATASETS = {
    "synth": DatasetSpec("synth", 4_000, 2_000, 60.0, 1.1, 16, 0.8),
    "ml1M":  DatasetSpec("ml1M", 6_038, 3_533, 95.28, 1.1, 24, 0.75),
    "ml10M": DatasetSpec("ml10M", 69_816, 10_472, 84.30, 1.1, 48, 0.75),
    "ml20M": DatasetSpec("ml20M", 138_362, 22_884, 88.14, 1.1, 64, 0.75),
    "AM":    DatasetSpec("AM", 57_430, 171_356, 56.82, 1.3, 96, 0.8),
    "DBLP":  DatasetSpec("DBLP", 18_889, 203_030, 36.67, 1.4, 128, 0.85),
    "GW":    DatasetSpec("GW", 20_270, 135_540, 54.64, 1.3, 96, 0.8),
}


def _zipf_weights(n: int, a: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** a
    return w / w.sum()


def make_dataset(name: str, scale: float = 1.0, seed: int = 0,
                 min_profile: int = 20) -> Dataset:
    """Generate a statistics-matched synthetic dataset.

    ``scale`` multiplies the user count (the paper filters users with <20
    ratings; we enforce ``min_profile`` the same way).
    """
    spec = PAPER_DATASETS[name]
    rng = np.random.default_rng(seed)
    n_users = max(64, int(round(spec.n_users * scale)))
    n_items = spec.n_items
    n_topics = spec.n_topics

    # Item → topic assignment: contiguous blocks over the popularity-ranked
    # item list so every topic has both popular and niche items.
    item_topic = rng.integers(0, n_topics, size=n_items)
    global_w = _zipf_weights(n_items, spec.zipf_a)
    # Per-topic sampling weights: global popularity restricted to the topic.
    topic_items = [np.where(item_topic == t)[0] for t in range(n_topics)]
    topic_w = [global_w[ti] / global_w[ti].sum() for ti in topic_items]

    user_topic = rng.integers(0, n_topics, size=n_users)
    # Profile sizes: lognormal around the paper's mean, clipped at
    # [min_profile, 16·mean] like the paper's ≥20-ratings filter.
    mu = np.log(spec.mean_profile)
    sizes = np.clip(
        rng.lognormal(mean=mu, sigma=0.6, size=n_users),
        min_profile, spec.mean_profile * 16,
    ).astype(np.int64)
    sizes = np.minimum(sizes, n_items // 2)

    rows = []
    for u in range(n_users):
        sz = int(sizes[u])
        t = int(user_topic[u])
        n_home = int(round(sz * spec.topic_affinity))
        ti, tw = topic_items[t], topic_w[t]
        n_home = min(n_home, len(ti))
        home = rng.choice(ti, size=n_home, replace=False, p=tw) if n_home else np.empty(0, np.int64)
        n_bg = sz - n_home
        bg = rng.choice(n_items, size=n_bg, replace=False, p=global_w) if n_bg > 0 else np.empty(0, np.int64)
        rows.append(np.unique(np.concatenate([home, bg])).astype(np.int32))

    sizes = np.array([len(r) for r in rows], dtype=np.int64)
    offsets = np.zeros(n_users + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    return Dataset(
        name=f"{name}@{scale:g}",
        n_users=n_users,
        n_items=n_items,
        items=np.concatenate(rows).astype(np.int32),
        offsets=offsets,
    )


def train_test_split(ds: Dataset, test_frac: float = 0.2, seed: int = 0):
    """Per-user item holdout for the recommendation experiment (Table III).

    Returns (train Dataset, test item lists). Mirrors the paper's 5-fold
    cross-validation: each fold holds out ``test_frac`` of every profile.
    """
    rng = np.random.default_rng(seed)
    train_rows, test_rows = [], []
    for u in range(ds.n_users):
        p = ds.profile(u)
        n_test = max(1, int(len(p) * test_frac))
        perm = rng.permutation(len(p))
        test_rows.append(np.sort(p[perm[:n_test]]))
        train_rows.append(np.sort(p[perm[n_test:]]))
    sizes = np.array([len(r) for r in train_rows], dtype=np.int64)
    offsets = np.zeros(ds.n_users + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    train = Dataset(
        name=f"{ds.name}:train", n_users=ds.n_users, n_items=ds.n_items,
        items=np.concatenate(train_rows).astype(np.int32), offsets=offsets,
    )
    return train, test_rows
