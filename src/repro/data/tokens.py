"""Deterministic synthetic LM token pipeline with restart skip.

Batches are a pure function of (seed, step): after a crash/restart the
loader resumes at exactly the next step with zero replayed or skipped
data — the data-side half of the fault-tolerance contract (the
checkpoint holds the step counter). A real deployment swaps `_synth_doc`
for tokenized shards; the step-indexed determinism is the part that
matters and is what tests pin down.

Also exposes C²-locality ordering: documents are pre-clustered with
FastRandomHash over their token-set profiles and batches draw from one
cluster at a time (paper §II-B's cache-locality insight, mapped to
embedding-gather locality / MoE routing coherence — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    ordering: str = "iid"  # "iid" | "c2"
    n_docs: int = 4096     # synthetic corpus size for c2 ordering


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        self._order = None
        if dc.ordering == "c2":
            self._order = self._c2_order()

    def _doc_tokens(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.dc.seed, doc_id))
        # Zipf-ish token stream with doc-specific topic offset.
        topic = rng.integers(0, max(self.cfg.vocab_size // 64, 1))
        z = rng.zipf(1.3, size=self.dc.seq_len).astype(np.int64)
        toks = (z + topic * 64) % self.cfg.vocab_size
        return toks.astype(np.int32)

    def _c2_order(self) -> np.ndarray:
        """Cluster docs by FastRandomHash over their token sets; return a
        doc order that groups same-cluster docs together."""
        from repro.core import hashing

        n = self.dc.n_docs
        profiles = []
        for d in range(n):
            toks = self._doc_tokens(d)
            profiles.append(np.unique(toks)[:64])
        sizes = np.array([len(p) for p in profiles], dtype=np.int64)
        offsets = np.zeros(n + 1, np.int64)
        np.cumsum(sizes, out=offsets[1:])
        items = np.concatenate(profiles).astype(np.int32)
        h = hashing.item_hashes(items, np.array([self.dc.seed], np.int32),
                                4096)
        H = hashing.user_min_hash_np(h, offsets)[0]
        return np.argsort(H, kind="stable").astype(np.int64)

    def batch(self, step: int) -> dict:
        B, S = self.dc.global_batch, self.dc.seq_len
        docs = np.arange(step * B, (step + 1) * B, dtype=np.int64)
        if self._order is not None:
            docs = self._order[docs % self.dc.n_docs]
        else:
            docs = docs % self.dc.n_docs
        toks = np.stack([self._doc_tokens(int(d)) for d in docs])
        batch = {"labels": toks}
        if self.cfg.frontend:
            rng = np.random.default_rng((self.dc.seed, 777, step))
            batch["embeddings"] = rng.standard_normal(
                (B, S, self.cfg.d_model)).astype(np.float32)
        else:
            batch["tokens"] = toks
        return batch
