from repro.data.synthetic import PAPER_DATASETS, make_dataset  # noqa: F401
