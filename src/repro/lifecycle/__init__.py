"""Index lifecycle: deletes, updates, TTL expiry, online repair.

Everything beyond append-only growth lives here. The index layer
(``query/index.py``) provides the mutation primitives — tombstoning
with best-effort edge patching (:meth:`KNNIndex.remove_user`),
fingerprint swaps (:meth:`KNNIndex.swap_profile`), forward-row
replacement with mutuality restoration (:meth:`KNNIndex.relink_user`)
— and :class:`LifecycleManager` composes them into serving-level
operations scheduled BETWEEN ticks, so continuous plans' in-flight
slots never observe a half-applied mutation:

* ``remove``  — tombstone + patch + router deregistration (the router
  filters dead members at seed time; membership stays append-only for
  delta resharding);
* ``update``  — profile swap, re-sketch, and localized re-linking via a
  neighbors-of-neighbors seeded descent (no FRH routing, cost bounded
  by the neighborhood);
* TTL expiry — per-row last-touched logical clocks, stale rows expire
  in bounded batches;
* repair     — a periodic bounded NN-descent pass over churn-touched
  cohorts, re-linking survivors whose neighborhoods lost edges.

Correctness rests on the tombstone mask, not the patching: the mask is
threaded through routing, descent init, and both scorers (jnp ref and
the fused Pallas hop, bitwise-identical), so a dead id is never seeded,
scored, or returned even while stale references linger in unpatched
rows (the bounded reverse table makes patching inherently lossy).
:func:`scrub_dead_references` is the test-side excision comparator
that pins down masking ≡ physical excision.
"""
from repro.lifecycle.manager import (LifecycleConfig,  # noqa: F401
                                     LifecycleManager)
from repro.lifecycle.scrub import scrub_dead_references  # noqa: F401
