"""Physical excision of dead references — the masking comparator.

The lifecycle design keeps deleted rows in place (tombstone mask) and
patches incident edges best-effort: the bounded reverse table drops
entries under pressure, so an in-neighbor the dead row never knew about
keeps a stale forward lane. Descent correctness comes from the mask —
dead lanes retire positionally (PAD in place, no compaction) inside
both scorers before anything downstream sees them.

:func:`scrub_dead_references` makes that claim testable: it rewrites
the host adjacency so every lane referencing a tombstoned row is PAD'd
*at the same lane position* the mask would retire it. Running descent
over the scrubbed index with an all-live mask must then be bitwise
equal to running the original index under its tombstone mask — same
candidate multiset, same lane order, same merge tie-breaks
(``tests/test_lifecycle.py`` locks this down across the plan matrix).
"""
from __future__ import annotations

import numpy as np

from repro.types import NEG_INF, PAD_ID


def scrub_dead_references(index, resort: bool = False) -> int:
    """PAD every adjacency lane referencing a tombstoned row, in place.

    Mutates ``index`` (callers wanting a comparator copy deepcopy
    first), journals the touched rows, and bumps the version once so
    device copies resync. Returns the number of lanes scrubbed.

    ``resort=False`` (default) keeps lanes POSITIONAL — holes stay
    where the dead ids sat, exactly mirroring the in-kernel mask; this
    is the bitwise-comparator mode, and it intentionally leaves forward
    rows out of by-similarity order. ``resort=True`` restores the sort
    invariant afterwards (physical cleanup mode) at the cost of the
    positional equivalence.
    """
    bufs = index._bufs
    n = index.n
    tomb = bufs["tombstone"][:n]
    graph_ids = bufs["graph_ids"]
    graph_sims = bufs["graph_sims"]
    rev_ids = bufs["rev_ids"]
    touched = set()
    n_scrubbed = 0
    for u in np.flatnonzero(~tomb):
        u = int(u)
        row = graph_ids[u]
        dead = (row != PAD_ID) & tomb[np.clip(row, 0, n - 1)]
        if dead.any():
            graph_ids[u][dead] = PAD_ID
            graph_sims[u][dead] = NEG_INF
            if resort:
                index._resort_row(u)
            touched.add(u)
            n_scrubbed += int(dead.sum())
        rrow = rev_ids[u]
        rdead = (rrow != PAD_ID) & tomb[np.clip(rrow, 0, n - 1)]
        if rdead.any():
            rev_ids[u][rdead] = PAD_ID
            touched.add(u)
            n_scrubbed += int(rdead.sum())
    if touched:
        index.version += 1
        index._journal_rows(tuple(sorted(touched)))
    return n_scrubbed
