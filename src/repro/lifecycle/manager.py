"""Serving-level lifecycle operations over a live query engine.

:class:`LifecycleManager` is host bookkeeping between the engine's
scheduler steps: it owns the logical clock TTL expiry runs on, the
churn-touched cohort the periodic repair pass re-links, and the
delegation into the index's mutation primitives. It deliberately holds
the *engine* (not just the index) so update/repair searches run through
the engine's own :class:`~repro.query.plan.DescentPlan` — the same
compiled programs, placement, and scorer serving queries, with the
tombstone mask already threaded through.

Scheduling discipline: all maintenance fires from :meth:`maintain`,
which the engine calls BETWEEN plan steps (one logical tick per step).
Continuous plans therefore never observe a half-applied mutation
mid-hop; a delete landing between ticks reaches in-flight beams as the
updated tombstone mask on the next hop, which linearizes it as
"completed before the delete" for slots already past their final hop.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sched import Cadence
from repro.types import PAD_ID


@dataclasses.dataclass(frozen=True)
class LifecycleConfig:
    """Knobs for churn maintenance (all off by default: a lifecycle-less
    engine behaves exactly as before)."""

    ttl: int = 0             # logical ticks a row may go untouched
                             # (0 = never expire)
    repair_every: int = 0    # repair-pass cadence in ticks (0 = off)
    repair_hops: int = 2     # descent depth for update/repair re-linking
    repair_beam: int = 16    # frontier width for update/repair descents
    repair_batch: int = 32   # cohort rows re-linked per compiled wave
    expire_batch: int = 64   # max TTL expirations per maintain() call


class LifecycleManager:
    """Deletes, updates, TTL expiry, and online repair for one engine."""

    def __init__(self, engine, cfg: LifecycleConfig | None = None):
        self.engine = engine
        self.cfg = cfg or LifecycleConfig()
        self.clock = 0                      # logical ticks (maintain calls)
        self._repair_cadence = Cadence(self.cfg.repair_every)
        self._touched: set[int] = set()     # churn-touched repair cohort
        self.n_removed = 0
        self.n_updated = 0
        self.n_expired = 0
        self.n_repairs = 0
        self.n_relinked = 0

    # -- activity ----------------------------------------------------------

    def touch(self, u: int):
        """Record user activity: resets ``u``'s TTL clock."""
        self.engine.index.touch_row(int(u), self.clock)

    def note_insert(self, u: int):
        """Stamp a freshly inserted row (the engine calls this so new
        users start their TTL window at the current tick, not 0)."""
        self.engine.index.touch_row(int(u), self.clock)

    # -- mutation ----------------------------------------------------------

    def _ring(self, u: int) -> set[int]:
        """Live forward+reverse neighbors of ``u`` (its 1-hop ring)."""
        ix = self.engine.index
        tomb = ix.tombstone
        ring = set()
        for v in np.concatenate([ix.graph_ids[u], ix.rev_ids[u]]):
            if v != PAD_ID and not tomb[int(v)]:
                ring.add(int(v))
        ring.discard(int(u))
        return ring

    def remove(self, u: int):
        """Delete ``u``: tombstone + edge patch + router deregistration
        (``query/router.py`` filters dead members at seed time). The
        survivors that lost an edge join the repair cohort."""
        u = int(u)
        ring = self._ring(u)
        self.engine.index.remove_user(u)
        self._touched |= ring
        self._touched.discard(u)
        self.n_removed += 1

    def update(self, u: int, profile) -> tuple[np.ndarray, np.ndarray]:
        """Replace ``u``'s profile and re-link it into the graph.

        Re-sketches the profile with the index's fingerprint seeds,
        swaps it in (re-scoring every incident edge —
        :meth:`KNNIndex.swap_profile`), then runs a LOCALIZED descent
        seeded from ``u``'s neighbors-of-neighbors — no FRH routing, so
        the search cost is bounded by the neighborhood — and rewrites
        ``u``'s forward row from the result
        (:meth:`KNNIndex.relink_user`). Returns the (ids, sims) row
        ``u`` was re-linked with.
        """
        # Imported here, not at module scope: repro.query's package init
        # pulls in the engine, which imports this module — the deferred
        # import breaks the cycle for whichever side loads first.
        from repro.query.router import (fingerprint_profiles,
                                        profiles_to_csr)

        u = int(u)
        ix = self.engine.index
        cfg = self.cfg
        items, offsets = profiles_to_csr([profile])
        qgf = fingerprint_profiles(items, offsets, ix.n_bits, ix.fp_seed)
        before = self._ring(u)
        ix.swap_profile(u, np.asarray(qgf.words)[0], int(qgf.card[0]))
        seeds = self._neighborhood_seeds([u])
        ids, sims = self.engine.plan.descend_rows(
            np.asarray(qgf.words), np.asarray(qgf.card), seeds,
            k=ix.k + 1, hops=cfg.repair_hops, beam=cfg.repair_beam)
        ix.relink_user(u, ids[0], sims[0])
        ix.touch_row(u, self.clock)
        # Old and new neighborhoods both shifted under the swap.
        self._touched |= before | self._ring(u)
        self._touched.discard(u)
        self.n_updated += 1
        return ids[0], sims[0]

    # -- TTL expiry --------------------------------------------------------

    def expire_stale(self) -> int:
        """Remove rows untouched for more than ``cfg.ttl`` ticks, lowest
        id first, at most ``cfg.expire_batch`` per call (bounding the
        between-tick pause a burst of simultaneous expiries can cause)."""
        cfg = self.cfg
        if cfg.ttl <= 0:
            return 0
        ix = self.engine.index
        stale = np.flatnonzero(
            ~ix.tombstone & (self.clock - ix.last_touch > cfg.ttl))
        n = 0
        for u in stale[: cfg.expire_batch]:
            self.remove(int(u))
            n += 1
        self.n_expired += n
        return n

    # -- repair ------------------------------------------------------------

    def _neighborhood_seeds(self, users) -> np.ndarray:
        """int32[len(users), W] descent seeds: each user's live 1-hop
        ring first, then its neighbors-of-neighbors (first-seen order,
        deduped), truncated/PAD-padded to the fixed width W — one
        compiled shape per plan no matter the neighborhood. Users whose
        ring died entirely fall back to an id-strided sample of live
        rows so the descent always has a frontier."""
        ix = self.engine.index
        graph, rev, tomb = ix.graph_ids, ix.rev_ids, ix.tombstone
        W = self.seed_width
        out = np.full((len(users), W), PAD_ID, dtype=np.int32)
        alive = None
        for i, u in enumerate(users):
            u = int(u)
            ring = [int(v) for v in np.concatenate([graph[u], rev[u]])
                    if v != PAD_ID]
            non = [int(x) for v in ring for x in graph[v] if x != PAD_ID]
            seen, cand = set(), []
            for v in ring + non:
                if v == u or v in seen or tomb[v]:
                    continue
                seen.add(v)
                cand.append(v)
            if not cand:
                if alive is None:
                    alive = ix.alive_ids()
                pool = alive[alive != u]
                take = np.linspace(0, len(pool) - 1,
                                   num=min(W, len(pool)), dtype=np.int64)
                cand = [int(v) for v in pool[take]]
            out[i, : min(len(cand), W)] = cand[:W]
        return out

    @property
    def seed_width(self) -> int:
        """Static seed-column count for update/repair descents."""
        ix = self.engine.index
        return 2 * (ix.k + ix.rev_ids.shape[1])

    def repair(self) -> int:
        """Bounded NN-descent over the churn-touched cohort.

        Every surviving user whose forward row actually LOST edges (PAD
        holes from delete patching) gets it re-searched — seeded from
        its current ring, the descent climbs back to whatever replaced
        the lost neighbors — and re-linked. Touched rows that kept full
        degree are left alone: their build-time edges (including the
        non-greedy ones NN-descent converged to) navigate better than a
        freshly re-ranked pure top-k row, so minimal intervention wins.
        Runs in ``cfg.repair_batch`` waves so the compiled shapes stay
        fixed. Returns rows re-linked."""
        ix = self.engine.index
        cfg = self.cfg
        tomb = ix.tombstone
        graph = ix.graph_ids
        cohort = sorted(v for v in self._touched
                        if 0 <= v < ix.n and not tomb[v]
                        and (graph[v] == PAD_ID).any())
        self._touched.clear()
        if not cohort:
            return 0
        B = max(cfg.repair_batch, 1)
        for lo in range(0, len(cohort), B):
            chunk = cohort[lo: lo + B]
            seeds = self._neighborhood_seeds(chunk)
            ids, sims = self.engine.plan.descend_rows(
                ix.words[chunk], ix.card[chunk], seeds,
                k=ix.k + 1, hops=cfg.repair_hops, beam=cfg.repair_beam)
            for j, u in enumerate(chunk):
                ix.relink_user(u, ids[j], sims[j])
        self.n_repairs += 1
        self.n_relinked += len(cohort)
        return len(cohort)

    # -- the between-ticks hook --------------------------------------------

    def maintain(self) -> dict:
        """One maintenance tick: advance the clock, expire stale rows,
        and fire the repair cadence. The engine calls this after every
        scheduler step; with an all-default config it is a no-op beyond
        the clock.

        While the fleet is DEGRADED (a shard masked out — see
        repro/faults) only the clock advances: TTL expiry and churn
        repair both re-link rows via descents over the surviving
        shards, and baking those degraded results into the graph would
        outlive the failure. Deferred work fires on the first healthy
        tick (the touched cohort is kept; stale rows are re-measured)."""
        self.clock += 1
        if getattr(self.engine, "degraded", False):
            return {"clock": self.clock, "expired": 0, "relinked": 0,
                    "deferred": True}
        n_expired = self.expire_stale()
        n_relinked = 0
        if self._repair_cadence.tick() and self._touched:
            n_relinked = self.repair()
        return {"clock": self.clock, "expired": n_expired,
                "relinked": n_relinked}

    def stats(self) -> dict:
        return {"clock": self.clock, "removed": self.n_removed,
                "updated": self.n_updated, "expired": self.n_expired,
                "repairs": self.n_repairs, "relinked": self.n_relinked,
                "pending_repair": len(self._touched)}
