"""Table IV: FastRandomHash vs MinHash inside Cluster-and-Conquer.

The MinHash variant buckets with t min-wise hashes over the full item
universe (one bucket per signature, no recursive splitting) and then runs
the same local-KNN + merge — exactly the paper's C²/MinHash ablation."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import K_DEFAULT, bench_params, emit, exact_graph, load
from repro.core.local_knn import local_knn
from repro.core.merge import merge_partial
from repro.core.pipeline import cluster_and_conquer
from repro.eval.metrics import quality
from repro.knn.lsh import lsh_plan

DATASETS = ("ml10M", "AM")


def run(datasets=DATASETS, k: int = K_DEFAULT):
    rows = []
    for name in datasets:
        ds, gf = load(name)
        exact, _ = exact_graph(ds, gf, k)
        p = bench_params(name, ds.n_users, k)

        t0 = time.perf_counter()
        plan_mh = lsh_plan(ds, t=p.t)
        ids, sims = local_knn(plan_mh, gf, p)
        g_mh = merge_partial(ids, sims, k)
        t_mh = time.perf_counter() - t0

        t0 = time.perf_counter()
        g_frh, st = cluster_and_conquer(ds, p, gf=gf)
        t_frh = time.perf_counter() - t0

        q_mh = quality(ds, g_mh, exact)
        q_frh = quality(ds, g_frh, exact)
        rows += [
            {"dataset": ds.name, "mechanism": "MinHash",
             "time_s": round(t_mh, 3), "quality": round(q_mh, 4),
             "n_clusters": plan_mh.n_clusters,
             "sims": plan_mh.brute_force_sims()},
            {"dataset": ds.name, "mechanism": "FRH",
             "time_s": round(t_frh, 3), "quality": round(q_frh, 4),
             "n_clusters": st.n_clusters, "sims": st.n_sims,
             "speedup": round(t_mh / t_frh, 2)},
        ]
        print(f"[table4] {name}: MinHash {t_mh:.1f}s q={q_mh:.3f} "
              f"({plan_mh.n_clusters} buckets) | FRH {t_frh:.1f}s "
              f"q={q_frh:.3f} ({st.n_clusters} clusters) "
              f"→ x{t_mh / t_frh:.2f}")
    return emit(rows, "table4")


if __name__ == "__main__":
    run()
