"""Shared benchmark harness: dataset prep, parameter scaling, exact-graph
caching, timing.

Scale rationale (documented in EXPERIMENTS.md): the paper's datasets run
minutes on an 8-thread Xeon; this container has ONE core, so benchmarks
default to user-count scales that keep the whole suite under ~20 min
while preserving each dataset's item universe, profile statistics and
density class. C² parameters are scaled to preserve the paper's
*occupancy ratios*: b ≈ n/16 (paper: 70k/4096 ≈ 17 users/cluster) and
N ≈ 3% of n (paper: 2000/70k). k defaults to 10 (paper: 30) — at these
user counts k=30 would be ~1% of the whole dataset per neighborhood.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.core.params import C2Params, params_for
from repro.data.synthetic import make_dataset
from repro.knn.brute_force import brute_force_knn
from repro.sketch.goldfinger import fingerprint_dataset, incidence_fingerprint
from repro.types import KNNGraph

ART = Path(__file__).resolve().parent.parent / "artifacts"
CACHE = ART / "bench_cache"

# Per-dataset user-count scale (full item universes preserved).
BENCH_SCALES = {
    "ml1M": 0.35, "ml10M": 0.06, "ml20M": 0.02,
    "AM": 0.055, "DBLP": 0.15, "GW": 0.15,
}
K_DEFAULT = 10


def bench_params(name: str, n_users: int, k: int = K_DEFAULT,
                 **overrides) -> C2Params:
    base = params_for(name)
    b = 1 << max(6, int(np.ceil(np.log2(max(n_users / 16, 1)))))
    N = max(64, int(0.03 * n_users))
    kw = dict(k=k, b=b, max_cluster=N)
    kw.update(overrides)
    return dataclasses.replace(base, **kw)


def load(name: str, seed: int = 0):
    ds = make_dataset(name, scale=BENCH_SCALES[name], seed=seed)
    gf = fingerprint_dataset(ds)
    return ds, gf


def exact_graph(ds, gf=None, k: int = K_DEFAULT, tag: str = "gf"):
    """Brute-force graph, cached on disk (the quality denominator)."""
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / f"exact_{ds.name.replace('@','_')}_{k}_{tag}.npz"
    if f.exists():
        z = np.load(f)
        return KNNGraph(ids=z["ids"], sims=z["sims"]), float(z["t"])
    gf = gf if gf is not None else (
        incidence_fingerprint(ds) if tag == "raw" else fingerprint_dataset(ds))
    t0 = time.perf_counter()
    g = brute_force_knn(gf, k=k)
    t = time.perf_counter() - t0
    np.savez(f, ids=g.ids, sims=g.sims, t=t)
    return g, t


def emit(rows: list[dict], name: str):
    """Write a benchmark table to artifacts + print CSV."""
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(rows, indent=2))
    return rows
