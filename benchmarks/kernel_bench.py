"""Kernel micro-benchmarks: the three GoldFinger-similarity paths on an
all-pairs KNN tile (CPU wall time; the Pallas path runs in interpret mode
here — its TPU performance is characterized structurally in §Roofline,
this table establishes correctness-path overheads and the popcount-vs-MXU
layout tradeoff on real data)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.synthetic import make_dataset
from repro.kernels.goldfinger_knn import ops as gk_ops
from repro.kernels.goldfinger_knn import ref as gk_ref
from repro.sketch.goldfinger import fingerprint_dataset


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(n: int = 1024, k: int = 10):
    ds = make_dataset("ml1M", scale=max(n / 6038, 0.01), seed=5)
    gf = fingerprint_dataset(ds)
    n = min(n, gf.n)
    w = jnp.asarray(gf.words[:n])
    c = jnp.asarray(gf.card[:n])
    ids = jnp.arange(n, dtype=jnp.int32)

    ref_j = jax.jit(lambda *a: gk_ref.knn_ref(*a, k=k))
    t_ref = _time(ref_j, w, c, ids, w, c, ids)

    from repro.sketch.goldfinger import jaccard_pairwise_mxu

    def mxu_knn(w, c, ids):
        sims = jaccard_pairwise_mxu(w, c, w, c)
        sims = jnp.where(ids[None, :] == ids[:, None], -jnp.inf, sims)
        return jax.lax.top_k(sims, k)

    t_mxu = _time(jax.jit(mxu_knn), w, c, ids)
    t_pal = _time(lambda *a: gk_ops.knn(*a, k=k), w, c, ids, w, c, ids)

    rows = [
        {"path": "jnp_popcount_ref", "n": n, "time_s": t_ref,
         "us_per_pair": 1e6 * t_ref / (n * n)},
        {"path": "jnp_mxu_bitplane", "n": n, "time_s": t_mxu,
         "us_per_pair": 1e6 * t_mxu / (n * n)},
        {"path": "pallas_interpret", "n": n, "time_s": t_pal,
         "us_per_pair": 1e6 * t_pal / (n * n)},
    ]
    for r in rows:
        print(f"[kernel] {r['path']:18s} n={n}: {r['time_s']*1e3:8.1f} ms "
              f"({r['us_per_pair']:.4f} µs/pair)")
    return emit(rows, "kernel_bench")


if __name__ == "__main__":
    run()
