"""Kernel micro-benchmarks (CPU wall time; Pallas paths run in interpret
mode here — their TPU performance is characterized structurally in
§Roofline, these tables establish correctness-path overheads and the
popcount-vs-MXU layout tradeoff on real data).

Two sections:

* **all-pairs** — the three GoldFinger-similarity paths on a KNN tile
  (jnp popcount ref, jnp MXU bit-plane, fused goldfinger_knn kernel).
* **descent** — the serving hot path, per beam width: the unfused jnp
  hop (score every ``beam·(kg+kr)`` lane, dedup after, wide top-k) vs
  the fused descent_score kernel in BOTH placements — blocked-VMEM
  tables and HBM-resident tables with per-chunk candidate-row DMA —
  with the kernel's scored-lane counts showing how much estimator work
  dedup-before-scoring removes and the DMA path's byte columns showing
  the HBM traffic the suppressed-lane skip avoids.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke]

``--smoke`` shrinks both sections for CI and fails loudly (exit 1) if
the fused descent hop (either placement) drifts from the jnp oracle by
a single bit, stops reducing scored work, moves no DMA / saves no
bytes on the dedup-heavy workload, or re-misses the shape-keyed
autotuner cache on a repeated shape.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.synthetic import make_dataset
from repro.kernels.goldfinger_knn import ops as gk_ops
from repro.kernels.goldfinger_knn import ref as gk_ref
from repro.sketch.goldfinger import fingerprint_dataset


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(n: int = 1024, k: int = 10):
    ds = make_dataset("ml1M", scale=max(n / 6038, 0.01), seed=5)
    gf = fingerprint_dataset(ds)
    n = min(n, gf.n)
    w = jnp.asarray(gf.words[:n])
    c = jnp.asarray(gf.card[:n])
    ids = jnp.arange(n, dtype=jnp.int32)

    ref_j = jax.jit(lambda *a: gk_ref.knn_ref(*a, k=k))
    t_ref = _time(ref_j, w, c, ids, w, c, ids)

    from repro.sketch.goldfinger import jaccard_pairwise_mxu

    def mxu_knn(w, c, ids):
        sims = jaccard_pairwise_mxu(w, c, w, c)
        sims = jnp.where(ids[None, :] == ids[:, None], -jnp.inf, sims)
        return jax.lax.top_k(sims, k)

    t_mxu = _time(jax.jit(mxu_knn), w, c, ids)
    t_pal = _time(lambda *a: gk_ops.knn(*a, k=k), w, c, ids, w, c, ids)

    rows = [
        {"path": "jnp_popcount_ref", "n": n, "time_s": t_ref,
         "us_per_pair": 1e6 * t_ref / (n * n)},
        {"path": "jnp_mxu_bitplane", "n": n, "time_s": t_mxu,
         "us_per_pair": 1e6 * t_mxu / (n * n)},
        {"path": "pallas_interpret", "n": n, "time_s": t_pal,
         "us_per_pair": 1e6 * t_pal / (n * n)},
    ]
    for r in rows:
        print(f"[kernel] {r['path']:18s} n={n}: {r['time_s']*1e3:8.1f} ms "
              f"({r['us_per_pair']:.4f} µs/pair)")
    return emit(rows, "kernel_bench")


def run_descent(scale: float = 0.1, n_queries: int = 128,
                beams=(8, 16, 32), k: int = 10, seed: int = 5):
    """Descent-hop rows: jnp vs fused per beam width + scored-lane stats.

    Returns the rows; raises AssertionError on any jnp/fused bit drift
    (the smoke gate turns that into a CI failure).
    """
    from repro.core.params import params_for
    from repro.kernels.descent_score import ops as ds_ops
    from repro.kernels.descent_score import ref as ds_ref
    from repro.query.index import build_index
    from repro.query.router import routed_queries
    from repro.query.search import descent_init

    ds = make_dataset("synth", scale=scale, seed=seed)
    index = build_index(ds, params_for("synth", k=k,
                                       b=max(64, ds.n_users // 16),
                                       max_cluster=max(48,
                                                       int(0.06 * ds.n_users))))
    qds = make_dataset("synth", scale=scale, seed=seed + 1)
    profiles = [qds.profile(u) for u in range(min(n_queries, qds.n_users))]
    qw, qc, seeds = (jnp.asarray(x)
                     for x in routed_queries(index, profiles, 16))
    g, r = jnp.asarray(index.graph_ids), jnp.asarray(index.rev_ids)
    w, c = jnp.asarray(index.words), jnp.asarray(index.card)
    kg, kr = g.shape[1], r.shape[1]

    jnp_hop = jax.jit(ds_ref.descent_hop_ref)
    W = w.shape[1]
    rows = []
    for beam in beams:
        bi, bs = descent_init(w, c, qw, qc, seeds, beam=beam)
        bi, bs = jax.block_until_ready((bi, bs))
        t_jnp = _time(jnp_hop, g, r, w, c, qw, qc, bi, bs)
        t_pal = _time(lambda *a: ds_ops.descent_hop(*a),
                      g, r, w, c, qw, qc, bi, bs)
        t_dma = _time(lambda *a: ds_ops.descent_hop(*a, dma=True),
                      g, r, w, c, qw, qc, bi, bs)
        ri, rs = jnp_hop(g, r, w, c, qw, qc, bi, bs)
        ki, ks, nsc, _, _ = ds_ops.descent_hop(
            g, r, w, c, qw, qc, bi, bs, with_counts=True)
        di, dsim, dnsc, dmab, saved = ds_ops.descent_hop(
            g, r, w, c, qw, qc, bi, bs, dma=True, with_counts=True)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))
        np.testing.assert_array_equal(np.asarray(di), np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(dsim), np.asarray(rs))
        # DMA accounting must agree with the scored-lane counter: the
        # kernel fetches exactly the surviving lanes' fingerprint rows.
        np.testing.assert_array_equal(np.asarray(dmab),
                                      np.asarray(dnsc) * W * 4)
        total = beam * (kg + kr)
        scored = float(np.asarray(nsc).mean())
        q_dma = float(np.asarray(dmab).mean())
        q_saved = float(np.asarray(saved).mean())
        rows.append({
            "beam": beam, "n": index.n, "n_queries": len(profiles),
            "candidates_per_hop": total,
            "scored_per_hop_mean": round(scored, 1),
            "scored_fraction": round(scored / total, 3),
            "jnp_hop_ms": round(t_jnp * 1e3, 2),
            "fused_interpret_ms": round(t_pal * 1e3, 2),
            "fused_dma_interpret_ms": round(t_dma * 1e3, 2),
            "dma_kb_per_query": round(q_dma / 1e3, 2),
            "dma_saved_kb_per_query": round(q_saved / 1e3, 2),
            "dma_saved_fraction": round(q_saved / (q_dma + q_saved), 3),
        })
    for row in rows:
        print(f"[descent] beam={row['beam']:3d}: scored "
              f"{row['scored_per_hop_mean']:7.1f}/{row['candidates_per_hop']}"
              f" lanes ({row['scored_fraction']:.0%}) | jnp "
              f"{row['jnp_hop_ms']:.1f} ms, fused(interpret) "
              f"{row['fused_interpret_ms']:.1f} ms, fused-dma(interpret) "
              f"{row['fused_dma_interpret_ms']:.1f} ms | dma "
              f"{row['dma_kb_per_query']:.1f} KB/q, skipped "
              f"{row['dma_saved_kb_per_query']:.1f} KB/q "
              f"({row['dma_saved_fraction']:.0%})")
    return emit(rows, "kernel_bench_descent")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; exit 1 on fused-hop drift")
    args = ap.parse_args()
    if args.smoke:
        from repro.kernels.descent_score import tune

        run(n=256)
        tune.clear()
        try:
            rows = run_descent(scale=0.05, n_queries=48, beams=(8, 16))
        except AssertionError as e:
            print(f"[kernel_bench] FAIL fused descent hop drifted from "
                  f"the jnp oracle: {e}", file=sys.stderr)
            sys.exit(1)
        if not all(row["scored_fraction"] < 1.0 for row in rows):
            print("[kernel_bench] FAIL dedup-before-scoring removed no "
                  "work", file=sys.stderr)
            sys.exit(1)
        if not all(row["dma_saved_kb_per_query"] > 0 for row in rows):
            print("[kernel_bench] FAIL suppressed-lane DMA skip saved "
                  "no bytes on a dedup-heavy workload", file=sys.stderr)
            sys.exit(1)
        # Shape-keyed autotuner: the first dma hop per beam width is a
        # cache miss, every repeat (timing reps + counted rerun) a hit.
        if tune.stats["misses"] != 2 or tune.stats["hits"] < 2:
            print(f"[kernel_bench] FAIL autotuner cache re-missed on a "
                  f"repeated shape: {tune.stats}", file=sys.stderr)
            sys.exit(1)
        print(f"[kernel_bench] smoke OK (tune cache {tune.stats})")
        return
    run()
    run_descent()


if __name__ == "__main__":
    main()
