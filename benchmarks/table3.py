"""Table III: recommendation recall — exact (brute force) KNN graph vs C²,
30 items recommended per user, per-user item holdout."""
from __future__ import annotations

from benchmarks.common import K_DEFAULT, bench_params, emit, load
from repro.core.pipeline import cluster_and_conquer
from repro.data.synthetic import train_test_split
from repro.eval.metrics import recall, recommend
from repro.knn.brute_force import brute_force_knn
from repro.sketch.goldfinger import fingerprint_dataset

DATASETS = ("ml1M", "AM", "DBLP")


def run(datasets=DATASETS, k: int = K_DEFAULT, n_rec: int = 30):
    rows = []
    for name in datasets:
        ds, _ = load(name)
        train, test_rows = train_test_split(ds, 0.2, seed=1)
        gf = fingerprint_dataset(train)
        exact = brute_force_knn(gf, k=k)
        p = bench_params(name, train.n_users, k)
        gc, _ = cluster_and_conquer(train, p, gf=gf)
        r_bf = recall(recommend(train, exact, n_rec), test_rows)
        r_c2 = recall(recommend(train, gc, n_rec), test_rows)
        rows.append({"dataset": ds.name, "recall_bruteforce": round(r_bf, 4),
                     "recall_c2": round(r_c2, 4),
                     "delta": round(r_c2 - r_bf, 4)})
        print(f"[table3] {name}: BF recall {r_bf:.3f} | C2 {r_c2:.3f} "
              f"(Δ {r_c2 - r_bf:+.3f})")
    return emit(rows, "table3")


if __name__ == "__main__":
    run()
