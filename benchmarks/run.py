"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per table row) and
writes full JSON tables to artifacts/.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small datasets only (CI-sized run)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table2,fig6")
    args = ap.parse_args()

    from benchmarks import fig6, fig7_8, kernel_bench, table2, table3, \
        table4, table5

    quick2 = ("ml1M", "DBLP") if args.quick else table2.DATASETS
    quick3 = ("ml1M",) if args.quick else table3.DATASETS
    quickp = ("ml10M",) if args.quick else ("ml10M", "AM")

    jobs = {
        "table2": lambda: table2.run(quick2),
        "table3": lambda: table3.run(quick3),
        "table4": lambda: table4.run(quickp),
        "table5": lambda: table5.run(quickp),
        "fig6": lambda: fig6.run(quickp),
        "fig7_8": lambda: fig7_8.run(quickp),
        "kernel": lambda: kernel_bench.run(512 if args.quick else 1024),
    }
    if args.only:
        keep = set(args.only.split(","))
        jobs = {k: v for k, v in jobs.items() if k in keep}

    csv = ["name,us_per_call,derived"]
    for name, fn in jobs.items():
        try:
            rows = fn()
        except Exception as e:  # keep the suite going; report the failure
            print(f"[run] {name} FAILED: {e}", file=sys.stderr)
            csv.append(f"{name},NaN,error:{type(e).__name__}")
            continue
        for r in rows:
            t = r.get("time_s")
            us = f"{t * 1e6:.0f}" if t is not None else ""
            derived = r.get("quality", r.get("recall_c2",
                            r.get("us_per_pair", r.get("delta", ""))))
            label = "/".join(str(r.get(k)) for k in
                             ("dataset", "algo", "mechanism", "path", "b",
                              "t", "N") if r.get(k) is not None)
            csv.append(f"{name}/{label},{us},{derived}")
    print("\n".join(csv))


if __name__ == "__main__":
    main()
