"""Table II: computation time + KNN quality, C² vs BruteForce / Hyrec /
NNDescent / LSH on the six (statistics-matched synthetic) datasets.
Speed-ups are reported against the best competing baseline, as in the
paper."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (K_DEFAULT, bench_params, emit, exact_graph,
                               load)
from repro.core.pipeline import cluster_and_conquer
from repro.eval.metrics import quality
from repro.knn.greedy import hyrec, nndescent
from repro.knn.lsh import lsh_knn

DATASETS = ("ml1M", "ml10M", "ml20M", "AM", "DBLP", "GW")


def run(datasets=DATASETS, k: int = K_DEFAULT):
    rows = []
    for name in datasets:
        ds, gf = load(name)
        exact, t_bf = exact_graph(ds, gf, k)
        p = bench_params(name, ds.n_users, k)

        def timed(fn):
            t0 = time.perf_counter()
            out = fn()
            return out, time.perf_counter() - t0

        (gh, _), th = timed(lambda: hyrec(gf, k=k))
        (gn, _), tn = timed(lambda: nndescent(gf, k=k))
        (gl, _), tl = timed(lambda: lsh_knn(ds, gf, k=k, t=min(p.t, 10)))
        (gc, st), tc = timed(lambda: cluster_and_conquer(ds, p, gf=gf))

        results = {
            "BruteForce": (t_bf, 1.0),
            "Hyrec": (th, quality(ds, gh, exact)),
            "NNDescent": (tn, quality(ds, gn, exact)),
            "LSH": (tl, quality(ds, gl, exact)),
            "C2": (tc, quality(ds, gc, exact)),
        }
        best_baseline = min(th, tn, tl)
        for algo, (t, q) in results.items():
            rows.append({
                "dataset": ds.name, "n_users": ds.n_users, "algo": algo,
                "time_s": round(t, 3), "quality": round(q, 4),
                "speedup_vs_best_baseline": round(best_baseline / t, 2)
                if algo == "C2" else None,
            })
        print(f"[table2] {name}: BF {t_bf:.1f}s | Hyrec {th:.1f}s "
              f"| NND {tn:.1f}s | LSH {tl:.1f}s | C2 {tc:.1f}s "
              f"(x{best_baseline / tc:.2f}, q={results['C2'][1]:.3f})")
    return emit(rows, "table2")


if __name__ == "__main__":
    run()
