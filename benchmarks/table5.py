"""Table V: GoldFinger vs raw data inside C². "Raw" = exact Jaccard via
full-universe incidence vectors (identical kernel layout, zero hash
error) — |I|/1024 times wider than the 1024-bit sketch."""
from __future__ import annotations

import time

from benchmarks.common import K_DEFAULT, bench_params, emit, exact_graph, load
from repro.core.pipeline import cluster_and_conquer
from repro.eval.metrics import quality
from repro.sketch.goldfinger import incidence_fingerprint

DATASETS = ("ml10M", "AM")


def run(datasets=DATASETS, k: int = K_DEFAULT):
    rows = []
    for name in datasets:
        ds, gf = load(name)
        exact, _ = exact_graph(ds, gf, k)
        p = bench_params(name, ds.n_users, k)

        t0 = time.perf_counter()
        g_gf, _ = cluster_and_conquer(ds, p, gf=gf)
        t_gf = time.perf_counter() - t0

        gf_raw = incidence_fingerprint(ds)
        t0 = time.perf_counter()
        g_raw, _ = cluster_and_conquer(ds, p, gf=gf_raw)
        t_raw = time.perf_counter() - t0

        q_gf = quality(ds, g_gf, exact)
        q_raw = quality(ds, g_raw, exact)
        rows += [
            {"dataset": ds.name, "mechanism": "raw",
             "time_s": round(t_raw, 3), "quality": round(q_raw, 4),
             "words_per_user": gf_raw.words.shape[1]},
            {"dataset": ds.name, "mechanism": "GoldFinger",
             "time_s": round(t_gf, 3), "quality": round(q_gf, 4),
             "words_per_user": gf.words.shape[1],
             "speedup": round(t_raw / t_gf, 2)},
        ]
        print(f"[table5] {name}: raw {t_raw:.1f}s q={q_raw:.3f} | "
              f"Golfi {t_gf:.1f}s q={q_gf:.3f} → x{t_raw / t_gf:.2f}")
    return emit(rows, "table5")


if __name__ == "__main__":
    run()
