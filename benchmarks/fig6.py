"""Fig. 6: sensitivity to the number of hash functions t and clusters b —
time × quality curves on ml10M (dense) and AM (sparse)."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import K_DEFAULT, bench_params, emit, exact_graph, load
from repro.core.pipeline import cluster_and_conquer
from repro.eval.metrics import quality

DATASETS = ("ml10M", "AM")
T_VALUES = (1, 2, 4, 8, 10)
B_FACTORS = (0.25, 1.0, 4.0)  # × the scaled default b


def run(datasets=DATASETS, k: int = K_DEFAULT):
    rows = []
    for name in datasets:
        ds, gf = load(name)
        exact, _ = exact_graph(ds, gf, k)
        p0 = bench_params(name, ds.n_users, k)
        for bf in B_FACTORS:
            b = max(64, int(p0.b * bf))
            for t in T_VALUES:
                p = dataclasses.replace(p0, b=b, t=t)
                t0 = time.perf_counter()
                g, _ = cluster_and_conquer(ds, p, gf=gf)
                el = time.perf_counter() - t0
                q = quality(ds, g, exact)
                rows.append({"dataset": ds.name, "b": b, "t": t,
                             "time_s": round(el, 3), "quality": round(q, 4)})
            print(f"[fig6] {name} b={b}: " + " ".join(
                f"t={r['t']}:{r['time_s']:.1f}s/q{r['quality']:.3f}"
                for r in rows[-len(T_VALUES):]))
    return emit(rows, "fig6")


if __name__ == "__main__":
    run()
