"""Fig. 7/8: recursive-splitting sensitivity — max cluster size N vs
time/quality, plus the 100 biggest cluster sizes (ml10M strongly affected,
AM nearly immune, per the paper's popularity-distribution argument)."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import K_DEFAULT, bench_params, emit, exact_graph, load
from repro.core.clustering import build_plan
from repro.core.pipeline import cluster_and_conquer
from repro.eval.metrics import quality

DATASETS = ("ml10M", "AM")
N_FACTORS = (0.25, 0.5, 1.0, 2.0, 4.0, 1e9)  # × scaled default N (∞ = off)


def run(datasets=DATASETS, k: int = K_DEFAULT):
    rows = []
    for name in datasets:
        ds, gf = load(name)
        exact, _ = exact_graph(ds, gf, k)
        p0 = bench_params(name, ds.n_users, k)
        for nf in N_FACTORS:
            N = int(min(p0.max_cluster * nf, 10**9))
            p = dataclasses.replace(p0, max_cluster=N)
            plan = build_plan(ds, p)
            sizes = np.sort(plan.sizes)[::-1][:100]
            t0 = time.perf_counter()
            g, st = cluster_and_conquer(ds, p, gf=gf)
            el = time.perf_counter() - t0
            q = quality(ds, g, exact)
            rows.append({
                "dataset": ds.name, "N": N, "time_s": round(el, 3),
                "quality": round(q, 4), "n_clusters": plan.n_clusters,
                "max_cluster": int(sizes[0]),
                "top100_sizes": sizes.tolist(),
            })
            print(f"[fig7_8] {name} N={N}: {el:.1f}s q={q:.3f} "
                  f"max_cluster={sizes[0]} n_clusters={plan.n_clusters}")
    return emit(rows, "fig7_8")


if __name__ == "__main__":
    run()
