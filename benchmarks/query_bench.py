"""Query-serving benchmark: QPS, latency percentiles, recall@k vs brute
force, for cold (compile included) and warm waves, in single-device and
sharded modes — each also through the fused Pallas descent-scoring
kernel (``*_kernel`` rows, plus a ``single_dma`` row for its
HBM-resident DMA placement, and a ``descent_scoring`` block reporting
scored-lane counts per hop vs the unfused ``beam·(kg+kr)`` alongside
the DMA path's bytes-moved / bytes-saved-per-query columns) — plus
online-insert throughput.

    PYTHONPATH=src python benchmarks/query_bench.py [--dataset synth]
        [--scale 0.2] [--queries 256] [--shards 2] [--out BENCH_query.json]

``--devices N`` (default: the shard count) emulates N XLA host devices —
the multi-core serving configuration, one shard per device via
shard_map; ``--devices 0`` forces the single-device vmap fallback.
``--continuous`` adds the slot-scheduler comparison: closed-loop
continuous rows plus a Poisson-arrival *open-loop* run (requests are
submitted at their arrival times, not all at once) reporting p50/p95
under load for wave vs continuous serving — the tail-latency case
continuous batching exists for — both single-device AND under the
sharded placement (the ``sharded_N_continuous`` block: per-shard slot
arrays with a release-time cross-shard merge, same Poisson protocol).
``--overload`` adds the SLO-serving rows: a 0.85/0.95/1.2-offered-load
sweep under slo admission (priority classes + deadlines, explicit
shedding, bounded pending queue) against a FIFO baseline whose queue
collapses at 1.2x, the adaptive-hop-budget comparison (free a slot once
its top-k prefix stabilizes vs run to budget), and the
journal-invalidated result cache on a repeated-query stream with
interleaved churn (gated bitwise against cache-off).
``--rebalance`` adds the background re-balance rows: frozen-extend vs
rebalanced imbalance trajectories under skewed insert growth, the
forced blue/green swap checks (merge rebuild bitwise vs from-scratch,
cache flush, recall across the swap), and the tiered-residency sweep
(``resident_configs`` subset size vs recall vs per-shard resident
bytes).
``--faults`` adds the fault-tolerance rows: kill 1 of N shards
mid-open-loop (every request still answered, degraded answers stamped
and their recall priced, health-machine walk to a failover rebuild,
post-recovery wave bitwise vs pre-failure) and a crash between
scheduler steps recovered from snapshot + write-ahead-log replay,
gated bitwise — tensors and answers — against a never-crashed mirror.
``--smoke`` shrinks the workload for CI: it still exercises build,
every serving plan, and insertion, and fails loudly (exit 1) if the
sharded mode regresses against single-device beyond the allowed
margins (with ``--continuous``: if streaming admission loses results,
recall parity with waves, or — sharded × continuous — bitwise
closed-loop equality with the sharded wave).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# The device count must be pinned before jax initializes (same pattern
# as launch/dryrun.py), so peek at argv before the heavy imports.
_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--devices", type=int, default=None)
_pre.add_argument("--shards", type=int, default=2)
_pre_args, _ = _pre.parse_known_args()
_n_dev = (_pre_args.devices if _pre_args.devices is not None
          else _pre_args.shards)
if _n_dev and _n_dev > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_dev}")

import jax
import numpy as np

from repro.core.params import params_for
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index


def _serve_waves(engine: QueryEngine, profiles, k: int) -> dict:
    """One cold + one warm wave through ``engine``; per-wave stats."""
    out = {}
    for tag in ("cold", "warm"):
        for rid, p in enumerate(profiles):
            engine.submit(QueryRequest(rid=rid, profile=p))
        stats = engine.run()
        recall = engine.recall_vs_brute_force(engine.done[-len(profiles):])
        out[tag] = {
            "qps": round(stats["qps"], 1),
            "p50_latency_ms": round(stats["p50_latency_s"] * 1e3, 2),
            "p95_latency_ms": round(stats["p95_latency_s"] * 1e3, 2),
            f"recall_at_{k}": round(recall, 4),
        }
    return out


def _warm_wave_capacities(engine: QueryEngine, profiles, hop_set=(None,)):
    """Compile the wave program for every pow-2 wave capacity × hop
    budget the open-loop run can hit (waves are padded to capacity
    buckets), so a mid-run compile doesn't pollute the latency
    measurement."""
    for hops in hop_set:
        n = 1
        while True:
            engine.query_batch(profiles[: min(n, len(profiles))],
                               hops=hops)
            if n >= len(profiles):  # final call warms the top bucket
                break
            n *= 2


def _latency_row(reqs) -> dict:
    """p50/p95/max over SERVED requests (rejected ones carry no service
    latency — their submit→shed interval is queueing, not service)."""
    lats = np.array([r.latency for r in reqs
                     if r.status == "done" and r.latency is not None])
    if not len(lats):
        return {"p50_latency_ms": None, "p95_latency_ms": None,
                "max_latency_ms": None}
    return {
        "p50_latency_ms": round(float(np.percentile(lats, 50)) * 1e3, 2),
        "p95_latency_ms": round(float(np.percentile(lats, 95)) * 1e3, 2),
        "max_latency_ms": round(float(lats.max()) * 1e3, 2),
    }


def median_row(rows: list) -> dict:
    """Representative open-loop row: the rep whose p95 is the median.

    Taking per-key medians independently across reps stitches together
    a row no rep actually measured — the median p50 can come from one
    rep and the median p95 from another, breaking p50 <= p95 coherence
    and detaching achieved_qps from the latencies that run paid for it.
    The tail is the quantity under test, so pick the rep whose p95 is
    the median and report that rep's ENTIRE row, keeping every rep's
    p95 alongside so the spread stays visible.
    """
    p95s = [np.inf if r["p95_latency_ms"] is None else r["p95_latency_ms"]
            for r in rows]
    pick = rows[int(np.argsort(p95s, kind="stable")[(len(p95s) - 1) // 2])]
    out = {key: pick[key] for key in ("rate_qps", "achieved_qps",
                                      "p50_latency_ms", "p95_latency_ms",
                                      "max_latency_ms")}
    out["p95_latency_ms_reps"] = [r["p95_latency_ms"] for r in rows]
    return out


def open_loop(engine: QueryEngine, profiles, rate_qps: float,
              budgets=None, seed: int = 0, stall_s: float = 60.0,
              priorities=None, deadline_ms: float = 0.0,
              clock=None) -> dict:
    """Poisson-arrival open-loop serving through ``engine.step()``.

    Requests are submitted at their arrival times (exponential
    inter-arrivals at ``rate_qps``) while the engine serves — so a
    request's latency includes the queueing it actually experiences
    behind in-flight work, which is where wave and continuous modes
    diverge. ``budgets`` (optional int[n]) gives each request its own
    hop budget: wave mode convoys a wave to its deepest member, while
    continuous mode frees each slot at its own budget. ``priorities``
    (optional int[n]) assigns SLO classes and ``deadline_ms`` stamps
    each request with a deadline that many ms after its arrival — both
    only matter to engines configured with slo admission.

    SHED requests count as completions (they come back with a
    ``rejected`` marker): an overloaded slo engine shedding its way
    through the backlog is making progress, not stalling. The stall
    guard therefore watches completions of EITHER kind — it fires only
    when the engine stops completing work for ``stall_s`` seconds,
    which is a serving bug, never a load response.

    ``clock`` (optional, default ``time.perf_counter``) makes the loop
    time-source injectable: pass a ``repro.sched.ManualClock`` and the
    run advances virtual time only through the idle-sleep path (the
    clock's ``sleep`` doubles as ``advance``), so tests drive the whole
    open loop without a single real ``time.sleep``.
    """
    clock = clock or time.perf_counter
    sleep = getattr(clock, "sleep", time.sleep)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps,
                                         size=len(profiles)))
    reqs = [QueryRequest(
                rid=i, profile=p,
                hops=None if budgets is None else int(budgets[i]),
                priority=0 if priorities is None else int(priorities[i]))
            for i, p in enumerate(profiles)]
    n_done0 = len(engine.done)
    sched = engine.plan.scheduler
    n_steps = 0
    max_depth = 0
    t0 = clock()
    t_progress = t0
    i = 0
    while len(engine.done) - n_done0 < len(reqs):
        now = clock() - t0
        while i < len(reqs) and arrivals[i] <= now:
            req = reqs[i]
            # Latency counts from the ARRIVAL time, not from when the
            # driver got around to enqueueing it — a request that landed
            # while a long wave was in flight has been waiting since its
            # arrival, and that queueing is the quantity under test.
            req.t_submit = t0 + arrivals[i]
            if deadline_ms > 0:
                req.deadline = req.t_submit + deadline_ms / 1e3
            engine.queue.append(req)
            i += 1
        depth = len(engine.queue) + (len(sched.pending) if sched else 0)
        max_depth = max(max_depth, depth)
        if engine.busy():
            if engine.step():
                t_progress = clock()
            n_steps += 1
        elif i < len(reqs):  # idle: sleep to the next arrival
            t_progress = clock()
            sleep(max(min(arrivals[i] - now, 0.01), 0.0))
        if clock() - t_progress > stall_s:
            part = engine.done[n_done0:]
            n_srv = sum(1 for r in part if r.status == "done")
            n_shd = sum(1 for r in part if r.rejected)
            raise RuntimeError(
                f"open_loop stalled: engine stopped completing work — "
                f"{len(part)}/{len(reqs)} complete ({n_srv} served, "
                f"{n_shd} shed) and no completion of either kind for "
                f"{stall_s:.0f}s. Shedding counts as progress here, so "
                f"this is a serving bug, not admission-control load "
                f"response.")
    dt = max(clock() - t0, 1e-9)
    finished = engine.done[n_done0:]
    served = [r for r in finished if r.status == "done"]
    n_shed = len(finished) - len(served)
    row = {
        "rate_qps": round(rate_qps, 1),
        "achieved_qps": round(len(served) / dt, 1),
        "steps": n_steps,
        "served": len(served),
        "shed": n_shed,
        "max_queue_depth": int(max_depth),
        **_latency_row(finished),
    }
    if priorities is not None:
        classes = {}
        for cls in sorted(set(int(c) for c in priorities)):
            part = [r for r in finished if r.priority == cls]
            classes[str(cls)] = {
                "n": len(part),
                "served": sum(1 for r in part if r.status == "done"),
                "shed": sum(1 for r in part if r.rejected),
                **_latency_row(part),
            }
        row["classes"] = classes
    return row


def run_continuous(index, profiles, k: int, beam: int, hops: int,
                   slots: int, load: float = 0.85, deep_frac: float = 0.2,
                   seed: int = 0, shards: int = 1,
                   oversample: float = 1.25) -> dict:
    """Wave vs continuous under identical Poisson load + closed-loop rows.

    The open-loop workload is heterogeneous — ``deep_frac`` of the
    requests carry a 2× hop budget (refinement queries, the "slow
    descent" of the PR motivation). Wave batching convoys every wave
    containing a deep request to the deep budget; continuous serving
    frees each slot at its own budget, which is where the tail-latency
    gap comes from. ``shards > 1`` runs BOTH modes under the sharded
    placement (the sharded × continuous plan composition): batching is
    results-transparent for a fixed placement, so the closed-loop
    parity check below must hold bitwise — and the smoke gate fails if
    it drifts by even one bit.
    """
    place = dict(shards=shards, shard_oversample=oversample)
    cont = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                          continuous=True, slots=slots,
                                          **place))
    closed = _serve_waves(cont, profiles, k)

    # A sustained arrival stream (2× the profile set) and a few
    # repetitions: a single short burst is a convoy lottery — backlog
    # needs time to build before the wave-mode tail shows.
    deep_hops = 2 * hops
    stream = profiles * 2
    reps = 3
    rng = np.random.default_rng(seed + 1)
    budgets = np.where(rng.random(len(stream)) < deep_frac,
                       deep_hops, hops)

    # Calibrate offered load against the wave engine's warm closed-loop
    # throughput on this mixed workload (one drain = one deep-budget
    # wave), then run below the knee so neither mode saturates outright.
    wave_ol = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                             max_wave=len(stream),
                                             **place))
    _warm_wave_capacities(wave_ol, stream, hop_set=(hops, deep_hops))
    # Closed-loop parity vs wave on the SAME placement: batching must be
    # results-transparent, i.e. bitwise-equal (ids AND sims) per request.
    for rid, p in enumerate(profiles):
        wave_ol.submit(QueryRequest(rid=rid, profile=p))
    wave_ol.run()
    wave_closed_recall = wave_ol.recall_vs_brute_force()
    w_by = {r.rid: r for r in wave_ol.done}
    c_by = {r.rid: r for r in cont.done[-len(profiles):]}
    bitwise = all(np.array_equal(w_by[rid].ids, c_by[rid].ids)
                  and np.array_equal(w_by[rid].sims, c_by[rid].sims)
                  for rid in c_by)
    wave_ol.done.clear()
    for rid, p in enumerate(stream):
        wave_ol.submit(QueryRequest(rid=rid, profile=p,
                                    hops=int(budgets[rid])))
    mixed_qps = wave_ol.run()["qps"]
    wave_ol.done.clear()
    rate = max(load * mixed_qps, 1.0)

    cont_ol = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                             continuous=True, slots=slots,
                                             **place))
    for rid, p in enumerate(stream[: 2 * slots]):
        cont_ol.submit(QueryRequest(rid=-1 - rid, profile=p))  # warm ticks
    cont_ol.run()
    cont_ol.done.clear()

    runs = {"wave": [], "continuous": []}
    for rep in range(reps):
        runs["wave"].append(open_loop(wave_ol, stream, rate,
                                      budgets=budgets, seed=seed + rep))
        runs["continuous"].append(open_loop(cont_ol, stream, rate,
                                            budgets=budgets,
                                            seed=seed + rep))

    open_rows = {mode: median_row(rows) for mode, rows in runs.items()}
    wave_recall = wave_ol.recall_vs_brute_force()
    cont_recall = cont_ol.recall_vs_brute_force()
    return {
        "slots": slots,
        "shards": shards,
        "plan": cont.plan.describe(),
        "closed_loop": closed,
        "closed_loop_vs_wave": {
            "bitwise_equal": bitwise,
            "recall_delta": round(
                closed["warm"][f"recall_at_{k}"] - wave_closed_recall, 4),
        },
        "open_loop_workload": {
            "deep_frac": deep_frac,
            "hops": hops,
            "deep_hops": deep_hops,
            "load": load,
            "arrivals_per_rep": len(stream),
            "reps": reps,
            "mixed_wave_closed_loop_qps": round(mixed_qps, 1),
        },
        "open_loop": open_rows,
        "open_loop_recall": {
            "wave": round(wave_recall, 4),
            "continuous": round(cont_recall, 4),
            "delta": round(cont_recall - wave_recall, 4),
        },
        "p95_improvement": round(
            open_rows["wave"]["p95_latency_ms"]
            / max(open_rows["continuous"]["p95_latency_ms"], 1e-9), 3),
    }


def run_churn(index0, profiles, k: int, beam: int, hops: int,
              insert_pool, seed: int = 0, turnover: float = 0.2,
              rounds: int = 4, shards: int = 1) -> dict:
    """Sustained-churn recall trajectory, repair on vs off.

    Each round deletes ``turnover/rounds`` of the live rows and inserts
    replacements (true turnover: the live count is conserved), then
    serves the same fixed query wave through the scheduler loop — so
    lifecycle maintenance fires exactly as it would in production
    (between steps). The two arms see IDENTICAL mutation streams; the
    only difference is the repair cadence. Repair-off decays as deletes
    punch PAD holes into survivors' rows; repair-on re-links the
    churn-touched cohort and should hold recall near the no-churn
    baseline.
    """
    import copy

    m_round = max(1, int(turnover * index0.n_live / rounds))
    arms = {}
    baseline = None
    for arm, repair_every in (("repair_on", 1), ("repair_off", 0)):
        ix = copy.deepcopy(index0)
        eng = QueryEngine(ix, QueryConfig(
            k=k, beam=beam, hops=hops, max_wave=len(profiles),
            shards=shards, refresh_every=10**9,
            repair_every=repair_every))
        rng = np.random.default_rng(seed + 7)  # same stream both arms
        pool = iter(insert_pool)

        def wave_recall(eng=eng):
            for rid, p in enumerate(profiles):
                eng.submit(QueryRequest(rid=rid, profile=p))
            eng.run()
            return eng.recall_vs_brute_force(eng.done[-len(profiles):])

        if baseline is None:  # no-churn reference (arm-independent)
            baseline = round(wave_recall(), 4)
        else:
            wave_recall()  # warm this arm's programs identically
        trajectory = []
        for _ in range(rounds):
            alive = eng.index.alive_ids()
            for u in rng.choice(alive, size=min(m_round, len(alive) - 1),
                                replace=False):
                eng.remove_user(int(u))
            for _i in range(m_round):
                eng.insert(next(pool))
            trajectory.append(round(wave_recall(), 4))
        arms[arm] = {
            "recall_trajectory": trajectory,
            "final_recall": trajectory[-1],
            "lifecycle": eng.lifecycle.stats(),
        }
    return {
        "turnover": turnover,
        "rounds": rounds,
        "deletes_per_round": m_round,
        "no_churn_recall": baseline,
        **arms,
        "repair_recovery": round(
            arms["repair_on"]["final_recall"]
            - arms["repair_off"]["final_recall"], 4),
        "repair_vs_baseline": round(
            arms["repair_on"]["final_recall"] - baseline, 4),
    }


def run_overload(index, profiles, k: int, beam: int, hops: int,
                 slots: int, seed: int = 0, high_frac: float = 0.3,
                 loads=(0.85, 0.95, 1.2)) -> dict:
    """SLO admission under increasing offered load, vs a FIFO baseline.

    The workload mixes ``high_frac`` high-priority (class 0) requests
    into a best-effort (class 1) stream, every request carrying a
    deadline. Offered load is calibrated against the engine's own
    closed-loop throughput; at 1.2× the engine CANNOT serve everything,
    and the two policies diverge: slo admission serves class 0 first
    and sheds expired/overflow class-1 work explicitly (bounded queue,
    high-priority p95 held near its uncontended value), while FIFO
    accepts everything in arrival order (queue collapse: depth and tail
    latency grow with the backlog, every class degrades together).
    """
    # A long stream: overload is an ACCUMULATION phenomenon (a 20%
    # deficit needs arrivals to pile into a backlog), so the absolute
    # excess — and the shed counts — scale with stream length. The
    # overloaded 1.2x rows run a 2x-longer stream for the same reason:
    # FIFO's queue growth is linear in time, and the collapse contrast
    # needs horizon to integrate over.
    stream = profiles * 4
    peak_stream = profiles * 8
    rng = np.random.default_rng(seed + 3)
    priorities = (rng.random(len(peak_stream)) >= high_frac) \
        .astype(np.int64)

    # Capacity: the engine's sustainable service rate, measured by a
    # saturating open-loop probe — arrivals offered at 3x the closed-
    # loop estimate keep every slot full for the whole stream, so the
    # probe's achieved rate IS the demonstrated capacity. (The closed-
    # loop qps alone underestimates it: its ramp and drain tail run
    # with idle slots, and tick time itself shifts with occupancy.)
    cal = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                         continuous=True, slots=slots))
    for rid, p in enumerate(stream[: 2 * slots]):
        cal.submit(QueryRequest(rid=-1 - rid, profile=p))
    cal.run()
    cal.done.clear()
    for rid, p in enumerate(stream):
        cal.submit(QueryRequest(rid=rid, profile=p))
    est = cal.run()["qps"]
    capacity = open_loop(cal, stream, 3.0 * max(est, 1.0),
                         seed=seed)["achieved_qps"]

    # Deadline: a tenth of the ideal full-stream duration — several
    # uncontended service times, binding for work queued behind a
    # sustained overload. max_pending is the hard bound, set well below
    # the backlog a 20% deficit accumulates over this stream so the
    # 1.2x row MUST shed (and the pending queue can never grow past the
    # bound, unlike FIFO's).
    deadline_ms = 0.1 * len(stream) / max(capacity, 1e-9) * 1e3
    max_pending = max(slots // 2, len(stream) // 24)

    slo_eng = QueryEngine(index, QueryConfig(
        k=k, beam=beam, hops=hops, continuous=True, slots=slots,
        admission="slo", max_pending=max_pending))
    for rid, p in enumerate(stream[: 2 * slots]):
        slo_eng.submit(QueryRequest(rid=-1 - rid, profile=p))
    slo_eng.run()
    slo_eng.done.clear()

    slo_rows = {}
    hp_recall = {}
    for load in loads:
        work = peak_stream if load > 1.0 else stream
        n0 = len(slo_eng.done)
        slo_rows[str(load)] = open_loop(
            slo_eng, work, max(load * capacity, 1.0), seed=seed,
            priorities=priorities[: len(work)], deadline_ms=deadline_ms)
        hp = [r for r in slo_eng.done[n0:]
              if r.priority == 0 and r.ids is not None]
        hp_recall[str(load)] = round(
            slo_eng.recall_vs_brute_force(hp), 4) if hp else None

    # FIFO baseline at the overloaded point: the calibration engine IS
    # a warm fifo continuous engine, so reuse it. Deadlines are stamped
    # but fifo admission ignores them — nothing sheds, the queue absorbs
    # the full excess.
    fifo_row = open_loop(cal, peak_stream,
                         max(loads[-1] * capacity, 1.0), seed=seed,
                         priorities=priorities, deadline_ms=deadline_ms)

    def hp_p95(row):
        return row["classes"]["0"]["p95_latency_ms"]

    base, peak = slo_rows[str(loads[0])], slo_rows[str(loads[-1])]
    return {
        "slots": slots,
        "capacity_qps": round(capacity, 1),
        "high_frac": high_frac,
        "deadline_ms": round(deadline_ms, 1),
        "max_pending": max_pending,
        "arrivals": len(stream),
        "arrivals_at_peak": len(peak_stream),
        "slo": slo_rows,
        "high_priority_recall": hp_recall,
        f"fifo_{loads[-1]}": fifo_row,
        # Degradation of the protected class across the load sweep, and
        # the queue-collapse contrast at the overloaded point.
        "hp_p95_degradation": (
            round(hp_p95(peak) / max(hp_p95(base), 1e-9), 3)
            if hp_p95(peak) is not None and hp_p95(base) else None),
        "queue_collapse": {
            "slo_max_queue_depth": peak["max_queue_depth"],
            "fifo_max_queue_depth": fifo_row["max_queue_depth"],
            "depth_ratio": round(
                fifo_row["max_queue_depth"]
                / max(peak["max_queue_depth"], 1), 2),
            "slo_shed": peak["shed"],
            "fifo_shed": fifo_row["shed"],
        },
    }


def run_adaptive(index, profiles, k: int, beam: int, hops: int,
                 slots: int, seed: int = 0, patience: int = 1) -> dict:
    """Adaptive hop budgets: free a slot once its top-k prefix held
    ``patience`` hops, vs running every request to a fixed 2× budget.

    The deep budget is the refinement regime (the continuous-batching
    motivation); most descents converge well before it. The fixed arm
    burns the full budget anyway, the adaptive arm frees the slot when
    the result has stopped moving — fewer ticks for the same stream,
    measured as QPS against the recall it gives up (the exact-fixed-
    point early exit already comes free; patience trades the last
    epsilon of prefix churn for throughput).
    """
    deep = 2 * hops
    rows = {}
    for name, pat in (("fixed", 0), ("adaptive", patience)):
        eng = QueryEngine(index, QueryConfig(
            k=k, beam=beam, hops=deep, continuous=True, slots=slots,
            adaptive=pat))
        for rid, p in enumerate(profiles[: 2 * slots]):
            eng.submit(QueryRequest(rid=-1 - rid, profile=p))
        eng.run()
        eng.done.clear()
        ticks0 = eng.n_ticks
        for rid, p in enumerate(profiles):
            eng.submit(QueryRequest(rid=rid, profile=p))
        stats = eng.run()
        rows[name] = {
            "qps": round(stats["qps"], 1),
            "ticks": eng.n_ticks - ticks0,
            "p95_latency_ms": round(stats["p95_latency_s"] * 1e3, 2),
            f"recall_at_{k}": round(eng.recall_vs_brute_force(
                eng.done[-len(profiles):]), 4),
        }
    rk = f"recall_at_{k}"
    return {
        "slots": slots,
        "hop_budget": deep,
        "patience": patience,
        **rows,
        "qps_gain": round(rows["adaptive"]["qps"]
                          / max(rows["fixed"]["qps"], 1e-9), 3),
        "ticks_saved": rows["fixed"]["ticks"] - rows["adaptive"]["ticks"],
        "recall_delta": round(rows["adaptive"][rk] - rows["fixed"][rk], 4),
    }


def run_cache(index0, profiles, k: int, beam: int, hops: int,
              insert_pool, seed: int = 0, repeat_factor: int = 4,
              n_mutations: int = 6, capacity: int = 256) -> dict:
    """Result cache on a repeated-query stream with interleaved churn.

    The stream draws ``repeat_factor`` passes over a hot profile subset
    (the recommendation front-door shape the cache exists for), with a
    delete + insert between passes — each mutation flushes the cache via
    the journal rule. Cache-on and cache-off run the IDENTICAL request
    and mutation schedule on private index deepcopies; the gate is
    bitwise equality of every (ids, sims) pair, with the hit rate and
    flush count as the payoff/cost measurements.
    """
    import copy

    rng = np.random.default_rng(seed + 9)
    hot = profiles[: max(8, len(profiles) // 4)]
    # First pass covers every hot profile (populating the cache), later
    # passes redraw from the hot set — the repeated-query front-door
    # shape the cache exists for.
    order = np.concatenate([
        np.arange(len(hot)),
        rng.integers(0, len(hot), size=(repeat_factor - 1) * len(hot))])
    wave = max(4, len(hot) // 2)
    n_waves = int(np.ceil(len(order) / wave))
    # Mutations at evenly spaced wave boundaries — each flushes the
    # cache (journal rule), so they are capped to leave the cache at
    # least one re-warm wave between flushes or the hit rate would
    # measure the mutation cadence, not the cache.
    n_mut = min(n_mutations, max(1, n_waves // 2 - 1))
    mut_at = {round((m + 1) * n_waves / (n_mut + 1))
              for m in range(n_mut)}

    arms = {}
    results = {}
    for arm, cap in (("cache_off", 0), ("cache_on", capacity)):
        ix = copy.deepcopy(index0)
        eng = QueryEngine(ix, QueryConfig(
            k=k, beam=beam, hops=hops, max_wave=wave,
            refresh_every=10**9, cache=cap))
        mut_rng = np.random.default_rng(seed + 11)  # same stream per arm
        pool = iter(insert_pool)
        rid = 0
        t0 = time.perf_counter()
        for wi in range(n_waves):
            if wi in mut_at:
                alive = ix.alive_ids()
                eng.remove_user(int(alive[mut_rng.integers(len(alive))]))
                eng.insert(next(pool))
            for qi in order[wi * wave:(wi + 1) * wave]:
                eng.submit(QueryRequest(rid=rid, profile=hot[int(qi)]))
                rid += 1
            eng.run()
        dt = max(time.perf_counter() - t0, 1e-9)
        results[arm] = {r.rid: (np.asarray(r.ids), np.asarray(r.sims))
                        for r in eng.done}
        arms[arm] = {"qps": round(len(order) / dt, 1)}
        if eng.plan.cache is not None:
            arms[arm]["cache"] = eng.plan.cache.stats()
    bitwise = (set(results["cache_on"]) == set(results["cache_off"])
               and all(np.array_equal(results["cache_on"][r][0],
                                      results["cache_off"][r][0])
                       and np.array_equal(results["cache_on"][r][1],
                                          results["cache_off"][r][1])
                       for r in results["cache_off"]))
    return {
        "hot_profiles": len(hot),
        "requests": len(order),
        "waves": n_waves,
        "mutations": n_mut,
        "capacity": capacity,
        **arms,
        "bitwise_equal": bitwise,
        "hit_rate": arms["cache_on"]["cache"]["hit_rate"],
        "qps_gain": round(arms["cache_on"]["qps"]
                          / max(arms["cache_off"]["qps"], 1e-9), 3),
    }


def run_rebalance(index0, ds, profiles, k: int, beam: int, hops: int,
                  shards: int, seed: int = 0, rounds: int = 4,
                  growth: float = 0.25, threshold: float = 1.25) -> dict:
    """Frozen-extend vs background re-balance under skewed insert growth,
    plus the forced-swap mechanism checks.

    The insert stream clones profiles of the users whose cluster
    memberships are most CONCENTRATED on shard 0 under the initial plan
    — the adversarial drift for a frozen partition. (An insert registers
    into its deepest matching cluster of EVERY hash configuration, so
    cloning an arbitrary resident spreads its mass over all the shards
    its t clusters live on and the skew averages away; cloning the
    shard-0-concentrated cohort lands most of each insert's mass on
    shard-0 clusters.) The frozen ``extend_plan`` arm's measured
    imbalance then climbs round over round while the rebalanced arm's
    re-derived LPT packing pulls it back toward 1. Both arms see the
    IDENTICAL mutation stream (same seed); the only difference is
    ``rebalance_every``. The mechanism block then
    forces one blue/green swap on a grown copy and checks the swap
    invariants the serving path relies on: merge-based rebuild
    bitwise-equal to a from-scratch ``plan_shards`` build, result cache
    flushed exactly once, recall preserved across the swap, post-swap
    imbalance back under the threshold.
    """
    import copy

    from repro.query.rebalance import measured_imbalance
    from repro.query.sharded import ShardedDescent, plan_shards

    base = plan_shards(index0, shards)
    mass = np.zeros((index0.n, shards))
    for ci in range(index0.n_clusters):
        mem = index0.cluster_users(ci)
        mem = mem[(mem >= 0) & (mem < index0.n)]
        mass[mem, base.cluster_shard[ci]] += 1.0
    frac0 = mass[:, 0] / np.maximum(mass.sum(axis=1), 1.0)
    donors = np.argsort(-frac0, kind="stable")[: max(32, index0.n // 8)]

    def wave(eng):
        for rid, p in enumerate(profiles):
            eng.submit(QueryRequest(rid=rid, profile=p))
        eng.run()
        return eng.recall_vs_brute_force(eng.done[-len(profiles):])

    arms = {}
    for arm in ("frozen", "rebalanced"):
        ix = copy.deepcopy(index0)
        kw = dict(k=k, beam=beam, hops=hops, max_wave=len(profiles),
                  shards=shards, refresh_every=10**9)
        if arm == "rebalanced":
            kw.update(rebalance_every=1, rebalance_threshold=threshold)
        eng = QueryEngine(ix, QueryConfig(**kw))
        rng = np.random.default_rng(seed + 13)  # same stream both arms
        imbs = []
        recall = 0.0
        for _ in range(rounds):
            n_ins = max(1, int(growth * eng.index.n_live))
            for u in rng.choice(donors, size=n_ins, replace=True):
                eng.insert(ds.profile(int(u)))
            recall = wave(eng)
            sd = eng.plan.sharded_state()
            imbs.append(round(measured_imbalance(eng.index, sd.plan), 4))
        row = {"imbalance_trajectory": imbs,
               "final_imbalance": imbs[-1],
               f"recall_at_{k}": round(recall, 4)}
        if arm == "rebalanced":
            row["rebalance"] = eng.rebalance.stats()
            ref = QueryEngine(eng.index, QueryConfig(
                k=k, beam=beam, hops=hops, max_wave=len(profiles)))
            single = wave(ref)
            row["single_shard_recall"] = round(single, 4)
            row["recall_delta_vs_single"] = round(recall - single, 4)
        arms[arm] = row

    # Mechanism block: one round of growth, then a FORCED swap (so the
    # checks run even at smoke scale, where natural drift may stay
    # under the threshold) with the result cache enabled.
    ix = copy.deepcopy(index0)
    eng = QueryEngine(ix, QueryConfig(
        k=k, beam=beam, hops=hops, max_wave=len(profiles), shards=shards,
        refresh_every=10**9, cache=256, rebalance_every=10**9,
        rebalance_threshold=threshold))
    rng = np.random.default_rng(seed + 13)
    for u in rng.choice(donors, size=max(1, int(growth * ix.n_live)),
                        replace=True):
        eng.insert(ds.profile(int(u)))
    pre_recall = wave(eng)
    pre_imb = measured_imbalance(ix, eng.plan.sharded_state().plan)
    flushes0 = eng.plan.cache.flushes
    post_imb = eng.rebalance.swap()
    cache_flushed = eng.plan.cache.flushes == flushes0 + 1
    sd = eng.plan.sharded_state()
    scratch = ShardedDescent(ix, shards, plan=sd.plan, use_mesh=False)
    merge_equal = (np.array_equal(sd._g2l, scratch._g2l)
                   and all(np.array_equal(np.asarray(a), np.asarray(b))
                           for a, b in zip(sd._dev, scratch._dev)))
    post_recall = wave(eng)
    return {
        "rounds": rounds,
        "growth_per_round": growth,
        "threshold": threshold,
        "donor_pool": int(len(donors)),
        "frozen": arms["frozen"],
        "rebalanced": arms["rebalanced"],
        "frozen_exceeds_threshold":
            arms["frozen"]["final_imbalance"] > threshold,
        "forced_swap": {
            "pre_swap_imbalance": round(pre_imb, 4),
            "post_swap_imbalance": round(post_imb, 4),
            "recall_pre_swap": round(pre_recall, 4),
            "recall_post_swap": round(post_recall, 4),
            "recall_delta": round(post_recall - pre_recall, 4),
            "cache_flushed": bool(cache_flushed),
            "merge_bitwise_equal": bool(merge_equal),
            "merge": eng.rebalance.merge_stats,
        },
    }


def run_residency_sweep(index, profiles, k: int, beam: int, hops: int,
                        shards: int, oversample: float = 1.25) -> dict:
    """Tiered residency: restrict shard residency to the first ``m`` of
    the ``t`` hash configurations and price the memory saving in recall.

    Routing still sees every cluster (``cluster_shard`` covers all of
    them); only RESIDENCY — which users' rows sit on a shard — shrinks
    to the clusters of the first ``m`` configurations, with the
    uncovered users striped across shards so every row stays hosted
    somewhere. ``m = 0`` is full residency (the baseline row).
    """
    t = index.t
    ms = sorted({0, max(2, t // 4), t // 2, max(1, 3 * t // 4)})
    rows = []
    for m in ms:
        eng = QueryEngine(index, QueryConfig(
            k=k, beam=beam, hops=hops, max_wave=len(profiles),
            shards=shards, shard_oversample=oversample,
            resident_configs=m))
        for rid, p in enumerate(profiles):
            eng.submit(QueryRequest(rid=rid, profile=p))
        eng.run()
        recall = eng.recall_vs_brute_force(eng.done[-len(profiles):])
        sd = eng.plan.sharded_state()
        rb = sd.resident_bytes()
        rows.append({
            "resident_configs": m or t,
            "full_residency": m == 0,
            f"recall_at_{k}": round(recall, 4),
            "residents_per_shard": [len(r) for r in sd.plan.residents],
            "resident_bytes_per_shard": rb,
            "max_resident_bytes": int(max(rb)),
        })
    full = rows[0]  # m = 0 sorts first
    for r in rows:
        r["bytes_vs_full"] = round(
            r["max_resident_bytes"] / max(full["max_resident_bytes"], 1), 3)
        r["recall_delta_vs_full"] = round(
            r[f"recall_at_{k}"] - full[f"recall_at_{k}"], 4)
    return {"t": t, "shards": shards, "rows": rows}


def run_faults(index0, profiles, k: int, beam: int, hops: int,
               insert_pool, seed: int = 0, shards: int = 2) -> dict:
    """Fault-tolerance rows, both CI-gated.

    (a) kill 1 of ``shards`` mid-open-loop: the surviving fleet must
    keep answering EVERY request (degraded answers stamped, their
    recall priced against brute force), walk the dead shard through
    the health machine (suspect -> backoff re-probes -> dead), rebuild
    it from survivors + index via the merge path, blue/green-swap the
    plan back in, and then serve a wave BITWISE equal to the
    pre-failure wave — fail-and-recover must be invisible after the
    fact (nothing mutated the index, so any drift is a failover bug).

    (b) crash between scheduler steps mid-mutation-stream: recovery
    from the latest snapshot + write-ahead-log replay must land an
    engine whose index tensors AND served answers are bitwise what a
    never-crashed mirror (driven through the identical mutations,
    including the step the crash pre-empted) holds.
    """
    import copy
    import shutil
    import tempfile

    from repro.faults import (CrashStore, EngineCrash, FaultInjector,
                              FaultPlan, HealthConfig)
    from repro.query.index import _ROWS
    from repro.sched import ManualClock

    def wave(eng, ps):
        base = len(eng.done)
        for rid, p in enumerate(ps):
            eng.submit(QueryRequest(rid=rid, profile=p))
        eng.run()
        part = eng.done[base:]
        return ({r.rid: (np.asarray(r.ids), np.asarray(r.sims))
                 for r in part},
                round(eng.recall_vs_brute_force(part), 4))

    def same(a, b):
        return set(a) == set(b) and all(
            np.array_equal(a[r][0], b[r][0])
            and np.array_equal(a[r][1], b[r][1]) for r in a)

    # -- (a) kill/failover under an open-loop stream ------------------
    # The injector starts DISARMED so the pre-failure wave measures the
    # healthy fleet; arm() restarts its step count, so the kill lands
    # on the 3rd serving step of the open loop — mid-stream.
    inj = FaultInjector(FaultPlan.parse("kill:1@2"), armed=False,
                        health=HealthConfig(max_retries=2, backoff_cap=2,
                                            recover_after=6))
    eng = QueryEngine(copy.deepcopy(index0), QueryConfig(
        k=k, beam=beam, hops=hops, shards=shards, continuous=True,
        slots=8, max_wave=len(profiles)), faults=inj)
    pre, pre_recall = wave(eng, profiles)
    inj.arm()
    n_done0 = len(eng.done)
    row = open_loop(eng, profiles, rate_qps=64.0, seed=seed + 21,
                    stall_s=120.0)
    finished = eng.done[n_done0:]
    deg = [r for r in finished if r.status == "done" and r.degraded]
    # Idle steps walk the health machine the rest of the way to the
    # failover swap if the open loop drained before it fired.
    idle = 0
    while (eng.degraded or eng.failover.n_failovers == 0) and idle < 200:
        eng.step()
        idle += 1
    post, post_recall = wave(eng, profiles)
    kill_row = {
        "submitted": len(profiles),
        "served": row["served"],
        "shed": row["shed"],
        "degraded_served": len(deg),
        "degraded_recall": (round(eng.recall_vs_brute_force(deg), 4)
                            if deg else None),
        "failovers": int(eng.failover.n_failovers),
        "recovery_steps": eng.failover.recovery_steps,
        "idle_steps_to_recover": idle,
        "health": list(eng.failover.health.state),
        "recall_pre_failure": pre_recall,
        "recall_post_recovery": post_recall,
        "post_recovery_bitwise": bool(same(pre, post)),
        "open_loop": {key: row[key] for key in
                      ("achieved_qps", "p50_latency_ms", "p95_latency_ms",
                       "max_queue_depth")},
        "injector": eng.faults.stats(),
    }

    # -- (b) crash + snapshot/WAL recovery ----------------------------
    tmp = tempfile.mkdtemp(prefix="query_bench_faults_")
    qc = QueryConfig(k=k, beam=beam, hops=hops, shards=shards,
                     max_wave=16, refresh_every=6)
    store = CrashStore(tmp, every=3)
    ceng = QueryEngine(copy.deepcopy(index0), qc, clock=ManualClock(),
                       faults=FaultInjector(FaultPlan.parse("crash@5")),
                       store=store)
    mirror = QueryEngine(copy.deepcopy(index0), qc, clock=ManualClock())
    crashed = False
    for t in range(10):
        for e in (ceng, mirror):
            e.insert(insert_pool[t])
            if t % 3 == 2:
                e.remove_user(10 * t)
        try:
            ceng.step()
        except EngineCrash:
            crashed = True
            break
        mirror.step()
    if crashed:
        mirror.step()  # the mirror runs the step the crash pre-empted
    wal_at_crash = int(store.wal.n_records)
    rec_eng = QueryEngine.recover(tmp, qc, clock=ManualClock())
    rows_ok = all(np.array_equal(getattr(rec_eng.index, name),
                                 getattr(mirror.index, name))
                  for name in _ROWS)
    probe = profiles[:16]
    a, recall_rec = wave(rec_eng, probe)
    b, _ = wave(mirror, probe)
    crash_row = {
        "crashed": bool(crashed),
        "crash_step": 5,
        "snapshot_every": 3,
        "snapshots": int(store.n_snapshots),
        "wal_records_at_crash": wal_at_crash,
        "rows_bitwise": bool(rows_ok),
        "answers_bitwise": bool(same(a, b)),
        "recovered_version": int(rec_eng.index.version),
        "recall_after_recovery": recall_rec,
    }
    shutil.rmtree(tmp, ignore_errors=True)
    return {"shards": shards, "kill_failover": kill_row,
            "crash_recovery": crash_row}


def descent_scoring_stats(index, profiles, k: int, beam: int, hops: int,
                          seeds_per_config: int = 16) -> dict:
    """Per-hop scored-candidate counts through the fused kernel on the
    same routed wave the serving rows answer: how many estimator lanes
    survive dedup-before-scoring vs the unfused ``beam·(kg+kr)``, and —
    through the HBM-resident DMA placement of the same hop — how many
    fingerprint bytes actually move vs how many the suppressed-lane
    skip leaves in HBM. The DMA hop's (ids, sims) are asserted bitwise
    against the VMEM hop's along the way."""
    import jax.numpy as jnp

    from repro.kernels.descent_score import ops as ds_ops
    from repro.query.router import routed_queries
    from repro.query.search import descent_init

    qw, qc, seeds = (jnp.asarray(x) for x in
                     routed_queries(index, profiles, seeds_per_config))
    g, r = jnp.asarray(index.graph_ids), jnp.asarray(index.rev_ids)
    w, c = jnp.asarray(index.words), jnp.asarray(index.card)
    beam = max(beam, k)
    bi, bs = descent_init(w, c, qw, qc, seeds, beam=beam)
    di, dsm = bi, bs
    per_hop, dma_per_hop, saved_per_hop = [], [], []
    for _ in range(hops):
        bi, bs, nsc, _, _ = ds_ops.descent_hop(
            g, r, w, c, qw, qc, bi, bs, with_counts=True)
        di, dsm, dnsc, dmab, saved = ds_ops.descent_hop(
            g, r, w, c, qw, qc, di, dsm, dma=True, with_counts=True)
        np.testing.assert_array_equal(np.asarray(di), np.asarray(bi))
        np.testing.assert_array_equal(np.asarray(dsm), np.asarray(bs))
        np.testing.assert_array_equal(np.asarray(dnsc), np.asarray(nsc))
        per_hop.append(float(np.asarray(nsc).mean()))
        dma_per_hop.append(float(np.asarray(dmab).mean()))
        saved_per_hop.append(float(np.asarray(saved).mean()))
    total = beam * (g.shape[1] + r.shape[1])
    dma_b, saved_b = float(np.sum(dma_per_hop)), float(np.sum(saved_per_hop))
    return {
        "candidates_per_hop": total,
        "scored_per_hop_mean": [round(x, 1) for x in per_hop],
        "scored_fraction": round(float(np.mean(per_hop)) / total, 3),
        "dma_kb_per_query_per_hop": [round(x / 1e3, 2)
                                     for x in dma_per_hop],
        "dma_kb_per_query": round(dma_b / 1e3, 2),
        "dma_saved_kb_per_query": round(saved_b / 1e3, 2),
        "dma_saved_fraction": round(saved_b / max(dma_b + saved_b, 1.0),
                                    3),
    }


def run(dataset: str = "synth", scale: float = 0.2, n_queries: int = 256,
        k: int = 10, beam: int = 32, hops: int = 3, seed: int = 0,
        shards: int = 2, oversample: float = 1.25,
        continuous: bool = False, slots: int = 32,
        churn: bool = False, overload: bool = False,
        rebalance: bool = False, faults: bool = False) -> dict:
    if shards < 2:
        raise SystemExit("query_bench compares sharded vs single-device "
                         "serving; --shards must be >= 2")
    ds = make_dataset(dataset, scale=scale, seed=seed)
    params = params_for(dataset, k=k, b=max(64, ds.n_users // 16),
                        max_cluster=max(48, int(0.06 * ds.n_users)))
    t0 = time.perf_counter()
    index = build_index(ds, params)
    t_build = time.perf_counter() - t0

    qds = make_dataset(dataset, scale=scale, seed=seed + 1)
    n_q = min(n_queries, qds.n_users)
    profiles = [qds.profile(u) for u in range(n_q)]

    single = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                            max_wave=n_queries))
    sharded = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                             max_wave=n_queries,
                                             shards=shards,
                                             shard_oversample=oversample))
    # Fused descent-scoring kernel rows, same index and query set — the
    # acceptance bar is recall parity to ±0.000 (the kernel is bitwise
    # transparent), so these rows isolate pure serving-path overheads.
    single_kernel = QueryEngine(index, QueryConfig(
        k=k, beam=beam, hops=hops, max_wave=n_queries, kernel=True))
    sharded_kernel = QueryEngine(index, QueryConfig(
        k=k, beam=beam, hops=hops, max_wave=n_queries, shards=shards,
        shard_oversample=oversample, kernel=True))
    # The same fused hop with HBM-resident tables + per-chunk candidate
    # DMA ("pallas_dma" scorer) — still bitwise, now with byte
    # accounting for the suppressed-lane skip.
    single_dma = QueryEngine(index, QueryConfig(
        k=k, beam=beam, hops=hops, max_wave=n_queries, kernel=True,
        dma=True))
    modes = {
        "single": _serve_waves(single, profiles, k),
        f"sharded_{shards}": _serve_waves(sharded, profiles, k),
        "single_kernel": _serve_waves(single_kernel, profiles, k),
        f"sharded_{shards}_kernel": _serve_waves(sharded_kernel, profiles, k),
        "single_dma": _serve_waves(single_dma, profiles, k),
    }
    scoring = descent_scoring_stats(index, profiles, k, beam, hops)
    served_dma = single_dma.plan.descent_stats
    scoring["serving_dma_bytes_per_query"] = round(
        served_dma["dma_bytes"] / max(served_dma["hop_queries"], 1), 1)
    scoring["serving_bytes_saved_per_query"] = round(
        served_dma["bytes_saved"] / max(served_dma["hop_queries"], 1), 1)
    sd = sharded.sharded_state()
    sharded_exec = "mesh" if sd is not None and sd.mesh is not None else "vmap"

    # Continuous-batching rows BEFORE the insert benchmark mutates the
    # shared index, so wave and continuous are measured on the same
    # index state and their recall numbers are directly comparable.
    cont = None
    cont_sharded = None
    if continuous:
        cont = run_continuous(index, profiles, k, beam, hops, slots,
                              seed=seed)
        # The sharded × continuous plan composition: same Poisson
        # open-loop protocol, per-shard slot arrays + release-time
        # cross-shard merge, gated bitwise against the sharded wave.
        cont_sharded = run_continuous(index, profiles, k, beam, hops,
                                      slots, seed=seed, shards=shards,
                                      oversample=oversample)

    # SLO-serving rows (overload sweep, adaptive budgets, result cache)
    # BEFORE the insert benchmark for the same same-index-state reason;
    # the cache arms mutate private deepcopies only.
    overload_rec = None
    adaptive_rec = None
    cache_rec = None
    if overload:
        overload_rec = run_overload(index, profiles, k, beam, hops,
                                    slots, seed=seed)
        adaptive_rec = run_adaptive(index, profiles, k, beam, hops,
                                    slots, seed=seed)
        cache_ds = make_dataset(dataset, scale=scale, seed=seed + 3)
        cache_pool = [cache_ds.profile(u)
                      for u in range(min(16, cache_ds.n_users))]
        cache_rec = run_cache(index, profiles, k, beam, hops, cache_pool,
                              seed=seed)

    # Sustained-churn trajectory BEFORE the insert benchmark, on private
    # deepcopies — the serving rows above and the churn arms must not
    # see each other's mutations.
    churn_rec = None
    if churn:
        # Replacement users come from an INDEPENDENT draw (seed+2) so the
        # inserts don't shadow the query distribution — the trajectory
        # should isolate graph damage, not ground-truth drift.
        ins_ds = make_dataset(dataset, scale=scale, seed=seed + 2)
        need = min(int(0.2 * index.n_live) + 8, ins_ds.n_users)
        pool = [ins_ds.profile(u) for u in range(need)]
        churn_rec = run_churn(index, profiles, k, beam, hops, pool,
                              seed=seed)

    # Re-balance arms run on private deepcopies; the residency sweep
    # reads the shared index, so both run BEFORE the insert benchmark.
    rebalance_rec = None
    residency_rec = None
    if rebalance:
        rebalance_rec = run_rebalance(index, ds, profiles, k, beam, hops,
                                      shards, seed=seed)
        residency_rec = run_residency_sweep(index, profiles, k, beam,
                                            hops, shards,
                                            oversample=oversample)

    # Fault-tolerance arms run on private deepcopies (and the crash arm
    # in a throwaway store dir), so they too run BEFORE the insert
    # benchmark mutates the shared index.
    faults_rec = None
    if faults:
        f_ds = make_dataset(dataset, scale=scale, seed=seed + 4)
        f_pool = [f_ds.profile(u) for u in range(min(12, f_ds.n_users))]
        faults_rec = run_faults(index, profiles, k, beam, hops, f_pool,
                                seed=seed, shards=shards)

    # Online insertion through the amortized-growth path (single engine;
    # the index is shared, so the sharded engine reshards lazily).
    t0 = time.perf_counter()
    n_ins = min(64, qds.n_users - n_q)
    for m in range(n_ins):
        single.insert(qds.profile(n_q + m))
    t_ins = time.perf_counter() - t0

    sh = modes[f"sharded_{shards}"]["warm"]
    sg = modes["single"]["warm"]
    return {
        "dataset": ds.name,
        "n_users": ds.n_users,
        "n_queries": n_q,
        "k": k,
        "beam": beam,
        "hops": hops,
        "shards": shards,
        "shard_oversample": oversample,
        "sharded_execution": sharded_exec,
        "n_devices": jax.device_count(),
        "t_build_s": round(t_build, 2),
        "modes": modes,
        "inserts": n_ins,
        "inserts_per_s": round(n_ins / max(t_ins, 1e-9), 1),
        "cohort_refreshes": single.n_refreshes,
        "index_capacity": index.capacity,
        "descent_scoring": scoring,
        "kernel_vs_jnp": {
            "recall_delta": round(
                modes["single_kernel"]["warm"][f"recall_at_{k}"]
                - modes["single"]["warm"][f"recall_at_{k}"], 4),
            "sharded_recall_delta": round(
                modes[f"sharded_{shards}_kernel"]["warm"][f"recall_at_{k}"]
                - modes[f"sharded_{shards}"]["warm"][f"recall_at_{k}"], 4),
            "dma_recall_delta": round(
                modes["single_dma"]["warm"][f"recall_at_{k}"]
                - modes["single"]["warm"][f"recall_at_{k}"], 4),
        },
        "sharded_vs_single": {
            "qps_ratio": round(sh["qps"] / max(sg["qps"], 1e-9), 3),
            "recall_delta": round(sh[f"recall_at_{k}"]
                                  - sg[f"recall_at_{k}"], 4),
        },
        **({"continuous": cont} if cont is not None else {}),
        **({f"sharded_{shards}_continuous": cont_sharded}
           if cont_sharded is not None else {}),
        **({"churn": churn_rec} if churn_rec is not None else {}),
        **({"overload": overload_rec} if overload_rec is not None else {}),
        **({"adaptive": adaptive_rec} if adaptive_rec is not None else {}),
        **({"cache": cache_rec} if cache_rec is not None else {}),
        **({"rebalance": rebalance_rec} if rebalance_rec is not None
           else {}),
        **({"residency_sweep": residency_rec} if residency_rec is not None
           else {}),
        **({"faults": faults_rec} if faults_rec is not None else {}),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=32)
    ap.add_argument("--hops", type=int, default=3)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--oversample", type=float, default=1.25,
                    help="sharded fleet frontier vs single-device beam")
    ap.add_argument("--devices", type=int, default=None,
                    help="emulated host devices (default: --shards; 0=off)")
    ap.add_argument("--continuous", action="store_true",
                    help="add wave-vs-continuous closed/open-loop rows")
    ap.add_argument("--slots", type=int, default=32,
                    help="continuous-mode in-flight slot capacity")
    ap.add_argument("--churn", action="store_true",
                    help="add sustained-churn recall-trajectory rows "
                         "(repair on vs off under 20%% turnover)")
    ap.add_argument("--overload", action="store_true",
                    help="add SLO-serving rows: 0.85/0.95/1.2-load "
                         "overload sweep (slo vs fifo), adaptive hop "
                         "budgets, and the journal-invalidated result "
                         "cache")
    ap.add_argument("--rebalance", action="store_true",
                    help="add background re-balance rows: frozen-extend "
                         "vs rebalanced imbalance under skewed insert "
                         "growth, forced blue/green swap checks, and "
                         "the tiered-residency sweep")
    ap.add_argument("--faults", action="store_true",
                    help="add fault-tolerance rows: kill 1 shard mid-"
                         "open-loop (keeps answering, degraded recall "
                         "priced, failover rebuild, post-recovery "
                         "bitwise) and crash + snapshot/WAL-replay "
                         "bitwise recovery")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; exit 1 on sharded regression")
    ap.add_argument("--out", default="BENCH_query.json")
    args = ap.parse_args()

    if args.smoke:
        args.scale, args.queries = min(args.scale, 0.1), min(args.queries, 64)
        args.slots = min(args.slots, 16)
    rec = run(args.dataset, args.scale, args.queries, args.k, args.beam,
              args.hops, shards=args.shards, oversample=args.oversample,
              continuous=args.continuous, slots=args.slots,
              churn=args.churn, overload=args.overload,
              rebalance=args.rebalance, faults=args.faults)
    Path(args.out).write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec, indent=2))
    print(f"[query_bench] wrote {args.out}")

    if args.smoke:
        ratio = rec["sharded_vs_single"]["qps_ratio"]
        delta = rec["sharded_vs_single"]["recall_delta"]
        # CI floor: sharded must not collapse (generous margins — CI
        # machines are noisy; the committed BENCH_query.json carries the
        # quiet-machine numbers).
        if ratio < 0.5 or delta < -0.05:
            print(f"[query_bench] FAIL sharded regression: qps_ratio="
                  f"{ratio} recall_delta={delta}", file=sys.stderr)
            sys.exit(1)
        print(f"[query_bench] smoke OK: qps_ratio={ratio} "
              f"recall_delta={delta}")
        # The fused kernel is bitwise transparent: recall must match the
        # jnp rows EXACTLY (±0.000), and dedup-before-scoring must have
        # removed estimator work.
        kd = rec["kernel_vs_jnp"]
        frac = rec["descent_scoring"]["scored_fraction"]
        if (kd["recall_delta"] != 0.0 or kd["sharded_recall_delta"] != 0.0
                or kd["dma_recall_delta"] != 0.0):
            print(f"[query_bench] FAIL kernel recall drift: {kd}",
                  file=sys.stderr)
            sys.exit(1)
        if not frac < 1.0:
            print(f"[query_bench] FAIL kernel scored no fewer lanes: "
                  f"{rec['descent_scoring']}", file=sys.stderr)
            sys.exit(1)
        if not (rec["descent_scoring"]["dma_saved_kb_per_query"] > 0
                and rec["descent_scoring"]["serving_bytes_saved_per_query"]
                > 0):
            print(f"[query_bench] FAIL DMA suppressed-lane skip saved no "
                  f"bytes: {rec['descent_scoring']}", file=sys.stderr)
            sys.exit(1)
        print(f"[query_bench] kernel smoke OK: recall_delta=0.0 "
              f"scored_fraction={frac} dma_saved_fraction="
              f"{rec['descent_scoring']['dma_saved_fraction']}")
        if args.continuous:
            # Streaming admission must keep result quality: recall parity
            # with waves (identical descent ⇒ tight margin even on noisy
            # CI) and full completion of the open-loop run.
            cd = rec["continuous"]["open_loop_recall"]["delta"]
            if abs(cd) > 0.005:
                print(f"[query_bench] FAIL continuous recall drift: "
                      f"delta={cd}", file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] continuous smoke OK: recall_delta={cd} "
                  f"p95_improvement="
                  f"{rec['continuous']['p95_improvement']}")
            # Sharded × continuous composition: batching is results-
            # transparent under a fixed placement, so closed-loop results
            # must equal the sharded wave BITWISE (recall delta ±0.000).
            sc = rec[f"sharded_{args.shards}_continuous"]
            scw = sc["closed_loop_vs_wave"]
            if not scw["bitwise_equal"] or scw["recall_delta"] != 0.0:
                print(f"[query_bench] FAIL sharded-continuous drift vs "
                      f"sharded wave: {scw}", file=sys.stderr)
                sys.exit(1)
            scd = sc["open_loop_recall"]["delta"]
            if abs(scd) > 0.005:
                print(f"[query_bench] FAIL sharded-continuous open-loop "
                      f"recall drift: delta={scd}", file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] sharded-continuous smoke OK: "
                  f"closed-loop bitwise, open-loop recall_delta={scd}")
        if args.overload:
            # Overload-degradation gate: at 1.2× capacity the slo policy
            # must (a) shed explicitly, (b) keep the pending queue
            # bounded while FIFO's collapses, and (c) hold the protected
            # class's p95 near its uncontended value (generous CI margin
            # on the ratio; the committed BENCH_query.json carries the
            # quiet-machine <= 2x number).
            ov = rec["overload"]
            peak = ov["slo"]["1.2"]
            if peak["shed"] == 0:
                print(f"[query_bench] FAIL overload: slo shed nothing at "
                      f"1.2x capacity: {peak}", file=sys.stderr)
                sys.exit(1)
            if peak["max_queue_depth"] > ov["max_pending"] + args.slots:
                print(f"[query_bench] FAIL overload: slo queue exceeded "
                      f"its bound: {peak['max_queue_depth']} > "
                      f"{ov['max_pending']}", file=sys.stderr)
                sys.exit(1)
            # FIFO collapse criterion: its queue must grow past the
            # bound slo admission enforces (the depth_ratio in the
            # committed BENCH_query.json shows the full contrast; the
            # smoke gate uses the bound because absolute depths are
            # noise-prone at CI scale).
            if (ov["queue_collapse"]["fifo_max_queue_depth"]
                    <= ov["max_pending"]):
                print(f"[query_bench] FAIL overload: fifo queue stayed "
                      f"within the slo bound ({ov['max_pending']}): "
                      f"{ov['queue_collapse']}", file=sys.stderr)
                sys.exit(1)
            deg = ov["hp_p95_degradation"]
            if deg is None or deg > 4.0:
                print(f"[query_bench] FAIL overload: high-priority p95 "
                      f"degraded {deg}x from 0.85 to 1.2 load",
                      file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] overload smoke OK: shed={peak['shed']} "
                  f"hp_p95_degradation={deg} "
                  f"depth_ratio={ov['queue_collapse']['depth_ratio']}")
            # Adaptive budgets must actually save hops without giving up
            # meaningful recall (tight -0.005 on the committed bench;
            # smoke allows noise).
            ad = rec["adaptive"]
            if ad["ticks_saved"] <= 0 or ad["recall_delta"] < -0.02:
                print(f"[query_bench] FAIL adaptive budgets: "
                      f"ticks_saved={ad['ticks_saved']} "
                      f"recall_delta={ad['recall_delta']}",
                      file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] adaptive smoke OK: "
                  f"ticks_saved={ad['ticks_saved']} "
                  f"qps_gain={ad['qps_gain']} "
                  f"recall_delta={ad['recall_delta']}")
            # The cache is only correct if it is invisible: bitwise
            # equality against cache-off across interleaved mutations,
            # AND it must actually hit on the repeated stream.
            ca = rec["cache"]
            if not ca["bitwise_equal"] or ca["hit_rate"] <= 0.0:
                print(f"[query_bench] FAIL cache: bitwise_equal="
                      f"{ca['bitwise_equal']} hit_rate={ca['hit_rate']}",
                      file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] cache smoke OK: bitwise, "
                  f"hit_rate={ca['hit_rate']} qps_gain={ca['qps_gain']}")
        if args.churn:
            # Under sustained turnover the repair pass must hold recall
            # near the no-churn baseline while repair-off is the decayed
            # arm (CI margins are generous; the committed
            # BENCH_query.json carries the quiet-machine trajectory).
            ch = rec["churn"]
            if ch["repair_vs_baseline"] < -0.03:
                print(f"[query_bench] FAIL churn repair did not hold "
                      f"recall: {ch['repair_vs_baseline']} vs baseline "
                      f"{ch['no_churn_recall']}", file=sys.stderr)
                sys.exit(1)
            # At smoke scale the two arms sit within noise of each other;
            # the gate only trips when repair actively HURTS recall.
            if ch["repair_recovery"] < -0.01:
                print(f"[query_bench] FAIL repair-on recall below "
                      f"repair-off: {ch['repair_recovery']}",
                      file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] churn smoke OK: repair_vs_baseline="
                  f"{ch['repair_vs_baseline']} recovery="
                  f"{ch['repair_recovery']}")
        if args.rebalance:
            # Blue/green swap gate: the forced swap must restore balance,
            # keep recall (placement moves individual results, so the
            # margin is the same ±0.005 the continuous rows get), flush
            # the result cache (journals cannot see a swap), and the
            # merge-based rebuild must equal a from-scratch build
            # BITWISE — the symmetric-merge + audit-patch guarantee.
            rb = rec["rebalance"]
            fs = rb["forced_swap"]
            if fs["post_swap_imbalance"] > 1.25:
                print(f"[query_bench] FAIL rebalance: post-swap imbalance "
                      f"{fs['post_swap_imbalance']} > 1.25", file=sys.stderr)
                sys.exit(1)
            # A swap changes placement — the one axis that may move
            # individual results — so the recall check is granular: at
            # the 64-query smoke scale one flipped result slot is
            # 0.0016, and the committed full-scale BENCH_query.json
            # carries the tight ±0.005 number.
            if abs(fs["recall_delta"]) > 0.02:
                print(f"[query_bench] FAIL rebalance: recall moved "
                      f"{fs['recall_delta']} across the swap",
                      file=sys.stderr)
                sys.exit(1)
            if not fs["cache_flushed"]:
                print("[query_bench] FAIL rebalance: swap did not flush "
                      "the result cache", file=sys.stderr)
                sys.exit(1)
            if not fs["merge_bitwise_equal"]:
                print("[query_bench] FAIL rebalance: merge-based rebuild "
                      "!= from-scratch plan_shards build", file=sys.stderr)
                sys.exit(1)
            # The rebalanced arm must end at or under the threshold (the
            # re-balancer's contract), and never land above the frozen
            # arm it exists to beat.
            fin = rb["rebalanced"]["final_imbalance"]
            if fin > rb["threshold"] + 0.01 \
                    or fin > rb["frozen"]["final_imbalance"] + 1e-9:
                print(f"[query_bench] FAIL rebalance: rebalanced arm "
                      f"imbalance {fin} vs frozen "
                      f"{rb['frozen']['final_imbalance']} (threshold "
                      f"{rb['threshold']})", file=sys.stderr)
                sys.exit(1)
            if rb["rebalanced"]["recall_delta_vs_single"] < -0.05:
                print(f"[query_bench] FAIL rebalance: recall fell "
                      f"{rb['rebalanced']['recall_delta_vs_single']} vs "
                      f"single-shard", file=sys.stderr)
                sys.exit(1)
            rs = rec["residency_sweep"]["rows"]
            if any(r["max_resident_bytes"] > rs[0]["max_resident_bytes"]
                   for r in rs[1:]):
                print(f"[query_bench] FAIL residency: restricting configs "
                      f"did not shrink resident bytes: {rs}",
                      file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] rebalance smoke OK: post_swap_imbalance="
                  f"{fs['post_swap_imbalance']} recall_delta="
                  f"{fs['recall_delta']} merge_coverage="
                  f"{fs['merge']['merge_coverage']} rebalanced_final={fin} "
                  f"frozen_final={rb['frozen']['final_imbalance']}")
        if args.faults:
            # Kill-recover gate: killing 1 of N shards mid-open-loop
            # must never drop a request, degraded answers must stay
            # useful (bounded recall, not zero — survivors still own
            # their basins), the failover must actually fire, and the
            # recovered fleet must answer BITWISE what the pre-failure
            # fleet answered (no mutations happened, so any drift is a
            # rebuild/swap bug).
            kf = rec["faults"]["kill_failover"]
            if kf["served"] != kf["submitted"] or kf["shed"] != 0:
                print(f"[query_bench] FAIL faults: dropped requests under "
                      f"shard kill: served={kf['served']}/"
                      f"{kf['submitted']} shed={kf['shed']}",
                      file=sys.stderr)
                sys.exit(1)
            if kf["degraded_served"] == 0:
                print("[query_bench] FAIL faults: kill window served no "
                      "degraded requests (injection did not land)",
                      file=sys.stderr)
                sys.exit(1)
            if kf["degraded_recall"] is None or kf["degraded_recall"] < 0.2:
                print(f"[query_bench] FAIL faults: degraded recall "
                      f"collapsed: {kf['degraded_recall']}",
                      file=sys.stderr)
                sys.exit(1)
            if kf["failovers"] < 1 or not kf["post_recovery_bitwise"]:
                print(f"[query_bench] FAIL faults: failover did not "
                      f"restore the fleet: failovers={kf['failovers']} "
                      f"post_recovery_bitwise="
                      f"{kf['post_recovery_bitwise']}", file=sys.stderr)
                sys.exit(1)
            # Crash-consistency gate: snapshot + WAL replay must be
            # bitwise — tensors AND answers — against the never-crashed
            # mirror.
            cr = rec["faults"]["crash_recovery"]
            if not (cr["crashed"] and cr["rows_bitwise"]
                    and cr["answers_bitwise"]):
                print(f"[query_bench] FAIL faults: crash recovery not "
                      f"bitwise: {cr}", file=sys.stderr)
                sys.exit(1)
            print(f"[query_bench] faults smoke OK: "
                  f"degraded_served={kf['degraded_served']} "
                  f"degraded_recall={kf['degraded_recall']} "
                  f"failovers={kf['failovers']} post_recovery=bitwise "
                  f"crash_recovery=bitwise "
                  f"(snapshots={cr['snapshots']}, "
                  f"wal_records={cr['wal_records_at_crash']})")


if __name__ == "__main__":
    main()
