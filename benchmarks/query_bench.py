"""Query-serving benchmark: QPS, latency percentiles, recall@k vs brute
force, for cold (compile included) and warm waves, plus online-insert
throughput.

    PYTHONPATH=src python benchmarks/query_bench.py [--dataset synth]
        [--scale 0.2] [--queries 256] [--out BENCH_query.json]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.params import params_for
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index


def run(dataset: str = "synth", scale: float = 0.2, n_queries: int = 256,
        k: int = 10, beam: int = 32, hops: int = 3, seed: int = 0) -> dict:
    ds = make_dataset(dataset, scale=scale, seed=seed)
    params = params_for(dataset, k=k, b=max(64, ds.n_users // 16),
                        max_cluster=max(48, int(0.06 * ds.n_users)))
    t0 = time.perf_counter()
    index = build_index(ds, params)
    t_build = time.perf_counter() - t0

    engine = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                            max_wave=n_queries))
    qds = make_dataset(dataset, scale=scale, seed=seed + 1)
    n_q = min(n_queries, qds.n_users)
    profiles = [qds.profile(u) for u in range(n_q)]

    def wave(tag: str) -> dict:
        for rid, p in enumerate(profiles):
            engine.submit(QueryRequest(rid=rid, profile=p))
        stats = engine.run()
        recall = engine.recall_vs_brute_force(engine.done[-n_q:])
        return {
            "tag": tag,
            "qps": round(stats["qps"], 1),
            "p50_latency_ms": round(stats["p50_latency_s"] * 1e3, 2),
            "p95_latency_ms": round(stats["p95_latency_s"] * 1e3, 2),
            f"recall_at_{k}": round(recall, 4),
        }

    cold = wave("cold")        # includes descent compilation
    warm = wave("warm")        # compiled program reused

    t0 = time.perf_counter()
    n_ins = min(32, qds.n_users - n_q)
    for m in range(n_ins):
        engine.insert(qds.profile(n_q + m))
    t_ins = time.perf_counter() - t0

    return {
        "dataset": ds.name,
        "n_users": ds.n_users,
        "n_queries": n_q,
        "k": k,
        "beam": beam,
        "hops": hops,
        "t_build_s": round(t_build, 2),
        "cold": cold,
        "warm": warm,
        "inserts": n_ins,
        "inserts_per_s": round(n_ins / max(t_ins, 1e-9), 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=32)
    ap.add_argument("--hops", type=int, default=3)
    ap.add_argument("--out", default="BENCH_query.json")
    args = ap.parse_args()

    rec = run(args.dataset, args.scale, args.queries, args.k, args.beam,
              args.hops)
    Path(args.out).write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec, indent=2))
    print(f"[query_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
