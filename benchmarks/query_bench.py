"""Query-serving benchmark: QPS, latency percentiles, recall@k vs brute
force, for cold (compile included) and warm waves, in single-device and
sharded modes, plus online-insert throughput.

    PYTHONPATH=src python benchmarks/query_bench.py [--dataset synth]
        [--scale 0.2] [--queries 256] [--shards 2] [--out BENCH_query.json]

``--devices N`` (default: the shard count) emulates N XLA host devices —
the multi-core serving configuration, one shard per device via
shard_map; ``--devices 0`` forces the single-device vmap fallback.
``--smoke`` shrinks the workload for CI: it still exercises build, both
serving modes, and insertion, and fails loudly (exit 1) if the sharded
mode regresses against single-device beyond the allowed margins.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# The device count must be pinned before jax initializes (same pattern
# as launch/dryrun.py), so peek at argv before the heavy imports.
_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--devices", type=int, default=None)
_pre.add_argument("--shards", type=int, default=2)
_pre_args, _ = _pre.parse_known_args()
_n_dev = (_pre_args.devices if _pre_args.devices is not None
          else _pre_args.shards)
if _n_dev and _n_dev > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_dev}")

import jax
import numpy as np

from repro.core.params import params_for
from repro.data.synthetic import make_dataset
from repro.query.engine import QueryConfig, QueryEngine, QueryRequest
from repro.query.index import build_index


def _serve_waves(engine: QueryEngine, profiles, k: int) -> dict:
    """One cold + one warm wave through ``engine``; per-wave stats."""
    out = {}
    for tag in ("cold", "warm"):
        for rid, p in enumerate(profiles):
            engine.submit(QueryRequest(rid=rid, profile=p))
        stats = engine.run()
        recall = engine.recall_vs_brute_force(engine.done[-len(profiles):])
        out[tag] = {
            "qps": round(stats["qps"], 1),
            "p50_latency_ms": round(stats["p50_latency_s"] * 1e3, 2),
            "p95_latency_ms": round(stats["p95_latency_s"] * 1e3, 2),
            f"recall_at_{k}": round(recall, 4),
        }
    return out


def run(dataset: str = "synth", scale: float = 0.2, n_queries: int = 256,
        k: int = 10, beam: int = 32, hops: int = 3, seed: int = 0,
        shards: int = 2, oversample: float = 1.25) -> dict:
    if shards < 2:
        raise SystemExit("query_bench compares sharded vs single-device "
                         "serving; --shards must be >= 2")
    ds = make_dataset(dataset, scale=scale, seed=seed)
    params = params_for(dataset, k=k, b=max(64, ds.n_users // 16),
                        max_cluster=max(48, int(0.06 * ds.n_users)))
    t0 = time.perf_counter()
    index = build_index(ds, params)
    t_build = time.perf_counter() - t0

    qds = make_dataset(dataset, scale=scale, seed=seed + 1)
    n_q = min(n_queries, qds.n_users)
    profiles = [qds.profile(u) for u in range(n_q)]

    single = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                            max_wave=n_queries))
    sharded = QueryEngine(index, QueryConfig(k=k, beam=beam, hops=hops,
                                             max_wave=n_queries,
                                             shards=shards,
                                             shard_oversample=oversample))
    modes = {
        "single": _serve_waves(single, profiles, k),
        f"sharded_{shards}": _serve_waves(sharded, profiles, k),
    }
    sd = sharded.sharded_state()
    sharded_exec = "mesh" if sd is not None and sd.mesh is not None else "vmap"

    # Online insertion through the amortized-growth path (single engine;
    # the index is shared, so the sharded engine reshards lazily).
    t0 = time.perf_counter()
    n_ins = min(64, qds.n_users - n_q)
    for m in range(n_ins):
        single.insert(qds.profile(n_q + m))
    t_ins = time.perf_counter() - t0

    sh = modes[f"sharded_{shards}"]["warm"]
    sg = modes["single"]["warm"]
    return {
        "dataset": ds.name,
        "n_users": ds.n_users,
        "n_queries": n_q,
        "k": k,
        "beam": beam,
        "hops": hops,
        "shards": shards,
        "shard_oversample": oversample,
        "sharded_execution": sharded_exec,
        "n_devices": jax.device_count(),
        "t_build_s": round(t_build, 2),
        "modes": modes,
        "inserts": n_ins,
        "inserts_per_s": round(n_ins / max(t_ins, 1e-9), 1),
        "cohort_refreshes": single.n_refreshes,
        "index_capacity": index.capacity,
        "sharded_vs_single": {
            "qps_ratio": round(sh["qps"] / max(sg["qps"], 1e-9), 3),
            "recall_delta": round(sh[f"recall_at_{k}"]
                                  - sg[f"recall_at_{k}"], 4),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth")
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=32)
    ap.add_argument("--hops", type=int, default=3)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--oversample", type=float, default=1.25,
                    help="sharded fleet frontier vs single-device beam")
    ap.add_argument("--devices", type=int, default=None,
                    help="emulated host devices (default: --shards; 0=off)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; exit 1 on sharded regression")
    ap.add_argument("--out", default="BENCH_query.json")
    args = ap.parse_args()

    if args.smoke:
        args.scale, args.queries = min(args.scale, 0.1), min(args.queries, 64)
    rec = run(args.dataset, args.scale, args.queries, args.k, args.beam,
              args.hops, shards=args.shards, oversample=args.oversample)
    Path(args.out).write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec, indent=2))
    print(f"[query_bench] wrote {args.out}")

    if args.smoke:
        ratio = rec["sharded_vs_single"]["qps_ratio"]
        delta = rec["sharded_vs_single"]["recall_delta"]
        # CI floor: sharded must not collapse (generous margins — CI
        # machines are noisy; the committed BENCH_query.json carries the
        # quiet-machine numbers).
        if ratio < 0.5 or delta < -0.05:
            print(f"[query_bench] FAIL sharded regression: qps_ratio="
                  f"{ratio} recall_delta={delta}", file=sys.stderr)
            sys.exit(1)
        print(f"[query_bench] smoke OK: qps_ratio={ratio} "
              f"recall_delta={delta}")


if __name__ == "__main__":
    main()
